"""Build-time configuration for all AOT artifacts.

Every shape and hyperparameter baked into the HLO artifacts is defined
here and recorded into artifacts/manifest.json so the rust runtime can
marshal tensors without guessing.  The rust side pads variable-length
fragments up to these static shapes and passes an explicit `mask` input
so padding never contributes to losses.
"""

# Environment interface (CartPole-v0/v1 physics port on the rust side).
OBS_DIM = 4
NUM_ACTIONS = 2

# Policy/value trunk.
HIDDEN = (64, 64)

# Inference batch for rollout workers (vectorized env width; rust pads).
INF_BATCH = 8

# Training batch shapes, per algorithm family.
A2C_TRAIN_BATCH = 256   # ConcatBatches target for A2C; A3C uses FRAGMENT
FRAGMENT = 64           # rollout_fragment_length (per-worker sample size)
PPO_MINIBATCH = 128
DQN_MINIBATCH = 64
IMPALA_T = 20           # time dimension of an IMPALA learner batch
IMPALA_B = 8            # batch lanes of an IMPALA learner batch

# Numerics baked into the losses.
GAMMA = 0.99
GAE_LAMBDA = 0.95       # used by the rust-side GAE; recorded for parity
PPO_CLIP = 0.2
VF_COEFF = 0.5
ENT_COEFF = 0.01
HUBER_DELTA = 1.0
VTRACE_RHO_CLIP = 1.0
VTRACE_C_CLIP = 1.0

# Pallas block-shape targets (largest divisor of the dim <= target is used;
# see kernels/fused_linear.py::pick_block).  128 targets the MXU tile edge.
BLOCK_M = 128
BLOCK_N = 128


def mlp_layer_shapes(in_dim, hidden, head_dims):
    """[(w_shape, b_shape), ...] for trunk layers followed by parallel heads.

    Trunk: in_dim -> hidden[0] -> hidden[1] ...; each head maps the last
    hidden width to one of head_dims.
    """
    shapes = []
    d = in_dim
    for h in hidden:
        shapes.append(((d, h), (h,)))
        d = h
    for out in head_dims:
        shapes.append(((d, out), (out,)))
    return shapes


def param_size(shapes):
    n = 0
    for w, b in shapes:
        n += w[0] * w[1] + b[0]
    return n


PG_SHAPES = mlp_layer_shapes(OBS_DIM, HIDDEN, [NUM_ACTIONS, 1])
PG_PARAM_SIZE = param_size(PG_SHAPES)

DQN_SHAPES = mlp_layer_shapes(OBS_DIM, HIDDEN, [NUM_ACTIONS])
DQN_PARAM_SIZE = param_size(DQN_SHAPES)


def apply_overrides(obs_dim=None, num_actions=None, hidden=None):
    """Re-derive the model geometry (aot.py --obs-dim/--num-actions/
    --hidden): one artifact set serves one geometry, so alternative envs
    (e.g. MountainCar: obs 2, actions 3) build into their own dir."""
    global OBS_DIM, NUM_ACTIONS, HIDDEN
    global PG_SHAPES, PG_PARAM_SIZE, DQN_SHAPES, DQN_PARAM_SIZE
    if obs_dim is not None:
        OBS_DIM = obs_dim
    if num_actions is not None:
        NUM_ACTIONS = num_actions
    if hidden is not None:
        HIDDEN = tuple(hidden)
    PG_SHAPES = mlp_layer_shapes(OBS_DIM, HIDDEN, [NUM_ACTIONS, 1])
    PG_PARAM_SIZE = param_size(PG_SHAPES)
    DQN_SHAPES = mlp_layer_shapes(OBS_DIM, HIDDEN, [NUM_ACTIONS])
    DQN_PARAM_SIZE = param_size(DQN_SHAPES)
