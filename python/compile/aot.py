"""AOT lowering: every L2 computation -> artifacts/*.hlo.txt + manifest.json.

HLO *text* (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the rust
`xla` crate links) rejects; the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Run once via `make artifacts`; python never runs on the training path.
Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config, model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(dtype):
    return {jnp.float32: "f32", jnp.int32: "i32"}[dtype]


def build_entries():
    """(name, fn, [(input_name, shape, dtype)], [output_name]) per artifact."""
    p = config.PG_PARAM_SIZE
    pd = config.DQN_PARAM_SIZE
    o = config.OBS_DIM
    bi = config.INF_BATCH
    entries = []

    entries.append((
        "pg_fwd", model.pg_fwd,
        [("params", (p,), F32), ("obs", (bi, o), F32)],
        ["logits", "value"],
    ))
    entries.append((
        "dqn_q_fwd", model.dqn_q,
        [("params", (pd,), F32), ("obs", (bi, o), F32)],
        ["qvalues"],
    ))

    def train_inputs(n, extra=()):
        base = [("params", (p,), F32), ("obs", (n, o), F32),
                ("actions", (n,), I32)]
        base.extend(extra)
        return base

    n = config.A2C_TRAIN_BATCH
    entries.append((
        "a2c_grad", model.a2c_grad,
        train_inputs(n, [("advantages", (n,), F32),
                         ("value_targets", (n,), F32), ("mask", (n,), F32)]),
        ["grads", "loss", "pi_loss", "vf_loss", "entropy"],
    ))

    # A3C computes gradients per worker fragment, not per concat batch.
    nf = config.FRAGMENT
    entries.append((
        "a3c_grad", model.a2c_grad,
        [("params", (p,), F32), ("obs", (nf, o), F32),
         ("actions", (nf,), I32), ("advantages", (nf,), F32),
         ("value_targets", (nf,), F32), ("mask", (nf,), F32)],
        ["grads", "loss", "pi_loss", "vf_loss", "entropy"],
    ))

    n = config.PPO_MINIBATCH
    entries.append((
        "ppo_grad", model.ppo_grad,
        [("params", (p,), F32), ("obs", (n, o), F32), ("actions", (n,), I32),
         ("old_logp", (n,), F32), ("advantages", (n,), F32),
         ("value_targets", (n,), F32), ("mask", (n,), F32)],
        ["grads", "loss", "pi_loss", "vf_loss", "entropy", "kl"],
    ))

    n = config.DQN_MINIBATCH
    entries.append((
        "dqn_grad", model.dqn_grad,
        [("params", (pd,), F32), ("target_params", (pd,), F32),
         ("obs", (n, o), F32), ("actions", (n,), I32),
         ("rewards", (n,), F32), ("next_obs", (n, o), F32),
         ("dones", (n,), F32), ("weights", (n,), F32), ("mask", (n,), F32)],
        ["grads", "loss", "td_abs"],
    ))

    t, b = config.IMPALA_T, config.IMPALA_B
    entries.append((
        "impala_grad", model.impala_grad,
        [("params", (p,), F32), ("obs", (t, b, o), F32),
         ("actions", (t, b), I32), ("behaviour_logp", (t, b), F32),
         ("rewards", (t, b), F32), ("dones", (t, b), F32),
         ("bootstrap_obs", (b, o), F32), ("mask", (t, b), F32)],
        ["grads", "loss", "pi_loss", "vf_loss", "entropy"],
    ))

    for name, size in (("adam_pg", p), ("adam_dqn", pd)):
        entries.append((
            name, model.adam_apply,
            [("params", (size,), F32), ("grads", (size,), F32),
             ("m", (size,), F32), ("v", (size,), F32),
             ("t", (), F32), ("lr", (), F32)],
            ["params", "m", "v"],
        ))

    entries.append((
        "sgd_pg", model.sgd_apply,
        [("params", (p,), F32), ("grads", (p,), F32), ("lr", (), F32)],
        ["params"],
    ))
    return entries


def lower_all(out_dir, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "config": {
            "obs_dim": config.OBS_DIM,
            "num_actions": config.NUM_ACTIONS,
            "hidden": list(config.HIDDEN),
            "inf_batch": config.INF_BATCH,
            "a2c_train_batch": config.A2C_TRAIN_BATCH,
            "fragment": config.FRAGMENT,
            "ppo_minibatch": config.PPO_MINIBATCH,
            "dqn_minibatch": config.DQN_MINIBATCH,
            "impala_t": config.IMPALA_T,
            "impala_b": config.IMPALA_B,
            "gamma": config.GAMMA,
            "gae_lambda": config.GAE_LAMBDA,
            "ppo_clip": config.PPO_CLIP,
            "pg_param_size": config.PG_PARAM_SIZE,
            "dqn_param_size": config.DQN_PARAM_SIZE,
        },
        "executables": {},
    }

    for name, fn, inputs, outputs in build_entries():
        in_specs = [spec(shape, dtype) for _, shape, dtype in inputs]
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["executables"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(shape), "dtype": _dtype_name(d)}
                for n, shape, d in inputs
            ],
            "outputs": outputs,
        }
        if verbose:
            print(f"  lowered {name:12s} -> {fname} ({len(text)} chars)")

    # Initial parameters (so rust matches the jax init exactly).
    key = jax.random.PRNGKey(0)
    k_pg, k_dqn = jax.random.split(key)
    for name, flat in (
        ("init_pg", model.init_flat(k_pg, config.PG_SHAPES)),
        ("init_dqn", model.init_flat(k_dqn, config.DQN_SHAPES)),
    ):
        arr = np.asarray(flat, dtype=np.float32)
        arr.tofile(os.path.join(out_dir, f"{name}.bin"))
        manifest[name] = {"file": f"{name}.bin", "len": int(arr.size)}
        if verbose:
            print(f"  wrote {name}.bin ({arr.size} f32)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  wrote manifest.json ({len(manifest['executables'])} exes)")
    return manifest


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--obs-dim", type=int, default=None,
                        help="override observation dim (default 4, CartPole)")
    parser.add_argument("--num-actions", type=int, default=None,
                        help="override action count (default 2)")
    parser.add_argument("--hidden", type=int, nargs="*", default=None,
                        help="override hidden widths (default 64 64)")
    args = parser.parse_args()
    if (args.obs_dim, args.num_actions, args.hidden) != (None, None, None):
        config.apply_overrides(args.obs_dim, args.num_actions, args.hidden)
    lower_all(args.out_dir)


if __name__ == "__main__":
    main()
