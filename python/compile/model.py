"""L2: policy/value networks and losses over flat parameter vectors.

Every network is a function of a single flat f32[P] parameter vector (the
rust side stores parameters as one contiguous buffer — `FlatParams`); the
layer structure from config.mlp_layer_shapes is unflattened internally.
All dense layers go through the L1 Pallas kernel `fused_linear`.

Exported computations (lowered to HLO text by aot.py):
  pg_fwd       (params, obs)                       -> (logits, value)
  dqn_q        (params, obs)                       -> qvalues
  a2c_grad     (params, batch...)                  -> (grads, stats...)
  ppo_grad     (params, batch...)                  -> (grads, stats...)
  dqn_grad     (params, target_params, batch...)   -> (grads, loss, |td|)
  impala_grad  (params, T x B batch...)            -> (grads, stats...)
  adam_apply_* (params, grads, m, v, t, lr)        -> (params, m, v)
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import config
from .kernels.fused_linear import fused_linear
from .kernels.vtrace import vtrace


# ---------------------------------------------------------------------------
# Flat-parameter plumbing
# ---------------------------------------------------------------------------

def unflatten(flat, shapes):
    """Split a flat f32[P] vector into [(w, b), ...] per config shapes."""
    layers = []
    off = 0
    for w_shape, b_shape in shapes:
        w_n = w_shape[0] * w_shape[1]
        w = flat[off:off + w_n].reshape(w_shape)
        off += w_n
        b = flat[off:off + b_shape[0]]
        off += b_shape[0]
        layers.append((w, b))
    return layers


def init_flat(key, shapes, scale=None):
    """He-style init, returned already flattened."""
    parts = []
    for w_shape, b_shape in shapes:
        key, sub = jax.random.split(key)
        std = scale if scale is not None else (2.0 / w_shape[0]) ** 0.5
        w = jax.random.normal(sub, w_shape, dtype=jnp.float32) * std
        parts.append(w.reshape(-1))
        parts.append(jnp.zeros(b_shape, dtype=jnp.float32))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------

def pg_net(flat_params, obs):
    """Shared-trunk actor-critic: obs -> (logits[B, A], value[B])."""
    layers = unflatten(flat_params, config.PG_SHAPES)
    n_trunk = len(config.HIDDEN)
    h = obs
    for w, b in layers[:n_trunk]:
        h = fused_linear(h, w, b, "tanh")
    logits_w, logits_b = layers[n_trunk]
    value_w, value_b = layers[n_trunk + 1]
    logits = fused_linear(h, logits_w, logits_b, "linear")
    value = fused_linear(h, value_w, value_b, "linear")[:, 0]
    return logits, value


def dqn_net(flat_params, obs):
    """Q-network: obs -> qvalues[B, A]."""
    layers = unflatten(flat_params, config.DQN_SHAPES)
    n_trunk = len(config.HIDDEN)
    h = obs
    for w, b in layers[:n_trunk]:
        h = fused_linear(h, w, b, "tanh")
    q_w, q_b = layers[n_trunk]
    return fused_linear(h, q_w, q_b, "linear")


def pg_fwd(params, obs):
    logits, value = pg_net(params, obs)
    return logits, value


def dqn_q(params, obs):
    return (dqn_net(params, obs),)


# ---------------------------------------------------------------------------
# Loss helpers
# ---------------------------------------------------------------------------

def _masked_mean(x, mask):
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _logp_entropy(logits, actions):
    logp_all = jax.nn.log_softmax(logits)
    p_all = jax.nn.softmax(logits)
    logp = jnp.take_along_axis(logp_all, actions[:, None], axis=1)[:, 0]
    entropy = -jnp.sum(p_all * logp_all, axis=1)
    return logp, entropy


# ---------------------------------------------------------------------------
# A2C / A3C
# ---------------------------------------------------------------------------

def a2c_loss(params, obs, actions, advantages, value_targets, mask):
    logits, value = pg_net(params, obs)
    logp, entropy = _logp_entropy(logits, actions)
    pi_loss = -_masked_mean(logp * advantages, mask)
    vf_loss = 0.5 * _masked_mean((value - value_targets) ** 2, mask)
    ent = _masked_mean(entropy, mask)
    loss = pi_loss + config.VF_COEFF * vf_loss - config.ENT_COEFF * ent
    return loss, (pi_loss, vf_loss, ent)


def a2c_grad(params, obs, actions, advantages, value_targets, mask):
    (loss, (pi, vf, ent)), grads = jax.value_and_grad(a2c_loss, has_aux=True)(
        params, obs, actions, advantages, value_targets, mask)
    return grads, loss, pi, vf, ent


# ---------------------------------------------------------------------------
# PPO
# ---------------------------------------------------------------------------

def ppo_loss(params, obs, actions, old_logp, advantages, value_targets, mask):
    logits, value = pg_net(params, obs)
    logp, entropy = _logp_entropy(logits, actions)
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - config.PPO_CLIP, 1.0 + config.PPO_CLIP)
    surrogate = jnp.minimum(ratio * advantages, clipped * advantages)
    pi_loss = -_masked_mean(surrogate, mask)
    vf_loss = 0.5 * _masked_mean((value - value_targets) ** 2, mask)
    ent = _masked_mean(entropy, mask)
    kl = _masked_mean(old_logp - logp, mask)
    loss = pi_loss + config.VF_COEFF * vf_loss - config.ENT_COEFF * ent
    return loss, (pi_loss, vf_loss, ent, kl)


def ppo_grad(params, obs, actions, old_logp, advantages, value_targets, mask):
    (loss, (pi, vf, ent, kl)), grads = jax.value_and_grad(
        ppo_loss, has_aux=True)(
        params, obs, actions, old_logp, advantages, value_targets, mask)
    return grads, loss, pi, vf, ent, kl


# ---------------------------------------------------------------------------
# DQN (double-Q with target network, huber TD, prioritized-replay weights)
# ---------------------------------------------------------------------------

def _huber(x, delta):
    abs_x = jnp.abs(x)
    quad = jnp.minimum(abs_x, delta)
    return 0.5 * quad ** 2 + delta * (abs_x - quad)


def dqn_loss(params, target_params, obs, actions, rewards, next_obs, dones,
             weights, mask):
    q = dqn_net(params, obs)
    q_a = jnp.take_along_axis(q, actions[:, None], axis=1)[:, 0]
    # Double DQN: argmax under online net, value under target net.
    next_q_online = dqn_net(params, next_obs)
    next_a = jnp.argmax(next_q_online, axis=1)
    next_q_target = dqn_net(target_params, next_obs)
    next_v = jnp.take_along_axis(next_q_target, next_a[:, None], axis=1)[:, 0]
    target = rewards + config.GAMMA * (1.0 - dones) * next_v
    td = q_a - lax.stop_gradient(target)
    loss = _masked_mean(weights * _huber(td, config.HUBER_DELTA), mask)
    return loss, jnp.abs(td)


def dqn_grad(params, target_params, obs, actions, rewards, next_obs, dones,
             weights, mask):
    (loss, td_abs), grads = jax.value_and_grad(dqn_loss, has_aux=True)(
        params, target_params, obs, actions, rewards, next_obs, dones,
        weights, mask)
    return grads, loss, td_abs


# ---------------------------------------------------------------------------
# IMPALA (V-trace actor-critic)
# ---------------------------------------------------------------------------

def impala_loss(params, obs, actions, behaviour_logp, rewards, dones,
                bootstrap_obs, mask):
    """obs[T,B,O] actions[T,B] behaviour_logp/rewards/dones/mask[T,B]."""
    t_len, batch, obs_dim = obs.shape
    flat_obs = obs.reshape(t_len * batch, obs_dim)
    logits, values = pg_net(params, flat_obs)
    logits = logits.reshape(t_len, batch, -1)
    values = values.reshape(t_len, batch)

    logp_all = jax.nn.log_softmax(logits)
    p_all = jax.nn.softmax(logits)
    target_logp = jnp.take_along_axis(
        logp_all, actions[:, :, None], axis=2)[:, :, 0]
    entropy = -jnp.sum(p_all * logp_all, axis=2)

    log_rhos = target_logp - behaviour_logp
    discounts = config.GAMMA * (1.0 - dones)
    _, bootstrap_value = pg_net(params, bootstrap_obs)

    vs, pg_adv = vtrace(
        lax.stop_gradient(log_rhos), discounts, rewards,
        lax.stop_gradient(values), lax.stop_gradient(bootstrap_value),
        rho_clip=config.VTRACE_RHO_CLIP, c_clip=config.VTRACE_C_CLIP)
    vs = lax.stop_gradient(vs)
    pg_adv = lax.stop_gradient(pg_adv)

    pi_loss = -_masked_mean(target_logp * pg_adv, mask)
    vf_loss = 0.5 * _masked_mean((values - vs) ** 2, mask)
    ent = _masked_mean(entropy, mask)
    loss = pi_loss + config.VF_COEFF * vf_loss - config.ENT_COEFF * ent
    return loss, (pi_loss, vf_loss, ent)


def impala_grad(params, obs, actions, behaviour_logp, rewards, dones,
                bootstrap_obs, mask):
    (loss, (pi, vf, ent)), grads = jax.value_and_grad(
        impala_loss, has_aux=True)(
        params, obs, actions, behaviour_logp, rewards, dones,
        bootstrap_obs, mask)
    return grads, loss, pi, vf, ent


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
GRAD_CLIP = 40.0


def adam_apply(params, grads, m, v, t, lr):
    """One Adam step over flat vectors; t is the 1-based step count (f32).

    Gradients are global-norm-clipped to GRAD_CLIP first (RLlib's default
    for A3C/IMPALA-family algorithms).
    """
    gnorm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    g = grads * scale
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    m_hat = m / (1.0 - ADAM_B1 ** t)
    v_hat = v / (1.0 - ADAM_B2 ** t)
    new_params = params - lr * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS)
    return new_params, m, v


def sgd_apply(params, grads, lr):
    """Plain SGD step (used by the MAML inner-adaptation loop)."""
    gnorm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    return (params - lr * grads * scale,)
