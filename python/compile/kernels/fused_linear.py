"""Pallas fused dense layer: act(x @ w + b) with a custom VJP.

This is the L1 hot-spot of the policy networks: every trunk/head layer of
every policy and every loss goes through this kernel, so it dominates the
MACs of the whole system.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles
[B, IN] x [IN, OUT] into VMEM-resident blocks via BlockSpec; the matmul
accumulates in f32 targeting the MXU; bias-add and the activation are
fused into the epilogue while the output tile is still in VMEM, saving an
HBM round-trip per layer.  K (=IN) is deliberately unsplit: policy nets
have IN <= 64, so one MXU pass consumes the whole contraction.

The backward pass reuses the same tiled-matmul structure (`matmul`) for
dx = dz @ w^T and dw = x^T @ dz, with the activation derivative applied
elementwise from the forward residual.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(dim, target):
    """Largest divisor of `dim` that is <= target (so grids tile exactly)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _act(y, activation):
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "linear":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    # One (bm, bn) output tile: whole-K matmul + fused bias/activation
    # epilogue while the tile lives in VMEM.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = _act(acc, activation).astype(o_ref.dtype)


def _fused_linear_pallas(x, w, b, activation, block_m=128, block_n=128):
    batch, in_dim = x.shape
    in_dim2, out_dim = w.shape
    assert in_dim == in_dim2 and b.shape == (out_dim,)
    bm = pick_block(batch, block_m)
    bn = pick_block(out_dim, block_n)
    grid = (batch // bm, out_dim // bn)
    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, in_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((in_dim, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, out_dim), x.dtype),
        interpret=True,
    )(x, w, b)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(a, b, block_m=128, block_n=128):
    """Tiled Pallas matmul (whole-K); used by the fused_linear backward."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation="tanh"):
    """act(x @ w + b) as a Pallas kernel, differentiable via custom VJP."""
    return _fused_linear_pallas(x, w, b, activation)


def _fused_linear_fwd(x, w, b, activation):
    y = _fused_linear_pallas(x, w, b, activation)
    return y, (x, w, y)


def _fused_linear_bwd(activation, residuals, dy):
    x, w, y = residuals
    if activation == "tanh":
        dz = dy * (1.0 - y * y)
    elif activation == "relu":
        dz = dy * (y > 0).astype(dy.dtype)
    elif activation == "linear":
        dz = dy
    else:  # pragma: no cover - guarded at fwd time
        raise ValueError(activation)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
