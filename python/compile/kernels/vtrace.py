"""Pallas V-trace kernel: IMPALA's off-policy return correction.

The recurrence vs_t = V(s_t) + delta_t + gamma_t * c_t * (vs_{t+1} -
V(s_{t+1})) is sequential in T but embarrassingly parallel in B.  The
kernel grid therefore tiles the batch dimension only; the T loop runs
*inside* the kernel with the carry held in VMEM-resident values — the TPU
analog of how the GPU reference keeps the recurrence in registers
(DESIGN.md §Hardware-Adaptation).  T is static, so the loop unrolls into
straight-line HLO.

V-trace outputs are used as stop-gradient constants in the IMPALA loss
(the paper's/IMPALA's convention), so no VJP is defined — callers wrap
the results in lax.stop_gradient.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import pick_block


def _vtrace_kernel(log_rhos_ref, discounts_ref, rewards_ref, values_ref,
                   bootstrap_ref, vs_ref, pg_adv_ref, *, rho_clip, c_clip):
    log_rhos = log_rhos_ref[...]
    discounts = discounts_ref[...]
    rewards = rewards_ref[...]
    values = values_ref[...]
    bootstrap = bootstrap_ref[...]

    rhos = jnp.minimum(jnp.exp(log_rhos), rho_clip)
    cs = jnp.minimum(jnp.exp(log_rhos), c_clip)

    t_len = log_rhos.shape[0]
    # Backward recurrence, carry in VMEM-resident values (unrolled: T static).
    acc = jnp.zeros_like(bootstrap)
    vs_minus_v = [None] * t_len
    for t in reversed(range(t_len)):
        v_tp1 = bootstrap if t == t_len - 1 else values[t + 1]
        delta = rhos[t] * (rewards[t] + discounts[t] * v_tp1 - values[t])
        acc = delta + discounts[t] * cs[t] * acc
        vs_minus_v[t] = acc
    vs = jnp.stack(vs_minus_v, axis=0) + values

    # Forward pass for policy-gradient advantages against the vs targets.
    vs_tp1 = jnp.concatenate([vs[1:], bootstrap[None]], axis=0)
    pg_adv = rhos * (rewards + discounts * vs_tp1 - values)

    vs_ref[...] = vs.astype(vs_ref.dtype)
    pg_adv_ref[...] = pg_adv.astype(pg_adv_ref.dtype)


def vtrace(log_rhos, discounts, rewards, values, bootstrap_value,
           rho_clip=1.0, c_clip=1.0, block_b=128):
    """V-trace targets vs[T,B] and pg_advantages[T,B] (Pallas kernel).

    All inputs [T, B] except bootstrap_value [B].  Matches
    ref.vtrace_ref to float tolerance.
    """
    t_len, batch = log_rhos.shape
    bb = pick_block(batch, block_b)
    grid = (batch // bb,)
    tb_spec = pl.BlockSpec((t_len, bb), lambda i: (0, i))
    b_spec = pl.BlockSpec((bb,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((t_len, batch), values.dtype)
    return pl.pallas_call(
        functools.partial(_vtrace_kernel, rho_clip=rho_clip, c_clip=c_clip),
        grid=grid,
        in_specs=[tb_spec, tb_spec, tb_spec, tb_spec, b_spec],
        out_specs=[tb_spec, tb_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(log_rhos, discounts, rewards, values, bootstrap_value)
