"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its `*_ref` counterpart to numerical tolerance (pytest +
hypothesis sweeps in python/tests/test_kernels.py).
"""

import jax.numpy as jnp
from jax import lax


def apply_activation(y, activation):
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "linear":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def fused_linear_ref(x, w, b, activation="tanh"):
    """act(x @ w + b) — the oracle for kernels.fused_linear."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return apply_activation(y, activation).astype(x.dtype)


def matmul_ref(a, b):
    """a @ b — the oracle for kernels.matmul."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def vtrace_ref(log_rhos, discounts, rewards, values, bootstrap_value,
               rho_clip=1.0, c_clip=1.0):
    """V-trace targets and policy-gradient advantages (Espeholt et al. 2018).

    Args (all [T, B] except bootstrap_value [B]):
      log_rhos:  log(pi_target(a|s) / pi_behaviour(a|s))
      discounts: gamma * (1 - done)
      rewards, values: environment rewards, critic values under pi_target
    Returns (vs [T, B], pg_advantages [T, B]).
    """
    rhos = jnp.minimum(jnp.exp(log_rhos), rho_clip)
    cs = jnp.minimum(jnp.exp(log_rhos), c_clip)
    values_tp1 = jnp.concatenate([values[1:], bootstrap_value[None]], axis=0)
    deltas = rhos * (rewards + discounts * values_tp1 - values)

    def scan_fn(acc, xs):
        delta_t, discount_t, c_t = xs
        acc = delta_t + discount_t * c_t * acc
        return acc, acc

    _, vs_minus_v = lax.scan(
        scan_fn,
        jnp.zeros_like(bootstrap_value),
        (deltas, discounts, cs),
        reverse=True,
    )
    vs = vs_minus_v + values
    vs_tp1 = jnp.concatenate([vs[1:], bootstrap_value[None]], axis=0)
    pg_advantages = rhos * (rewards + discounts * vs_tp1 - values)
    return vs, pg_advantages
