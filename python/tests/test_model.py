"""L2 correctness: networks and losses, shapes and semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def pg_params():
    return model.init_flat(jax.random.PRNGKey(0), config.PG_SHAPES)


@pytest.fixture(scope="module")
def dqn_params():
    return model.init_flat(jax.random.PRNGKey(1), config.DQN_SHAPES)


def make_batch(key, n):
    keys = jax.random.split(key, 4)
    obs = jax.random.normal(keys[0], (n, config.OBS_DIM))
    actions = jax.random.randint(keys[1], (n,), 0, config.NUM_ACTIONS)
    adv = jax.random.normal(keys[2], (n,))
    vtarg = jax.random.normal(keys[3], (n,))
    mask = jnp.ones((n,))
    return obs, actions, adv, vtarg, mask


# ---------------------------------------------------------------------------
# Flat-param plumbing
# ---------------------------------------------------------------------------

def test_param_sizes_match_config(pg_params, dqn_params):
    assert pg_params.shape == (config.PG_PARAM_SIZE,)
    assert dqn_params.shape == (config.DQN_PARAM_SIZE,)


def test_unflatten_roundtrip(pg_params):
    layers = model.unflatten(pg_params, config.PG_SHAPES)
    assert len(layers) == len(config.PG_SHAPES)
    refl = jnp.concatenate(
        [jnp.concatenate([w.reshape(-1), b]) for w, b in layers])
    np.testing.assert_array_equal(refl, pg_params)


def test_unflatten_layer_shapes(pg_params):
    layers = model.unflatten(pg_params, config.PG_SHAPES)
    for (w, b), (w_shape, b_shape) in zip(layers, config.PG_SHAPES):
        assert w.shape == w_shape
        assert b.shape == b_shape


# ---------------------------------------------------------------------------
# Networks: shapes + parity with a pure-jnp (ref-kernel) forward
# ---------------------------------------------------------------------------

def _pg_net_ref(flat_params, obs):
    layers = model.unflatten(flat_params, config.PG_SHAPES)
    n_trunk = len(config.HIDDEN)
    h = obs
    for w, b in layers[:n_trunk]:
        h = ref.fused_linear_ref(h, w, b, "tanh")
    lw, lb = layers[n_trunk]
    vw, vb = layers[n_trunk + 1]
    return (ref.fused_linear_ref(h, lw, lb, "linear"),
            ref.fused_linear_ref(h, vw, vb, "linear")[:, 0])


def test_pg_net_shapes(pg_params):
    obs = jnp.zeros((7, config.OBS_DIM))
    logits, value = model.pg_net(pg_params, obs)
    assert logits.shape == (7, config.NUM_ACTIONS)
    assert value.shape == (7,)


def test_pg_net_matches_pure_jnp(pg_params):
    obs = jax.random.normal(jax.random.PRNGKey(2), (16, config.OBS_DIM))
    logits, value = model.pg_net(pg_params, obs)
    logits_r, value_r = _pg_net_ref(pg_params, obs)
    np.testing.assert_allclose(logits, logits_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(value, value_r, rtol=1e-5, atol=1e-5)


def test_dqn_net_shapes(dqn_params):
    obs = jnp.zeros((5, config.OBS_DIM))
    q = model.dqn_net(dqn_params, obs)
    assert q.shape == (5, config.NUM_ACTIONS)


def test_grad_through_pallas_matches_pure_jnp(pg_params):
    """jax.grad of the a2c loss via kernels == via the pure-jnp net."""
    obs, actions, adv, vtarg, mask = make_batch(jax.random.PRNGKey(3), 32)

    def loss_ref(params):
        logits, value = _pg_net_ref(params, obs)
        logp_all = jax.nn.log_softmax(logits)
        p_all = jax.nn.softmax(logits)
        logp = jnp.take_along_axis(logp_all, actions[:, None], 1)[:, 0]
        entropy = -jnp.sum(p_all * logp_all, axis=1)
        pi = -jnp.mean(logp * adv)
        vf = 0.5 * jnp.mean((value - vtarg) ** 2)
        ent = jnp.mean(entropy)
        return pi + config.VF_COEFF * vf - config.ENT_COEFF * ent

    g_kernel, *_ = model.a2c_grad(pg_params, obs, actions, adv, vtarg, mask)
    g_ref = jax.grad(loss_ref)(pg_params)
    np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Loss semantics
# ---------------------------------------------------------------------------

def test_a2c_mask_zeroes_padding(pg_params):
    """Padded rows (mask 0) must not change the loss or grads."""
    key = jax.random.PRNGKey(4)
    obs, actions, adv, vtarg, _ = make_batch(key, 16)
    mask_full = jnp.ones((16,))
    g1, l1, *_ = model.a2c_grad(pg_params, obs, actions, adv, vtarg,
                                mask_full)

    # Append garbage rows with mask 0.
    obs2 = jnp.concatenate([obs, 100.0 * jnp.ones((4, config.OBS_DIM))])
    actions2 = jnp.concatenate([actions, jnp.zeros(4, jnp.int32)])
    adv2 = jnp.concatenate([adv, 1e6 * jnp.ones(4)])
    vtarg2 = jnp.concatenate([vtarg, -1e6 * jnp.ones(4)])
    mask2 = jnp.concatenate([mask_full, jnp.zeros(4)])
    g2, l2, *_ = model.a2c_grad(pg_params, obs2, actions2, adv2, vtarg2,
                                mask2)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_ppo_surrogate_at_ratio_one(pg_params):
    """old_logp == current logp: ratio==1, surrogate == -mean(adv), kl==0."""
    obs, actions, adv, vtarg, mask = make_batch(jax.random.PRNGKey(5), 32)
    logits, _ = model.pg_net(pg_params, obs)
    logp_all = jax.nn.log_softmax(logits)
    old_logp = jnp.take_along_axis(logp_all, actions[:, None], 1)[:, 0]
    _, (pi_ppo, _, _, kl) = model.ppo_loss(
        pg_params, obs, actions, old_logp, adv, vtarg, mask)
    np.testing.assert_allclose(pi_ppo, -jnp.mean(adv), rtol=1e-4)
    np.testing.assert_allclose(kl, 0.0, atol=1e-6)


def test_ppo_clip_blocks_large_ratios(pg_params):
    """With old_logp far below current, positive-adv surrogate is clipped."""
    obs, actions, _, vtarg, mask = make_batch(jax.random.PRNGKey(6), 32)
    logits, _ = model.pg_net(pg_params, obs)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, actions[:, None], 1)[:, 0]
    old_logp = logp - 5.0  # ratio = e^5 >> 1 + clip
    adv = jnp.ones((32,))
    _, (pi_loss, _, _, _) = model.ppo_loss(
        pg_params, obs, actions, old_logp, adv, vtarg, mask)
    np.testing.assert_allclose(pi_loss, -(1.0 + config.PPO_CLIP), rtol=1e-5)


def test_dqn_target_uses_target_net(dqn_params):
    """Zero reward, done=1 everywhere: target == 0, td == q(s,a)."""
    n = 8
    obs = jax.random.normal(jax.random.PRNGKey(7), (n, config.OBS_DIM))
    actions = jnp.zeros((n,), jnp.int32)
    rewards = jnp.zeros((n,))
    dones = jnp.ones((n,))
    weights = jnp.ones((n,))
    mask = jnp.ones((n,))
    _, td_abs = model.dqn_loss(dqn_params, dqn_params, obs, actions, rewards,
                               obs, dones, weights, mask)
    q = model.dqn_net(dqn_params, obs)[:, 0]
    np.testing.assert_allclose(td_abs, jnp.abs(q), rtol=1e-5)


def test_dqn_grad_td_shape(dqn_params):
    n = config.DQN_MINIBATCH
    obs = jnp.zeros((n, config.OBS_DIM))
    grads, loss, td_abs = model.dqn_grad(
        dqn_params, dqn_params, obs, jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,)), obs, jnp.zeros((n,)), jnp.ones((n,)),
        jnp.ones((n,)))
    assert grads.shape == (config.DQN_PARAM_SIZE,)
    assert td_abs.shape == (n,)
    assert jnp.isfinite(loss)


def test_impala_grad_shapes(pg_params):
    t, b = 4, 3
    obs = jax.random.normal(jax.random.PRNGKey(8), (t, b, config.OBS_DIM))
    actions = jnp.zeros((t, b), jnp.int32)
    blogp = jnp.full((t, b), -0.7)
    rewards = jnp.ones((t, b))
    dones = jnp.zeros((t, b))
    boot = jnp.zeros((b, config.OBS_DIM))
    mask = jnp.ones((t, b))
    grads, loss, pi, vf, ent = model.impala_grad(
        pg_params, obs, actions, blogp, rewards, dones, boot, mask)
    assert grads.shape == (config.PG_PARAM_SIZE,)
    for s in (loss, pi, vf, ent):
        assert jnp.isfinite(s)


def test_impala_vtrace_targets_stop_gradient(pg_params):
    """The vf part of the grad must treat vs as constant: perturbing the
    reward path (which only enters via vtrace) changes the loss but the
    policy-entropy part of the grad structure stays finite/sane."""
    t, b = 3, 2
    obs = jax.random.normal(jax.random.PRNGKey(9), (t, b, config.OBS_DIM))
    actions = jnp.zeros((t, b), jnp.int32)
    blogp = jnp.full((t, b), -0.7)
    dones = jnp.zeros((t, b))
    boot = jnp.zeros((b, config.OBS_DIM))
    mask = jnp.ones((t, b))
    g1, *_ = model.impala_grad(pg_params, obs, actions, blogp,
                               jnp.zeros((t, b)), dones, boot, mask)
    g2, *_ = model.impala_grad(pg_params, obs, actions, blogp,
                               jnp.ones((t, b)), dones, boot, mask)
    assert jnp.all(jnp.isfinite(g1)) and jnp.all(jnp.isfinite(g2))
    assert not jnp.allclose(g1, g2)  # rewards do flow through targets


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def test_adam_first_step_is_lr_sized(pg_params):
    grads = jnp.ones_like(pg_params)
    m = jnp.zeros_like(pg_params)
    v = jnp.zeros_like(pg_params)
    new_params, m1, v1 = model.adam_apply(
        pg_params, grads, m, v, jnp.float32(1.0), jnp.float32(1e-3))
    # With bias correction at t=1, |step| == lr for unit gradients
    # (up to the global-norm clip, which rescales uniformly).
    step = pg_params - new_params
    assert jnp.all(step > 0)
    np.testing.assert_allclose(step, jnp.full_like(step, step[0]), rtol=1e-3)


def test_adam_descends_quadratic():
    params = jnp.array([5.0, -3.0])
    m = jnp.zeros(2)
    v = jnp.zeros(2)
    for t in range(1, 200):
        grads = 2.0 * params
        params, m, v = model.adam_apply(
            params, grads, m, v, jnp.float32(t), jnp.float32(0.1))
    np.testing.assert_allclose(params, jnp.zeros(2), atol=1e-2)


def test_sgd_clips_global_norm():
    params = jnp.zeros(4)
    grads = jnp.full(4, 1e9)
    (new_params,) = model.sgd_apply(params, grads, jnp.float32(1.0))
    gnorm = float(jnp.sqrt(jnp.sum((params - new_params) ** 2)))
    np.testing.assert_allclose(gnorm, model.GRAD_CLIP, rtol=1e-4)
