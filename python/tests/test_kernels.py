"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every case asserts allclose against
kernels/ref.py.  This is the core correctness signal for the kernels that
end up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import fused_linear, matmul, pick_block
from compile.kernels.vtrace import vtrace

jax.config.update("jax_platform_name", "cpu")

ACTIVATIONS = ["tanh", "relu", "linear"]


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(
        dtype)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------

@given(dim=st.integers(1, 4096), target=st.integers(1, 256))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides_and_bounds(dim, target):
    b = pick_block(dim, target)
    assert dim % b == 0
    assert b <= max(target, 1) or b == dim
    if dim <= target:
        assert b == dim


# ---------------------------------------------------------------------------
# fused_linear forward
# ---------------------------------------------------------------------------

@given(
    batch=st.sampled_from([1, 3, 8, 16, 64, 100, 256]),
    in_dim=st.sampled_from([1, 4, 7, 64]),
    out_dim=st.sampled_from([1, 2, 64, 65, 128]),
    activation=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_fused_linear_matches_ref(batch, in_dim, out_dim, activation, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (batch, in_dim))
    w = rand(k2, (in_dim, out_dim), scale=0.5)
    b = rand(k3, (out_dim,), scale=0.1)
    got = fused_linear(x, w, b, activation)
    want = ref.fused_linear_ref(x, w, b, activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_fused_linear_bf16(activation):
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    x = rand(k1, (32, 16), jnp.bfloat16)
    w = rand(k2, (16, 32), jnp.bfloat16, scale=0.3)
    b = rand(k3, (32,), jnp.bfloat16, scale=0.1)
    got = fused_linear(x, w, b, activation).astype(jnp.float32)
    want = ref.fused_linear_ref(x, w, b, activation).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_fused_linear_unknown_activation_raises():
    x = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        fused_linear(x, x, jnp.zeros(2), "gelu!!")


# ---------------------------------------------------------------------------
# fused_linear backward (custom VJP vs autodiff of the oracle)
# ---------------------------------------------------------------------------

@given(
    batch=st.sampled_from([2, 8, 33, 128]),
    in_dim=st.sampled_from([4, 5, 64]),
    out_dim=st.sampled_from([1, 2, 64]),
    activation=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_fused_linear_grad_matches_ref(batch, in_dim, out_dim, activation,
                                       seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (batch, in_dim))
    w = rand(k2, (in_dim, out_dim), scale=0.5)
    b = rand(k3, (out_dim,), scale=0.1)

    def loss_kernel(x, w, b):
        return jnp.sum(jnp.sin(fused_linear(x, w, b, activation)))

    def loss_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.fused_linear_ref(x, w, b, activation)))

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-4)


def test_fused_linear_grad_under_jit():
    k = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(k, 3)
    x, w, b = rand(k1, (16, 4)), rand(k2, (4, 8)), rand(k3, (8,))
    f = jax.jit(jax.grad(lambda x, w, b: jnp.sum(fused_linear(x, w, b)),
                         argnums=1))
    fr = jax.grad(lambda x, w, b: jnp.sum(ref.fused_linear_ref(x, w, b)),
                  argnums=1)
    np.testing.assert_allclose(f(x, w, b), fr(x, w, b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@given(
    m=st.sampled_from([1, 2, 17, 64, 128, 200]),
    k=st.sampled_from([1, 4, 64]),
    n=st.sampled_from([1, 8, 64, 129]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_matmul_matches_ref(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = rand(k1, (m, k))
    b = rand(k2, (k, n))
    np.testing.assert_allclose(
        matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# vtrace
# ---------------------------------------------------------------------------

@given(
    t_len=st.sampled_from([1, 2, 5, 20, 50]),
    batch=st.sampled_from([1, 3, 8, 32]),
    rho_clip=st.sampled_from([0.5, 1.0, 2.0]),
    c_clip=st.sampled_from([0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_vtrace_matches_ref(t_len, batch, rho_clip, c_clip, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    log_rhos = rand(keys[0], (t_len, batch), scale=0.3)
    dones = (jax.random.uniform(keys[1], (t_len, batch)) < 0.1).astype(
        jnp.float32)
    discounts = 0.99 * (1.0 - dones)
    rewards = rand(keys[2], (t_len, batch))
    values = rand(keys[3], (t_len, batch))
    bootstrap = rand(keys[4], (batch,))
    vs, adv = vtrace(log_rhos, discounts, rewards, values, bootstrap,
                     rho_clip=rho_clip, c_clip=c_clip)
    vs_r, adv_r = ref.vtrace_ref(log_rhos, discounts, rewards, values,
                                 bootstrap, rho_clip=rho_clip, c_clip=c_clip)
    np.testing.assert_allclose(vs, vs_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(adv, adv_r, rtol=1e-4, atol=1e-4)


def test_vtrace_on_policy_reduces_to_discounted_returns():
    """With rhos == 1 and no dones, vs is the n-step discounted return."""
    t_len, batch = 5, 2
    log_rhos = jnp.zeros((t_len, batch))
    discounts = jnp.full((t_len, batch), 0.9)
    rewards = jnp.ones((t_len, batch))
    values = jnp.zeros((t_len, batch))
    bootstrap = jnp.zeros((batch,))
    vs, _ = vtrace(log_rhos, discounts, rewards, values, bootstrap)
    expected_t0 = sum(0.9 ** i for i in range(t_len))
    np.testing.assert_allclose(vs[0, 0], expected_t0, rtol=1e-5)


def test_vtrace_terminal_cuts_bootstrap():
    """A done at the last step must erase the bootstrap value."""
    t_len, batch = 3, 1
    log_rhos = jnp.zeros((t_len, batch))
    discounts = jnp.zeros((t_len, batch))  # done everywhere
    rewards = jnp.array([[1.0], [2.0], [3.0]])
    values = jnp.zeros((t_len, batch))
    bootstrap = jnp.full((batch,), 100.0)
    vs, _ = vtrace(log_rhos, discounts, rewards, values, bootstrap)
    np.testing.assert_allclose(vs, rewards, rtol=1e-6)
