"""AOT pipeline: artifacts lower, manifest is consistent, HLO text parses."""

import json
import os

import pytest

from compile import aot, config


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out), verbose=False)
    return str(out), manifest


def test_all_expected_executables_present(built):
    _, manifest = built
    expected = {
        "pg_fwd", "dqn_q_fwd", "a2c_grad", "a3c_grad", "ppo_grad",
        "dqn_grad", "impala_grad", "adam_pg", "adam_dqn", "sgd_pg",
    }
    assert set(manifest["executables"]) == expected


def test_files_exist_and_nonempty(built):
    out, manifest = built
    for entry in manifest["executables"].values():
        path = os.path.join(out, entry["file"])
        assert os.path.getsize(path) > 100


def test_hlo_text_has_entry_computation(built):
    out, manifest = built
    for entry in manifest["executables"].values():
        with open(os.path.join(out, entry["file"])) as f:
            text = f.read()
        assert "HloModule" in text
        assert "ENTRY" in text


def test_manifest_input_shapes(built):
    _, manifest = built
    exe = manifest["executables"]["ppo_grad"]
    names = [i["name"] for i in exe["inputs"]]
    assert names == ["params", "obs", "actions", "old_logp", "advantages",
                     "value_targets", "mask"]
    assert exe["inputs"][0]["shape"] == [config.PG_PARAM_SIZE]
    assert exe["inputs"][1]["shape"] == [config.PPO_MINIBATCH, config.OBS_DIM]
    assert exe["inputs"][2]["dtype"] == "i32"


def test_manifest_config_roundtrips(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["config"] == json.loads(json.dumps(manifest["config"]))
    assert loaded["config"]["pg_param_size"] == config.PG_PARAM_SIZE
    assert loaded["config"]["gamma"] == config.GAMMA


def test_init_params_written(built):
    out, manifest = built
    for name, size in (("init_pg", config.PG_PARAM_SIZE),
                       ("init_dqn", config.DQN_PARAM_SIZE)):
        assert manifest[name]["len"] == size
        path = os.path.join(out, manifest[name]["file"])
        assert os.path.getsize(path) == size * 4


def test_parameter_count_order_is_stable(built):
    """The rust runtime passes inputs positionally; the manifest order is
    the ABI.  Guard it."""
    _, manifest = built
    for name, entry in manifest["executables"].items():
        assert entry["inputs"][0]["name"] == "params", name
