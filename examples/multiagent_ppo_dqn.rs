//! Multi-agent multi-policy composition (paper §5.3, Fig. 11/12): PPO
//! trains half the agents, DQN the other half, in the same environment,
//! composed from two independent trainer subflows with `Union` — the
//! workflow the paper highlights as impossible for end users on
//! template-based RL libraries.
//!
//! ```bash
//! cargo run --release --example multiagent_ppo_dqn
//! ```

use flowrl::algorithms::{
    multi_agent_plan, DqnConfig, MultiAgentConfig, TrainerConfig,
};

fn main() {
    let config = TrainerConfig {
        num_workers: 2,
        rollout_fragment_length: 32,
        train_batch_size: 256,
        lr: 2e-3,
        ..TrainerConfig::default()
    };
    let ma = MultiAgentConfig {
        agents_per_policy: 4, // the paper's Fig. 14 setup
        dqn: DqnConfig {
            buffer_capacity: 20_000,
            learning_starts: 500,
            target_update_every: 500,
            weight_sync_every: 5,
        },
        ppo_epochs: 2,
    };

    let mut train = multi_agent_plan(&config, &ma);
    for i in 0..60 {
        let r = train.next().expect("stream ended");
        if i % 6 == 0 {
            let ppo_loss = r.learner_stats.get("ppo/loss");
            let dqn_loss = r.learner_stats.get("dqn/loss");
            println!(
                "iter {i:3}  reward_mean={:7.2} episodes={:5} \
                 ppo_loss={:?} dqn_loss={:?}",
                r.episode_reward_mean, r.episodes_total, ppo_loss, dqn_loss
            );
        }
    }
}
