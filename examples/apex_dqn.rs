//! Ape-X distributed prioritized replay on CartPole (paper Fig. 10).
//!
//! Three dataflow fragments run concurrently: async rollouts storing
//! into sharded replay actors (with staleness-bounded weight refresh),
//! and the replay->learn->priority-update loop that surfaces metrics.
//!
//! ```bash
//! cargo run --release --example apex_dqn
//! ```

use flowrl::algorithms::{apex_plan, ApexConfig, DqnConfig, TrainerConfig};

fn main() {
    let config = TrainerConfig {
        num_workers: 4,
        num_envs_per_worker: 2,
        rollout_fragment_length: 50,
        lr: 1e-3,
        ..TrainerConfig::default()
    };
    let apex = ApexConfig {
        dqn: DqnConfig {
            buffer_capacity: 50_000,
            learning_starts: 1_000,
            target_update_every: 500,
            weight_sync_every: usize::MAX, // Ape-X syncs via store_op
        },
        num_replay_actors: 2,
        max_weight_sync_delay: 400,
        replay_queue_depth: 4,
    };

    let mut train = apex_plan(&config, &apex);
    for i in 0..50 {
        let r = train.next().expect("stream ended");
        if i % 5 == 0 {
            println!("iter {i:3}  {r}");
        }
    }
}
