//! Quickstart: train PPO on CartPole for a handful of iterations.
//!
//! ```bash
//! make artifacts              # once: AOT-compile the JAX/Pallas model
//! cargo run --release --example quickstart
//! ```
//!
//! The whole training program is a lazy dataflow plan; each `next()`
//! drives one report's worth of the pipeline.

use flowrl::algorithms::{ppo_plan, TrainerConfig};

fn main() {
    let config = TrainerConfig {
        num_workers: 2,
        num_envs_per_worker: 4,
        rollout_fragment_length: 32,
        train_batch_size: 256,
        lr: 5e-3,
        ..TrainerConfig::default()
    };

    // Build the plan (nothing runs yet — iterators are lazy)...
    let mut train = ppo_plan(&config);

    // ...then drive it.
    for i in 0..20 {
        let result = train.next().expect("training stream ended");
        println!("iter {i:3}  {result}");
    }
}
