//! End-to-end validation run (EXPERIMENTS.md §E2E): train PPO on
//! CartPole-v0 until the 100-episode mean reward reaches the solved
//! threshold (195) or a max iteration budget, logging the full curve.
//!
//! This exercises every layer of the stack on a real workload:
//! Pallas `fused_linear` kernels -> JAX PPO loss -> HLO artifacts ->
//! PJRT execution from the rust policies -> actor rollout workers ->
//! the dataflow plan -> metrics.
//!
//! ```bash
//! cargo run --release --example e2e_train
//! ```

use flowrl::algorithms::{ppo_plan, TrainerConfig};

fn main() {
    let config = TrainerConfig {
        num_workers: 4,
        num_envs_per_worker: 4,
        rollout_fragment_length: 64,
        train_batch_size: 1024,
        lr: 1e-3,
        seed: 0,
        ..TrainerConfig::default()
    };
    let solved_at = 195.0;
    let max_iters = 300;

    println!("# PPO CartPole-v0 — end-to-end training run");
    println!(
        "# workers={} envs/worker={} batch={} lr={}",
        config.num_workers,
        config.num_envs_per_worker,
        config.train_batch_size,
        config.lr
    );
    println!("| iter | episodes | reward_mean | len_mean | loss | kl | steps/s |");
    println!("|------|----------|-------------|----------|------|-----|---------|");

    let start = std::time::Instant::now();
    let mut train = ppo_plan(&config);
    let mut solved_iter = None;
    for i in 1..=max_iters {
        let r = train.next().expect("stream ended");
        if i % 5 == 0 || r.episode_reward_mean >= solved_at {
            println!(
                "| {i} | {} | {:.1} | {:.1} | {:.4} | {:.4} | {:.0} |",
                r.episodes_total,
                r.episode_reward_mean,
                r.episode_len_mean,
                r.learner_stats.get("loss").copied().unwrap_or(f64::NAN),
                r.learner_stats.get("kl").copied().unwrap_or(f64::NAN),
                r.sampled_steps_per_s,
            );
        }
        if r.episode_reward_mean >= solved_at && r.episodes_total >= 100 {
            solved_iter = Some(i);
            break;
        }
    }
    match solved_iter {
        Some(i) => println!(
            "\nSOLVED: reward_mean >= {solved_at} at iteration {i} \
             ({:.0?} wall-clock)",
            start.elapsed()
        ),
        None => println!(
            "\nNOT SOLVED within {max_iters} iterations \
             ({:.0?} wall-clock)",
            start.elapsed()
        ),
    }
}
