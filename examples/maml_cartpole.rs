//! MAML on a CartPole task distribution (paper §A.2.1, Fig. A2):
//! workers draw dynamics tasks (pole length / gravity / force), adapt a
//! local policy copy with inner SGD steps, and contribute post-
//! adaptation gradients to a barrier-synchronized meta-update.
//!
//! ```bash
//! cargo run --release --example maml_cartpole
//! ```

use flowrl::algorithms::{maml_plan, MamlConfig, TrainerConfig};

fn main() {
    let config = TrainerConfig {
        num_workers: 4,
        num_envs_per_worker: 2,
        rollout_fragment_length: 64,
        lr: 1e-3,
        ..TrainerConfig::default()
    };
    let maml = MamlConfig { inner_steps: 2, inner_lr: 0.05 };

    let mut train = maml_plan(&config, &maml);
    for i in 0..30 {
        let r = train.next().expect("stream ended");
        println!(
            "meta-iter {i:3}  post-adapt reward_mean={:7.2} episodes={:5} \
             loss={:.4}",
            r.episode_reward_mean,
            r.episodes_total,
            r.learner_stats.get("loss").copied().unwrap_or(f64::NAN)
        );
    }
}
