//! IMPALA on CartPole: async rollouts feed a V-trace learner (the
//! Pallas `vtrace` kernel inside the `impala_grad` artifact corrects
//! for policy lag).
//!
//! ```bash
//! cargo run --release --example impala_pipeline
//! ```

use flowrl::algorithms::{impala_plan, TrainerConfig};

fn main() {
    let config = TrainerConfig {
        num_workers: 4,
        lr: 2e-3,
        num_async: 2,
        ..TrainerConfig::default()
    };

    let mut train = impala_plan(&config);
    for i in 0..100 {
        let r = train.next().expect("stream ended");
        if i % 10 == 0 {
            println!("iter {i:3}  {r}");
        }
    }
}
