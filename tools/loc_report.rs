//! Table 2 generator: lines-of-code comparison between each algorithm's
//! dataflow plan and its low-level baseline twin.
//!
//! Counts non-blank, non-comment lines of the *distributed execution*
//! code only (the plan function files vs the baseline optimizer files),
//! mirroring the paper's methodology ("all lines of code directly
//! related to distributed execution... not including utility functions
//! shared across all algorithms").  The "+shared" column adds each
//! plan's share of the reusable operator library (`ops/`), the paper's
//! conservative estimate.
//!
//! ```bash
//! cargo run --bin loc_report
//! ```

use std::path::Path;

/// Count non-blank, non-comment lines (comment = line whose first
/// non-whitespace is `//`; block doc tests inside /* */ are not used in
/// this codebase).
fn loc(path: &Path) -> usize {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    text.lines()
        .map(str::trim)
        // Unit tests are not execution code: stop at the test module.
        .take_while(|l| *l != "#[cfg(test)]")
        .filter(|l| !l.is_empty() && !l.starts_with("//") && !l.starts_with("#!"))
        .count()
}

fn main() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let algo = |f: &str| root.join("rust/src/algorithms").join(f);
    let base = |f: &str| root.join("rust/src/baseline").join(f);
    let ops = |f: &str| root.join("rust/src/ops").join(f);

    // Shared operator files each plan leans on (conservative column).
    let rollout_ops = loc(&ops("rollout_ops.rs"));
    let train_ops = loc(&ops("train_ops.rs"));
    let replay_ops = loc(&ops("replay_ops.rs"));
    let metrics_ops = loc(&ops("metrics_ops.rs"));

    struct Row {
        name: &'static str,
        flow: usize,
        shared: usize,
        baseline: Option<usize>,
        baseline_file: &'static str,
    }

    let rows = vec![
        Row {
            name: "A3C",
            flow: loc(&algo("a3c.rs")),
            shared: rollout_ops + train_ops + metrics_ops,
            baseline: Some(loc(&base("async_gradients.rs"))),
            baseline_file: "async_gradients.rs",
        },
        Row {
            name: "A2C",
            flow: loc(&algo("a2c.rs")),
            shared: rollout_ops + train_ops + metrics_ops,
            baseline: Some(loc(&base("sync_samples.rs"))),
            baseline_file: "sync_samples.rs",
        },
        Row {
            name: "DQN",
            flow: loc(&algo("dqn.rs")),
            shared: rollout_ops + train_ops + replay_ops + metrics_ops,
            baseline: Some(loc(&base("sync_replay.rs"))),
            baseline_file: "sync_replay.rs",
        },
        Row {
            name: "PPO",
            flow: loc(&algo("ppo.rs")),
            shared: rollout_ops + train_ops + metrics_ops,
            baseline: Some(loc(&base("sync_samples.rs"))),
            baseline_file: "sync_samples.rs",
        },
        Row {
            name: "Ape-X",
            flow: loc(&algo("apex.rs")),
            shared: rollout_ops + train_ops + replay_ops + metrics_ops,
            baseline: Some(loc(&base("async_replay.rs"))),
            baseline_file: "async_replay.rs",
        },
        Row {
            name: "IMPALA",
            flow: loc(&algo("impala.rs")),
            shared: rollout_ops + train_ops + metrics_ops,
            baseline: Some(loc(&base("async_pipeline.rs"))),
            baseline_file: "async_pipeline.rs",
        },
        Row {
            name: "MAML",
            flow: loc(&algo("maml.rs")),
            shared: rollout_ops + metrics_ops,
            // The paper compares against an external codebase (ProMP);
            // we have no low-level MAML twin.
            baseline: None,
            baseline_file: "(paper: ProMP, 370 lines)",
        },
    ];

    println!("# Table 2 — distributed-execution LoC: baseline vs flow plan");
    println!();
    println!(
        "| Algorithm | Baseline (low-level) | Flow plan | +shared ops | \
         Ratio (optimistic) | Ratio (conservative) |"
    );
    println!("|---|---|---|---|---|---|");
    for r in &rows {
        let cons = r.flow + r.shared;
        match r.baseline {
            Some(b) => println!(
                "| {} | {} ({}) | {} | {} | {:.1}x | {:.1}x |",
                r.name,
                b,
                r.baseline_file,
                r.flow,
                cons,
                b as f64 / r.flow as f64,
                b as f64 / cons as f64,
            ),
            None => println!(
                "| {} | {} | {} | {} | — | — |",
                r.name, r.baseline_file, r.flow, cons,
            ),
        }
    }
    println!();
    println!(
        "shared operator library: rollout_ops={rollout_ops} \
         train_ops={train_ops} replay_ops={replay_ops} \
         metrics_ops={metrics_ops} LoC"
    );
    println!(
        "(counts: non-blank non-comment lines; flow = the plan file, \
         baseline = the dedicated low-level optimizer file)"
    );
}
