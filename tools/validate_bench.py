#!/usr/bin/env python3
"""Schema-check the committed BENCH_*.json reports.

The bench reporters regenerate these files with `-- --write`; nothing
else checks that a hand-edit (or a reporter refactor) kept them sane.
Rules enforced per file:

  * top-level required keys: bench, units, how_to_regenerate, results;
  * "bench" matches the filename (BENCH_<bench>.json);
  * "units" is a known unit string, or "mixed" — in which case every
    result row must carry its own "units" key (a known unit string);
  * "results" is a list of objects; every numeric field is finite and
    non-negative; every entry carries an "op" string;
  * if entries carry timestamps ("recorded_at_unix_ms"), they must be
    non-negative and monotonically non-decreasing in file order;
  * if an "ops" allowlist is present, every result's "op" is in it;
  * BENCH_elastic.json additionally must allowlist (and, once results
    are recorded, cover) the scale-out ops "scale_up_latency" and
    "growth_throughput" — the schema rust/benches/elastic_scale.rs
    emits;
  * BENCH_autoscale.json must allowlist (and, once results are
    recorded, cover) "time_to_converge" and "steady_utilization" — the
    schema rust/benches/autoscale.rs emits ("percent" rows are the
    learner busy fraction x 100 and must stay within [0, 100]);
  * BENCH_faults.json must allowlist (and, once results are recorded,
    cover) "hang_detection_latency" and "disarmed_overhead" — the
    schema rust/benches/fault_detection.rs emits;
  * BENCH_replay_shard.json must allowlist (and, once results are
    recorded, cover) "add_throughput" and "sample_throughput" — the
    per-shard-count sweep rust/benches/replay_shard.rs emits;
  * BENCH_gateway.json must allowlist (and, once results are recorded,
    cover) "sessions_held" and "p99_action_latency" — the client-swarm
    sweep rust/benches/gateway.rs emits ("count" rows are peak
    concurrent sessions, "us_per_op" rows the p99 submit-to-serve
    wait);
  * BENCH_offline.json must allowlist (and, once results are recorded,
    cover) "reader_frames_per_s" and "offline_dqn_steps_per_s" — the
    log-ingest + train-from-logs schema rust/benches/offline.rs emits.

Exit code 0 = all files pass; 1 = any violation (listed on stderr).

Usage: tools/validate_bench.py BENCH_a.json [BENCH_b.json ...]
"""

import json
import math
import pathlib
import sys

KNOWN_UNITS = {
    "ns_per_op",
    "us_per_op",
    "ms_per_op",
    "steps_per_s",
    "items_per_s",
    "percent",
    "count",
}
REQUIRED_KEYS = ("bench", "units", "how_to_regenerate", "results")

# Per-bench schema extensions: ops the named bench's allowlist must
# contain (and, once results exist, cover with at least one row each).
REQUIRED_OPS = {
    "elastic": ("scale_up_latency", "growth_throughput"),
    "autoscale": ("time_to_converge", "steady_utilization"),
    "faults": ("hang_detection_latency", "disarmed_overhead"),
    "replay_shard": ("add_throughput", "sample_throughput"),
    "gateway": ("sessions_held", "p99_action_latency"),
    "offline": ("reader_frames_per_s", "offline_dqn_steps_per_s"),
}


def check_file(path: pathlib.Path) -> list:
    errors = []

    def err(msg):
        errors.append(f"{path.name}: {msg}")

    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable/unparsable: {e}"]

    if not isinstance(doc, dict):
        return [f"{path.name}: top level must be an object"]

    for key in REQUIRED_KEYS:
        if key not in doc:
            err(f"missing required key {key!r}")
    if errors:
        return errors

    expected_bench = path.stem.removeprefix("BENCH_")
    if doc["bench"] != expected_bench:
        err(f'"bench" is {doc["bench"]!r}, filename says {expected_bench!r}')
    mixed_units = doc["units"] == "mixed"
    if not mixed_units and doc["units"] not in KNOWN_UNITS:
        err(
            f'unknown "units" {doc["units"]!r} '
            f'(known: {sorted(KNOWN_UNITS)} or "mixed")'
        )

    results = doc["results"]
    if not isinstance(results, list):
        err('"results" must be a list')
        return errors

    allowed_ops = doc.get("ops")
    if allowed_ops is not None and not isinstance(allowed_ops, list):
        err('"ops" must be a list when present')
        allowed_ops = None

    required_ops = REQUIRED_OPS.get(expected_bench, ())
    if required_ops:
        if allowed_ops is None:
            err(f'bench {expected_bench!r} must declare an "ops" allowlist')
        else:
            for op in required_ops:
                if op not in allowed_ops:
                    err(f'"ops" allowlist is missing required op {op!r}')

    seen_ops = set()
    last_ts = None
    for i, row in enumerate(results):
        where = f"results[{i}]"
        if not isinstance(row, dict):
            err(f"{where}: must be an object")
            continue
        op = row.get("op")
        if not isinstance(op, str) or not op:
            err(f"{where}: missing/empty 'op'")
        elif allowed_ops is not None and op not in allowed_ops:
            err(f"{where}: op {op!r} not in the file's 'ops' allowlist")
        else:
            seen_ops.add(op)
        row_units = row.get("units") if mixed_units else doc["units"]
        if mixed_units and row_units not in KNOWN_UNITS:
            err(
                f'{where}: file units are "mixed", so the row needs '
                f"its own known 'units' (got {row_units!r})"
            )
        if row_units == "percent":
            val = row.get("percent")
            if not isinstance(val, (int, float)) or isinstance(val, bool) \
                    or not 0 <= val <= 100:
                err(f"{where}.percent: must be in [0, 100] (got {val!r})")
        for key, value in row.items():
            if isinstance(value, bool):
                continue
            if isinstance(value, (int, float)):
                if not math.isfinite(value):
                    err(f"{where}.{key}: non-finite number {value!r}")
                elif value < 0:
                    err(f"{where}.{key}: negative number {value!r}")
        ts = row.get("recorded_at_unix_ms")
        if ts is not None:
            if not isinstance(ts, (int, float)) or ts < 0:
                err(f"{where}.recorded_at_unix_ms: invalid {ts!r}")
            elif last_ts is not None and ts < last_ts:
                err(
                    f"{where}.recorded_at_unix_ms: went backwards "
                    f"({ts} after {last_ts})"
                )
            else:
                last_ts = ts

    # Schema coverage: once a required-ops bench has recorded results,
    # every required op must appear (an empty `results` is the
    # committed numbers-pending state and passes).
    if results and required_ops:
        for op in required_ops:
            if op not in seen_ops:
                err(f"results cover no {op!r} row (required for this bench)")

    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    all_errors = []
    for arg in argv[1:]:
        path = pathlib.Path(arg)
        if not path.name.startswith("BENCH_") or path.suffix != ".json":
            all_errors.append(f"{path.name}: not a BENCH_*.json file")
            continue
        file_errors = check_file(path)
        all_errors.extend(file_errors)
        status = "FAIL" if file_errors else "ok"
        print(f"{path.name}: {status}")
    for e in all_errors:
        print(f"error: {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
