#!/usr/bin/env python3
"""Schema-check the committed BENCH_*.json reports.

The bench reporters regenerate these files with `-- --write`; nothing
else checks that a hand-edit (or a reporter refactor) kept them sane.
Rules enforced per file:

  * top-level required keys: bench, units, how_to_regenerate, results;
  * "bench" matches the filename (BENCH_<bench>.json);
  * "units" is a known unit string;
  * "results" is a list of objects; every numeric field is finite and
    non-negative; every entry carries an "op" string;
  * if entries carry timestamps ("recorded_at_unix_ms"), they must be
    non-negative and monotonically non-decreasing in file order;
  * if an "ops" allowlist is present, every result's "op" is in it.

Exit code 0 = all files pass; 1 = any violation (listed on stderr).

Usage: tools/validate_bench.py BENCH_a.json [BENCH_b.json ...]
"""

import json
import math
import pathlib
import sys

KNOWN_UNITS = {"ns_per_op", "us_per_op", "ms_per_op", "steps_per_s"}
REQUIRED_KEYS = ("bench", "units", "how_to_regenerate", "results")


def check_file(path: pathlib.Path) -> list:
    errors = []

    def err(msg):
        errors.append(f"{path.name}: {msg}")

    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable/unparsable: {e}"]

    if not isinstance(doc, dict):
        return [f"{path.name}: top level must be an object"]

    for key in REQUIRED_KEYS:
        if key not in doc:
            err(f"missing required key {key!r}")
    if errors:
        return errors

    expected_bench = path.stem.removeprefix("BENCH_")
    if doc["bench"] != expected_bench:
        err(f'"bench" is {doc["bench"]!r}, filename says {expected_bench!r}')
    if doc["units"] not in KNOWN_UNITS:
        err(f'unknown "units" {doc["units"]!r} (known: {sorted(KNOWN_UNITS)})')

    results = doc["results"]
    if not isinstance(results, list):
        err('"results" must be a list')
        return errors

    allowed_ops = doc.get("ops")
    if allowed_ops is not None and not isinstance(allowed_ops, list):
        err('"ops" must be a list when present')
        allowed_ops = None

    last_ts = None
    for i, row in enumerate(results):
        where = f"results[{i}]"
        if not isinstance(row, dict):
            err(f"{where}: must be an object")
            continue
        op = row.get("op")
        if not isinstance(op, str) or not op:
            err(f"{where}: missing/empty 'op'")
        elif allowed_ops is not None and op not in allowed_ops:
            err(f"{where}: op {op!r} not in the file's 'ops' allowlist")
        for key, value in row.items():
            if isinstance(value, bool):
                continue
            if isinstance(value, (int, float)):
                if not math.isfinite(value):
                    err(f"{where}.{key}: non-finite number {value!r}")
                elif value < 0:
                    err(f"{where}.{key}: negative number {value!r}")
        ts = row.get("recorded_at_unix_ms")
        if ts is not None:
            if not isinstance(ts, (int, float)) or ts < 0:
                err(f"{where}.recorded_at_unix_ms: invalid {ts!r}")
            elif last_ts is not None and ts < last_ts:
                err(
                    f"{where}.recorded_at_unix_ms: went backwards "
                    f"({ts} after {last_ts})"
                )
            else:
                last_ts = ts

    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    all_errors = []
    for arg in argv[1:]:
        path = pathlib.Path(arg)
        if not path.name.startswith("BENCH_") or path.suffix != ".json":
            all_errors.append(f"{path.name}: not a BENCH_*.json file")
            continue
        file_errors = check_file(path)
        all_errors.extend(file_errors)
        status = "FAIL" if file_errors else "ok"
        print(f"{path.name}: {status}")
    for e in all_errors:
        print(f"error: {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
