// Fixture: allocation tokens inside a hot-path function.

// flowlint: hot-path
pub fn tick(xs: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let label = format!("{}", xs.len());
    drop(label);
    out.extend_from_slice(xs);
    out
}

pub fn cold(xs: &[u32]) -> Vec<u32> {
    xs.to_vec()
}
