// Fixture: an all-Relaxed counter field is self-consistent and needs
// no annotation.
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Counters {
    hits: AtomicU64,
}

impl Counters {
    pub fn bump(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}
