// Fixture: mixed orderings on the same field without an allow.
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Registry {
    version: AtomicU64,
}

impl Registry {
    pub fn publish(&self) {
        self.version.fetch_add(1, Ordering::SeqCst);
    }

    pub fn stats(&self) -> u64 {
        self.version.load(Ordering::Relaxed)
    }
}
