// Fixture: manual completion-tag arithmetic outside actor/tags.rs.
pub fn tag(epoch: u64, shard: u64) -> u64 {
    (epoch << 16) | shard
}

pub fn untag(tag: u64) -> u64 {
    tag >> EPOCH_SHIFT
}
