// Fixture: a shift by 16 that is not a completion tag, justified.
pub fn spread(seed: u64, i: u64) -> u64 {
    // flowlint: allow(epoch-tag) -- rng seed spreading, not a completion tag
    seed.wrapping_add(i << 16)
}
