// Fixture: the Relaxed site carries a justified allow, so the mixed
// group lints clean.
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Registry {
    version: AtomicU64,
}

impl Registry {
    pub fn publish(&self) {
        self.version.fetch_add(1, Ordering::SeqCst);
    }

    pub fn stats(&self) -> u64 {
        // flowlint: allow(atomics-ordering) -- monotonic gauge read; staleness is acceptable
        self.version.load(Ordering::Relaxed)
    }
}
