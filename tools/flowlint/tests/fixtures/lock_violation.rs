// Fixture: a MutexGuard live across an actor send.
pub fn publish(state: &std::sync::Mutex<Vec<u32>>, handle: &Handle) {
    let guard = state.lock().unwrap();
    handle.cast(guard.len());
}

pub fn wait_under_lock(state: &std::sync::Mutex<u32>, cq: &Queue) {
    let mut g = state.lock().unwrap();
    let done = cq.pop_timeout(100);
    *g += done;
}
