// Fixture: malformed flowlint directives are themselves violations.

// flowlint: allow(atomics-ordering)
pub fn missing_why() {}

// flowlint: allow(no-such-rule) -- whatever
pub fn unknown_rule() {}

// flowlint: allwo(epoch-tag) -- typo
pub fn typo_directive() {}
