// Fixture: the send sits behind a faults:: failpoint in the same
// function, and sends inside #[cfg(test)] mods are exempt.
impl Handle {
    pub fn cast(&self, msg: u32) {
        if faults::send_failpoint(faults::SITE_CAST, &self.name).is_some() {
            return;
        }
        if let Err(e) = self.shared.try_send(msg) {
            drop(e);
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn raw_send_in_tests_is_fine() {
        let h = helper();
        h.shared.try_send(1).unwrap();
    }
}
