// Fixture: a raw send in actor/ without a faults:: gate (linted under
// the rel path `actor/failpoint_violation.rs`).
impl Handle {
    pub fn cast_unguarded(&self, msg: u32) {
        if let Err(e) = self.shared.try_send(msg) {
            drop(e);
        }
    }
}
