// Fixture: a justified allocation-shaped token inside a hot-path fn.

// flowlint: hot-path
pub fn forward(handle: &Handle) {
    // flowlint: allow(hot-path-alloc) -- Arc clone is a refcount bump, not a heap allocation
    let h = handle.clone();
    h.poke();
}
