// Fixture: guard scoped out (or explicitly dropped) before the send.
pub fn publish(state: &std::sync::Mutex<Vec<u32>>, handle: &Handle) {
    let len = {
        let guard = state.lock().unwrap();
        guard.len()
    };
    handle.cast(len);
}

pub fn publish_dropped(state: &std::sync::Mutex<u32>, handle: &Handle) {
    let guard = state.lock().unwrap();
    let v = *guard;
    drop(guard);
    handle.cast(v);
}
