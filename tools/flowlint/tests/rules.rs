//! Fixture-based pinning tests: each rule's true-positive lines, its
//! clean counterpart, and its allow-comment behavior.  The Python
//! mirror (`mirror.py`) is held to the same expectations by
//! `tools/ci.sh --lint`, which runs whichever implementation the
//! environment can execute.

use flowlint::{lint_file_content, Diagnostic};

fn fixture(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// Lint a fixture under an explicit root-relative path (the path
/// selects per-file scoping: `actor/` for failpoint coverage).
fn lint(rel: &str, name: &str) -> Vec<Diagnostic> {
    lint_file_content(rel, &fixture(name))
}

fn rule_lines(diags: &[Diagnostic]) -> Vec<(&'static str, usize)> {
    diags.iter().map(|d| (d.rule, d.line)).collect()
}

// ---------------------------------------------------------------- atomics

#[test]
fn atomics_mixed_ordering_is_flagged_at_the_relaxed_site() {
    let diags = lint("atomics_violation.rs", "atomics_violation.rs");
    assert_eq!(rule_lines(&diags), vec![("atomics-ordering", 14)]);
    assert!(
        diags[0].message.contains("`version`")
            && diags[0].message.contains("SeqCst"),
        "message names the field and the conflicting ordering: {}",
        diags[0].message
    );
}

#[test]
fn atomics_allow_with_justification_suppresses() {
    assert_eq!(lint("atomics_allowed.rs", "atomics_allowed.rs"), vec![]);
}

#[test]
fn atomics_all_relaxed_counter_group_is_clean() {
    assert_eq!(lint("atomics_clean.rs", "atomics_clean.rs"), vec![]);
}

// ------------------------------------------------------------------- lock

#[test]
fn lock_guard_across_send_and_pop_timeout_is_flagged() {
    let diags = lint("lock_violation.rs", "lock_violation.rs");
    assert_eq!(
        rule_lines(&diags),
        vec![("lock-discipline", 4), ("lock-discipline", 9)]
    );
    assert!(diags[0].message.contains("`guard` (line 3)"));
    assert!(diags[1].message.contains(".pop_timeout()"));
}

#[test]
fn lock_guard_scoped_out_or_dropped_is_clean() {
    assert_eq!(lint("lock_clean.rs", "lock_clean.rs"), vec![]);
}

// --------------------------------------------------------------- hot-path

#[test]
fn hot_path_alloc_tokens_are_flagged_only_in_marked_fn() {
    let diags = lint("hotpath_violation.rs", "hotpath_violation.rs");
    // `cold()` below the marked fn uses .to_vec() freely.
    assert_eq!(
        rule_lines(&diags),
        vec![("hot-path-alloc", 5), ("hot-path-alloc", 6)]
    );
    assert!(diags[0].message.contains("Vec::new"));
    assert!(diags[1].message.contains("format!"));
}

#[test]
fn hot_path_allow_covers_the_next_code_line() {
    assert_eq!(lint("hotpath_allowed.rs", "hotpath_allowed.rs"), vec![]);
}

// -------------------------------------------------------------- failpoint

#[test]
fn failpoint_ungated_send_in_actor_is_flagged() {
    let diags =
        lint("actor/failpoint_violation.rs", "failpoint_violation.rs");
    assert_eq!(rule_lines(&diags), vec![("failpoint-coverage", 5)]);
    assert!(diags[0].message.contains(".try_send()"));
}

#[test]
fn failpoint_gated_send_and_test_mod_sends_are_clean() {
    assert_eq!(
        lint("actor/failpoint_clean.rs", "failpoint_clean.rs"),
        vec![]
    );
}

#[test]
fn failpoint_rule_is_scoped_to_actor_paths() {
    // The same ungated send outside actor/ is not this rule's business.
    assert_eq!(
        lint("ops/failpoint_violation.rs", "failpoint_violation.rs"),
        vec![]
    );
}

// -------------------------------------------------------------- epoch-tag

#[test]
fn epoch_manual_shifts_are_flagged() {
    let diags = lint("epoch_violation.rs", "epoch_violation.rs");
    assert_eq!(
        rule_lines(&diags),
        vec![("epoch-tag", 3), ("epoch-tag", 7)]
    );
    assert!(diags[0].message.contains("shift by 16"));
    assert!(diags[1].message.contains("shift by EPOCH_SHIFT"));
}

#[test]
fn epoch_allow_and_tags_file_exemption() {
    assert_eq!(lint("epoch_allowed.rs", "epoch_allowed.rs"), vec![]);
    // tags.rs itself is the one place tag arithmetic is legal.
    assert_eq!(
        lint(flowlint::TAGS_FILE, "epoch_violation.rs"),
        vec![]
    );
}

// ----------------------------------------------------------- allow-syntax

#[test]
fn malformed_directives_are_violations_themselves() {
    let diags = lint("allow_syntax.rs", "allow_syntax.rs");
    assert_eq!(
        rule_lines(&diags),
        vec![
            ("allow-syntax", 3),
            ("allow-syntax", 6),
            ("allow-syntax", 9),
        ]
    );
    assert!(diags[0].message.contains("needs a `-- <justification>`"));
    assert!(diags[1].message.contains("unknown rule"));
    assert!(diags[2].message.contains("unrecognized flowlint directive"));
}

#[test]
fn allow_without_why_does_not_suppress() {
    // The unjustified allow on line 3 of allow_syntax.rs must not act
    // as a waiver: splice the same comment above a real violation.
    let src = "\
// flowlint: allow(epoch-tag)
pub fn tag(e: u64) -> u64 { e << 16 }
";
    let diags = lint_file_content("splice.rs", src);
    assert_eq!(
        rule_lines(&diags),
        vec![("allow-syntax", 1), ("epoch-tag", 2)]
    );
}

// ------------------------------------------------------------------ lexer

#[test]
fn backslash_continued_strings_do_not_shift_line_numbers() {
    // The `\`-escaped newline inside the string still ends a source
    // line; the violation below it must report its true line.
    let src = "\
pub fn msg() -> String {
    let s = \"spans \\
             two lines\";
    s.into()
}

pub fn tag(e: u64) -> u64 { e << 16 }
";
    let diags = flowlint::lint_file_content("splice.rs", src);
    assert_eq!(rule_lines(&diags), vec![("epoch-tag", 7)]);
}

// ----------------------------------------------------------------- output

#[test]
fn diagnostics_render_file_line_rule_message() {
    let diags = lint("epoch_violation.rs", "epoch_violation.rs");
    let line = format!("{}", diags[0]);
    assert!(
        line.starts_with("epoch_violation.rs:3: epoch-tag: "),
        "unexpected rendering: {line}"
    );
}

#[test]
fn json_mode_escapes_and_lists_all_fields() {
    let diags = lint("atomics_violation.rs", "atomics_violation.rs");
    let json = flowlint::to_json(&diags);
    assert!(json.contains("\"file\": \"atomics_violation.rs\""));
    assert!(json.contains("\"line\": 14"));
    assert!(json.contains("\"rule\": \"atomics-ordering\""));
    assert!(flowlint::to_json(&[]).trim() == "[]");
}
