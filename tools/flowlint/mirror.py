#!/usr/bin/env python3
"""flowlint mirror: a line-for-line Python port of tools/flowlint.

The Rust binary (src/lib.rs) is canonical; this mirror exists so the
lint gate still runs in environments with no Rust toolchain
(tools/ci.sh --lint falls back to it and says so).  Keep the two in
lockstep: every rule, token pattern, and allow-grammar decision here
mirrors a named function in src/lib.rs, and the fixture expectations in
tests/rules.rs pin both implementations to the same diagnostics.

Usage: mirror.py [--json] [ROOT]   (default ROOT: rust/src)
Exit codes: 0 clean, 1 violations, 2 usage/IO error.
"""

import json
import os
import sys

RULE_ATOMICS = "atomics-ordering"
RULE_LOCK = "lock-discipline"
RULE_HOT_PATH = "hot-path-alloc"
RULE_FAILPOINT = "failpoint-coverage"
RULE_EPOCH_TAG = "epoch-tag"
RULE_ALLOW_SYNTAX = "allow-syntax"
RULES = [RULE_ATOMICS, RULE_LOCK, RULE_HOT_PATH, RULE_FAILPOINT,
         RULE_EPOCH_TAG]
TAGS_FILE = "actor/tags.rs"

IDENT, NUM, PUNCT = "ident", "num", "punct"


def is_ident_start(c):
    return c.isascii() and (c.isalpha() or c == "_")


def is_ident_continue(c):
    return c.isascii() and (c.isalnum() or c == "_")


def lex(src):
    """Mirror of lex(): (tokens, comments).

    tokens: list of (line, kind, text); comments: (line, standalone,
    text)."""
    chars = src
    n = len(chars)
    tokens, comments = [], []
    i, line = 0, 1
    line_has_code = False
    while i < n:
        c = chars[i]
        if c == "\n":
            line += 1
            line_has_code = False
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and chars[i + 1] == "/":
            j = i + 2
            while j < n and chars[j] != "\n":
                j += 1
            comments.append((line, not line_has_code, chars[i + 2:j]))
            i = j
            continue
        if c == "/" and i + 1 < n and chars[i + 1] == "*":
            depth, j = 1, i + 2
            while j < n and depth > 0:
                if chars[j] == "\n":
                    line += 1
                    line_has_code = False
                elif chars[j] == "/" and j + 1 < n and chars[j + 1] == "*":
                    depth += 1
                    j += 1
                elif chars[j] == "*" and j + 1 < n and chars[j + 1] == "/":
                    depth -= 1
                    j += 1
                j += 1
            i = j
            continue
        if c == '"':
            i, line = skip_string(chars, i, line)
            line_has_code = True
            continue
        if c == "'":
            line_has_code = True
            nxt = chars[i + 1] if i + 1 < n else ""
            after = chars[i + 2] if i + 2 < n else ""
            if nxt and is_ident_start(nxt) and after != "'":
                j = i + 1
                while j < n and is_ident_continue(chars[j]):
                    j += 1
                i = j
            else:
                j = i + 1
                while j < n and chars[j] != "'":
                    if chars[j] == "\\":
                        j += 1
                    j += 1
                i = j + 1
            continue
        if is_ident_start(c):
            line_has_code = True
            j = i
            while j < n and is_ident_continue(chars[j]):
                j += 1
            ident = chars[i:j]
            if ident in ("r", "b", "br") and j < n and chars[j] in '"#':
                i, line = skip_raw_string(chars, j, line)
                continue
            tokens.append((line, IDENT, ident))
            i = j
            continue
        if c.isdigit():
            line_has_code = True
            j = i
            while j < n:
                d = chars[j]
                if is_ident_continue(d):
                    j += 1
                elif (d == "." and j + 1 < n and chars[j + 1].isdigit()):
                    j += 1
                else:
                    break
            tokens.append((line, NUM, chars[i:j]))
            i = j
            continue
        line_has_code = True
        tokens.append((line, PUNCT, c))
        i += 1
    return tokens, comments


def skip_string(chars, i, line):
    n = len(chars)
    j = i + 1
    while j < n:
        if chars[j] == "\\":
            # A `\`-continued string escapes the newline itself; it
            # still ends a source line.
            if j + 1 < n and chars[j + 1] == "\n":
                line += 1
            j += 2
        elif chars[j] == '"':
            return j + 1, line
        else:
            if chars[j] == "\n":
                line += 1
            j += 1
    return j, line


def skip_raw_string(chars, i, line):
    n = len(chars)
    hashes, j = 0, i
    while j < n and chars[j] == "#":
        hashes += 1
        j += 1
    if j >= n or chars[j] != '"':
        return j, line
    if hashes == 0:
        return skip_string(chars, j, line)
    j += 1
    while j < n:
        if chars[j] == "\n":
            line += 1
            j += 1
            continue
        if chars[j] == '"' and chars[j + 1:j + 1 + hashes] == "#" * hashes:
            return j + 1 + hashes, line
        j += 1
    return j, line


def parse_directives(file, tokens, comments):
    """Mirror of parse_directives()."""
    allows, hot_markers, errors = [], [], []
    for (cline, standalone, text) in comments:
        pos = text.find("flowlint:")
        if pos < 0:
            continue
        body = text[pos + len("flowlint:"):].strip()
        if body == "hot-path" or body.startswith("hot-path "):
            hot_markers.append(cline)
            continue
        if body.startswith("allow("):
            rest = body[len("allow("):]
            close = rest.find(")")
            if close < 0:
                errors.append(diag(file, cline, RULE_ALLOW_SYNTAX,
                                   "unterminated flowlint allow(...)"))
                continue
            rule = rest[:close].strip()
            if rule not in RULES:
                errors.append(diag(file, cline, RULE_ALLOW_SYNTAX,
                                   f'unknown rule "{rule}" in allow'))
                continue
            tail = rest[close + 1:].strip()
            has_why = tail.startswith("--") and bool(tail[2:].strip())
            if not has_why:
                errors.append(diag(
                    file, cline, RULE_ALLOW_SYNTAX,
                    f"allow({rule}) needs a `-- <justification>`"))
            targets = [cline]
            if standalone:
                nxt = next((t[0] for t in tokens if t[0] > cline), None)
                if nxt is not None:
                    targets.append(nxt)
            allows.append((rule, has_why, targets))
            continue
        word = body.split()[0] if body.split() else ""
        errors.append(diag(file, cline, RULE_ALLOW_SYNTAX,
                           f'unrecognized flowlint directive: "{word}"'))
    return allows, hot_markers, errors


def allowed(allows, rule, line):
    return any(r == rule and has_why and line in targets
               for (r, has_why, targets) in allows)


def diag(file, line, rule, message):
    return {"file": file, "line": line, "rule": rule, "message": message}


def match_brace(tokens, open_idx):
    depth = 0
    for j in range(open_idx, len(tokens)):
        t = tokens[j]
        if t[1] == PUNCT and t[2] == "{":
            depth += 1
        elif t[1] == PUNCT and t[2] == "}":
            depth -= 1
            if depth == 0:
                return j
    return len(tokens) - 1


def fn_spans(tokens):
    """Mirror of fn_spans(): [(sig_line, body_start, body_end)]."""
    spans = []
    i = 0
    while i < len(tokens):
        if tokens[i][1] == IDENT and tokens[i][2] == "fn":
            sig_line = tokens[i][0]
            j, paren, body = i + 1, 0, None
            while j < len(tokens):
                k, txt = tokens[j][1], tokens[j][2]
                if k == PUNCT and txt in "([":
                    paren += 1
                elif k == PUNCT and txt in ")]":
                    paren -= 1
                elif k == PUNCT and txt == "{" and paren == 0:
                    body = j
                    break
                elif k == PUNCT and txt == ";" and paren == 0:
                    break
                j += 1
            if body is not None:
                spans.append((sig_line, body, match_brace(tokens, body)))
            i = max(j, i + 1)
            continue
        i += 1
    return spans


def test_mod_spans(tokens):
    """Mirror of test_mod_spans()."""
    spans = []
    i = 0
    n = len(tokens)

    def tok(k):
        return (tokens[k][1], tokens[k][2]) if k < n else (None, None)

    while i + 6 < n:
        if (tok(i) == (PUNCT, "#") and tok(i + 1) == (PUNCT, "[")
                and tok(i + 2) == (IDENT, "cfg")
                and tok(i + 3) == (PUNCT, "(")
                and tok(i + 4) == (IDENT, "test")
                and tok(i + 5) == (PUNCT, ")")
                and tok(i + 6) == (PUNCT, "]")):
            j = i + 7
            while j < n and tok(j) == (PUNCT, "#"):
                if tok(j + 1) == (PUNCT, "["):
                    depth = 0
                    while j < n:
                        if tok(j) == (PUNCT, "["):
                            depth += 1
                        elif tok(j) == (PUNCT, "]"):
                            depth -= 1
                            if depth == 0:
                                break
                        j += 1
                    j += 1
                else:
                    break
            if tok(j) == (IDENT, "mod"):
                k = j + 1
                while k < n and tok(k) not in ((PUNCT, "{"), (PUNCT, ";")):
                    k += 1
                if k < n and tok(k) == (PUNCT, "{"):
                    end = match_brace(tokens, k)
                    spans.append((k, end))
                    i = k + 1
                    continue
        i += 1
    return spans


def in_spans(spans, idx):
    return any(a <= idx <= b for (a, b) in spans)


ATOMIC_OPS = {"load", "store", "swap", "fetch_add", "fetch_sub",
              "fetch_and", "fetch_or", "fetch_xor", "fetch_max",
              "fetch_min", "fetch_nand", "fetch_update",
              "compare_exchange", "compare_exchange_weak"}
ORDERINGS = {"Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"}


def atomic_sites(tokens):
    """Mirror of atomic_sites()."""
    sites = []
    i = 1
    n = len(tokens)
    while i + 1 < n:
        is_op = (tokens[i - 1][1] == PUNCT and tokens[i - 1][2] == "."
                 and tokens[i][1] == IDENT and tokens[i][2] in ATOMIC_OPS
                 and tokens[i + 1][1] == PUNCT and tokens[i + 1][2] == "(")
        if not is_op:
            i += 1
            continue
        field = None
        if i >= 2 and tokens[i - 2][1] in (IDENT, NUM):
            field = tokens[i - 2][2]
        depth, j, orderings = 0, i + 1, []
        while j < n:
            k, txt = tokens[j][1], tokens[j][2]
            if k == PUNCT and txt == "(":
                depth += 1
            elif k == PUNCT and txt == ")":
                depth -= 1
                if depth == 0:
                    break
            elif k == IDENT and txt in ORDERINGS:
                orderings.append(txt)
            j += 1
        if field is not None and orderings:
            sites.append((tokens[i][0], field, orderings))
        i = max(j, i + 1)
    return sites


def check_atomics(file, tokens, allows, out):
    by_field = {}
    for (line, field, orderings) in atomic_sites(tokens):
        by_field.setdefault(field, []).append((line, orderings))
    for field in sorted(by_field):
        group = by_field[field]
        strongest = sorted({o for (_, os) in group for o in os
                            if o != "Relaxed"})
        if not strongest:
            continue
        for (line, orderings) in group:
            if not all(o == "Relaxed" for o in orderings):
                continue
            if allowed(allows, RULE_ATOMICS, line):
                continue
            out.append(diag(
                file, line, RULE_ATOMICS,
                f"Ordering::Relaxed on `{field}` conflicts with "
                f"{'/'.join(strongest)} used on the same field in this "
                f"file"))


SEND_METHODS = {"cast", "try_cast", "call", "call_deferred",
                "try_call_deferred", "call_into", "broadcast",
                "broadcast_sync", "pop_timeout"}


def let_binding_name(tokens, let_idx):
    name = None
    for j in range(let_idx + 1, len(tokens)):
        k, txt = tokens[j][1], tokens[j][2]
        if k == PUNCT and txt == "=":
            return name
        if k == PUNCT and txt in ";{":
            return None
        if k == IDENT and txt not in ("mut", "ref", "else"):
            name = txt
    return None


def parse_let_lock(tokens, let_idx, depth):
    name = let_binding_name(tokens, let_idx)
    if name is None:
        return None
    j = let_idx + 1
    while j < len(tokens) and not (tokens[j][1] == PUNCT
                                   and tokens[j][2] == "="):
        if tokens[j][1] == PUNCT and tokens[j][2] in ";{":
            return None
        j += 1
    nest, has_lock, if_let = 0, False, False
    k = j + 1
    while k < len(tokens):
        kind, txt = tokens[k][1], tokens[k][2]
        if kind == PUNCT and txt in "([":
            nest += 1
        elif kind == PUNCT and txt in ")]":
            nest -= 1
        elif kind == PUNCT and txt == ";" and nest == 0:
            break
        elif kind == PUNCT and txt == "{" and nest == 0:
            if_let = True
            break
        elif (kind == IDENT and txt == "lock" and k > 0
              and tokens[k - 1][1] == PUNCT and tokens[k - 1][2] == "."
              and k + 1 < len(tokens) and tokens[k + 1][1] == PUNCT
              and tokens[k + 1][2] == "("):
            has_lock = True
        k += 1
    if not has_lock:
        return None
    guard_depth = depth + 1 if if_let else depth
    return (name, guard_depth, tokens[let_idx][0]), k


def check_lock_discipline(file, tokens, allows, out):
    guards = []  # (name, depth, line)
    depth = 0
    i = 0
    n = len(tokens)
    while i < n:
        kind, txt = tokens[i][1], tokens[i][2]
        if kind == PUNCT and txt == "{":
            depth += 1
        elif kind == PUNCT and txt == "}":
            depth -= 1
            guards = [g for g in guards if g[1] <= depth]
        elif kind == IDENT and txt == "let":
            r = parse_let_lock(tokens, i, depth)
            if r is not None:
                guard, nxt = r
                guards = [g for g in guards if g[0] != guard[0]]
                guards.append(guard)
                i = nxt
                continue
            name = let_binding_name(tokens, i)
            if name is not None:
                guards = [g for g in guards if g[0] != name]
        elif kind == IDENT and txt == "drop":
            if (i + 3 < n and tokens[i + 1][1] == PUNCT
                    and tokens[i + 1][2] == "("
                    and tokens[i + 2][1] == IDENT
                    and tokens[i + 3][1] == PUNCT
                    and tokens[i + 3][2] == ")"):
                guards = [g for g in guards if g[0] != tokens[i + 2][2]]
        elif (kind == IDENT and txt in SEND_METHODS and i > 0
              and tokens[i - 1][1] == PUNCT and tokens[i - 1][2] == "."
              and i + 1 < n and tokens[i + 1][1] == PUNCT
              and tokens[i + 1][2] == "("):
            if guards:
                line = tokens[i][0]
                if not allowed(allows, RULE_LOCK, line):
                    held = ", ".join(f"`{g[0]}` (line {g[2]})"
                                     for g in guards)
                    out.append(diag(
                        file, line, RULE_LOCK,
                        f".{txt}() with lock guard {held} still live"))
        i += 1


def check_hot_path(file, tokens, allows, hot_markers, out):
    if not hot_markers:
        return
    spans = fn_spans(tokens)
    for marker in hot_markers:
        candidates = [s for s in spans if s[0] >= marker]
        if not candidates:
            out.append(diag(file, marker, RULE_HOT_PATH,
                            "hot-path marker with no following fn"))
            continue
        span = min(candidates, key=lambda s: s[0])
        scan_alloc_tokens(file, tokens, span, allows, out)


def scan_alloc_tokens(file, tokens, span, allows, out):
    _, body_start, body_end = span
    toks = tokens[body_start:min(body_end, len(tokens) - 1) + 1]
    n = len(toks)

    def report(line, what):
        if not allowed(allows, RULE_HOT_PATH, line):
            out.append(diag(
                file, line, RULE_HOT_PATH,
                f"{what} inside a `// flowlint: hot-path` function"))

    i = 0
    while i < n:
        line, kind, txt = toks[i]
        if kind == IDENT and txt in ("Vec", "Box", "String"):
            if (i + 3 < n and toks[i + 1][1:] == (PUNCT, ":")
                    and toks[i + 2][1:] == (PUNCT, ":")
                    and toks[i + 3][1] == IDENT):
                m = toks[i + 3][2]
                if m == "new" or (txt == "String" and m == "from"):
                    report(line, f"{txt}::{m}")
                    i += 4
                    continue
        elif kind == IDENT and txt in ("vec", "format"):
            if i + 1 < n and toks[i + 1][1:] == (PUNCT, "!"):
                report(line, f"{txt}!")
                i += 2
                continue
        elif (kind == IDENT and txt in ("to_vec", "to_string", "clone")
              and i > 0 and toks[i - 1][1:] == (PUNCT, ".")
              and i + 1 < n and toks[i + 1][1:] == (PUNCT, "(")):
            if txt == "clone":
                flag = i + 2 < n and toks[i + 2][1:] == (PUNCT, ")")
            else:
                flag = True
            if flag:
                report(line, f".{txt}()")
        i += 1


RAW_SEND_METHODS = {"send", "try_send", "cast", "try_cast"}


def check_failpoint_coverage(file, tokens, allows, out):
    base = file.rsplit("/", 1)[-1]
    in_actor = file.startswith("actor/") or file == "actor.rs"
    if not in_actor or base in ("mailbox.rs", "faults.rs"):
        return
    spans = fn_spans(tokens)
    tests = test_mod_spans(tokens)
    n = len(tokens)
    for i in range(1, n):
        is_send = (tokens[i - 1][1] == PUNCT and tokens[i - 1][2] == "."
                   and tokens[i][1] == IDENT
                   and tokens[i][2] in RAW_SEND_METHODS
                   and i + 1 < n and tokens[i + 1][1] == PUNCT
                   and tokens[i + 1][2] == "(")
        if not is_send or in_spans(tests, i):
            continue
        enclosing = [s for s in spans if s[1] <= i <= s[2]]
        if not enclosing:
            continue
        span = min(enclosing, key=lambda s: s[2] - s[1])
        gated = any(
            tokens[j][1] == IDENT and tokens[j][2] == "faults"
            and j + 2 < n and tokens[j + 1][1] == PUNCT
            and tokens[j + 1][2] == ":" and tokens[j + 2][1] == PUNCT
            and tokens[j + 2][2] == ":"
            for j in range(span[1], i))
        if gated:
            continue
        line = tokens[i][0]
        if allowed(allows, RULE_FAILPOINT, line):
            continue
        out.append(diag(
            file, line, RULE_FAILPOINT,
            f".{tokens[i][2]}() send site without a faults:: failpoint "
            f"in the same function"))


def check_epoch_tag(file, tokens, allows, out):
    if file == TAGS_FILE:
        return
    for i in range(2, len(tokens)):
        a, b = tokens[i - 2], tokens[i - 1]
        shift = (a[1] == PUNCT and b[1] == PUNCT
                 and ((a[2] == "<" and b[2] == "<")
                      or (a[2] == ">" and b[2] == ">")))
        if not shift:
            continue
        kind, txt = tokens[i][1], tokens[i][2]
        if kind == NUM and txt == "16":
            operand = "16"
        elif kind == IDENT and txt == "EPOCH_SHIFT":
            operand = "EPOCH_SHIFT"
        else:
            continue
        line = tokens[i][0]
        if allowed(allows, RULE_EPOCH_TAG, line):
            continue
        out.append(diag(
            file, line, RULE_EPOCH_TAG,
            f"manual tag arithmetic (shift by {operand}); use "
            f"actor::tags::{{encode_tag, decode_tag}}"))


def lint_file_content(rel_path, src):
    """Mirror of lint_file_content()."""
    rel = rel_path.replace("\\", "/")
    tokens, comments = lex(src)
    allows, hot_markers, errors = parse_directives(rel, tokens, comments)
    out = list(errors)
    check_atomics(rel, tokens, allows, out)
    check_lock_discipline(rel, tokens, allows, out)
    check_hot_path(rel, tokens, allows, hot_markers, out)
    check_failpoint_coverage(rel, tokens, allows, out)
    check_epoch_tag(rel, tokens, allows, out)
    out.sort(key=lambda d: (d["line"], d["rule"]))
    return out


def lint_tree(root):
    files = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for f in filenames:
            if f.endswith(".rs"):
                files.append(os.path.join(dirpath, f))
    files.sort()
    out = []
    for f in files:
        rel = os.path.relpath(f, root).replace(os.sep, "/")
        with open(f, encoding="utf-8") as fh:
            out.extend(lint_file_content(rel, fh.read()))
    return out


def main(argv):
    json_mode = False
    root = None
    for a in argv[1:]:
        if a == "--json":
            json_mode = True
        elif a in ("--help", "-h"):
            print("usage: mirror.py [--json] [ROOT]", file=sys.stderr)
            return 0
        elif a.startswith("-"):
            print(f"flowlint-mirror: unknown flag {a!r}", file=sys.stderr)
            return 2
        elif root is None:
            root = a
        else:
            print("flowlint-mirror: more than one ROOT", file=sys.stderr)
            return 2
    root = root or "rust/src"
    if not os.path.isdir(root):
        print(f"flowlint-mirror: {root} is not a directory",
              file=sys.stderr)
        return 2
    diags = lint_tree(root)
    if json_mode:
        print(json.dumps(diags, indent=2))
    else:
        for d in diags:
            print(f"{d['file']}:{d['line']}: {d['rule']}: {d['message']}")
        if diags:
            print(f"flowlint-mirror: {len(diags)} violation(s)",
                  file=sys.stderr)
        else:
            print(f"flowlint-mirror: clean ({root})", file=sys.stderr)
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
