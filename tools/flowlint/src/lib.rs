//! flowlint: project-invariant static analysis for the flowrl tree.
//!
//! Five rules, each encoding an invariant the hand-rolled concurrency
//! layer depends on (see `docs/static_analysis.md` for the catalog and
//! the rationale behind each):
//!
//! * `atomics-ordering` — every `Ordering::Relaxed` site must either
//!   carry an allow justification or pair consistently with the other
//!   orderings used on the same named atomic field in the same file
//!   (all-`Relaxed` counter fields pass; a `Relaxed` load of a field
//!   that is stored `Release`/`SeqCst` elsewhere is flagged).
//! * `lock-discipline` — no lock guard may be live across an actor
//!   send (`cast`/`call`/`call_deferred`/`try_call_deferred`/
//!   `call_into`/`try_cast`/`broadcast`/`broadcast_sync`) or a
//!   `pop_timeout` wait — the PR 5 `broadcast_sync` deadlock shape.
//! * `hot-path-alloc` — functions marked `// flowlint: hot-path` must
//!   not contain allocation tokens (`Vec::new`, `vec!`, `Box::new`,
//!   `format!`, `String::new`, `.to_vec()`, `.to_string()`,
//!   `.clone()`).
//! * `failpoint-coverage` — mailbox/caster send sites in `actor/`
//!   must sit behind a `faults::` failpoint in the same function.
//! * `epoch-tag` — completion tags are built by `actor/tags.rs` only;
//!   manual `<< 16` / `>> 16` / `<< EPOCH_SHIFT` arithmetic anywhere
//!   else is flagged.
//!
//! The escape hatch is an inline comment:
//!
//! ```text
//! // flowlint: allow(<rule-id>) -- <justification>
//! ```
//!
//! which suppresses the named rule on its own line and, when the
//! comment stands alone on its line, on the next code line.  An allow
//! without a `--` justification is itself a violation
//! (`allow-syntax`), so the waiver ledger stays self-documenting.
//!
//! The analysis is a hand-rolled lexer over token streams — no `syn`,
//! no dependencies — deliberately conservative: it skips comments,
//! strings, chars, and lifetimes, and matches structural token
//! patterns rather than parsing full Rust.

use std::collections::BTreeMap;
use std::fmt;

// ---------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------

/// One lint finding, pre-allow-filtering already applied.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the lint root (e.g. `actor/registry.rs`).
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Stable rule id (`atomics-ordering`, `lock-discipline`, ...).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

pub const RULE_ATOMICS: &str = "atomics-ordering";
pub const RULE_LOCK: &str = "lock-discipline";
pub const RULE_HOT_PATH: &str = "hot-path-alloc";
pub const RULE_FAILPOINT: &str = "failpoint-coverage";
pub const RULE_EPOCH_TAG: &str = "epoch-tag";
pub const RULE_ALLOW_SYNTAX: &str = "allow-syntax";

/// Every enforceable rule id (`allow-syntax` guards the grammar itself
/// and cannot be allowed away).
pub const RULES: &[&str] = &[
    RULE_ATOMICS,
    RULE_LOCK,
    RULE_HOT_PATH,
    RULE_FAILPOINT,
    RULE_EPOCH_TAG,
];

// ---------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
enum Tok {
    Ident(String),
    Num(String),
    Punct(char),
}

#[derive(Debug, Clone)]
struct Token {
    line: usize,
    tok: Tok,
}

#[derive(Debug, Clone)]
struct Comment {
    line: usize,
    /// True when nothing but whitespace precedes the `//` on its line.
    standalone: bool,
    text: String,
}

struct Lexed {
    tokens: Vec<Token>,
    comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize Rust source: idents, numbers, and single-char puncts, with
/// comments captured separately and strings/chars/lifetimes skipped.
fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut tokens = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut line_has_code = false;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < chars.len() && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < chars.len() && chars[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                standalone: !line_has_code,
                text: chars[start..j].iter().collect(),
            });
            i = j;
            continue;
        }
        // Block comment (nests in Rust).
        if c == '/' && i + 1 < chars.len() && chars[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < chars.len() && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    line_has_code = false;
                } else if chars[j] == '/'
                    && j + 1 < chars.len()
                    && chars[j + 1] == '*'
                {
                    depth += 1;
                    j += 1;
                } else if chars[j] == '*'
                    && j + 1 < chars.len()
                    && chars[j + 1] == '/'
                {
                    depth -= 1;
                    j += 1;
                }
                j += 1;
            }
            i = j;
            continue;
        }
        // String literal.
        if c == '"' {
            i = skip_string(&chars, i, &mut line);
            line_has_code = true;
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            line_has_code = true;
            let next = chars.get(i + 1).copied();
            let after = chars.get(i + 2).copied();
            let is_lifetime = matches!(next, Some(n) if is_ident_start(n))
                && after != Some('\'');
            if is_lifetime {
                let mut j = i + 1;
                while j < chars.len() && is_ident_continue(chars[j]) {
                    j += 1;
                }
                i = j;
            } else {
                // Char literal: handle escapes; never spans lines.
                let mut j = i + 1;
                while j < chars.len() && chars[j] != '\'' {
                    if chars[j] == '\\' {
                        j += 1;
                    }
                    j += 1;
                }
                i = j + 1;
            }
            continue;
        }
        // Identifier (with raw/byte-string prefixes).
        if is_ident_start(c) {
            line_has_code = true;
            let mut j = i;
            while j < chars.len() && is_ident_continue(chars[j]) {
                j += 1;
            }
            let ident: String = chars[i..j].iter().collect();
            // r"...", r#"..."#, b"...", br#"..."# — string follows the
            // prefix directly.
            if matches!(ident.as_str(), "r" | "b" | "br")
                && matches!(chars.get(j), Some('"') | Some('#'))
            {
                i = skip_raw_string(&chars, j, &mut line);
                continue;
            }
            tokens.push(Token { line, tok: Tok::Ident(ident) });
            i = j;
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            line_has_code = true;
            let mut j = i;
            while j < chars.len() {
                let d = chars[j];
                if is_ident_continue(d) {
                    j += 1;
                } else if d == '.'
                    && matches!(chars.get(j + 1), Some(n) if n.is_ascii_digit())
                {
                    j += 1;
                } else {
                    break;
                }
            }
            tokens.push(Token {
                line,
                tok: Tok::Num(chars[i..j].iter().collect()),
            });
            i = j;
            continue;
        }
        line_has_code = true;
        tokens.push(Token { line, tok: Tok::Punct(c) });
        i += 1;
    }
    Lexed { tokens, comments }
}

/// Skip a `"..."` literal starting at `i` (the opening quote); returns
/// the index just past the closing quote.
fn skip_string(chars: &[char], i: usize, line: &mut usize) -> usize {
    let mut j = i + 1;
    while j < chars.len() {
        match chars[j] {
            // `\`-continued strings escape the newline itself; it still
            // ends a source line.
            '\\' => {
                if chars.get(j + 1) == Some(&'\n') {
                    *line += 1;
                }
                j += 2;
            }
            '"' => return j + 1,
            c => {
                if c == '\n' {
                    *line += 1;
                }
                j += 1;
            }
        }
    }
    j
}

/// Skip a raw/byte string whose hashes-or-quote start at `i`; returns
/// the index just past the closing delimiter.
fn skip_raw_string(chars: &[char], i: usize, line: &mut usize) -> usize {
    let mut hashes = 0usize;
    let mut j = i;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        // Not actually a raw string (e.g. `r#ident`); resume after the
        // hashes without consuming anything further.
        return j;
    }
    if hashes == 0 {
        return skip_string(chars, j, line);
    }
    j += 1;
    while j < chars.len() {
        if chars[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if chars[j] == '"' {
            let mut k = 0usize;
            while k < hashes && chars.get(j + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                return j + 1 + hashes;
            }
        }
        j += 1;
    }
    j
}

// ---------------------------------------------------------------------
// Directives (allow comments, hot-path markers)
// ---------------------------------------------------------------------

#[derive(Debug)]
struct AllowDirective {
    line: usize,
    /// Lines this allow covers (its own + the next code line when the
    /// comment stands alone).
    targets: Vec<usize>,
    rule: String,
    has_why: bool,
}

#[derive(Debug)]
struct Directives {
    allows: Vec<AllowDirective>,
    /// Lines carrying a `// flowlint: hot-path` marker.
    hot_path_markers: Vec<usize>,
    syntax_errors: Vec<Diagnostic>,
}

fn parse_directives(file: &str, lexed: &Lexed) -> Directives {
    let mut allows = Vec::new();
    let mut hot_path_markers = Vec::new();
    let mut syntax_errors = Vec::new();
    for c in &lexed.comments {
        let Some(pos) = c.text.find("flowlint:") else { continue };
        let body = c.text[pos + "flowlint:".len()..].trim();
        if body == "hot-path" || body.starts_with("hot-path ") {
            hot_path_markers.push(c.line);
            continue;
        }
        if let Some(rest) = body.strip_prefix("allow(") {
            let Some(close) = rest.find(')') else {
                syntax_errors.push(Diagnostic {
                    file: file.to_string(),
                    line: c.line,
                    rule: RULE_ALLOW_SYNTAX,
                    message: "unterminated flowlint allow(...)".to_string(),
                });
                continue;
            };
            let rule = rest[..close].trim().to_string();
            if !RULES.contains(&rule.as_str()) {
                syntax_errors.push(Diagnostic {
                    file: file.to_string(),
                    line: c.line,
                    rule: RULE_ALLOW_SYNTAX,
                    message: format!("unknown rule {rule:?} in allow"),
                });
                continue;
            }
            let tail = rest[close + 1..].trim();
            let has_why = match tail.strip_prefix("--") {
                Some(why) => !why.trim().is_empty(),
                None => false,
            };
            if !has_why {
                syntax_errors.push(Diagnostic {
                    file: file.to_string(),
                    line: c.line,
                    rule: RULE_ALLOW_SYNTAX,
                    message: format!(
                        "allow({rule}) needs a `-- <justification>`"
                    ),
                });
            }
            let mut targets = vec![c.line];
            if c.standalone {
                // Covers the next code line (first token past the
                // comment line).
                if let Some(t) =
                    lexed.tokens.iter().find(|t| t.line > c.line)
                {
                    targets.push(t.line);
                }
            }
            allows.push(AllowDirective {
                line: c.line,
                targets,
                rule,
                has_why,
            });
            continue;
        }
        syntax_errors.push(Diagnostic {
            file: file.to_string(),
            line: c.line,
            rule: RULE_ALLOW_SYNTAX,
            message: format!(
                "unrecognized flowlint directive: {:?}",
                body.split_whitespace().next().unwrap_or("")
            ),
        });
    }
    Directives { allows, hot_path_markers, syntax_errors }
}

impl Directives {
    /// True when `rule` is allowed (with justification) on `line`.
    fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows.iter().any(|a| {
            a.has_why && a.rule == rule && a.targets.contains(&line)
        })
    }
}

// ---------------------------------------------------------------------
// Structure passes: fn spans, #[cfg(test)] mod regions
// ---------------------------------------------------------------------

#[derive(Debug)]
struct FnSpan {
    /// Line of the `fn` keyword.
    sig_line: usize,
    /// Token index of the body's opening `{`.
    body_start: usize,
    /// Token index of the matching `}`.
    body_end: usize,
}

/// Every `fn` with its body token span (brace-matched).
fn fn_spans(tokens: &[Token]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i].tok == Tok::Ident("fn".to_string()) {
            let sig_line = tokens[i].line;
            // Find the body's `{`: first `{` after the signature's
            // parameter list closes (paren/bracket/angle depth 0).
            let mut j = i + 1;
            let mut paren = 0i64;
            let mut body = None;
            while j < tokens.len() {
                match tokens[j].tok {
                    Tok::Punct('(') | Tok::Punct('[') => paren += 1,
                    Tok::Punct(')') | Tok::Punct(']') => paren -= 1,
                    Tok::Punct('{') if paren == 0 => {
                        body = Some(j);
                        break;
                    }
                    // A `;` at depth 0 ends a bodyless fn (trait
                    // method declaration, extern).
                    Tok::Punct(';') if paren == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if let Some(start) = body {
                let end = match_brace(tokens, start);
                spans.push(FnSpan { sig_line, body_start: start, body_end: end });
            }
            i = j.max(i + 1);
            continue;
        }
        i += 1;
    }
    spans
}

/// Index of the `}` matching the `{` at `open` (last token if
/// unbalanced).
fn match_brace(tokens: &[Token], open: usize) -> usize {
    let mut depth = 0i64;
    for (j, t) in tokens.iter().enumerate().skip(open) {
        match t.tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    tokens.len().saturating_sub(1)
}

/// Token ranges of `#[cfg(test)] mod <name> { ... }` bodies.
fn test_mod_spans(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 6 < tokens.len() {
        let is_cfg_test = tokens[i].tok == Tok::Punct('#')
            && tokens[i + 1].tok == Tok::Punct('[')
            && tokens[i + 2].tok == Tok::Ident("cfg".to_string())
            && tokens[i + 3].tok == Tok::Punct('(')
            && tokens[i + 4].tok == Tok::Ident("test".to_string())
            && tokens[i + 5].tok == Tok::Punct(')')
            && tokens[i + 6].tok == Tok::Punct(']');
        if is_cfg_test {
            // Allow further attributes between the cfg and the item.
            let mut j = i + 7;
            while j < tokens.len() && tokens[j].tok == Tok::Punct('#') {
                if tokens.get(j + 1).map(|t| &t.tok) == Some(&Tok::Punct('['))
                {
                    let mut depth = 0i64;
                    while j < tokens.len() {
                        match tokens[j].tok {
                            Tok::Punct('[') => depth += 1,
                            Tok::Punct(']') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    j += 1;
                } else {
                    break;
                }
            }
            if matches!(&tokens.get(j).map(|t| &t.tok), Some(Tok::Ident(k)) if k == "mod")
            {
                // mod <name> { ... }
                let mut k = j + 1;
                while k < tokens.len()
                    && tokens[k].tok != Tok::Punct('{')
                    && tokens[k].tok != Tok::Punct(';')
                {
                    k += 1;
                }
                if k < tokens.len() && tokens[k].tok == Tok::Punct('{') {
                    let end = match_brace(tokens, k);
                    spans.push((k, end));
                    i = k + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], idx: usize) -> bool {
    spans.iter().any(|&(a, b)| idx >= a && idx <= b)
}

// ---------------------------------------------------------------------
// Rule: atomics-ordering
// ---------------------------------------------------------------------

const ATOMIC_OPS: &[&str] = &[
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_nand",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

const ORDERINGS: &[&str] =
    &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

struct AtomicSite {
    line: usize,
    field: String,
    orderings: Vec<String>,
}

fn atomic_sites(tokens: &[Token]) -> Vec<AtomicSite> {
    let mut sites = Vec::new();
    let mut i = 1usize;
    while i + 1 < tokens.len() {
        let is_op = tokens[i - 1].tok == Tok::Punct('.')
            && matches!(&tokens[i].tok, Tok::Ident(n) if ATOMIC_OPS.contains(&n.as_str()))
            && tokens[i + 1].tok == Tok::Punct('(');
        if !is_op {
            i += 1;
            continue;
        }
        // Receiver: the token before the `.` — a field name, a static,
        // or a tuple index.  Method chains / index expressions ending
        // in `)` / `]` give an anonymous receiver; those sites cannot
        // be grouped and are skipped.
        let field = match tokens.get(i.wrapping_sub(2)).map(|t| &t.tok) {
            Some(Tok::Ident(n)) => Some(n.clone()),
            Some(Tok::Num(n)) => Some(n.clone()),
            _ => None,
        };
        // Collect ordering idents inside the argument list.
        let mut depth = 0i64;
        let mut j = i + 1;
        let mut orderings = Vec::new();
        while j < tokens.len() {
            match &tokens[j].tok {
                Tok::Punct('(') => depth += 1,
                Tok::Punct(')') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                Tok::Ident(n) if ORDERINGS.contains(&n.as_str()) => {
                    orderings.push(n.clone());
                }
                _ => {}
            }
            j += 1;
        }
        if let Some(field) = field {
            if !orderings.is_empty() {
                sites.push(AtomicSite {
                    line: tokens[i].line,
                    field,
                    orderings,
                });
            }
        }
        i = j.max(i + 1);
    }
    sites
}

fn check_atomics(
    file: &str,
    tokens: &[Token],
    directives: &Directives,
    out: &mut Vec<Diagnostic>,
) {
    let sites = atomic_sites(tokens);
    let mut by_field: BTreeMap<&str, Vec<&AtomicSite>> = BTreeMap::new();
    for s in &sites {
        by_field.entry(s.field.as_str()).or_default().push(s);
    }
    for (field, group) in by_field {
        let strongest: Vec<&str> = {
            let mut v: Vec<&str> = group
                .iter()
                .flat_map(|s| s.orderings.iter())
                .filter(|o| o.as_str() != "Relaxed")
                .map(|o| o.as_str())
                .collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        if strongest.is_empty() {
            continue; // all-Relaxed field: consistent by construction
        }
        for site in group {
            let relaxed_only =
                site.orderings.iter().all(|o| o == "Relaxed");
            if !relaxed_only {
                continue;
            }
            if directives.allowed(RULE_ATOMICS, site.line) {
                continue;
            }
            out.push(Diagnostic {
                file: file.to_string(),
                line: site.line,
                rule: RULE_ATOMICS,
                message: format!(
                    "Ordering::Relaxed on `{field}` conflicts with \
                     {} used on the same field in this file",
                    strongest.join("/")
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// Rule: lock-discipline
// ---------------------------------------------------------------------

/// Methods a live guard must never span: actor/caster sends and the
/// completion-queue timed wait.
const SEND_METHODS: &[&str] = &[
    "cast",
    "try_cast",
    "call",
    "call_deferred",
    "try_call_deferred",
    "call_into",
    "broadcast",
    "broadcast_sync",
    "pop_timeout",
];

#[derive(Debug)]
struct LiveGuard {
    name: String,
    /// Brace depth the guard's scope lives at; it dies when the walk
    /// drops below this depth.
    depth: i64,
    line: usize,
}

fn check_lock_discipline(
    file: &str,
    tokens: &[Token],
    directives: &Directives,
    out: &mut Vec<Diagnostic>,
) {
    let mut guards: Vec<LiveGuard> = Vec::new();
    let mut depth = 0i64;
    let mut i = 0usize;
    while i < tokens.len() {
        match &tokens[i].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
            }
            Tok::Ident(kw) if kw == "let" => {
                if let Some((guard, next)) =
                    parse_let_lock(tokens, i, depth)
                {
                    // Shadowing rebind kills the old guard.
                    guards.retain(|g| g.name != guard.name);
                    guards.push(guard);
                    i = next;
                    continue;
                }
                // A plain `let` rebinding a guard name releases it.
                if let Some(name) = let_binding_name(tokens, i) {
                    guards.retain(|g| g.name != name);
                }
            }
            Tok::Ident(kw) if kw == "drop" => {
                // drop(name)
                if tokens.get(i + 1).map(|t| &t.tok)
                    == Some(&Tok::Punct('('))
                {
                    if let Some(Tok::Ident(name)) =
                        tokens.get(i + 2).map(|t| &t.tok)
                    {
                        if tokens.get(i + 3).map(|t| &t.tok)
                            == Some(&Tok::Punct(')'))
                        {
                            guards.retain(|g| &g.name != name);
                        }
                    }
                }
            }
            Tok::Ident(m)
                if SEND_METHODS.contains(&m.as_str())
                    && i > 0
                    && tokens[i - 1].tok == Tok::Punct('.')
                    && tokens.get(i + 1).map(|t| &t.tok)
                        == Some(&Tok::Punct('(')) =>
            {
                if !guards.is_empty() {
                    let line = tokens[i].line;
                    if !directives.allowed(RULE_LOCK, line) {
                        let held: Vec<String> = guards
                            .iter()
                            .map(|g| {
                                format!("`{}` (line {})", g.name, g.line)
                            })
                            .collect();
                        out.push(Diagnostic {
                            file: file.to_string(),
                            line,
                            rule: RULE_LOCK,
                            message: format!(
                                ".{m}() with lock guard {} still live",
                                held.join(", ")
                            ),
                        });
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
}

/// Last plain ident of the binding pattern between `let` and `=`
/// (`let mut cells` -> `cells`, `if let Ok(mut g)` is entered at its
/// `let`).  `None` when no `=` closes the pattern nearby.
fn let_binding_name(tokens: &[Token], let_idx: usize) -> Option<String> {
    let mut name = None;
    let mut j = let_idx + 1;
    while j < tokens.len() {
        match &tokens[j].tok {
            Tok::Punct('=') => return name,
            Tok::Punct(';') | Tok::Punct('{') => return None,
            Tok::Ident(n)
                if n != "mut" && n != "ref" && n != "else" =>
            {
                name = Some(n.clone());
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// Parse `let <pat> = <expr-with-.lock(>` starting at `let_idx`.
/// Returns the guard plus the token index to resume at (the statement
/// terminator), or `None` when the initializer takes no lock.
fn parse_let_lock(
    tokens: &[Token],
    let_idx: usize,
    depth: i64,
) -> Option<(LiveGuard, usize)> {
    let name = let_binding_name(tokens, let_idx)?;
    // Find the `=`.
    let mut j = let_idx + 1;
    while j < tokens.len() && tokens[j].tok != Tok::Punct('=') {
        if matches!(tokens[j].tok, Tok::Punct(';') | Tok::Punct('{')) {
            return None;
        }
        j += 1;
    }
    // Scan the initializer to its terminator: `;` at nesting 0 for a
    // plain let, `{` at nesting 0 for `if/while let`.
    let mut nest = 0i64;
    let mut has_lock = false;
    let mut k = j + 1;
    let mut if_let = false;
    while k < tokens.len() {
        match &tokens[k].tok {
            Tok::Punct('(') | Tok::Punct('[') => nest += 1,
            Tok::Punct(')') | Tok::Punct(']') => nest -= 1,
            Tok::Punct(';') if nest == 0 => break,
            Tok::Punct('{') if nest == 0 => {
                if_let = true;
                break;
            }
            Tok::Ident(m)
                if m == "lock"
                    && k > 0
                    && tokens[k - 1].tok == Tok::Punct('.')
                    && tokens.get(k + 1).map(|t| &t.tok)
                        == Some(&Tok::Punct('(')) =>
            {
                has_lock = true;
            }
            _ => {}
        }
        k += 1;
    }
    if !has_lock {
        return None;
    }
    // An `if let`'s guard lives inside the following block only.
    let guard_depth = if if_let { depth + 1 } else { depth };
    Some((
        LiveGuard { name, depth: guard_depth, line: tokens[let_idx].line },
        k,
    ))
}

// ---------------------------------------------------------------------
// Rule: hot-path-alloc
// ---------------------------------------------------------------------

fn check_hot_path(
    file: &str,
    tokens: &[Token],
    directives: &Directives,
    out: &mut Vec<Diagnostic>,
) {
    if directives.hot_path_markers.is_empty() {
        return;
    }
    let spans = fn_spans(tokens);
    for &marker in &directives.hot_path_markers {
        // The marked fn: first fn signature at or past the marker line.
        let Some(span) = spans
            .iter()
            .filter(|s| s.sig_line >= marker)
            .min_by_key(|s| s.sig_line)
        else {
            out.push(Diagnostic {
                file: file.to_string(),
                line: marker,
                rule: RULE_HOT_PATH,
                message: "hot-path marker with no following fn"
                    .to_string(),
            });
            continue;
        };
        scan_alloc_tokens(file, tokens, span, directives, out);
    }
}

fn scan_alloc_tokens(
    file: &str,
    tokens: &[Token],
    span: &FnSpan,
    directives: &Directives,
    out: &mut Vec<Diagnostic>,
) {
    let mut report = |line: usize, what: &str| {
        if !directives.allowed(RULE_HOT_PATH, line) {
            out.push(Diagnostic {
                file: file.to_string(),
                line,
                rule: RULE_HOT_PATH,
                message: format!(
                    "{what} inside a `// flowlint: hot-path` function"
                ),
            });
        }
    };
    let toks = &tokens[span.body_start..=span.body_end.min(tokens.len() - 1)];
    let mut i = 0usize;
    while i < toks.len() {
        let line = toks[i].line;
        match &toks[i].tok {
            Tok::Ident(n) if n == "Vec" || n == "Box" || n == "String" => {
                // Vec::new / Box::new / String::new / String::from
                if toks.get(i + 1).map(|t| &t.tok) == Some(&Tok::Punct(':'))
                    && toks.get(i + 2).map(|t| &t.tok)
                        == Some(&Tok::Punct(':'))
                {
                    if let Some(Tok::Ident(m)) =
                        toks.get(i + 3).map(|t| &t.tok)
                    {
                        if m == "new" || (n == "String" && m == "from") {
                            report(line, &format!("{n}::{m}"));
                            i += 4;
                            continue;
                        }
                    }
                }
            }
            Tok::Ident(n) if n == "vec" || n == "format" => {
                if toks.get(i + 1).map(|t| &t.tok) == Some(&Tok::Punct('!'))
                {
                    report(line, &format!("{n}!"));
                    i += 2;
                    continue;
                }
            }
            Tok::Ident(n)
                if (n == "to_vec" || n == "to_string" || n == "clone")
                    && i > 0
                    && toks[i - 1].tok == Tok::Punct('.')
                    && toks.get(i + 1).map(|t| &t.tok)
                        == Some(&Tok::Punct('(')) =>
            {
                // `.clone()` only with an empty argument list; to_vec /
                // to_string always.
                let flag = if n == "clone" {
                    toks.get(i + 2).map(|t| &t.tok)
                        == Some(&Tok::Punct(')'))
                } else {
                    true
                };
                if flag {
                    report(line, &format!(".{n}()"));
                }
            }
            _ => {}
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------
// Rule: failpoint-coverage
// ---------------------------------------------------------------------

/// Send tokens that must sit behind a `faults::` failpoint when they
/// appear in `actor/` (outside the fault plane and the mailbox
/// primitive itself).
const RAW_SEND_METHODS: &[&str] =
    &["send", "try_send", "cast", "try_cast"];

fn check_failpoint_coverage(
    file: &str,
    tokens: &[Token],
    directives: &Directives,
    out: &mut Vec<Diagnostic>,
) {
    let base = file.rsplit('/').next().unwrap_or(file);
    let in_actor = file.starts_with("actor/") || file == "actor.rs";
    // mailbox.rs implements the send primitive; faults.rs is the
    // plane itself; tags.rs holds no sends.
    if !in_actor || base == "mailbox.rs" || base == "faults.rs" {
        return;
    }
    let spans = fn_spans(tokens);
    let tests = test_mod_spans(tokens);
    for i in 1..tokens.len() {
        let is_send = tokens[i - 1].tok == Tok::Punct('.')
            && matches!(&tokens[i].tok, Tok::Ident(n) if RAW_SEND_METHODS.contains(&n.as_str()))
            && tokens.get(i + 1).map(|t| &t.tok) == Some(&Tok::Punct('('));
        if !is_send || in_spans(&tests, i) {
            continue;
        }
        // Innermost enclosing fn.
        let Some(span) = spans
            .iter()
            .filter(|s| s.body_start <= i && i <= s.body_end)
            .min_by_key(|s| s.body_end - s.body_start)
        else {
            continue;
        };
        // A `faults::` path anywhere earlier in the same fn counts as
        // the gate (the failpoint precedes the send on every path the
        // runtime uses; finer flow analysis is not worth a parser).
        let gated = (span.body_start..i).any(|j| {
            matches!(&tokens[j].tok, Tok::Ident(n) if n == "faults")
                && tokens.get(j + 1).map(|t| &t.tok)
                    == Some(&Tok::Punct(':'))
                && tokens.get(j + 2).map(|t| &t.tok)
                    == Some(&Tok::Punct(':'))
        });
        if gated {
            continue;
        }
        let line = tokens[i].line;
        if directives.allowed(RULE_FAILPOINT, line) {
            continue;
        }
        let m = match &tokens[i].tok {
            Tok::Ident(n) => n.clone(),
            _ => unreachable!(),
        };
        out.push(Diagnostic {
            file: file.to_string(),
            line,
            rule: RULE_FAILPOINT,
            message: format!(
                ".{m}() send site without a faults:: failpoint in the \
                 same function"
            ),
        });
    }
}

// ---------------------------------------------------------------------
// Rule: epoch-tag
// ---------------------------------------------------------------------

/// The one file allowed to do tag-shift arithmetic.
pub const TAGS_FILE: &str = "actor/tags.rs";

fn check_epoch_tag(
    file: &str,
    tokens: &[Token],
    directives: &Directives,
    out: &mut Vec<Diagnostic>,
) {
    if file == TAGS_FILE {
        return;
    }
    for i in 2..tokens.len() {
        let shift = (tokens[i - 2].tok == Tok::Punct('<')
            && tokens[i - 1].tok == Tok::Punct('<'))
            || (tokens[i - 2].tok == Tok::Punct('>')
                && tokens[i - 1].tok == Tok::Punct('>'));
        if !shift {
            continue;
        }
        let operand = match &tokens[i].tok {
            Tok::Num(n) if n == "16" => "16",
            Tok::Ident(n) if n == "EPOCH_SHIFT" => "EPOCH_SHIFT",
            _ => continue,
        };
        let line = tokens[i].line;
        if directives.allowed(RULE_EPOCH_TAG, line) {
            continue;
        }
        out.push(Diagnostic {
            file: file.to_string(),
            line,
            rule: RULE_EPOCH_TAG,
            message: format!(
                "manual tag arithmetic (shift by {operand}); use \
                 actor::tags::{{encode_tag, decode_tag}}"
            ),
        });
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Lint one file's source.  `rel_path` is the path relative to the
/// lint root (e.g. `actor/registry.rs`) — it selects the per-file rule
/// scoping (failpoint coverage in `actor/`, the tags-file exemption).
pub fn lint_file_content(rel_path: &str, src: &str) -> Vec<Diagnostic> {
    let rel = rel_path.replace('\\', "/");
    let lexed = lex(src);
    let directives = parse_directives(&rel, &lexed);
    let mut out = Vec::new();
    out.extend(directives.syntax_errors.iter().cloned());
    check_atomics(&rel, &lexed.tokens, &directives, &mut out);
    check_lock_discipline(&rel, &lexed.tokens, &directives, &mut out);
    check_hot_path(&rel, &lexed.tokens, &directives, &mut out);
    check_failpoint_coverage(&rel, &lexed.tokens, &directives, &mut out);
    check_epoch_tag(&rel, &lexed.tokens, &directives, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Recursively lint every `.rs` file under `root`, returning
/// diagnostics with root-relative paths.
pub fn lint_tree(
    root: &std::path::Path,
) -> std::io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&f)?;
        out.extend(lint_file_content(&rel, &src));
    }
    Ok(out)
}

fn collect_rs_files(
    dir: &std::path::Path,
    out: &mut Vec<std::path::PathBuf>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Render diagnostics as a JSON array (machine-readable `--json` mode;
/// hand-rolled — no serde in an offline build).
pub fn to_json(diags: &[Diagnostic]) -> String {
    let mut s = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n  {{\"file\": {}, \"line\": {}, \"rule\": {}, \
             \"message\": {}}}",
            json_str(&d.file),
            d.line,
            json_str(d.rule),
            json_str(&d.message)
        ));
    }
    if !diags.is_empty() {
        s.push('\n');
    }
    s.push(']');
    s
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
