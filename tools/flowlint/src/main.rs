//! flowlint CLI.
//!
//! ```text
//! flowlint [--json] [ROOT]
//! ```
//!
//! Lints every `.rs` file under ROOT (default: `rust/src`, resolved
//! against the current directory).  Exit codes: 0 = clean, 1 = at
//! least one non-allowed violation, 2 = usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                eprintln!("usage: flowlint [--json] [ROOT]");
                return ExitCode::from(0);
            }
            a if a.starts_with('-') => {
                eprintln!("flowlint: unknown flag {a:?}");
                return ExitCode::from(2);
            }
            a => {
                if root.is_some() {
                    eprintln!("flowlint: more than one ROOT given");
                    return ExitCode::from(2);
                }
                root = Some(PathBuf::from(a));
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from("rust/src"));
    if !root.is_dir() {
        eprintln!("flowlint: {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    let diags = match flowlint::lint_tree(&root) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("flowlint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", flowlint::to_json(&diags));
    } else {
        for d in &diags {
            println!("{d}");
        }
        if diags.is_empty() {
            eprintln!("flowlint: clean ({})", root.display());
        } else {
            eprintln!("flowlint: {} violation(s)", diags.len());
        }
    }
    if diags.is_empty() {
        ExitCode::from(0)
    } else {
        ExitCode::from(1)
    }
}
