#!/usr/bin/env bash
# CI entry point: format, lint, build, test, bench smoke-run, bench
# schema validation.
#
#   tools/ci.sh           # run everything (includes --smoke + validator)
#   tools/ci.sh --quick   # skip release build, bench build/run (fmt +
#                         # clippy + tests + validator)
#   tools/ci.sh --smoke   # also *execute* every bench binary with tiny
#                         # iteration counts (implied by the full run)
#
# Benches are plain `fn main()` reporters; the smoke run executes each
# of them with `-- --smoke` so their mains cannot bit-rot silently.
# Benches that need the AOT artifacts skip themselves cleanly when
# `rust/artifacts/manifest.json` is absent.  Full measured runs stay
# manual, e.g. `cargo bench --bench actor_mailbox -- --write` to
# refresh BENCH_actor_mailbox.json on a real machine.

set -euo pipefail
cd "$(dirname "$0")/.."
repo_root="$(pwd)"
cd rust

quick=0
smoke=0
for arg in "$@"; do
  case "$arg" in
    --quick) quick=1 ;;
    --smoke) smoke=1 ;;
    *) echo "unknown argument: $arg" >&2; exit 2 ;;
  esac
done
# The default full run includes the smoke pass.
if [ "$quick" -eq 0 ]; then
  smoke=1
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy (warnings are errors)"
cargo clippy --all-targets -- -D warnings

if [ "$quick" -eq 0 ]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

if [ "$quick" -eq 0 ]; then
  echo "==> cargo build --benches --release"
  cargo build --benches --release
fi

if [ "$smoke" -eq 1 ]; then
  # Derived from the bench sources so a newly added reporter can never
  # be silently excluded from the smoke gate.
  for f in benches/*.rs; do
    b="$(basename "$f" .rs)"
    echo "==> bench smoke: $b"
    cargo bench --bench "$b" -- --smoke
  done
fi

echo "==> validate BENCH_*.json schemas"
python3 "$repo_root/tools/validate_bench.py" "$repo_root"/BENCH_*.json

echo "CI OK"
