#!/usr/bin/env bash
# CI entry point: format, lint, build, test.
#
#   tools/ci.sh           # run everything
#   tools/ci.sh --quick   # skip the release build (fmt + clippy + tests)
#
# Benches are built but not run (they are plain `fn main()` reporters;
# run them explicitly, e.g. `cargo bench --bench actor_mailbox -- --write`
# to refresh BENCH_actor_mailbox.json on a real machine).

set -euo pipefail
cd "$(dirname "$0")/../rust"

quick=0
for arg in "$@"; do
  case "$arg" in
    --quick) quick=1 ;;
    *) echo "unknown argument: $arg" >&2; exit 2 ;;
  esac
done

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy (warnings are errors)"
cargo clippy --all-targets -- -D warnings

if [ "$quick" -eq 0 ]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

if [ "$quick" -eq 0 ]; then
  echo "==> cargo build --benches --release"
  cargo build --benches --release
fi

echo "CI OK"
