#!/usr/bin/env bash
# CI entry point: format, lint, build, test, bench smoke-run, bench
# schema validation, chaos soak.
#
#   tools/ci.sh           # run everything (includes --smoke + validator)
#   tools/ci.sh --quick   # skip release build, bench build/run (fmt +
#                         # clippy + tests + validator).  Fails if the
#                         # run exceeds ${CI_QUICK_BUDGET_SECS:-1200}
#                         # wall-clock seconds, so the per-PR gate stays
#                         # fast as the crate grows.
#   tools/ci.sh --smoke   # also *execute* every bench binary with tiny
#                         # iteration counts (implied by the full run)
#   tools/ci.sh --lint    # run ONLY the flowlint invariant scan (plus
#                         # the linter's own fixture tests when cargo is
#                         # available): atomics-ordering, lock-discipline,
#                         # hot-path-alloc, failpoint-coverage, epoch-tag
#                         # over rust/src.  Non-zero exit on any
#                         # violation.  Falls back to the dependency-free
#                         # python mirror (tools/flowlint/mirror.py) on
#                         # machines without a rust toolchain, so the
#                         # gate is runnable everywhere.
#   tools/ci.sh --sanitize# run ONLY the sanitizer pass: ThreadSanitizer
#                         # and Miri over the actor/, offline/, and iter/
#                         # test suites.  Both need a nightly toolchain
#                         # (TSan additionally rust-src, Miri the miri
#                         # component); the script skips each leg cleanly
#                         # — exit 0 with a message — when its
#                         # prerequisite is missing, so the pass is safe
#                         # to wire into any environment.
#   tools/ci.sh --chaos   # run ONLY the chaos soaks in release mode
#                         # under hard timeouts: the elastic scale-out
#                         # soak (rust/tests/scale_out.rs, #[ignore]d
#                         # grow-2->8-while-killing-one-per-round), the
#                         # autoscale soak (rust/tests/autoscale.rs,
#                         # #[ignore]d idle->grow / busy->shrink
#                         # controller convergence), and the fault-matrix
#                         # soaks (rust/tests/faults.rs, #[ignore]d
#                         # scripted delay/drop/crash/hang mix under
#                         # deadline supervision + RestartPolicy, plus
#                         # rotating replay-shard kills under live
#                         # store+replay traffic), and the gateway churn
#                         # soak (rust/tests/gateway.rs, #[ignore]d
#                         # client connect/disconnect/timeout-mid-
#                         # episode swarm under live shard
#                         # kill/grow/retire), and the torn-log soak
#                         # (rust/tests/offline.rs, #[ignore]d writer
#                         # kill-restart mid-frame under a live tailing
#                         # reader: exactly-once in-order delivery)
#
# Every step prints its own wall-clock seconds (==> ... [Ns]) so a slow
# gate names the stage that slowed down.
#
# Benches are plain `fn main()` reporters; the smoke run executes each
# of them with `-- --smoke` so their mains cannot bit-rot silently.
# Benches that need the AOT artifacts skip themselves cleanly when
# `rust/artifacts/manifest.json` is absent.  Full measured runs stay
# manual, e.g. `cargo bench --bench actor_mailbox -- --write` to
# refresh BENCH_actor_mailbox.json on a real machine.

set -euo pipefail
cd "$(dirname "$0")/.."
repo_root="$(pwd)"
cd rust

quick=0
smoke=0
chaos=0
lint=0
sanitize=0
for arg in "$@"; do
  case "$arg" in
    --quick) quick=1 ;;
    --smoke) smoke=1 ;;
    --chaos) chaos=1 ;;
    --lint) lint=1 ;;
    --sanitize) sanitize=1 ;;
    *) echo "unknown argument: $arg" >&2; exit 2 ;;
  esac
done
# The default full run includes the smoke pass and the lint scan.
if [ "$quick" -eq 0 ] && [ "$chaos" -eq 0 ] && [ "$lint" -eq 0 ] \
  && [ "$sanitize" -eq 0 ]; then
  smoke=1
  lint=1
fi

ci_start=$SECONDS

# step <label> <cmd...>: run a stage and report its wall-clock cost.
step() {
  local label="$1"
  shift
  echo "==> $label"
  local t0=$SECONDS
  "$@"
  echo "==> $label [$((SECONDS - t0))s]"
}

# The flowlint stage: project-invariant static analysis over rust/src
# (atomics-ordering, lock-discipline, hot-path-alloc, failpoint-coverage,
# epoch-tag — see docs/static_analysis.md).  The canonical linter is the
# dependency-free rust binary in tools/flowlint; its line-for-line
# python mirror keeps the gate runnable on machines without cargo.
lint_stage() {
  if command -v cargo >/dev/null 2>&1; then
    step "flowlint: linter unit + fixture tests" \
      cargo test --quiet \
      --manifest-path "$repo_root/tools/flowlint/Cargo.toml"
    step "flowlint: invariant scan over rust/src" \
      cargo run --quiet \
      --manifest-path "$repo_root/tools/flowlint/Cargo.toml" -- \
      "$repo_root/rust/src"
  else
    step "flowlint (python mirror): invariant scan over rust/src" \
      python3 "$repo_root/tools/flowlint/mirror.py" "$repo_root/rust/src"
  fi
}

if [ "$lint" -eq 1 ] && [ "$quick" -eq 0 ] && [ "$smoke" -eq 0 ] \
  && [ "$chaos" -eq 0 ] && [ "$sanitize" -eq 0 ]; then
  # --lint alone: run just the scan (no cargo fmt/clippy/test), so the
  # gate works even where the rust toolchain is absent.
  lint_stage
  echo "CI OK (lint) [$((SECONDS - ci_start))s]"
  exit 0
fi

if [ "$sanitize" -eq 1 ]; then
  # The sanitizer pass is nightly-only by construction (TSan is
  # -Zsanitizer, Miri is a rustup component).  Each leg checks its own
  # prerequisite and skips with a message instead of failing, so this
  # mode is safe to invoke from any environment or cron job.
  if ! cargo +nightly -V >/dev/null 2>&1; then
    echo "sanitize: no nightly toolchain; skipping (install with:" \
      "rustup toolchain install nightly --component miri rust-src)"
    exit 0
  fi
  host="$(rustc +nightly -vV | sed -n 's/^host: //p')"
  if rustup component list --toolchain nightly 2>/dev/null \
    | grep -q '^rust-src.*(installed)'; then
    # -Zbuild-std so std itself is instrumented — without it TSan
    # reports races it cannot see into.  Scoped to the concurrency
    # suites: the mailbox/registry/caster/fault plane (actor::), the
    # log writer/reader pair (offline::), and the gather operators
    # (iter::).
    step "TSan (nightly): actor:: offline:: iter:: unit tests" \
      env RUSTFLAGS="-Zsanitizer=thread" \
      cargo +nightly test -Zbuild-std --target "$host" --lib -- \
      actor:: offline:: iter::
  else
    echo "sanitize: rust-src not installed for nightly; skipping TSan"
  fi
  if cargo +nightly miri --version >/dev/null 2>&1; then
    # Miri interprets every instruction, so the threaded tests (the
    # mailbox soaks, iter::par, iter::union) are too slow for it; this
    # slice covers the single-threaded core — the tag codec, the log
    # writer/reader with its wire framing, the local iterator algebra —
    # where UB would hide from TSan too.
    # -Zmiri-disable-isolation: the offline tests touch real tempdirs.
    step "Miri (nightly): actor::tags:: offline:: iter::local:: tests" \
      env MIRIFLAGS="-Zmiri-disable-isolation" \
      cargo +nightly miri test --lib -- \
      actor::tags:: offline:: iter::local::
  else
    echo "sanitize: miri not installed for nightly; skipping Miri"
  fi
  echo "CI OK (sanitize) [$((SECONDS - ci_start))s]"
  exit 0
fi

if [ "$chaos" -eq 1 ]; then
  # The chaos gate: build untimed (cache-dependent), then run the
  # #[ignore]d soaks under hard timeouts — each is designed to finish
  # well under 60s, so a hang is a failure, not a wait.
  step "cargo build --release --tests (chaos prebuild)" \
    cargo build --release --tests
  step "chaos soak: scale_out (grow 2->8 under kills, <60s)" \
    timeout 120 cargo test --release --test scale_out -- \
    --ignored --nocapture
  step "autoscale soak: controller converges (idle->grow, busy->shrink)" \
    timeout 120 cargo test --release --test autoscale -- \
    --ignored --nocapture
  step "fault-matrix soaks: delay/drop/crash/hang + replay-shard kills" \
    timeout 120 cargo test --release --test faults -- \
    --ignored --nocapture
  step "gateway churn soak: client swarm under shard kill/grow/retire" \
    timeout 120 cargo test --release --test gateway -- \
    --ignored --nocapture
  step "torn-log soak: writer kill-restart mid-frame under live reader" \
    timeout 120 cargo test --release --test offline -- \
    --ignored --nocapture
  echo "CI OK (chaos) [$((SECONDS - ci_start))s]"
  exit 0
fi

step "cargo fmt --check" cargo fmt --check

step "cargo clippy (warnings are errors)" \
  cargo clippy --all-targets -- -D warnings

if [ "$lint" -eq 1 ]; then
  lint_stage
fi

if [ "$quick" -eq 0 ]; then
  step "cargo build --release" cargo build --release
fi

step "cargo test -q" cargo test -q

if [ "$quick" -eq 0 ]; then
  step "cargo build --benches --release" cargo build --benches --release
fi

if [ "$smoke" -eq 1 ]; then
  # Derived from the bench sources so a newly added reporter can never
  # be silently excluded from the smoke gate.
  for f in benches/*.rs; do
    b="$(basename "$f" .rs)"
    step "bench smoke: $b" cargo bench --bench "$b" -- --smoke
  done
fi

step "validate BENCH_*.json schemas" \
  python3 "$repo_root/tools/validate_bench.py" "$repo_root"/BENCH_*.json

elapsed=$((SECONDS - ci_start))
if [ "$quick" -eq 1 ]; then
  budget="${CI_QUICK_BUDGET_SECS:-1200}"
  if [ "$elapsed" -gt "$budget" ]; then
    echo "CI FAIL: --quick took ${elapsed}s, over the ${budget}s budget" \
      "(raise CI_QUICK_BUDGET_SECS only with a reason)" >&2
    exit 1
  fi
  echo "quick budget: ${elapsed}s of ${budget}s"
fi

echo "CI OK [${elapsed}s]"
