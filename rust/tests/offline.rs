//! Offline dataflow e2e — log durability (rotation, torn tails, corrupt
//! frames) and the full record → train-from-logs → off-policy-evaluate
//! loop, including the plan's "zero envs constructed" guarantee.
//! The `--ignored` soak kill-restarts a writer mid-frame repeatedly
//! under a live tailing reader (the torn-log chaos case wired into
//! `tools/ci.sh --chaos`).

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use flowrl::algorithms::{
    offline_dqn_plan, DqnConfig, EnvKind, OfflineDqnConfig, TrainerConfig,
};
use flowrl::env::{CartPole, Env};
use flowrl::offline::{
    EpisodeLogWriter, LogStreamReader, OfflineCounters, WriterConfig,
};
use flowrl::ops::{log_frames, ope_estimate};
use flowrl::policy::{ActionOutput, Gradients, Policy};
use flowrl::rollout::{CollectMode, RolloutWorker};
use flowrl::sample_batch::{wire, SampleBatchBuilder};
use flowrl::SampleBatch;

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("flowrl_offline_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn segment_path(dir: &Path, stream: &str, seq: u64) -> PathBuf {
    dir.join(format!("{stream}.{seq:06}.flog"))
}

/// A frame whose rewards[0] carries `marker` — lets the durability tests
/// assert exactly-once, in-order delivery by value.
fn marked_batch(marker: f32, rows: usize) -> SampleBatch {
    let mut b = SampleBatchBuilder::new(3);
    for i in 0..rows {
        b.add_transition_with_logp(
            &[marker, i as f32, 0.5],
            (i % 2) as i32,
            if i == 0 { marker } else { 0.0 },
            &[marker, i as f32 + 1.0, 0.5],
            i + 1 == rows,
            -0.69,
        );
    }
    b.build()
}

/// Drain every currently-readable frame from `reader`.
fn drain(reader: &mut LogStreamReader) -> Vec<SampleBatch> {
    let mut out = Vec::new();
    while let Some(b) = reader.poll() {
        out.push(b);
    }
    out
}

// ---------------------------------------------------------------------
// Durability
// ---------------------------------------------------------------------

/// Frames written across many rotated segments come back byte-exact and
/// in order through a reader that followed the stream live.
#[test]
fn roundtrip_across_rotation_is_exact_and_ordered() {
    let dir = tmp_dir("rotation");
    // Tiny segments: every couple of appends rotates.
    let mut w = EpisodeLogWriter::create(
        &dir,
        "rot",
        WriterConfig { segment_bytes: 512 },
    )
    .unwrap();
    let counters = OfflineCounters::new();
    let mut r = LogStreamReader::follow(&dir, "rot", counters.clone());

    let mut written = Vec::new();
    let mut read = Vec::new();
    for i in 0..30 {
        let b = marked_batch(i as f32, 6);
        w.append(&b).unwrap();
        written.push(b);
        // Interleave reads with writes: the reader crosses segment
        // boundaries while the writer is still appending.
        read.extend(drain(&mut r));
    }
    assert!(w.current_seq() >= 2, "segment_bytes=512 never rotated");
    read.extend(drain(&mut r));

    assert_eq!(read, written, "frames lost, reordered, or altered");
    let stats = counters.snapshot();
    assert_eq!(stats.frames, 30);
    assert_eq!(stats.corrupt_frames, 0);
    assert_eq!(stats.truncated_tails, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A crash mid-write leaves a truncated frame at the tail.  The reader
/// must wait on it (it could still be completed), never panic, never
/// re-deliver earlier frames — and once a restarted writer opens the
/// next segment, skip the torn tail exactly once and move on.
#[test]
fn torn_tail_waits_then_skips_on_rotation() {
    let dir = tmp_dir("torn");
    let seq0 = {
        let mut w = EpisodeLogWriter::create(
            &dir,
            "t",
            WriterConfig::default(),
        )
        .unwrap();
        w.append(&marked_batch(0.0, 4)).unwrap();
        w.append(&marked_batch(1.0, 4)).unwrap();
        w.current_seq()
    };
    // Simulate the crash: append half a frame to the closed segment.
    let mut frame = Vec::new();
    let mut payload = Vec::new();
    wire::encode_batch(&marked_batch(2.0, 4), &mut payload);
    wire::encode_frame(&payload, &mut frame);
    let torn = &frame[..frame.len() / 2];
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(segment_path(&dir, "t", seq0))
        .unwrap();
    f.write_all(torn).unwrap();
    f.sync_all().unwrap();
    drop(f);

    let counters = OfflineCounters::new();
    let mut r = LogStreamReader::follow(&dir, "t", counters.clone());
    let before = drain(&mut r);
    assert_eq!(before.len(), 2, "complete frames before the tear");
    // The torn tail is indistinguishable from an in-progress write:
    // repeated polls wait (None) without advancing or re-reading.
    for _ in 0..5 {
        assert!(r.poll().is_none());
    }
    assert_eq!(counters.snapshot().truncated_tails, 0);

    // Writer restart: a fresh writer never appends to a possibly-torn
    // tail — it opens the next segment, which is the reader's signal
    // that the tail will never complete.
    let mut w2 =
        EpisodeLogWriter::create(&dir, "t", WriterConfig::default()).unwrap();
    assert!(w2.current_seq() > seq0);
    w2.append(&marked_batch(3.0, 4)).unwrap();

    let after = drain(&mut r);
    assert_eq!(after.len(), 1);
    assert_eq!(after[0].rewards[0], 3.0, "frame after the tear");
    let stats = counters.snapshot();
    assert_eq!(stats.truncated_tails, 1, "torn tail counted once");
    assert_eq!(stats.frames, 3);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A frame whose payload rotted on disk fails its CRC: it is counted,
/// skipped in place (framing survives — the length word is intact), and
/// every other frame is still delivered.
#[test]
fn corrupt_crc_frame_is_counted_and_skipped() {
    let dir = tmp_dir("crc");
    let seq0 = {
        let mut w = EpisodeLogWriter::create(
            &dir,
            "c",
            WriterConfig::default(),
        )
        .unwrap();
        for i in 0..3 {
            w.append(&marked_batch(i as f32, 4)).unwrap();
        }
        w.current_seq()
    };
    // Flip one payload byte inside the middle frame.
    let path = segment_path(&dir, "c", seq0);
    let mut bytes = std::fs::read(&path).unwrap();
    let frame0_len = {
        let mut payload = Vec::new();
        wire::encode_batch(&marked_batch(0.0, 4), &mut payload);
        payload.len() + wire::FRAME_HEADER_BYTES
    };
    let target = frame0_len + wire::FRAME_HEADER_BYTES + 10;
    bytes[target] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let counters = OfflineCounters::new();
    let mut r = LogStreamReader::follow(&dir, "c", counters.clone());
    let frames = drain(&mut r);
    assert_eq!(frames.len(), 2);
    assert_eq!(frames[0].rewards[0], 0.0);
    assert_eq!(frames[1].rewards[0], 2.0, "frame past the rot delivered");
    let stats = counters.snapshot();
    assert_eq!(stats.corrupt_frames, 1);
    assert_eq!(stats.frames, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Record → offline-train → OPE
// ---------------------------------------------------------------------

/// Uniform-random behavior policy over 2 actions, with honest logps —
/// what a data-collection run with an untrained policy looks like.
struct UniformPolicy {
    rng: u64,
}

const LN_HALF: f32 = -std::f32::consts::LN_2;

impl UniformPolicy {
    fn next_bit(&mut self) -> i32 {
        self.rng = self
            .rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((self.rng >> 33) & 1) as i32
    }
}

impl Policy for UniformPolicy {
    fn compute_actions_into(
        &mut self,
        _obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    ) {
        out.clear();
        for _ in 0..n {
            out.push(ActionOutput {
                action: self.next_bit(),
                logp: LN_HALF,
                value: 0.0,
            });
        }
    }

    fn compute_gradients(&mut self, _batch: &SampleBatch) -> Gradients {
        Gradients { flat: Vec::new(), stats: BTreeMap::new(), count: 0 }
    }

    fn apply_gradients(&mut self, _grads: &Gradients) {}

    fn get_weights(&self) -> Vec<f32> {
        Vec::new()
    }

    fn set_weights(&mut self, _weights: &[f32]) {}
}

/// The acceptance loop: record CartPole experience under a logged
/// uniform behavior policy, train offline DQN from the logs with zero
/// env instances constructed, and check off-policy evaluation ranks a
/// known-better target policy above a uniform one on the same logs.
#[test]
fn record_train_zero_envs_and_ope_ranks_policies() {
    let dir = tmp_dir("e2e");

    // (1) Record: a live rollout worker with a log sink tapped in.
    {
        let envs: Vec<Box<dyn Env>> =
            (0..4).map(|i| Box::new(CartPole::new(i)) as Box<dyn Env>).collect();
        let mut worker = RolloutWorker::new(
            envs,
            Box::new(UniformPolicy { rng: 7 }),
            64,
            CollectMode::TransitionsWithLogp,
        );
        worker.set_log_sink(
            EpisodeLogWriter::create(&dir, "cartpole", WriterConfig::default())
                .unwrap(),
        );
        for _ in 0..24 {
            worker.sample();
        }
    }

    // (2) Train from the logs alone.  EnvKind::Dummy selects the dummy
    // policy (no XLA artifacts in CI) — what matters here is the
    // dataflow: logs → replay → learner, no env anywhere.
    let envs_before = flowrl::env::constructed_count();
    let config = TrainerConfig {
        env: EnvKind::Dummy,
        min_replay_shards: 1,
        ..TrainerConfig::default()
    };
    let dqn = DqnConfig {
        buffer_capacity: 8192,
        learning_starts: 128,
        target_update_every: 256,
        weight_sync_every: 5,
    };
    let offline = OfflineDqnConfig {
        log_dir: dir.clone(),
        obs_dim: 4,
        ..OfflineDqnConfig::default()
    };
    {
        let mut plan = offline_dqn_plan(&config, &dqn, &offline);
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut trained = 0u64;
        let mut ingested = 0u64;
        while (trained == 0 || ingested == 0) && Instant::now() < deadline {
            let report = plan.next().expect("plan is infinite");
            trained += report.num_env_steps_trained;
            if let Some(stats) = report.offline {
                ingested = stats.transitions;
                assert_eq!(stats.corrupt_frames, 0);
            }
        }
        assert!(trained > 0, "offline plan never trained");
        assert!(ingested > 0, "offline plan never ingested log frames");
    }
    assert_eq!(
        flowrl::env::constructed_count(),
        envs_before,
        "offline training constructed an environment"
    );

    // (3) OPE over the same logs: a heuristic balancing controller
    // (push toward the pole's fall) must out-rank a uniform target —
    // both scored against the logged uniform behavior policy.
    let heuristic = |obs: &[f32], action: i32| -> f64 {
        let prefer = if obs[2] + obs[3] > 0.0 { 1 } else { 0 };
        if action == prefer { 0.98f64.ln() } else { 0.02f64.ln() }
    };
    let uniform = |_obs: &[f32], _action: i32| -> f64 { 0.5f64.ln() };

    let good = ope_estimate(log_frames(&dir), heuristic, 1.0);
    let base = ope_estimate(log_frames(&dir), uniform, 1.0);
    assert!(good.episodes > 20, "too few episodes: {}", good.episodes);
    assert_eq!(good.episodes, base.episodes);
    // Uniform target == behavior: WIS must recover the logged return.
    assert!(
        (base.weighted_is - base.behavior_mean_return).abs()
            < 1e-6 * base.behavior_mean_return.abs().max(1.0),
        "uniform-target WIS {} != behavior mean {}",
        base.weighted_is,
        base.behavior_mean_return
    );
    assert!(
        good.weighted_is > base.weighted_is,
        "heuristic target not ranked above uniform: {} vs {}",
        good.weighted_is,
        base.weighted_is
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Chaos soak (tools/ci.sh --chaos)
// ---------------------------------------------------------------------

/// Kill-restart a writer mid-frame over many cycles while a live reader
/// tails the stream: every completed frame is delivered exactly once in
/// order, every torn tail is skipped exactly once, nothing panics.
#[test]
#[ignore]
fn chaos_torn_log_kill_restart_soak() {
    const CYCLES: usize = 25;
    const FRAMES_PER_CYCLE: usize = 8;
    let dir = tmp_dir("chaos");
    std::fs::create_dir_all(&dir).unwrap();

    let counters = OfflineCounters::new();
    let total = CYCLES * FRAMES_PER_CYCLE + 1;
    let reader_counters = counters.clone();
    let reader_dir = dir.clone();
    let reader = std::thread::spawn(move || {
        let mut r = LogStreamReader::follow(
            &reader_dir,
            "chaos",
            reader_counters,
        );
        let mut markers = Vec::with_capacity(total);
        let deadline = Instant::now() + Duration::from_secs(60);
        while markers.len() < total {
            match r.poll() {
                Some(b) => markers.push(b.rewards[0]),
                None => {
                    assert!(
                        Instant::now() < deadline,
                        "reader stalled at {}/{total} frames",
                        markers.len()
                    );
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
        markers
    });

    let mut marker = 0u32;
    for _ in 0..CYCLES {
        let (seq, torn) = {
            let mut w = EpisodeLogWriter::create(
                &dir,
                "chaos",
                WriterConfig::default(),
            )
            .unwrap();
            for _ in 0..FRAMES_PER_CYCLE {
                w.append(&marked_batch(marker as f32, 4)).unwrap();
                marker += 1;
            }
            // The frame the "crash" interrupts: never counted.
            let mut payload = Vec::new();
            wire::encode_batch(&marked_batch(9999.0, 4), &mut payload);
            let mut frame = Vec::new();
            wire::encode_frame(&payload, &mut frame);
            let cut = 1 + (marker as usize * 7) % (frame.len() - 2);
            frame.truncate(cut);
            (w.current_seq(), frame)
        };
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(segment_path(&dir, "chaos", seq))
            .unwrap();
        f.write_all(&torn).unwrap();
        drop(f);
    }
    // A final clean frame so the last torn tail resolves via rotation.
    EpisodeLogWriter::create(&dir, "chaos", WriterConfig::default())
        .unwrap()
        .append(&marked_batch(marker as f32, 4))
        .unwrap();

    let markers = reader.join().expect("reader thread panicked");
    let expect: Vec<f32> = (0..total).map(|i| i as f32).collect();
    assert_eq!(markers, expect, "frames lost, duplicated, or reordered");
    let stats = counters.snapshot();
    assert_eq!(
        stats.truncated_tails, CYCLES as u64,
        "every torn tail skipped exactly once"
    );
    assert_eq!(stats.corrupt_frames, 0);
    let _ = std::fs::remove_dir_all(&dir);
}
