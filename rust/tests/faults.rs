//! Fault-plane acceptance tests: a scripted permanent Hang wedges a
//! rollout shard mid-plan, deadline supervision declares it suspect,
//! force-poisons it through `ActorHandle::kill`, and the `RestartPolicy`
//! either brings up a replacement that rejoins the *running* gather or
//! — on a crash loop — trips the circuit breaker and tombstones the
//! slot.  The driver never wedges and never double-counts a completion.
//!
//! Fault rules are process-global and tests in one binary run
//! concurrently, so every test scopes its rules with a unique actor-name
//! prefix and clears them on exit.
//!
//! These run on the Dummy env/policy, so they need no AOT artifacts.

use std::time::{Duration, Instant};

use flowrl::actor::faults::{
    self, SITE_CASTER_LANE, SITE_ROLLOUT_SAMPLE,
};
use flowrl::actor::{
    FaultAction, FaultStats, WeightCaster, DEFAULT_CAST_WATERMARK,
};
use flowrl::env::{DummyEnv, Env};
use flowrl::iter::DeadlineSupervision;
use flowrl::ops::parallel_rollouts_from;
use flowrl::policy::DummyPolicy;
use flowrl::rollout::{
    CollectMode, RestartPolicy, RolloutWorker, WorkerSet,
};

/// A `WorkerSet` with caller-chosen actor names, so each test's fault
/// rules match only its own actors (mirrors `WorkerSet::new`, which
/// hard-codes `worker-{i}` — too broad for a shared binary).
fn worker_set(local: &str, prefix: &str, n_remote: usize) -> WorkerSet {
    let set = WorkerSet::with_protocol(
        local,
        prefix,
        n_remote,
        |_| {
            Box::new(|| {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    4,
                    CollectMode::OnPolicy,
                )
            })
        },
        |local, fresh| {
            let weights: std::sync::Arc<[f32]> = local
                .call(|w| w.get_weights())
                .map_err(|e| {
                    flowrl::util::error::Error::msg(format!(
                        "learner is dead ({e})"
                    ))
                })?
                .into();
            fresh.cast(move |w| w.set_weights(&weights));
            Ok(())
        },
    );
    set.register_caster(std::sync::Arc::new(WeightCaster::new(
        set.registry().clone(),
        DEFAULT_CAST_WATERMARK,
        |w: &mut RolloutWorker, p: &[f32]| w.set_weights(p),
    )));
    set
}

/// Tentpole acceptance, async side: a shard wedged by a permanent
/// `Hang` is detected by the dispatch deadline, written off, killed
/// into the poison path, and its restarted replacement rejoins the SAME
/// running gather — with no item lost, duplicated, or attributed to the
/// corpse.
#[test]
fn gather_async_survives_permanent_hang() {
    let set = worker_set("fia-learner", "fia-w", 2);
    // Shard 1 wedges inside sample() on its very first dispatch.
    let rule = faults::inject(
        SITE_ROLLOUT_SAMPLE,
        Some("fia-w-1"),
        FaultAction::Hang,
    );
    let victim = set.remote(1).expect("live remote");
    let sup = DeadlineSupervision::with_counters(
        Duration::from_millis(150),
        set.fault_counters(),
    );
    let mut it = parallel_rollouts_from(&set)
        .gather_async_with_source_deadline(1, sup);

    // The stream keeps flowing off the healthy shard while the wedged
    // one counts down to its deadline; the hung shard never completed a
    // dispatch, so every item comes from shard 0.
    let mut pulls = 0u32;
    while set.fault_stats().suspects == 0 {
        let (_batch, src) =
            it.next().expect("stream wedged behind the hung shard");
        assert_ne!(src.id(), victim.id(), "hung shard produced an item");
        pulls += 1;
        assert!(pulls < 100_000, "deadline never fired");
    }

    // Write-off force-killed the corpse: the hang panics into the
    // normal supervision path (poison + death notice).
    assert!(victim.await_poisoned(Duration::from_secs(2)));
    assert_eq!(set.poisoned_indices(), vec![1]);

    // Release the rule so the replacement comes up clean, then recover
    // under the default policy (first restart is immediate).
    assert!(faults::clear(rule));
    let report = set.restart_dead_with_policy(&RestartPolicy::default());
    assert_eq!(report.restarted, vec![1]);
    assert!(report.tripped.is_empty());
    let fresh = set.remote(1).expect("replacement published");
    assert_ne!(fresh.id(), victim.id());

    // The SAME running gather streams off the replacement; the corpse's
    // written-off completion (its death notice) is consumed by the
    // forgiveness ledger, never surfacing as an item.
    let mut fresh_items = 0;
    for _ in 0..64 {
        let (_batch, src) = it.next().expect("stream must keep flowing");
        assert_ne!(src.id(), victim.id(), "item attributed to the corpse");
        if src.id() == fresh.id() {
            fresh_items += 1;
        }
    }
    assert!(fresh_items > 0, "replacement never rejoined the gather");
    assert_eq!(
        set.fault_stats(),
        FaultStats { suspects: 1, forced_restarts: 1, breaker_trips: 0 }
    );
}

/// Tentpole acceptance, sync side: a barrier round degrades to the
/// surviving quorum when a shard hangs past the deadline, and returns
/// to full rounds once the replacement is published.
#[test]
fn gather_sync_survives_permanent_hang() {
    let set = worker_set("fis-learner", "fis-w", 2);
    let rule = faults::inject(
        SITE_ROLLOUT_SAMPLE,
        Some("fis-w-0"),
        FaultAction::Hang,
    );
    let victim = set.remote(0).expect("live remote");
    let sup = DeadlineSupervision::with_counters(
        Duration::from_millis(150),
        set.fault_counters(),
    );
    let mut it = parallel_rollouts_from(&set).gather_sync_deadline(sup);

    // Round 1: shard 0 hangs, the deadline fires, and the round
    // completes off the survivor instead of wedging the driver.
    let round = it.next().expect("round must complete");
    assert_eq!(round.len(), 1, "round did not degrade to the quorum");
    assert_eq!(set.fault_stats().suspects, 1);

    assert!(victim.await_poisoned(Duration::from_secs(2)));
    assert!(faults::clear(rule));
    let report = set.restart_dead_with_policy(&RestartPolicy::default());
    assert_eq!(report.restarted, vec![0]);

    // The replacement joins at the next round boundary: full rounds
    // again, through the same running iterator, and the corpse's
    // written-off completion never corrupts a later round's count.
    assert_eq!(it.next().expect("stream must keep flowing").len(), 2);
    assert_eq!(it.next().expect("stream must keep flowing").len(), 2);
    assert_eq!(
        set.fault_stats(),
        FaultStats { suspects: 1, forced_restarts: 1, breaker_trips: 0 }
    );
}

/// Satellite: a crash-looping worker — `PanicOnce` re-injected at every
/// restart — burns its per-slot budget and trips the circuit breaker:
/// the slot is tombstoned exactly once, the set keeps serving off the
/// survivor, and `add_worker` reclaims the retired slot with a fresh
/// budget.
#[test]
fn breaker_trips_within_budget_and_slot_is_reclaimed() {
    let set = worker_set("fib-learner", "fib-w", 2);
    let policy = RestartPolicy {
        max_restarts: 2,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        reset_after: Duration::from_secs(3600),
    };

    let crash = |set: &WorkerSet| {
        let rule = faults::inject(
            SITE_ROLLOUT_SAMPLE,
            Some("fib-w-0"),
            FaultAction::PanicOnce,
        );
        let h = set.remote(0).expect("live remote");
        assert!(h.call(|w| { w.sample(); }).is_err());
        assert!(h.await_poisoned(Duration::from_secs(2)));
        faults::clear(rule);
    };

    crash(&set);
    let mut restarts = 0;
    let mut tripped = false;
    let start = Instant::now();
    while !tripped {
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "breaker never tripped"
        );
        let report = set.restart_dead_with_policy(&policy);
        if report.restarted == vec![0] {
            restarts += 1;
            crash(&set); // the replacement crash-loops too
        } else if report.tripped == vec![0] {
            tripped = true;
        } else {
            // Inside the backoff window: deferred, not dropped.
            assert_eq!(report.deferred, vec![0]);
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    assert_eq!(restarts, policy.max_restarts);
    assert_eq!(
        set.fault_stats(),
        FaultStats { suspects: 0, forced_restarts: 2, breaker_trips: 1 }
    );

    // Tombstoned exactly once: the slot is gone (not dead), the
    // survivor serves, and another policy pass is a no-op.
    assert!(set.remote(0).is_none());
    assert!(set.poisoned_indices().is_empty());
    assert_eq!(set.num_live_remotes(), 1);
    assert!(set.restart_dead_with_policy(&policy).is_empty());
    assert_eq!(set.fault_stats().breaker_trips, 1);

    // Queue capacity was reclaimed: backfill reuses the retired slot
    // (fresh budget, clean worker) instead of growing tag space.
    assert_eq!(set.add_worker().expect("backfill"), 0);
    let fresh = set.remote(0).expect("backfilled slot is live");
    assert!(fresh.call(|w| w.sample().len()).expect("samples") > 0);
    assert_eq!(set.num_live_remotes(), 2);
}

/// Fault-matrix soak (run by `tools/ci.sh --chaos`): a seeded mixture
/// of slow shards, shed cast lanes, a crash, and a deterministic wedge,
/// all against one live supervised plan.  The driver must keep
/// streaming, the restart policy must recover or retire every failure,
/// and the run must end with a live quorum.
#[test]
#[ignore = "fault soak: executed by tools/ci.sh --chaos"]
fn fault_matrix_soak() {
    let set = worker_set("soak-learner", "soak-w", 4);
    set.local.call(|w| w.set_weights(&[0.5])).unwrap();
    let rules = [
        // Every soak shard is sometimes slow (seeded draw).
        faults::inject_with(
            SITE_ROLLOUT_SAMPLE,
            Some("soak-w"),
            FaultAction::Delay(2),
            0.2,
            None,
            None,
        ),
        // Cast lanes drop a fraction of weight broadcasts: the caster
        // must shed, never wedge the barrier.
        faults::inject_with(
            SITE_CASTER_LANE,
            Some("soak-w"),
            FaultAction::DropReply,
            0.1,
            None,
            None,
        ),
        // One crash: shard 2 panics on its first sample.
        faults::inject(
            SITE_ROLLOUT_SAMPLE,
            Some("soak-w-2"),
            FaultAction::PanicOnce,
        ),
        // One wedge: shard 1 hangs on its 40th sample (the rule disarms
        // after firing, so the replacement comes up clean).
        faults::inject_with(
            SITE_ROLLOUT_SAMPLE,
            Some("soak-w-1"),
            FaultAction::Hang,
            1.0,
            Some(40),
            None,
        ),
    ];

    let sup = DeadlineSupervision::with_counters(
        Duration::from_millis(250),
        set.fault_counters(),
    );
    let mut it = parallel_rollouts_from(&set)
        .gather_async_with_source_deadline(2, sup);
    let policy = RestartPolicy {
        max_restarts: 3,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(8),
        reset_after: Duration::from_secs(3600),
    };

    let start = Instant::now();
    let mut items: u64 = 0;
    while start.elapsed() < Duration::from_secs(8) {
        assert!(
            it.next().is_some(),
            "supervised stream ended under faults"
        );
        items += 1;
        if items % 64 == 0 {
            set.restart_dead_with_policy(&policy);
            set.sync_weights(); // exercises the faulted cast lanes
        }
    }
    // Final recovery drive: every remaining corpse is restarted or
    // breaker-retired within a bounded number of policy passes.
    let drain = Instant::now();
    while !set.poisoned_indices().is_empty() {
        assert!(
            drain.elapsed() < Duration::from_secs(10),
            "policy never drained the dead set: {:?}",
            set.poisoned_indices()
        );
        set.restart_dead_with_policy(&policy);
        std::thread::sleep(Duration::from_millis(5));
    }

    for id in rules {
        faults::clear(id);
    }
    let stats = set.fault_stats();
    assert!(items > 100, "soak barely streamed: {items} items");
    assert!(stats.suspects >= 1, "the wedge was never detected: {stats:?}");
    assert!(
        stats.forced_restarts >= 1,
        "no fault was ever recovered: {stats:?}"
    );
    assert!(
        set.num_live_remotes() >= 2,
        "soak ended below quorum: {} live",
        set.num_live_remotes()
    );
    assert!(set.weight_cast_stats().shed >= 1, "no cast was ever shed");
}

// ---------------------------------------------------------------------
// Replay tier: shard crash recovery under live store+replay traffic
// ---------------------------------------------------------------------

fn replay_transitions(n: usize) -> flowrl::sample_batch::SampleBatch {
    let mut b = flowrl::sample_batch::SampleBatchBuilder::new(2);
    for i in 0..n {
        b.add_transition(
            &[i as f32, 0.0],
            0,
            1.0,
            &[i as f32 + 1.0, 0.0],
            false,
        );
    }
    b.build()
}

/// Sharded-replay acceptance: a replay shard killed mid-plan is
/// restarted by `restart_dead_with_policy` into the SAME running
/// store+replay streams, with no double-counted samples — the dead
/// incarnation's ring contents are *gone* (gauges restart from zero,
/// they are not re-counted by the replacement), the service-level
/// routing counters stay monotone, and the learner's in-flight priority
/// update for a pre-crash sample is discarded by the lease's epoch
/// check instead of corrupting the fresh buffer.
#[test]
fn replay_shard_killed_mid_traffic_recovers_without_double_count() {
    use flowrl::ops::{create_replay_shards, replay, store_to_replay_buffer};

    let service = create_replay_shards(2, 2, 64, 0, 4);
    let mut store = store_to_replay_buffer(&service);
    let mut it = replay(&service, 1);

    // Live traffic on both shards.
    for _ in 0..10 {
        store(replay_transitions(4));
    }
    let deadline = Instant::now();
    while service.backlog_stats().added < 40 {
        assert!(deadline.elapsed() < Duration::from_secs(5), "adds lost");
        std::thread::sleep(Duration::from_millis(2));
    }

    // Hold a pre-crash sample from the victim shard.
    let (victim, epoch0) =
        service.registry().get_live(0).expect("shard 0 live");
    let stale = loop {
        if let Some((sample, lease)) = it.next().unwrap() {
            if lease.shard_idx() == Some(0) {
                break (sample, lease);
            }
        }
    };
    let survivor_added = service
        .registry()
        .get_live(1)
        .unwrap()
        .0
        .call(|ra| ra.num_added)
        .unwrap();

    // Crash the shard; the supervised restart publishes a replacement
    // under a bumped epoch.
    assert!(victim.call(|_| -> () { panic!("fault injection") }).is_err());
    assert!(victim.await_poisoned(Duration::from_secs(2)));
    let report =
        service.restart_dead_with_policy(&RestartPolicy::default());
    assert_eq!(report.restarted, vec![0]);
    assert!(service.registry().epoch(0) > epoch0);

    // No double-counting: the corpse's transitions are not re-credited
    // — the pool's add gauge now shows ONLY the survivor's share (the
    // replacement restarts from zero), while the routing counter keeps
    // its lifetime count.  The replacement resets its gauge from inside
    // its own actor thread, so poll briefly instead of racing it.
    let deadline = Instant::now();
    loop {
        let stats = service.backlog_stats();
        if stats.added == survivor_added as u64 {
            assert_eq!(stats.stores, 10);
            break;
        }
        assert!(
            deadline.elapsed() < Duration::from_secs(5),
            "dead incarnation's samples still counted: added={} survivor={}",
            stats.added,
            survivor_added
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    // The learner's TD errors for the pre-crash sample reference ring
    // slots of the dead incarnation: discarded, not applied.
    let tds = vec![9.0; stale.0.indices.len()];
    assert!(!stale.1.update_priorities(stale.0.indices, tds));
    assert_eq!(service.backlog_stats().priority_discarded, 1);

    // Both running streams keep working across the recovery: new
    // batches route to the replacement and the SAME replay iterator
    // draws from its fresh incarnation (resolvable lease, new epoch).
    for _ in 0..20 {
        store(replay_transitions(4));
    }
    let deadline = Instant::now();
    let fresh_epoch = service.registry().epoch(0);
    loop {
        assert!(
            deadline.elapsed() < Duration::from_secs(5),
            "replacement never rejoined the replay stream"
        );
        if let Some((sample, lease)) = it.next().unwrap() {
            if lease.shard_idx() == Some(0) {
                assert_eq!(lease.epoch(), fresh_epoch);
                let tds = vec![1.0; sample.indices.len()];
                assert!(lease.update_priorities(sample.indices, tds));
                break;
            }
        }
    }
    assert!(service.backlog_stats().priority_applied >= 1);
}

/// Replay-tier chaos soak (run by `tools/ci.sh --chaos`): rotating
/// shard kills under continuous Ape-X-style store+replay traffic.  The
/// restart policy must recover every crash into the running streams,
/// priority feedback for dead incarnations must be discarded (never
/// misapplied), and the run must end with a full live pool and monotone
/// service counters.
#[test]
#[ignore = "fault soak: executed by tools/ci.sh --chaos"]
fn replay_shard_kill_soak_under_store_replay_traffic() {
    use flowrl::ops::{create_replay_shards, replay, store_to_replay_buffer};

    let service = create_replay_shards(3, 2, 128, 8, 4);
    let mut store = store_to_replay_buffer(&service);
    let mut it = replay(&service, 2);
    let policy = RestartPolicy {
        max_restarts: 1_000,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(8),
        reset_after: Duration::from_secs(3600),
    };

    let start = Instant::now();
    let mut pulls: u64 = 0;
    let mut samples: u64 = 0;
    let mut applied: u64 = 0;
    let mut discarded: u64 = 0;
    let mut kill_slot = 0usize;
    while start.elapsed() < Duration::from_secs(6) {
        store(replay_transitions(4));
        if let Some((sample, lease)) = it.next().unwrap() {
            samples += 1;
            // Learner round-trip: feed priorities straight back; a
            // lease whose incarnation died in the meantime must report
            // the discard rather than poking the replacement.
            let tds = vec![1.0; sample.indices.len()];
            if lease.update_priorities(sample.indices, tds) {
                applied += 1;
            } else {
                discarded += 1;
            }
        }
        pulls += 1;
        if pulls % 256 == 0 {
            // Rotate a kill across the pool, then drive recovery.
            if let Some((h, _)) = service.registry().get_live(kill_slot) {
                assert!(h.call(|_| -> () { panic!("chaos") }).is_err());
                assert!(h.await_poisoned(Duration::from_secs(2)));
            }
            kill_slot = (kill_slot + 1) % 3;
            service.restart_dead_with_policy(&policy);
        }
        if pulls % 64 == 0 {
            service.restart_dead_with_policy(&policy);
        }
    }
    // Drain: every corpse recovered before the soak ends.
    let drain = Instant::now();
    while !service.set().poisoned_indices().is_empty() {
        assert!(
            drain.elapsed() < Duration::from_secs(10),
            "policy never drained dead replay shards: {:?}",
            service.set().poisoned_indices()
        );
        service.restart_dead_with_policy(&policy);
        std::thread::sleep(Duration::from_millis(5));
    }

    let stats = service.backlog_stats();
    assert_eq!(service.num_live_shards(), 3, "soak ended below full pool");
    assert!(samples > 100, "soak barely replayed: {samples} samples");
    assert!(applied > 0, "no priority update ever landed");
    assert_eq!(stats.samples, samples, "sample accounting drifted");
    assert_eq!(stats.priority_applied, applied);
    assert_eq!(stats.priority_discarded, discarded);
    assert!(
        stats.stores >= pulls,
        "store routing stalled: {} stores / {pulls} pulls",
        stats.stores
    );
}
