//! Allocation-freedom test for the episode-log sink's append path
//! (acceptance criterion of the flowlint PR's hot-path satellite): once
//! the writer's scratch buffers are warm, `EpisodeLogWriter::append`
//! performs **zero** heap allocations per frame.
//!
//! `append` carries a `// flowlint: hot-path` mark, so the static lint
//! denies obvious allocation tokens in its body; this test pins the
//! property at runtime, including what the lexer cannot see (growth
//! inside `wire::encode_batch`/`encode_frame`, `BufWriter` internals).
//! Rotation is the designed cold path (it formats a segment file name
//! and opens a file), so the config's `segment_bytes` is set high
//! enough that the measured appends never rotate.
//!
//! The counting allocator counts per-thread (a thread-local counter),
//! and this file holds a single test for the same reason
//! `tests/actor_alloc.rs` does.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::PathBuf;

use flowrl::offline::{EpisodeLogWriter, WriterConfig};
use flowrl::sample_batch::SampleBatchBuilder;
use flowrl::SampleBatch;

struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

const OBS_DIM: usize = 8;
const ROWS: usize = 64;
const WARMUP: usize = 8;
const MEASURED: usize = 64;

fn batch() -> SampleBatch {
    let mut b = SampleBatchBuilder::new(OBS_DIM);
    for i in 0..ROWS {
        b.add_transition_with_logp(
            &[i as f32; OBS_DIM],
            (i % 2) as i32,
            1.0,
            &[i as f32 + 1.0; OBS_DIM],
            i == ROWS - 1,
            -0.69,
        );
    }
    b.build()
}

#[test]
fn warm_episode_log_append_is_allocation_free() {
    let dir: PathBuf = std::env::temp_dir()
        .join(format!("flowrl_alloc_log_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut w = EpisodeLogWriter::create(
        &dir,
        "alloc",
        // Far beyond anything this test writes: the measured appends
        // must never hit the (allocating, by design) rotation path.
        WriterConfig { segment_bytes: u64::MAX },
    )
    .unwrap();
    let b = batch();

    // Warm the payload/frame scratch buffers: the batch is identical
    // every append, so after the first few frames both scratches hold
    // their steady-state capacity.
    for _ in 0..WARMUP {
        w.append(&b).unwrap();
    }

    let before = allocs_here();
    for _ in 0..MEASURED {
        w.append(&b).unwrap();
    }
    let allocs = allocs_here() - before;

    assert_eq!(
        allocs, 0,
        "append allocated {allocs}x over {MEASURED} frames — the encode \
         scratch or the buffered writer grew on the hot path"
    );
    assert_eq!(w.current_seq(), 0, "measured appends must not rotate");
    let (frames, bytes, errors) = w.counters();
    assert_eq!(frames, (WARMUP + MEASURED) as u64);
    assert!(bytes > 0);
    assert_eq!(errors, 0);

    std::fs::remove_dir_all(&dir).ok();
}
