//! Elastic control-plane acceptance tests: a worker killed during a
//! *running* gather rejoins after `WorkerSet::restart_dead` without a
//! plan rebuild — the live re-binding the shard registry exists for —
//! and the epoch protocol keeps completions of dead incarnations from
//! being attributed to their replacements.
//!
//! These run on the Dummy env/policy, so they need no AOT artifacts.

use std::time::Duration;

use flowrl::env::{DummyEnv, Env};
use flowrl::ops::parallel_rollouts_from;
use flowrl::policy::DummyPolicy;
use flowrl::rollout::{CollectMode, RolloutWorker, WorkerSet};

/// The `broadcast_sync` wedge bugfix at the WorkerSet level: a worker
/// removed while `sync_weights` is mid-barrier (its apply stuck behind
/// a blocked message) must be dropped from the wait set — the barrier
/// returns instead of wedging the driver forever.  The blocked message
/// is only released AFTER the barrier returns, so the old behavior
/// deadlocks this test rather than passing by timing luck.
#[test]
fn sync_weights_survives_worker_removed_mid_barrier() {
    let set = worker_set(2);
    set.local.call(|w| w.set_weights(&[0.875])).unwrap();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let w0 = set.remote(0).expect("live remote");
    let parked = w0.call_deferred(move |_| {
        let _ = gate_rx.recv();
    });
    while w0.queue_len() > 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    let set2 = set.clone();
    let barrier = std::thread::spawn(move || set2.sync_weights());
    std::thread::sleep(Duration::from_millis(30));
    assert!(set.remove_worker(0));
    barrier.join().expect("sync_weights wedged on a removed worker");
    // The surviving remote applied the barrier version.
    let w1 = set.remote(1).expect("live remote");
    assert_eq!(w1.call(|w| w.get_weights()).unwrap(), vec![0.875]);
    gate_tx.send(()).unwrap();
    parked.recv().unwrap();
}

fn worker_set(n_remote: usize) -> WorkerSet {
    WorkerSet::new(n_remote, |_| {
        Box::new(|| {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(DummyPolicy::new(0.1)),
                4,
                CollectMode::OnPolicy,
            )
        })
    })
}

#[test]
fn killed_worker_rejoins_running_gather_async() {
    let set = worker_set(2);
    set.local.call(|w| w.set_weights(&[0.25])).unwrap();
    let mut it = parallel_rollouts_from(&set).gather_async_with_source(1);
    let w0 = set.remote(0).expect("live remote");
    let w1 = set.remote(1).expect("live remote");

    // The stream is live off both workers.
    for _ in 0..4 {
        assert!(it.next().is_some());
    }

    // Kill worker 1 while its gather submissions are in flight.
    assert!(w1.call(|_| -> () { panic!("fault injection") }).is_err());
    assert!(w1.await_poisoned(Duration::from_secs(2)));

    // The same gather keeps streaming off the survivor (at most one
    // already-buffered item from the dead incarnation may surface).
    let mut dead_items = 0;
    for _ in 0..6 {
        let (_batch, src) = it.next().expect("stream must survive the fault");
        if src.id() == w1.id() {
            dead_items += 1;
        } else {
            assert_eq!(src.id(), w0.id());
        }
    }
    assert!(dead_items <= 1, "dead worker kept producing: {dead_items}");

    // Restart: the replacement is published into the set's registry.
    assert_eq!(set.restart_dead(), vec![1]);
    let fresh = set.remote(1).expect("live remote");
    assert_ne!(fresh.id(), w1.id());

    // The SAME running gather — no rebuild — now yields the
    // replacement's batches, paired with the replacement's handle.
    let mut fresh_items = 0;
    for _ in 0..64 {
        let (_batch, src) = it.next().expect("stream must keep flowing");
        assert_ne!(src.id(), w1.id(), "item attributed to the corpse");
        if src.id() == fresh.id() {
            fresh_items += 1;
        }
    }
    assert!(
        fresh_items > 0,
        "replacement never rejoined the running gather"
    );
    // The replacement sampled with the learner's weights, not blanks.
    assert_eq!(fresh.call(|w| w.get_weights()).unwrap(), vec![0.25]);
}

#[test]
fn restart_before_notices_drain_discards_stale_epoch() {
    // Kill a worker with num_async=2 (multiple in-flight submissions ->
    // multiple epoch-0 death notices) and restart it BEFORE the gather
    // has consumed any of them.  The first notice makes the running
    // gather adopt the replacement; the later stale notices must be
    // discarded — without the epoch tag they would retire the fresh
    // incarnation and shard 1 would fall silent.
    let set = worker_set(2);
    let mut it = parallel_rollouts_from(&set).gather_async_with_source(2);
    let w1 = set.remote(1).expect("live remote");

    for _ in 0..4 {
        assert!(it.next().is_some());
    }
    assert!(w1.call(|_| -> () { panic!("fault injection") }).is_err());
    assert!(w1.await_poisoned(Duration::from_secs(2)));
    // Restart immediately: the dead incarnation's notices are still
    // queued (or in flight) when the replacement is published.
    assert_eq!(set.restart_dead(), vec![1]);
    let fresh = set.remote(1).expect("live remote");

    let mut fresh_items = 0;
    for _ in 0..96 {
        let (_batch, src) = it.next().expect("stream must keep flowing");
        if src.id() == fresh.id() {
            fresh_items += 1;
        }
    }
    assert!(
        fresh_items > 0,
        "stale death notice retired the replacement (double-counted)"
    );
    // Exactly one restart happened; the replacement is healthy.
    assert!(set.poisoned_indices().is_empty());
    assert!(set.restart_dead().is_empty());
}

#[test]
fn killed_worker_rejoins_gather_sync_at_round_boundary() {
    let set = worker_set(2);
    let mut it = parallel_rollouts_from(&set).gather_sync();
    assert_eq!(it.next().unwrap().len(), 2);

    let w0 = set.remote(0).expect("live remote");
    assert!(w0.call(|_| -> () { panic!("fault injection") }).is_err());
    assert!(w0.await_poisoned(Duration::from_secs(2)));

    // Barrier rounds complete off the survivor while the shard is dead.
    let survivors_round = it.next().unwrap();
    assert_eq!(survivors_round.len(), 1);

    assert_eq!(set.restart_dead(), vec![0]);
    // The replacement joins at the next round boundary: full rounds
    // again, through the same running iterator.
    assert_eq!(it.next().unwrap().len(), 2);
    assert_eq!(it.next().unwrap().len(), 2);
}

#[test]
fn sync_weights_reaches_restarted_workers() {
    let set = worker_set(2);
    let w1 = set.remote(1).expect("live remote");
    assert!(w1.call(|_| -> () { panic!("fault injection") }).is_err());
    assert!(w1.await_poisoned(Duration::from_secs(2)));
    // sync_weights with a dead remote: skipped, not fatal.
    set.local.call(|w| w.set_weights(&[0.125])).unwrap();
    set.sync_weights();

    assert_eq!(set.restart_dead(), vec![1]);
    // A later barrier sync must reach the replacement through the
    // registry (a build-time handle snapshot would miss it).
    set.local.call(|w| w.set_weights(&[0.5])).unwrap();
    set.sync_weights();
    for i in [0, 1] {
        let h = set.remote(i).expect("live remote");
        assert_eq!(h.call(|w| w.get_weights()).unwrap(), vec![0.5]);
    }
    // Versions are monotone across the restart.
    assert!(set.weight_cast_stats().version >= 2);
}
