//! Autoscaling acceptance tests — the closed elasticity loop.
//!
//! The controller's hysteresis logic (deadband / confirmation streak /
//! cooldown, the no-flap guarantees) is unit-tested deterministically
//! with synthetic signals in `rust/src/actor/autoscaler.rs`.  The tests
//! here drive the **whole loop** end-to-end: real worker actors, a real
//! dataflow plan, real telemetry — an idle-learner workload converges
//! to a larger sampler pool and a saturated one scales back down, with
//! no manual `scale_to` calls.  Workload skew is deliberately extreme
//! (milliseconds of sleep vs microseconds of work) so the utilization
//! signals are unambiguous on any CI machine.
//!
//! The phase-flipping soak (`autoscale_soak_idle_grow_busy_shrink`) is
//! `#[ignore]`d from plain `cargo test` and executed by
//! `tools/ci.sh --chaos` alongside the scale-out soak.
//!
//! These run on the Dummy env + a local sleep-knob policy, so they need
//! no AOT artifacts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use flowrl::actor::{Autoscaler, AutoscalerConfig};
use flowrl::env::{DummyEnv, Env, MultiAgentCartPole};
use flowrl::iter::ParIter;
use flowrl::metrics::TrainResult;
use flowrl::ops::{
    parallel_rollouts_from, train_one_step, Reporting, TrainItem,
};
use flowrl::policy::{ActionOutput, Gradients, Policy};
use flowrl::rollout::{
    CollectMode, MultiAgentRolloutWorker, RolloutWorker, WorkerSet,
};
use flowrl::sample_batch::SampleBatch;

/// A policy with two shared sleep knobs: `sample_us` burns time in
/// `compute_actions` (per env step, on the sampler actors) and
/// `learn_us` in `compute_gradients` (per train batch, on the learner).
/// Flipping the atomics mid-run flips which side of the pipeline is the
/// bottleneck — the workload the autoscaler must chase.
struct PhasedPolicy {
    sample_us: Arc<AtomicU64>,
    learn_us: Arc<AtomicU64>,
    weights: Vec<f32>,
}

impl Policy for PhasedPolicy {
    fn compute_actions_into(
        &mut self,
        _obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    ) {
        let us = self.sample_us.load(Ordering::Relaxed);
        if us > 0 {
            std::thread::sleep(Duration::from_micros(us));
        }
        out.clear();
        out.resize(n, ActionOutput { action: 0, logp: 0.0, value: 0.0 });
    }

    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        let us = self.learn_us.load(Ordering::Relaxed);
        if us > 0 {
            std::thread::sleep(Duration::from_micros(us));
        }
        let mut stats = BTreeMap::new();
        stats.insert("loss".to_string(), 0.5);
        Gradients { flat: vec![0.0], stats, count: batch.len() }
    }

    fn apply_gradients(&mut self, _grads: &Gradients) {}

    fn get_weights(&self) -> Vec<f32> {
        self.weights.clone()
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.weights = weights.to_vec();
    }
}

struct Knobs {
    sample_us: Arc<AtomicU64>,
    learn_us: Arc<AtomicU64>,
}

fn phased_set(n_remote: usize, sample_us: u64, learn_us: u64) -> (WorkerSet, Knobs) {
    let knobs = Knobs {
        sample_us: Arc::new(AtomicU64::new(sample_us)),
        learn_us: Arc::new(AtomicU64::new(learn_us)),
    };
    let (s, l) = (knobs.sample_us.clone(), knobs.learn_us.clone());
    let set = WorkerSet::new(n_remote, move |_| {
        let (s, l) = (s.clone(), l.clone());
        Box::new(move || {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(PhasedPolicy {
                    sample_us: s,
                    learn_us: l,
                    weights: vec![0.0],
                }),
                4,
                CollectMode::OnPolicy,
            )
        })
    });
    (set, knobs)
}

fn controller(min: usize, max: usize) -> Autoscaler {
    Autoscaler::new(AutoscalerConfig {
        min_workers: min,
        max_workers: max,
        learner_idle_below: 0.3,
        learner_busy_above: 0.8,
        // Secondary gauges neutralized: these tests pin the learner
        // utilization signal; the soak exercises the composite.
        sampler_queue_pressure: 1_000,
        shed_tolerance: u64::MAX / 2,
        cooldown_reports: 0,
        confirm_reports: 1,
        step: 1,
        ..AutoscalerConfig::default()
    })
}

/// The PR's acceptance criterion, grow direction: samplers sleep ~8ms
/// per fragment while the learner's step is microseconds — the learner
/// is idle, and the controller must grow the pool to `max_workers`
/// through the *running* plan, no manual `scale_to`.
#[test]
fn idle_learner_workload_converges_to_larger_pool() {
    let (set, _knobs) = phased_set(1, 2_000, 0);
    let mut train = train_one_step(&set);
    let train_op = parallel_rollouts_from(&set)
        .gather_async(1)
        .for_each(move |b| train(b));
    let mut reports = Reporting::new(train_op, &set, 1)
        .autoscale(controller(1, 3))
        .build();

    let mut last: Option<TrainResult> = None;
    for _ in 0..60 {
        last = reports.next();
        assert!(last.is_some(), "reporting stopped during autoscale");
        if set.num_live_remotes() == 3 {
            break;
        }
    }
    assert_eq!(
        set.num_live_remotes(),
        3,
        "idle-learner pool failed to converge to max_workers"
    );
    let r = last.unwrap();
    let a = r.autoscale.expect("autoscale stats attached");
    assert!(a.decisions_up >= 2, "{a:?}");
    assert_eq!(a.decisions_down, 0, "{a:?}");
    assert_eq!(a.last_target, 3, "{a:?}");
    assert!(r.pipeline_summary().contains("autoscale=t3("));
    // The grown workers joined the running gather with real weights —
    // keep streaming to prove the plan survived its own scaling.
    for _ in 0..6 {
        assert!(reports.next().is_some());
    }
    let sc = set.scale_stats();
    assert_eq!((sc.added, sc.live), (2, 3));
}

/// The shrink direction: the learner burns ~4ms per train item while
/// sampling is instant — the learner saturates, and the controller must
/// scale the over-provisioned pool back down to `min_workers`.
#[test]
fn saturated_learner_workload_scales_back_down() {
    let (set, _knobs) = phased_set(3, 0, 4_000);
    let mut train = train_one_step(&set);
    let train_op = parallel_rollouts_from(&set)
        .gather_async(1)
        .for_each(move |b| train(b));
    let mut reports = Reporting::new(train_op, &set, 1)
        .autoscale(controller(1, 4))
        .build();

    let mut last: Option<TrainResult> = None;
    for _ in 0..60 {
        last = reports.next();
        assert!(last.is_some(), "reporting stopped during autoscale");
        if set.num_live_remotes() == 1 {
            break;
        }
    }
    assert_eq!(
        set.num_live_remotes(),
        1,
        "saturated-learner pool failed to scale back down"
    );
    let a = last.unwrap().autoscale.expect("autoscale stats attached");
    assert!(a.decisions_down >= 2, "{a:?}");
    assert_eq!(a.decisions_up, 0, "{a:?}");
    // Tombstoned slots answer None; the stream keeps flowing off the
    // survivor.
    assert!(set.remote(2).is_none());
    for _ in 0..4 {
        assert!(reports.next().is_some());
    }
}

/// The multi-agent path rides the same loop: a multi-agent `WorkerSet`
/// under the generic `ops::Reporting` with a controller grows its pool
/// when
/// the (idle) learner signal says so — the satellite's "autoscaler
/// works there too" criterion.
#[test]
fn ma_autoscaler_grows_idle_pool_mid_plan() {
    let sample_us = Arc::new(AtomicU64::new(2_000));
    let s_outer = sample_us.clone();
    let set: WorkerSet<MultiAgentRolloutWorker> = WorkerSet::with_protocol(
        "ma_local",
        "ma_worker",
        1,
        move |i| {
            let s = s_outer.clone();
            Box::new(move || {
                let env = MultiAgentCartPole::new(2, i as u64, |a| {
                    if a % 2 == 0 { "even".into() } else { "odd".into() }
                });
                let mut policies: BTreeMap<String, Box<dyn Policy>> =
                    BTreeMap::new();
                for pid in ["even", "odd"] {
                    policies.insert(
                        pid.into(),
                        Box::new(PhasedPolicy {
                            sample_us: s.clone(),
                            learn_us: Arc::new(AtomicU64::new(0)),
                            weights: vec![0.0],
                        }),
                    );
                }
                MultiAgentRolloutWorker::new(env, policies, 4)
            })
        },
        flowrl::algorithms::ma_sync_protocol(),
    );
    let registry = set.registry().clone();
    let inner = ParIter::from_registry(registry, |w| Some(w.sample()))
        .gather_async(1)
        .for_each(|ma| TrainItem::new(BTreeMap::new(), ma.count()));
    let mut reports = Reporting::new(inner, &set, 1)
        .autoscale(controller(1, 3))
        .build();
    for _ in 0..60 {
        assert!(reports.next().is_some(), "ma reporting stopped");
        if set.num_live_remotes() == 3 {
            break;
        }
    }
    assert_eq!(
        set.num_live_remotes(),
        3,
        "multi-agent pool failed to autoscale"
    );
    // Streaming continues across the growth.
    for _ in 0..4 {
        assert!(reports.next().is_some());
    }
}

/// The autoscale soak behind `tools/ci.sh --chaos`: phase A starves
/// the learner (slow samplers) until the controller grows 1 -> 4, then
/// the knobs flip (instant sampling, slow learner) and it must shrink
/// back to 1 — asserting convergence in both directions, a live stream
/// throughout, and a bounded number of direction changes (no flap).
#[test]
#[ignore = "autoscale soak: executed by tools/ci.sh --chaos"]
fn autoscale_soak_idle_grow_busy_shrink() {
    let (set, knobs) = phased_set(1, 3_000, 0);
    let mut train = train_one_step(&set);
    let train_op = parallel_rollouts_from(&set)
        .gather_async(1)
        .for_each(move |b| train(b));
    // Production-shaped hysteresis: confirmation + cooldown on.
    let controller = Autoscaler::new(AutoscalerConfig {
        min_workers: 1,
        max_workers: 4,
        learner_idle_below: 0.3,
        learner_busy_above: 0.8,
        sampler_queue_pressure: 1_000,
        shed_tolerance: u64::MAX / 2,
        cooldown_reports: 1,
        confirm_reports: 2,
        step: 1,
        ..AutoscalerConfig::default()
    });
    let mut reports =
        Reporting::new(train_op, &set, 1).autoscale(controller).build();

    // Phase A: idle learner -> grow to 4.
    let mut phase_a_reports = 0;
    while set.num_live_remotes() < 4 {
        assert!(reports.next().is_some(), "stream died in phase A");
        phase_a_reports += 1;
        assert!(
            phase_a_reports < 150,
            "phase A never converged to 4 workers"
        );
    }

    // Phase flip: sampling instant, learning slow.
    knobs.sample_us.store(0, Ordering::Relaxed);
    knobs.learn_us.store(3_000, Ordering::Relaxed);

    // Phase B: saturated learner -> shrink to 1.
    let mut last: Option<TrainResult> = None;
    let mut phase_b_reports = 0;
    while set.num_live_remotes() > 1 {
        last = reports.next();
        assert!(last.is_some(), "stream died in phase B");
        phase_b_reports += 1;
        assert!(
            phase_b_reports < 150,
            "phase B never converged back to 1 worker"
        );
    }

    // No flap: exactly the decisions the two phases require, within a
    // small tolerance for boundary jitter.
    let a = last
        .or_else(|| reports.next())
        .unwrap()
        .autoscale
        .expect("autoscale stats attached");
    assert!(a.decisions_up >= 3, "{a:?}");
    assert!(a.decisions_down >= 3, "{a:?}");
    assert!(
        a.decisions_up + a.decisions_down <= 10,
        "controller flapped: {a:?}"
    );
    assert_eq!(a.failed, 0, "{a:?}");

    // The stream is still healthy at the end of the churn.
    for _ in 0..4 {
        assert!(reports.next().is_some());
    }
    println!(
        "autoscale soak: {} reports up-phase, {} reports down-phase, \
         decisions +{} -{}",
        phase_a_reports, phase_b_reports, a.decisions_up, a.decisions_down
    );
}
