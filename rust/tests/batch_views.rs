//! Property tests for the zero-copy experience path: view-based
//! `slice`/`minibatches`/`shuffle` must be row-identical to a reference
//! copy implementation (the pre-refactor semantics), and the
//! struct-of-arrays replay ring must serve only live slots across
//! wraparound.  Same randomized-cases harness as rust/tests/properties.rs
//! (proptest is not vendorable offline).

use flowrl::replay::PrioritizedReplayBuffer;
use flowrl::sample_batch::{SampleBatch, SampleBatchBuilder};
use flowrl::util::Rng;

/// Run `prop` on `cases` random instances, reporting the failing seed.
fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xB47C4 ^ seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut rng)),
        );
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Reference implementation: plain-Vec columns with the seed's copy
// semantics (slice copies ranges, shuffle swaps rows in place).
// ---------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
struct RefBatch {
    obs_dim: usize,
    obs: Vec<f32>,
    actions: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
    action_logp: Vec<f32>,
    vf_preds: Vec<f32>,
    weights: Vec<f32>,
    next_obs: Vec<f32>,
}

impl RefBatch {
    fn len(&self) -> usize {
        self.obs.len() / self.obs_dim
    }

    fn slice(&self, start: usize, end: usize) -> RefBatch {
        let d = self.obs_dim;
        let col = |v: &Vec<f32>| {
            if v.is_empty() { vec![] } else { v[start..end].to_vec() }
        };
        let coln = |v: &Vec<f32>| {
            if v.is_empty() { vec![] } else { v[start * d..end * d].to_vec() }
        };
        RefBatch {
            obs_dim: d,
            obs: coln(&self.obs),
            actions: self.actions[start..end].to_vec(),
            rewards: col(&self.rewards),
            dones: col(&self.dones),
            action_logp: col(&self.action_logp),
            vf_preds: col(&self.vf_preds),
            weights: col(&self.weights),
            next_obs: coln(&self.next_obs),
        }
    }

    fn minibatches(&self, size: usize) -> Vec<RefBatch> {
        let n = self.len() / size;
        (0..n).map(|i| self.slice(i * size, (i + 1) * size)).collect()
    }

    /// The seed's in-place Fisher–Yates (identical rng consumption to
    /// the view implementation's permutation gather).
    fn shuffle(&mut self, rng: &mut Rng) {
        let n = self.len();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            self.swap_rows(i, j);
        }
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        if i == j {
            return;
        }
        let d = self.obs_dim;
        for k in 0..d {
            self.obs.swap(i * d + k, j * d + k);
            if !self.next_obs.is_empty() {
                self.next_obs.swap(i * d + k, j * d + k);
            }
        }
        let swap1 = |v: &mut Vec<f32>| {
            if !v.is_empty() {
                v.swap(i, j)
            }
        };
        self.actions.swap(i, j);
        swap1(&mut self.rewards);
        swap1(&mut self.dones);
        swap1(&mut self.action_logp);
        swap1(&mut self.vf_preds);
        swap1(&mut self.weights);
    }
}

/// A random batch built through the public builder, mirrored into the
/// reference representation.
fn random_pair(rng: &mut Rng, n: usize, obs_dim: usize) -> (SampleBatch, RefBatch) {
    let with_next = rng.chance(0.5);
    let mut b = SampleBatchBuilder::new(obs_dim);
    for _ in 0..n {
        let obs: Vec<f32> =
            (0..obs_dim).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let next: Vec<f32> =
            (0..obs_dim).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let action = rng.below(3) as i32;
        let reward = rng.uniform_range(-1.0, 1.0);
        let done = rng.chance(0.1);
        if with_next {
            b.add_step_with_next(
                &obs,
                action,
                reward,
                &next,
                done,
                rng.uniform_range(-2.0, 0.0),
                rng.uniform_range(-1.0, 1.0),
            );
        } else {
            b.add_step(
                &obs,
                action,
                reward,
                done,
                rng.uniform_range(-2.0, 0.0),
                rng.uniform_range(-1.0, 1.0),
            );
        }
    }
    let batch = b.build();
    let reference = RefBatch {
        obs_dim,
        obs: batch.obs.to_vec(),
        actions: batch.actions.to_vec(),
        rewards: batch.rewards.to_vec(),
        dones: batch.dones.to_vec(),
        action_logp: batch.action_logp.to_vec(),
        vf_preds: batch.vf_preds.to_vec(),
        weights: batch.weights.to_vec(),
        next_obs: batch.next_obs.to_vec(),
    };
    (batch, reference)
}

fn assert_batches_equal(view: &SampleBatch, reference: &RefBatch, what: &str) {
    assert_eq!(view.len(), reference.len(), "{what}: len");
    assert_eq!(view.obs.to_vec(), reference.obs, "{what}: obs");
    assert_eq!(view.actions.to_vec(), reference.actions, "{what}: actions");
    assert_eq!(view.rewards.to_vec(), reference.rewards, "{what}: rewards");
    assert_eq!(view.dones.to_vec(), reference.dones, "{what}: dones");
    assert_eq!(
        view.action_logp.to_vec(),
        reference.action_logp,
        "{what}: action_logp"
    );
    assert_eq!(view.vf_preds.to_vec(), reference.vf_preds, "{what}: vf_preds");
    assert_eq!(view.weights.to_vec(), reference.weights, "{what}: weights");
    assert_eq!(view.next_obs.to_vec(), reference.next_obs, "{what}: next_obs");
}

// ---------------------------------------------------------------------
// View equivalence
// ---------------------------------------------------------------------

#[test]
fn prop_slice_views_match_reference_copies() {
    check("slice equivalence", 40, |rng| {
        let n = 1 + rng.below(40);
        let d = 1 + rng.below(4);
        let (batch, reference) = random_pair(rng, n, d);
        let start = rng.below(n);
        let end = start + rng.below(n - start + 1);
        assert_batches_equal(
            &batch.slice(start, end),
            &reference.slice(start, end),
            "slice",
        );
        // Slicing a slice (the minibatch-of-concat path).
        let s = batch.slice(start, end);
        let rs = reference.slice(start, end);
        if end - start >= 2 {
            assert_batches_equal(&s.slice(1, end - start), &rs.slice(1, end - start), "nested slice");
        }
    });
}

#[test]
fn prop_minibatch_views_match_reference_copies() {
    check("minibatch equivalence", 30, |rng| {
        let n = 1 + rng.below(60);
        let d = 1 + rng.below(3);
        let size = 1 + rng.below(12);
        let (batch, reference) = random_pair(rng, n, d);
        let got = batch.minibatches(size);
        let want = reference.minibatches(size);
        assert_eq!(got.len(), want.len(), "minibatch count");
        for (g, w) in got.iter().zip(&want) {
            assert_batches_equal(g, w, "minibatch");
        }
    });
}

#[test]
fn prop_shuffle_matches_reference_swaps_exactly() {
    // The permutation-gather shuffle consumes the rng exactly like the
    // seed's in-place Fisher–Yates, so same seed => same row order.
    check("shuffle equivalence", 30, |rng| {
        let n = 2 + rng.below(50);
        let d = 1 + rng.below(3);
        let (mut batch, mut reference) = random_pair(rng, n, d);
        let seed = rng.next_u64();
        batch.shuffle(&mut Rng::new(seed));
        reference.shuffle(&mut Rng::new(seed));
        assert_batches_equal(&batch, &reference, "shuffle");
    });
}

#[test]
fn prop_views_are_copy_isolated() {
    // Writing through a view (or the parent) must never be visible on
    // the other side — value semantics survive the sharing.
    check("copy isolation", 25, |rng| {
        let n = 2 + rng.below(30);
        let (batch, reference) = random_pair(rng, n, 2);
        let mut view = batch.slice(0, n / 2 + 1);
        for x in &mut view.rewards {
            *x += 100.0;
        }
        // Parent unchanged.
        assert_eq!(batch.rewards.to_vec(), reference.rewards);
        // View changed.
        assert!(view.rewards.iter().zip(&reference.rewards).all(
            |(v, r)| (v - (r + 100.0)).abs() < 1e-5
        ));
    });
}

// ---------------------------------------------------------------------
// Replay ring wraparound
// ---------------------------------------------------------------------

/// Transitions whose obs encodes a global sequence id, so liveness is
/// checkable after wraparound.
fn transitions(start_id: usize, n: usize) -> SampleBatch {
    let mut b = SampleBatchBuilder::new(2);
    for i in 0..n {
        let id = (start_id + i) as f32;
        b.add_transition(&[id, 0.5], (i % 2) as i32, id, &[id + 1.0, 0.5], false);
    }
    b.build()
}

#[test]
fn prop_replay_ring_serves_only_live_slots_after_wraparound() {
    check("ring wraparound", 20, |rng| {
        let capacity = 16usize; // power of two
        let mut buf =
            PrioritizedReplayBuffer::with_obs_dim(capacity, 2, 0.6, 0.4, rng.next_u64());
        let mut pushed = 0usize;
        // Fill well past capacity in random-sized chunks.
        while pushed < capacity * 3 {
            let n = 1 + rng.below(7);
            buf.add_batch(&transitions(pushed, n));
            pushed += n;
        }
        assert_eq!(buf.len(), capacity);
        let live_min = (pushed - capacity) as f32;
        let live_max = (pushed - 1) as f32;
        let s = buf.sample(64).unwrap();
        assert_eq!(s.batch.len(), 64);
        for i in 0..s.batch.len() {
            let id = s.batch.obs_row(i)[0];
            assert!(
                (live_min..=live_max).contains(&id),
                "sampled stale row id {id}, live range [{live_min}, {live_max}]"
            );
            // Row consistency across the SoA columns.
            assert_eq!(s.batch.rewards[i], id);
            assert_eq!(s.batch.next_obs_row(i)[0], id + 1.0);
        }
        for &idx in &s.indices {
            assert!(idx < capacity, "slot index out of ring bounds");
        }
    });
}

#[test]
fn prop_replay_priorities_apply_to_live_slots_after_wraparound() {
    check("ring priorities", 15, |rng| {
        let capacity = 8usize;
        let mut buf =
            PrioritizedReplayBuffer::with_obs_dim(capacity, 2, 1.0, 0.4, rng.next_u64());
        // Two full generations: ids 0..8 overwritten by ids 8..16.
        buf.add_batch(&transitions(0, capacity));
        buf.add_batch(&transitions(capacity, capacity));
        // Make one slot dominate; it must map to the *new* generation.
        let hot = rng.below(capacity);
        let mut tds = vec![0.001f32; capacity];
        tds[hot] = 1000.0;
        let indices: Vec<usize> = (0..capacity).collect();
        buf.update_priorities(&indices, &tds);
        let s = buf.sample(200).unwrap();
        let hot_frac = s.indices.iter().filter(|&&i| i == hot).count() as f64
            / s.indices.len() as f64;
        assert!(hot_frac > 0.8, "hot slot underrepresented: {hot_frac}");
        // Every sampled hot row carries the overwritten (live) content.
        for i in 0..s.batch.len() {
            if s.indices[i] == hot {
                let id = s.batch.obs_row(i)[0];
                assert_eq!(id, (capacity + hot) as f32, "stale content in slot");
            }
        }
    });
}
