//! Gateway e2e — concurrent client swarms against the elastic serving
//! tier: batched-inference coalescing, idle-deadline reaping, admission
//! control, and (`--ignored`) a connect/disconnect/timeout-mid-episode
//! churn soak under live shard kill/grow/retire.
//!
//! All tests run the dummy policy (no artifacts needed).

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use flowrl::env::GatewayConfig;
use flowrl::ops::GatewayService;
use flowrl::policy::DummyPolicy;
use flowrl::rollout::RestartPolicy;

fn service(num_shards: usize, cfg: GatewayConfig) -> GatewayService {
    GatewayService::new(num_shards, cfg, |_slot| {
        Box::new(DummyPolicy::new(0.01))
    })
}

/// Cheap per-thread generator for the soak's behavior rolls.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

/// N clients hammering one shard in lockstep: their concurrent action
/// requests must coalesce into shared batched forwards (fill > 1), the
/// whole point of the gateway's serving path.
#[test]
fn concurrent_clients_coalesce_into_batched_forwards() {
    const CLIENTS: usize = 8;
    const ROUNDS: usize = 40;
    let svc = service(1, GatewayConfig::default());
    let barrier = Arc::new(Barrier::new(CLIENTS));

    let handles: Vec<_> = (0..CLIENTS)
        .map(|t| {
            let svc = svc.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let obs = vec![t as f32; 4];
                let session = svc.connect().expect("connect");
                for _ in 0..ROUNDS {
                    barrier.wait();
                    session.request_action(&obs).expect("serve");
                    session.log_reward(1.0).expect("reward");
                }
                session.end(Some(&obs)).expect("end");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let stats = svc.backlog_stats();
    assert_eq!(stats.completed, CLIENTS as u64);
    assert_eq!(stats.batched_rows, (CLIENTS * ROUNDS) as u64);
    assert!(
        stats.max_batch_fill > 1,
        "{CLIENTS} lockstep clients never shared a forward \
         (max fill {})",
        stats.max_batch_fill
    );
    assert!(stats.p99_action_latency_us > 0.0);
}

/// A client that goes quiet past the idle deadline is reaped (its slot
/// freed, its lease dead) while an active client on the same shard is
/// untouched.
#[test]
fn idle_client_is_reaped_active_client_is_not() {
    let cfg = GatewayConfig {
        idle_deadline_ns: 20_000_000, // 20ms
        forgiveness: 0,
        ..GatewayConfig::default()
    };
    let svc = service(1, cfg);
    let obs = [0.0f32; 4];

    let idler = svc.connect().expect("connect idler");
    idler.request_action(&obs).expect("idler first step");
    let keeper = svc.connect().expect("connect keeper");

    // The keeper's traffic drives the shard's reap cadence while the
    // idler sits past its deadline.
    let deadline = Instant::now() + Duration::from_millis(500);
    loop {
        keeper.request_action(&obs).expect("keeper step");
        std::thread::sleep(Duration::from_millis(5));
        if svc.backlog_stats().reaped >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "idle session never reaped: {:?}",
            svc.backlog_stats()
        );
    }

    assert!(
        idler.request_action(&obs).is_err(),
        "reaped session still served"
    );
    keeper.request_action(&obs).expect("active session reaped");
    keeper.end(None).expect("keeper end");
}

/// A shard at its admission watermark sheds new connects instead of
/// queueing them; ending an episode frees the slot for the next client.
#[test]
fn admission_watermark_sheds_connects() {
    let cfg = GatewayConfig { max_sessions: 2, ..GatewayConfig::default() };
    let svc = service(1, cfg);
    let obs = [0.0f32; 4];

    let s1 = svc.connect().expect("first admit");
    let s2 = svc.connect().expect("second admit");
    assert!(svc.connect().is_err(), "watermark connect not shed");
    assert!(svc.backlog_stats().shed >= 1);

    s1.end(None).expect("end");
    let s3 = svc.connect().expect("freed slot re-admits");
    s3.request_action(&obs).expect("serve on freed slot");
    s3.end(Some(&obs)).expect("end");
    s2.end(None).expect("end");
}

/// Churn soak (CI `--chaos` gate): a client swarm that connects,
/// disconnects mid-episode, and times out mid-episode, under a chaos
/// thread growing/retiring/killing shards the whole time.  Passes if
/// nothing deadlocks or panics and the service still serves full
/// episodes afterwards.
#[test]
#[ignore]
fn churn_soak_under_shard_chaos() {
    const CLIENTS: usize = 8;
    const SOAK: Duration = Duration::from_secs(8);
    let cfg = GatewayConfig {
        max_sessions: 64,
        idle_deadline_ns: 50_000_000, // 50ms
        forgiveness: 0,
        ..GatewayConfig::default()
    };
    let svc = service(2, cfg);
    let stop = Arc::new(AtomicBool::new(false));

    // Clients: mostly clean episodes; some abandon the session without
    // ending it (reaper's problem), some stall past the idle deadline
    // mid-episode and must observe an error, never a hang.
    let clients: Vec<_> = (0..CLIENTS)
        .map(|t| {
            let svc = svc.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Lcg(0xC0FFEE ^ ((t as u64) << 7));
                let obs = vec![t as f32; 4];
                let mut completed = 0u64;
                while !stop.load(Relaxed) {
                    let Ok(session) = svc.connect() else {
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    };
                    let len = 5 + (rng.next() % 25) as usize;
                    let fate = rng.next() % 10;
                    let mut alive = true;
                    for step in 0..len {
                        if session.request_action(&obs).is_err() {
                            alive = false;
                            break;
                        }
                        let _ = session.log_reward(1.0);
                        if fate == 0 && step == len / 2 {
                            // Stall past the idle deadline; the
                            // session may be reaped under us.
                            std::thread::sleep(Duration::from_millis(
                                80,
                            ));
                        }
                    }
                    if fate == 1 {
                        drop(session); // abandon without end()
                    } else if alive && session.end(Some(&obs)).is_ok() {
                        completed += 1;
                    }
                }
                completed
            })
        })
        .collect();

    // Chaos: retire/grow the pool and force-kill live shards while the
    // swarm runs; killed shards restart under a generous budget.
    let chaos = {
        let svc = svc.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let restart = RestartPolicy {
                max_restarts: 10_000,
                backoff_base: Duration::from_millis(1),
                backoff_cap: Duration::from_millis(5),
                reset_after: Duration::from_millis(50),
            };
            let sizes = [1usize, 3, 2];
            let mut cycle = 0usize;
            while !stop.load(Relaxed) {
                std::thread::sleep(Duration::from_millis(100));
                let _ = svc.scale_to(sizes[cycle % sizes.len()]);
                if cycle % 3 == 2 {
                    let live = svc.registry().live_indices();
                    if let Some(&slot) = live.first() {
                        if let Some((h, _)) = svc.registry().get_live(slot)
                        {
                            h.kill();
                        }
                    }
                }
                let _ = svc.restart_dead_with_policy(&restart);
                cycle += 1;
            }
            // Leave the pool healthy for the post-soak check.
            let _ = svc.restart_dead_with_policy(&restart);
            let _ = svc.scale_to(2);
        })
    };

    std::thread::sleep(SOAK);
    stop.store(true, Relaxed);
    let completed: u64 =
        clients.into_iter().map(|h| h.join().unwrap()).sum();
    chaos.join().unwrap();

    assert!(
        completed > 0,
        "no client episode survived the soak: {:?}",
        svc.backlog_stats()
    );
    assert!(svc.num_live_shards() >= 1);

    // The tier must still serve a full clean episode.
    let obs = [0.5f32; 4];
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(session) = svc.connect() {
            let mut ok = true;
            for _ in 0..10 {
                if session.request_action(&obs).is_err() {
                    ok = false;
                    break;
                }
            }
            if ok && session.end(Some(&obs)).is_ok() {
                break;
            }
        }
        assert!(
            Instant::now() < deadline,
            "service cannot serve a clean episode after the soak"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = svc.backlog_stats();
    println!(
        "soak: completed={completed} started={} shed={} reaped={} \
         lost={} ticks={} max_fill={}",
        stats.started,
        stats.shed,
        stats.reaped,
        svc.counters().sessions_lost.load(Relaxed),
        stats.ticks,
        stats.max_batch_fill
    );
}
