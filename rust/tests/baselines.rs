//! Integration tests for the low-level baseline optimizers: each must
//! train with the same artifacts/policies as its dataflow twin.

use std::path::PathBuf;

use flowrl::algorithms::{EnvKind, TrainerConfig};
use flowrl::baseline::{
    AsyncGradientsOptimizer, AsyncPipelineOptimizer, AsyncReplayOptimizer,
    MicrobatchPpo, SyncReplayOptimizer, SyncSamplesOptimizer,
};
use flowrl::policy::PgLossKind;
use flowrl::rollout::CollectMode;

fn artifacts() -> PathBuf {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "run `make artifacts` before cargo test"
    );
    p
}

/// Skip (pass vacuously) when the AOT artifacts are absent — offline
/// builds have no PJRT backend, so nothing XLA-backed can run.
macro_rules! require_artifacts {
    () => {
        if !PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
        {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn test_config(num_workers: usize) -> TrainerConfig {
    TrainerConfig {
        num_workers,
        num_envs_per_worker: 2,
        rollout_fragment_length: 16,
        train_batch_size: 64,
        lr: 5e-3,
        artifacts_dir: artifacts(),
        seed: 11,
        num_async: 1,
        env: EnvKind::CartPole,
        ..TrainerConfig::default()
    }
}

#[test]
fn async_gradients_baseline_trains() {
    require_artifacts!();
    let cfg = test_config(2);
    let workers = cfg.pg_workers(PgLossKind::A3c, CollectMode::OnPolicy);
    let mut opt = AsyncGradientsOptimizer::new(workers);
    let mut last = None;
    for _ in 0..4 {
        last = Some(opt.step());
    }
    let r = last.unwrap();
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["loss"].is_finite());
    assert!(!opt.timer_report().is_empty());
}

#[test]
fn sync_samples_baseline_trains() {
    require_artifacts!();
    let cfg = test_config(2);
    let workers = cfg.pg_workers(
        PgLossKind::Ppo { epochs: 1 },
        CollectMode::OnPolicy,
    );
    let mut opt = SyncSamplesOptimizer::new(workers, cfg.train_batch_size);
    let r = (0..3).map(|_| opt.step()).last().unwrap();
    assert!(r.num_env_steps_trained >= 3 * 64);
    assert!(r.learner_stats["kl"].is_finite());
}

#[test]
fn sync_replay_baseline_trains() {
    require_artifacts!();
    let mut cfg = test_config(2);
    cfg.rollout_fragment_length = 32;
    let workers = cfg.dqn_workers();
    let mut opt = SyncReplayOptimizer::new(workers, 2048, 64, 64, 500);
    let r = (0..4).map(|_| opt.step()).last().unwrap();
    assert!(r.num_env_steps_trained > 0, "never learned");
    assert!(r.learner_stats["loss"].is_finite());
}

#[test]
fn async_replay_baseline_trains() {
    require_artifacts!();
    let mut cfg = test_config(2);
    cfg.rollout_fragment_length = 32;
    let workers = cfg.dqn_workers();
    let mut opt =
        AsyncReplayOptimizer::new(workers, 2, 2048, 64, 64, 64, 500);
    let mut trained = 0;
    for _ in 0..8 {
        trained = opt.step().num_env_steps_trained;
        if trained > 0 {
            break;
        }
    }
    assert!(trained > 0, "async replay never trained");
}

#[test]
fn async_pipeline_baseline_trains() {
    require_artifacts!();
    let mut cfg = test_config(2);
    // IMPALA geometry from the manifest.
    let m = flowrl::runtime::Manifest::load(artifacts().join("manifest.json"))
        .unwrap();
    cfg.rollout_fragment_length = m.config.impala_t;
    cfg.num_envs_per_worker = m.config.impala_b;
    let workers = cfg
        .pg_workers(PgLossKind::Impala, CollectMode::OnPolicyWithNextObs);
    let mut opt = AsyncPipelineOptimizer::new(
        workers,
        m.config.impala_t,
        m.config.impala_b,
        2,
    );
    let r = (0..3).map(|_| opt.step()).last().unwrap();
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["entropy"].is_finite());
}

#[test]
fn microbatch_spark_style_trains_with_overheads() {
    require_artifacts!();
    let mut cfg = test_config(2);
    cfg.train_batch_size = 64;
    let dir = std::env::temp_dir().join(format!(
        "flowrl_mb_test_{}",
        std::process::id()
    ));
    let mut mb = MicrobatchPpo::new(cfg, 1, &dir);
    let mut total_init = std::time::Duration::ZERO;
    for _ in 0..2 {
        let t = mb.step();
        assert!(t.sample > std::time::Duration::ZERO);
        assert!(t.train > std::time::Duration::ZERO);
        total_init += t.init;
    }
    // The whole point of the comparison: per-iteration re-init costs
    // are structural and nonzero.
    assert!(total_init > std::time::Duration::from_millis(1));
    assert!(mb.num_steps_sampled >= 128);
    std::fs::remove_dir_all(&dir).ok();
}
