//! Allocation-freedom test for the actor control plane (acceptance
//! criterion of the control-plane v2 PR): once an actor and a
//! completion queue are warm, `cast`, `call`, and `call_into` perform
//! **zero** heap allocations on the sending thread per message.
//!
//! The seed runtime boxed a `dyn FnOnce` per message and allocated an
//! mpsc node + a reply channel per call; the ring mailbox writes the
//! closure into a preallocated envelope slot, `call` parks on a
//! stack-held reply cell, and `call_into` delivers through the
//! preallocated completion-queue ring.
//!
//! The counting allocator counts per-thread (a thread-local counter),
//! so allocator traffic from actor threads or the test harness cannot
//! produce false positives/negatives; this file holds a single test for
//! the same reason.
//!
//! The fault-injection plane (`actor::faults`) is compiled into every
//! send/loop site permanently — no cfg gate — so this test also pins
//! its disarmed cost: one relaxed atomic load per site, no allocation.
//! The warmup covers the registry's one-time `OnceLock` init; no rule
//! is armed here, so if these assertions trip after touching the fault
//! plane, a failpoint grew onto the steady-state path.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use flowrl::actor::{ActorHandle, Completion, CompletionQueue};

struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

#[test]
fn steady_state_control_plane_is_allocation_free() {
    let h = ActorHandle::spawn("alloc-probe", || 0u64);
    let q: CompletionQueue<u64> = CompletionQueue::bounded(8);

    // Warm up every lazy path: thread, ring, queue storage, TLS.
    for i in 0..64u64 {
        h.cast(move |s| *s += i);
    }
    assert!(h.call(|s| *s).unwrap() > 0);
    for k in 0..8 {
        h.call_into(k, &q, |s| *s);
    }
    for _ in 0..8 {
        let _ = q.pop();
    }

    const N: u64 = 1_000;

    // cast: envelope slot write + condvar signal, nothing else.
    let before = allocs_here();
    for i in 0..N {
        h.cast(move |s| *s += i);
    }
    let cast_allocs = allocs_here() - before;

    // call: stack reply cell; also drains the casts above.
    let before = allocs_here();
    for _ in 0..N {
        h.call(|s| *s).unwrap();
    }
    let call_allocs = allocs_here() - before;

    // call_into + pop: completion-queue ring roundtrip.
    let before = allocs_here();
    for k in 0..N as usize {
        h.call_into(k % 4, &q, |s| *s);
        match q.pop() {
            Completion::Item { .. } => {}
            Completion::Dropped { tag } => panic!("actor died on {tag}"),
        }
    }
    let call_into_allocs = allocs_here() - before;

    // try_cast: the non-blocking send takes the same inline-envelope
    // path as cast (its `// flowlint: hot-path` mark).  A `call`
    // barrier after each 4-message batch keeps the mailbox drained so
    // the measured sends never observe Full; call itself is asserted
    // allocation-free above, so it cannot mask a try_cast allocation.
    let before = allocs_here();
    for _ in 0..(N / 4) {
        for i in 0..4u64 {
            h.try_cast(move |s| *s += i).expect("drained mailbox is Full");
        }
        h.call(|s| *s).unwrap();
    }
    let try_cast_allocs = allocs_here() - before;

    assert_eq!(cast_allocs, 0, "cast allocated {cast_allocs}x per {N} msgs");
    assert_eq!(call_allocs, 0, "call allocated {call_allocs}x per {N} msgs");
    assert_eq!(
        call_into_allocs, 0,
        "call_into allocated {call_into_allocs}x per {N} msgs"
    );
    assert_eq!(
        try_cast_allocs, 0,
        "try_cast allocated {try_cast_allocs}x per {N} msgs"
    );
}
