//! Elastic scale-out acceptance tests: a `WorkerSet` grows and shrinks
//! under a *running* dataflow plan — gathers discover shards appended
//! by `scale_to`/`add_worker` through the registry's publish counter,
//! tombstoned shards drain out, and the whole protocol survives a
//! chaos soak (grow 2 -> 8 while killing one worker per round) with no
//! duplicated completions and final weight-version convergence.
//!
//! The soak (`chaos_soak_grow_kill_converge`) is `#[ignore]`d from the
//! default `cargo test` run and executed by `tools/ci.sh --chaos`
//! (a dedicated job in `.github/workflows/ci.yml`).
//!
//! These run on the Dummy env/policy, so they need no AOT artifacts.

use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use flowrl::actor::{ActorHandle, ShardRegistry};
use flowrl::env::{DummyEnv, Env, MultiAgentCartPole};
use flowrl::iter::ParIter;
use flowrl::ops::parallel_rollouts_from;
use flowrl::policy::{DummyPolicy, Policy};
use flowrl::rollout::{
    CollectMode, MultiAgentRolloutWorker, RolloutWorker, WorkerSet,
};

fn worker_set(n_remote: usize) -> WorkerSet {
    WorkerSet::new(n_remote, |_| {
        Box::new(|| {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(DummyPolicy::new(0.1)),
                4,
                CollectMode::OnPolicy,
            )
        })
    })
}

/// The PR's acceptance criterion: a `gather_async` stream started on a
/// 2-worker set observes completions from workers added via
/// `scale_to(4)` — same iterator, no plan rebuild.
#[test]
fn gather_async_observes_workers_added_by_scale_to() {
    let set = worker_set(2);
    set.local.call(|w| w.set_weights(&[0.625])).unwrap();
    let mut it = parallel_rollouts_from(&set).gather_async_with_source(1);

    // Live off the original pair.
    for _ in 0..4 {
        assert!(it.next().is_some());
    }

    let (added, removed) = set.scale_to(4).unwrap();
    assert_eq!(added, vec![2, 3]);
    assert!(removed.is_empty());
    let new_ids: HashSet<u64> = added
        .iter()
        .map(|&i| set.remote(i).expect("live remote").id())
        .collect();

    // The SAME running gather must start yielding the new workers'
    // batches.
    let mut seen_new = HashSet::new();
    for _ in 0..64 {
        let (batch, src) = it.next().expect("stream must keep flowing");
        assert_eq!(batch.len(), 4);
        if new_ids.contains(&src.id()) {
            seen_new.insert(src.id());
        }
        if seen_new.len() == new_ids.len() {
            break;
        }
    }
    assert_eq!(
        seen_new.len(),
        new_ids.len(),
        "grown workers never joined the running gather"
    );
    // The additions sampled with the learner's weights, not blanks.
    for &i in &added {
        let h = set.remote(i).expect("live remote");
        assert_eq!(h.call(|w| w.get_weights()).unwrap(), vec![0.625]);
    }
}

#[test]
fn stream_survives_scale_down_then_back_up() {
    let set = worker_set(4);
    let mut it = parallel_rollouts_from(&set).gather_async_with_source(2);
    for _ in 0..8 {
        assert!(it.next().is_some());
    }
    let removed_ids: HashSet<u64> = [2, 3]
        .map(|i| set.remote(i).expect("live remote").id())
        .into();
    let (added, removed) = set.scale_to(2).unwrap();
    assert!(added.is_empty());
    assert_eq!(removed, vec![3, 2]);
    assert_eq!(set.num_live_remotes(), 2);

    // Tombstoned workers' in-flight items are drained (discarded by
    // the gather), never yielded: the stream continues off survivors.
    for _ in 0..24 {
        let (_b, src) = it.next().expect("stream must survive scale-down");
        assert!(
            !removed_ids.contains(&src.id()),
            "item attributed to a removed worker"
        );
    }

    // Scale back up: the tombstoned slots are reused (epoch bump) and
    // rejoin the same stream.
    let (added, _) = set.scale_to(3).unwrap();
    assert_eq!(added, vec![2]);
    let revived = set.remote(2).expect("live remote").id();
    let mut seen_revived = false;
    for _ in 0..48 {
        let (_b, src) = it.next().unwrap();
        if src.id() == revived {
            seen_revived = true;
            break;
        }
    }
    assert!(seen_revived, "reused slot never rejoined the stream");
}

#[test]
fn gather_sync_admits_scale_up_at_round_boundary() {
    let set = worker_set(2);
    let mut it = parallel_rollouts_from(&set).gather_sync();
    assert_eq!(it.next().unwrap().len(), 2);
    set.scale_to(3).unwrap();
    // Next boundary: the grown worker is part of the barrier round.
    assert_eq!(it.next().unwrap().len(), 3);
    set.scale_to(2).unwrap();
    assert_eq!(it.next().unwrap().len(), 2);
}

/// A full training plan (rollouts -> TrainOneStep -> metrics) keeps
/// reporting while the set scales, and the scale events surface in
/// `TrainResult::scale` / `pipeline_summary()`.
#[test]
fn train_plan_streams_across_scaling_and_reports_events() {
    use flowrl::ops::{train_one_step, Reporting};

    let set = worker_set(2);
    let mut train = train_one_step(&set);
    let train_op = parallel_rollouts_from(&set)
        .gather_async(1)
        .for_each(move |b| train(b));
    let mut reports = Reporting::new(train_op, &set, 2).build();

    assert!(reports.next().is_some());
    set.scale_to(4).unwrap();
    set.remove_worker(0);
    let mut last = None;
    for _ in 0..4 {
        last = reports.next();
        assert!(last.is_some(), "reporting stopped across a scale event");
    }
    let r = last.unwrap();
    let sc = r.scale.expect("scale stats attached");
    assert_eq!((sc.added, sc.removed, sc.live, sc.slots), (2, 1, 3, 4));
    let summary = r.pipeline_summary();
    assert!(summary.contains("scale=3/4slots(+2 -1)"), "{summary}");
    // Weight versions kept broadcasting throughout (one per train item).
    assert!(r.weight_casts.unwrap().version >= 5);
}

/// Grow-then-kill-then-restart on the same shard: epochs stay monotone
/// (0 at grow, +1 per restart) and the stream keeps flowing through
/// each incarnation.
#[test]
fn grow_kill_restart_keeps_epochs_monotone() {
    let set = worker_set(1);
    let mut it = parallel_rollouts_from(&set).gather_async_with_source(1);
    assert!(it.next().is_some());

    let (added, _) = set.scale_to(2).unwrap();
    assert_eq!(added, vec![1]);
    assert_eq!(set.registry().epoch(1), 0, "grown shards start at epoch 0");

    for round in 1..=2u64 {
        let victim = set.remote(1).expect("live remote");
        let _ = victim.call(|_| -> () { panic!("fault injection") });
        assert!(victim.await_poisoned(Duration::from_secs(5)));
        assert_eq!(set.restart_dead(), vec![1]);
        assert_eq!(
            set.registry().epoch(1),
            round,
            "epoch must advance monotonically across restarts"
        );
        // The replacement incarnation feeds the same running gather.
        let fresh = set.remote(1).expect("live remote").id();
        let mut seen_fresh = false;
        for _ in 0..48 {
            let (_b, src) = it.next().expect("stream must keep flowing");
            if src.id() == fresh {
                seen_fresh = true;
                break;
            }
        }
        assert!(seen_fresh, "incarnation {round} never rejoined");
    }
}

/// The 16-bit tag-space guard: `grow` beyond the cap errors out instead
/// of handing out an index that would corrupt `(epoch << 16) | shard`
/// tags, and the running gather is unaffected.
#[test]
fn grow_beyond_tag_space_errors_cleanly() {
    struct Src {
        id: usize,
        n: u64,
    }
    let spawn = |id: usize| {
        ActorHandle::spawn("scale-src", move || Src { id, n: 0 })
    };
    // Production cap is 65536; the guard path is identical at 3.
    let registry = ShardRegistry::with_max_shards(vec![spawn(0), spawn(1)], 3);
    let mut it = ParIter::from_registry(registry.clone(), |s: &mut Src| {
        s.n += 1;
        Some((s.id, s.n))
    })
    .gather_async(1);
    assert!(it.next().is_some());

    assert_eq!(registry.grow(spawn(2)).unwrap(), 2);
    let err = registry.grow(spawn(3)).unwrap_err();
    assert!(err.to_string().contains("16-bit"), "{err}");
    assert_eq!(registry.len(), 3, "failed grow must not consume a slot");

    // All three admitted shards stream; no tag corruption, no phantom
    // fourth shard.
    let mut ids = HashSet::new();
    for _ in 0..24 {
        let (id, _) = it.next().unwrap();
        assert!(id < 3);
        ids.insert(id);
    }
    assert_eq!(ids.len(), 3);
}

/// The `remote(i)` tombstone bugfix: a scaled-down set answers `None`
/// for the hole instead of panicking the driver, and the audited
/// post-scale-down paths (weight sync, metrics draining) keep working.
#[test]
fn remote_on_tombstoned_slot_returns_none() {
    let set = worker_set(3);
    assert!(set.remove_worker(1));
    assert!(set.remote(1).is_none(), "tombstone must not panic");
    assert!(set.remote(0).is_some());
    assert!(set.remote(2).is_some());
    // Audited callers survive the hole.
    set.sync_weights();
    let (_eps, _steps) = set.collect_metrics();
    // The slot revives on the next add and answers again.
    assert_eq!(set.add_worker().unwrap(), 1);
    assert!(set.remote(1).is_some());
}

/// The capacity-reclaim bugfix at the WorkerSet level: many scale
/// up/down cycles under one running gather keep the stream healthy —
/// an unreclaimed (or over-reclaimed) in-flight bound would eventually
/// stall or deadlock the gather.
#[test]
fn scale_cycles_keep_stream_healthy() {
    let set = worker_set(1);
    let mut it = parallel_rollouts_from(&set).gather_async_with_source(2);
    for _ in 0..4 {
        assert!(it.next().is_some());
    }
    for cycle in 0..6 {
        set.scale_to(3).unwrap();
        for _ in 0..12 {
            assert!(it.next().is_some(), "cycle {cycle}: stalled after up");
        }
        set.scale_to(1).unwrap();
        for _ in 0..12 {
            assert!(
                it.next().is_some(),
                "cycle {cycle}: stalled after down"
            );
        }
    }
    let sc = set.scale_stats();
    assert_eq!((sc.added, sc.removed, sc.live), (12, 12, 1));
    assert_eq!(sc.slots, 3, "tombstones reused, no slot growth");
}

// ---------------------------------------------------------------------
// Multi-agent WorkerSet: the same scale-out acceptance as above, on the
// MultiAgentRolloutWorker instantiation of the generic elastic owner.
// ---------------------------------------------------------------------

/// A Dummy-backed multi-agent set (no AOT artifacts): 2 policies
/// ("even"/"odd"), running the **shipped** per-policy spawn-and-sync
/// protocol (`algorithms::ma_sync_protocol`) so these tests cover what
/// `ma_worker_set` actually does.
fn ma_set(n_remote: usize) -> WorkerSet<MultiAgentRolloutWorker> {
    WorkerSet::with_protocol(
        "ma_local",
        "ma_worker",
        n_remote,
        |i| {
            Box::new(move || {
                let env = MultiAgentCartPole::new(2, i as u64, |a| {
                    if a % 2 == 0 { "even".into() } else { "odd".into() }
                });
                let mut policies: BTreeMap<String, Box<dyn Policy>> =
                    BTreeMap::new();
                policies.insert("even".into(), Box::new(DummyPolicy::new(0.1)));
                policies.insert("odd".into(), Box::new(DummyPolicy::new(0.1)));
                MultiAgentRolloutWorker::new(env, policies, 4)
            })
        },
        flowrl::algorithms::ma_sync_protocol(),
    )
}

/// Multi-agent mirror of the single-agent acceptance criterion: a
/// running `gather_async` over a multi-agent set observes completions
/// from workers added by `scale_to` — and every added worker starts
/// with **both** policies' learner weights.
#[test]
fn ma_gather_async_observes_workers_added_by_scale_to() {
    let set = ma_set(2);
    set.local
        .call(|w| {
            w.set_weights("even", &[0.25]);
            w.set_weights("odd", &[0.75]);
        })
        .unwrap();
    let registry = set.registry().clone();
    let mut it = ParIter::from_registry(registry, |w| Some(w.sample()))
        .gather_async_with_source(1);
    for _ in 0..4 {
        let (ma, _src) = it.next().expect("stream must flow");
        assert_eq!(ma.count(), 8); // 2 agents x fragment 4
    }

    let (added, removed) = set.scale_to(4).unwrap();
    assert_eq!(added, vec![2, 3]);
    assert!(removed.is_empty());
    let new_ids: HashSet<u64> = added
        .iter()
        .map(|&i| set.remote(i).expect("live remote").id())
        .collect();

    let mut seen_new = HashSet::new();
    for _ in 0..64 {
        let (_ma, src) = it.next().expect("stream must keep flowing");
        if new_ids.contains(&src.id()) {
            seen_new.insert(src.id());
        }
        if seen_new.len() == new_ids.len() {
            break;
        }
    }
    assert_eq!(
        seen_new.len(),
        new_ids.len(),
        "grown multi-agent workers never joined the running gather"
    );
    // The per-policy spawn-and-sync delivered BOTH policies' weights.
    for &i in &added {
        let h = set.remote(i).expect("live remote");
        let (even, odd) = h
            .call(|w| (w.get_weights("even"), w.get_weights("odd")))
            .unwrap();
        assert_eq!(even, vec![0.25]);
        assert_eq!(odd, vec![0.75]);
    }
}

/// Multi-agent scale-down mid-plan: tombstoned workers drain out of the
/// running stream (never attributed), and the reused slot rejoins.
#[test]
fn ma_stream_survives_scale_down_then_back_up() {
    let set = ma_set(4);
    let registry = set.registry().clone();
    let mut it = ParIter::from_registry(registry, |w| Some(w.sample()))
        .gather_async_with_source(2);
    for _ in 0..8 {
        assert!(it.next().is_some());
    }
    let removed_ids: HashSet<u64> = [2, 3]
        .map(|i| set.remote(i).expect("live remote").id())
        .into();
    let (added, removed) = set.scale_to(2).unwrap();
    assert!(added.is_empty());
    assert_eq!(removed, vec![3, 2]);
    assert_eq!(set.num_live_remotes(), 2);
    // A tombstoned slot answers None instead of panicking the driver.
    assert!(set.remote(2).is_none());

    for _ in 0..24 {
        let (_ma, src) = it.next().expect("stream must survive scale-down");
        assert!(
            !removed_ids.contains(&src.id()),
            "item attributed to a removed multi-agent worker"
        );
    }

    let (added, _) = set.scale_to(3).unwrap();
    assert_eq!(added, vec![2]);
    let revived = set.remote(2).expect("live remote").id();
    let mut seen_revived = false;
    for _ in 0..48 {
        if it.next().unwrap().1.id() == revived {
            seen_revived = true;
            break;
        }
    }
    assert!(seen_revived, "reused multi-agent slot never rejoined");
}

/// Multi-agent restart: kill a worker mid-stream, `restart_dead`
/// publishes a replacement carrying both policies' weights into the
/// SAME running gather.
#[test]
fn ma_killed_worker_rejoins_running_gather() {
    let set = ma_set(2);
    set.local
        .call(|w| {
            w.set_weights("even", &[0.5]);
            w.set_weights("odd", &[1.5]);
        })
        .unwrap();
    let registry = set.registry().clone();
    let mut it = ParIter::from_registry(registry, |w| Some(w.sample()))
        .gather_async_with_source(1);
    for _ in 0..4 {
        assert!(it.next().is_some());
    }
    let victim = set.remote(1).expect("live remote");
    let _ = victim.call(|_| -> () { panic!("fault injection") });
    assert!(victim.await_poisoned(Duration::from_secs(5)));
    assert_eq!(set.restart_dead(), vec![1]);
    let fresh = set.remote(1).expect("live remote");
    assert_ne!(fresh.id(), victim.id());
    let mut fresh_items = 0;
    for _ in 0..64 {
        let (_ma, src) = it.next().expect("stream must keep flowing");
        assert_ne!(src.id(), victim.id(), "item attributed to the corpse");
        if src.id() == fresh.id() {
            fresh_items += 1;
        }
    }
    assert!(fresh_items > 0, "ma replacement never rejoined");
    let (even, odd) = fresh
        .call(|w| (w.get_weights("even"), w.get_weights("odd")))
        .unwrap();
    assert_eq!(even, vec![0.5]);
    assert_eq!(odd, vec![1.5]);
}

/// The chaos soak behind `tools/ci.sh --chaos`: grow the set 2 -> 8
/// while killing (and restarting) one worker per round under a running
/// `gather_async`, with weight broadcasts in flight.  Asserts:
///
/// * no completion is ever yielded twice (per-item sequence numbers
///   are handed out on the worker actors and collected exactly once);
/// * after the churn stops, every live shard contributes (nothing fell
///   silent, nothing streams from a corpse incarnation);
/// * weight versions converge: the final barrier broadcast reaches all
///   8 workers and every live caster lane reports the newest version.
///
/// Bounded well under 60s; `ci.sh --chaos` adds a hard timeout on top.
#[test]
#[ignore = "chaos soak: executed by tools/ci.sh --chaos"]
fn chaos_soak_grow_kill_converge() {
    let set = worker_set(2);
    set.local.call(|w| w.set_weights(&[0.0])).unwrap();
    let caster = set.caster();

    // Per-completion sequence numbers, assigned on the worker actors:
    // a duplicated completion would insert twice below.
    let seq = Arc::new(AtomicU64::new(0));
    let plan_seq = seq.clone();
    let mut it =
        ParIter::from_registry(set.registry().clone(), move |w| {
            let batch = w.sample();
            assert_eq!(batch.len(), 4);
            Some(plan_seq.fetch_add(1, Ordering::SeqCst))
        })
        .gather_async_with_source(2);

    let mut seen = HashSet::new();
    for round in 0..7usize {
        // Grow one step toward 8 workers.
        let target = 2 + round + 1;
        set.scale_to(target).unwrap();

        // A new weight version under live traffic.
        let v = round as f32 + 1.0;
        set.local.call(move |w| w.set_weights(&[v])).unwrap();
        caster.broadcast(vec![v].into());

        // Kill one live worker and restart it into the same stream.
        let live = set.registry().live_indices();
        let victim_idx = live[round % live.len()];
        let victim = set.remote(victim_idx).expect("live remote");
        let _ = victim.call(|_| -> () { panic!("chaos kill") });
        assert!(victim.await_poisoned(Duration::from_secs(5)));
        assert_eq!(set.restart_dead(), vec![victim_idx]);

        // Stream through the churn; every completion exactly once.
        for _ in 0..20 {
            let (s, _src) = it.next().expect("stream died under chaos");
            assert!(seen.insert(s), "completion {s} yielded twice");
        }
    }

    // Quiesce at 8 workers: every live shard contributes and nothing
    // streams from a dead incarnation.
    assert_eq!(set.num_live_remotes(), 8);
    let live_ids: HashSet<u64> =
        set.remotes().iter().map(|h| h.id()).collect();
    let mut contributors = HashSet::new();
    for _ in 0..96 {
        let (s, src) = it.next().unwrap();
        assert!(seen.insert(s), "completion {s} yielded twice");
        assert!(
            live_ids.contains(&src.id()),
            "item attributed to a corpse incarnation"
        );
        contributors.insert(src.id());
    }
    assert_eq!(
        contributors.len(),
        8,
        "a shard fell silent after the soak: {contributors:?}"
    );

    // Weight-version convergence: the final barrier reaches all 8 and
    // every live lane reports the newest version.
    set.local.call(|w| w.set_weights(&[42.0])).unwrap();
    set.sync_weights();
    for h in set.remotes() {
        assert_eq!(h.call(|w| w.get_weights()).unwrap(), vec![42.0]);
    }
    let newest = caster.stats().version;
    let applied = caster.applied_versions();
    for i in set.registry().live_indices() {
        assert!(
            applied[i] >= newest,
            "lane {i} applied v{} < newest v{newest}",
            applied[i]
        );
    }
    println!(
        "chaos soak: {} unique completions, {} weight versions, 7 kills, \
         2 -> 8 workers",
        seen.len(),
        newest
    );
}

// ---------------------------------------------------------------------
// Replay tier: registry-backed shards under live store+replay traffic
// ---------------------------------------------------------------------

/// The sharded-replay PR's acceptance criterion: the replay tier is the
/// same elastic registry machinery as the rollout workers.  A `replay`
/// stream started on 2 shards (a) keeps yielding while `scale_to(4)`
/// grows the pool — the store op hash-routes new batches onto the added
/// shards and the SAME running gather adopts them — and (b) survives
/// `scale_to(1)` retiring three live shards mid-stream, with every
/// subsequent lease resolving to the survivor.  No plan rebuild at any
/// point.
#[test]
fn replay_stream_adopts_shards_added_and_retired_by_scale_to() {
    use flowrl::ops::{create_replay_shards, replay, store_to_replay_buffer};
    use flowrl::sample_batch::{SampleBatch, SampleBatchBuilder};

    fn transitions(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition(
                &[i as f32, 0.0],
                0,
                1.0,
                &[i as f32 + 1.0, 0.0],
                false,
            );
        }
        b.build()
    }

    let service = create_replay_shards(2, 2, 256, 8, 4);
    let mut store = store_to_replay_buffer(&service);
    let mut it = replay(&service, 2);

    // Warm both shards past learning_starts and draw off the pair.
    for _ in 0..10 {
        store(transitions(4));
    }
    let mut drawn = 0;
    while drawn < 4 {
        if let Some((sample, lease)) = it.next().unwrap() {
            assert_eq!(sample.batch.len(), 4);
            assert!(lease.shard_idx().unwrap() < 2);
            drawn += 1;
        }
    }

    // Grow mid-stream: the store op routes onto the new slots on later
    // batches, and the running gather must start yielding their samples.
    let (added, removed) = service.scale_to(4).unwrap();
    assert_eq!(added, vec![2, 3]);
    assert!(removed.is_empty());
    let mut seen_new = HashSet::new();
    for _ in 0..4096 {
        store(transitions(4));
        if let Some((_, lease)) = it.next().unwrap() {
            let idx = lease.shard_idx().expect("live producer");
            if idx >= 2 {
                seen_new.insert(idx);
            }
        }
        if seen_new.len() == 2 {
            break;
        }
    }
    assert_eq!(
        seen_new,
        HashSet::from([2, 3]),
        "running replay stream never adopted the grown shards"
    );

    // Shrink to 1 under the same stream: the three highest live slots
    // retire; the stream keeps yielding and every lease resolves to the
    // survivor.
    let (added, removed) = service.scale_to(1).unwrap();
    assert!(added.is_empty());
    assert_eq!(removed, vec![3, 2, 1]);
    assert_eq!(service.num_live_shards(), 1);
    let mut survivor_draws = 0;
    for _ in 0..4096 {
        store(transitions(4));
        if let Some((_, lease)) = it.next().unwrap() {
            // In-flight samples of just-retired shards may still drain
            // out with an unresolvable lease; fresh draws must all come
            // from slot 0.
            if let Some(idx) = lease.shard_idx() {
                assert_eq!(idx, 0, "lease resolved to a retired slot");
                survivor_draws += 1;
            }
        }
        if survivor_draws >= 8 {
            break;
        }
    }
    assert!(
        survivor_draws >= 8,
        "stream starved after retiring shards: {survivor_draws} draws"
    );
    let stats = service.backlog_stats();
    assert_eq!(stats.live_shards, 1);
    assert_eq!(stats.slots, 4);
    assert!(stats.samples >= 12);
}
