//! Allocation-freedom test for the rollout hot loop (acceptance
//! criterion of the sharded-replay PR's zero-alloc satellite): in
//! steady state, `RolloutWorker::sample` performs **zero** heap
//! allocations per environment step.
//!
//! The seed-era loop allocated a fresh `Vec<f32>` per env per step
//! (`Env::step` returning the next observation by value) plus a
//! `Vec<ActionOutput>` per vector-step; `Env::step_into`/`reset_into`
//! now write observations straight into the worker's flat SoA buffer
//! and `Policy::compute_actions_into` reuses one action buffer.
//!
//! Per-step freedom is asserted *differentially*: two workers identical
//! except for fragment length must spend exactly the same number of
//! allocations per `sample()` call once warm.  Whatever constant
//! per-fragment cost remains (the concat, the bootstrap-value vector,
//! Arc control blocks) cancels out; any per-step allocation would show
//! up multiplied by the fragment-length difference.
//!
//! The counting allocator counts per-thread (a thread-local counter),
//! so the worker is driven directly on the test thread — not through an
//! actor — and this file holds a single test for the same reason
//! `tests/actor_alloc.rs` does.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use flowrl::env::{DummyEnv, Env};
use flowrl::policy::DummyPolicy;
use flowrl::rollout::{CollectMode, RolloutWorker};

struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

const N_ENVS: usize = 4;
const OBS_DIM: usize = 8;
const WARMUP: usize = 4;
const MEASURED: usize = 8;

fn make_worker(fragment: usize, mode: CollectMode) -> RolloutWorker {
    // Episodes effectively never terminate, so the measurement sees the
    // pure step loop (episode-record pushes are per-episode, amortized,
    // and not the subject of this pin).
    let envs: Vec<Box<dyn Env>> = (0..N_ENVS)
        .map(|_| Box::new(DummyEnv::new(OBS_DIM, usize::MAX)) as Box<dyn Env>)
        .collect();
    RolloutWorker::new(envs, Box::new(DummyPolicy::new(0.1)), fragment, mode)
}

/// Allocations per `sample()` call once capacities are warm.
fn steady_allocs_per_sample(fragment: usize, mode: CollectMode) -> u64 {
    let mut w = make_worker(fragment, mode);
    for _ in 0..WARMUP {
        let b = w.sample();
        assert_eq!(b.len(), fragment * N_ENVS);
    }
    let before = allocs_here();
    for _ in 0..MEASURED {
        let b = w.sample();
        assert_eq!(b.len(), fragment * N_ENVS);
    }
    (allocs_here() - before) / MEASURED as u64
}

#[test]
fn rollout_hot_loop_is_allocation_free_per_step() {
    for mode in [CollectMode::Transitions, CollectMode::OnPolicy] {
        let short = steady_allocs_per_sample(32, mode);
        let long = steady_allocs_per_sample(256, mode);
        assert_eq!(
            short, long,
            "per-sample allocations scale with fragment length in \
             {mode:?} (32 steps: {short}, 256 steps: {long}) — \
             something allocates per step in the hot loop"
        );
    }
}

/// Spy policy pinning the GAE bootstrap's call shape: `sample()` must
/// take the buffer-writing [`flowrl::policy::Policy::values_into`]
/// (once per fragment, all envs in one batched forward), never the
/// allocating `values` wrapper.
struct BootstrapSpy {
    inner: DummyPolicy,
    values_into_calls: std::rc::Rc<Cell<u64>>,
}

impl flowrl::policy::Policy for BootstrapSpy {
    fn compute_actions_into(
        &mut self,
        obs: &[f32],
        n: usize,
        out: &mut Vec<flowrl::policy::ActionOutput>,
    ) {
        self.inner.compute_actions_into(obs, n, out);
    }

    fn compute_gradients(
        &mut self,
        batch: &flowrl::SampleBatch,
    ) -> flowrl::policy::Gradients {
        self.inner.compute_gradients(batch)
    }

    fn apply_gradients(&mut self, grads: &flowrl::policy::Gradients) {
        self.inner.apply_gradients(grads);
    }

    fn values_into(&mut self, obs: &[f32], n: usize, out: &mut Vec<f32>) {
        assert_eq!(n, N_ENVS, "bootstrap must batch all envs at once");
        assert_eq!(obs.len(), N_ENVS * OBS_DIM);
        self.values_into_calls.set(self.values_into_calls.get() + 1);
        out.clear();
        out.resize(n, 0.0);
    }

    fn values(&mut self, _obs: &[f32], _n: usize) -> Vec<f32> {
        panic!("GAE bootstrap went through the allocating values()");
    }

    fn get_weights(&self) -> Vec<f32> {
        self.inner.get_weights()
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.inner.set_weights(weights);
    }
}

#[test]
fn gae_bootstrap_uses_batched_values_into() {
    let calls = std::rc::Rc::new(Cell::new(0u64));
    let envs: Vec<Box<dyn Env>> = (0..N_ENVS)
        .map(|_| Box::new(DummyEnv::new(OBS_DIM, usize::MAX)) as Box<dyn Env>)
        .collect();
    let spy = BootstrapSpy {
        inner: DummyPolicy::new(0.1),
        values_into_calls: calls.clone(),
    };
    let mut w =
        RolloutWorker::new(envs, Box::new(spy), 16, CollectMode::OnPolicy);
    for round in 1..=3u64 {
        let b = w.sample();
        assert_eq!(b.len(), 16 * N_ENVS);
        assert_eq!(
            calls.get(),
            round,
            "expected exactly one batched values_into per fragment"
        );
    }
}
