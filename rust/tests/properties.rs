//! Property-based tests on coordinator invariants (routing, batching,
//! sequencing, replay).  proptest is not vendorable in this offline
//! environment, so this file uses a small randomized-cases harness over
//! `flowrl::util::Rng`: each property is checked on many random
//! instances with the failing seed printed for reproduction.

use flowrl::actor::spawn_group;
use flowrl::env::{CartPole, DummyEnv, Env, MountainCar, TaskCartPole};
use flowrl::iter::{concurrently, LocalIter, ParIter, UnionMode};
use flowrl::ops::concat_batches;
use flowrl::policy::{DummyPolicy, Policy};
use flowrl::replay::{PrioritizedReplayBuffer, SumTree};
use flowrl::sample_batch::{compute_gae, SampleBatch, SampleBatchBuilder};
use flowrl::util::Rng;

/// Run `prop` on `cases` random instances, reporting the failing seed.
fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x9E1513 ^ seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut rng)),
        );
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Sequencing operators
// ---------------------------------------------------------------------

#[test]
fn prop_gather_sync_yields_one_item_per_shard_per_round() {
    check("gather_sync rounds", 20, |rng| {
        let n_shards = 1 + rng.below(5);
        let rounds = 1 + rng.below(6);
        let limit = rounds as i32;
        let ws = spawn_group("p", n_shards, |i| {
            Box::new(move || (i, 0i32))
        });
        let mut it = ParIter::from_actors(ws, move |(id, count)| {
            *count += 1;
            if *count > limit {
                None
            } else {
                Some((*id, *count))
            }
        })
        .gather_sync();
        for round in 1..=rounds {
            let items = it.next().expect("round missing");
            assert_eq!(items.len(), n_shards);
            // One item from every shard, all at the same round index.
            let mut ids: Vec<usize> = items.iter().map(|(id, _)| *id).collect();
            ids.sort();
            assert_eq!(ids, (0..n_shards).collect::<Vec<_>>());
            assert!(items.iter().all(|(_, c)| *c == round as i32));
        }
        assert!(it.next().is_none());
    });
}

#[test]
fn prop_gather_async_preserves_multiset_and_shard_order() {
    check("gather_async multiset", 20, |rng| {
        let n_shards = 1 + rng.below(5);
        let per_shard = 1 + rng.below(10);
        let num_async = 1 + rng.below(3);
        let ws = spawn_group("p", n_shards, |i| Box::new(move || (i, 0i32)));
        let got = ParIter::from_actors(ws, move |(id, count)| {
            *count += 1;
            if *count > per_shard as i32 {
                None
            } else {
                Some((*id, *count))
            }
        })
        .gather_async(num_async)
        .collect();
        assert_eq!(got.len(), n_shards * per_shard);
        // Per-shard: items arrive in-order (actor mailbox FIFO)...
        for shard in 0..n_shards {
            let seq: Vec<i32> = got
                .iter()
                .filter(|(id, _)| *id == shard)
                .map(|(_, c)| *c)
                .collect();
            assert_eq!(seq, (1..=per_shard as i32).collect::<Vec<_>>());
        }
    });
}

#[test]
fn prop_union_round_robin_emits_every_item_exactly_once() {
    check("union round robin", 30, |rng| {
        let n_children = 1 + rng.below(4);
        let lens: Vec<usize> = (0..n_children).map(|_| rng.below(8)).collect();
        let weights: Vec<usize> =
            (0..n_children).map(|_| 1 + rng.below(3)).collect();
        let children: Vec<LocalIter<(usize, usize)>> = lens
            .iter()
            .enumerate()
            .map(|(c, &len)| {
                LocalIter::from_items((0..len).map(|i| (c, i)).collect())
            })
            .collect();
        let got = concurrently(
            children,
            UnionMode::RoundRobin { weights: Some(weights) },
            None,
        )
        .collect();
        assert_eq!(got.len(), lens.iter().sum::<usize>());
        // Exactly once, in order per child.
        for (c, &len) in lens.iter().enumerate() {
            let seq: Vec<usize> = got
                .iter()
                .filter(|(child, _)| *child == c)
                .map(|(_, i)| *i)
                .collect();
            assert_eq!(seq, (0..len).collect::<Vec<_>>());
        }
    });
}

#[test]
fn prop_union_async_emits_every_item_exactly_once() {
    check("union async", 15, |rng| {
        let n_children = 1 + rng.below(4);
        let lens: Vec<usize> =
            (0..n_children).map(|_| rng.below(20)).collect();
        let children: Vec<LocalIter<(usize, usize)>> = lens
            .iter()
            .enumerate()
            .map(|(c, &len)| {
                LocalIter::from_items((0..len).map(|i| (c, i)).collect())
            })
            .collect();
        let buffer = 1 + rng.below(4);
        let mut got = concurrently(
            children,
            UnionMode::Async { buffer },
            None,
        )
        .collect();
        got.sort();
        let mut expected: Vec<(usize, usize)> = lens
            .iter()
            .enumerate()
            .flat_map(|(c, &len)| (0..len).map(move |i| (c, i)))
            .collect();
        expected.sort();
        assert_eq!(got, expected);
    });
}

#[test]
fn prop_duplicate_both_sides_see_identical_streams() {
    check("duplicate equality", 25, |rng| {
        let len = rng.below(50);
        let items: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        let (mut a, mut b) = LocalIter::from_items(items.clone()).duplicate();
        let mut got_a = Vec::new();
        let mut got_b = Vec::new();
        // Random interleaving of consumers.
        loop {
            let pick_a = rng.chance(0.5);
            let (side, got) =
                if pick_a { (&mut a, &mut got_a) } else { (&mut b, &mut got_b) };
            if let Some(x) = side.next() {
                got.push(x);
            }
            if got_a.len() == len && got_b.len() == len {
                break;
            }
            if got_a.len() > len || got_b.len() > len {
                panic!("consumer overran");
            }
        }
        assert_eq!(got_a, items);
        assert_eq!(got_b, items);
        assert!(a.next().is_none());
        assert!(b.next().is_none());
    });
}

// ---------------------------------------------------------------------
// Batching
// ---------------------------------------------------------------------

fn random_batch(rng: &mut Rng, n: usize, obs_dim: usize) -> SampleBatch {
    let mut b = SampleBatchBuilder::new(obs_dim);
    for _ in 0..n {
        let obs: Vec<f32> =
            (0..obs_dim).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        b.add_step(
            &obs,
            rng.below(2) as i32,
            rng.uniform_range(-1.0, 1.0),
            rng.chance(0.1),
            rng.uniform_range(-2.0, 0.0),
            rng.uniform_range(-1.0, 1.0),
        );
    }
    b.build()
}

#[test]
fn prop_concat_batches_conserves_steps_and_hits_target() {
    check("concat_batches", 30, |rng| {
        let target = 1 + rng.below(64);
        let mut op = concat_batches(target);
        let mut fed = 0usize;
        let mut emitted = 0usize;
        for _ in 0..rng.below(30) {
            let n = 1 + rng.below(16);
            fed += n;
            for out in op(random_batch(rng, n, 2)) {
                assert!(out.len() >= target, "undersized emission");
                emitted += out.len();
            }
        }
        // Everything emitted so far is a prefix of what was fed; the
        // remainder (< target) is still buffered.
        assert!(emitted <= fed);
        assert!(fed - emitted < target + 16);
    });
}

#[test]
fn prop_shuffle_preserves_rows() {
    check("shuffle rows", 25, |rng| {
        let n = 2 + rng.below(40);
        // Tag rows: obs[0] == rewards so integrity is checkable.
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_step(&[i as f32, 0.5], 0, i as f32, false, 0.0, 0.0);
        }
        let mut batch = b.build();
        batch.shuffle(rng);
        assert_eq!(batch.len(), n);
        for i in 0..n {
            assert_eq!(batch.obs_row(i)[0], batch.rewards[i]);
        }
        let mut rewards = batch.rewards.clone();
        rewards.sort_by(f32::total_cmp);
        assert_eq!(rewards, (0..n).map(|i| i as f32).collect::<Vec<_>>());
    });
}

#[test]
fn prop_pad_or_truncate_mask_matches_valid_rows() {
    check("pad_or_truncate", 30, |rng| {
        let n = rng.below(30);
        let target = 1 + rng.below(30);
        let batch = random_batch(rng, n, 3);
        let (padded, mask) = batch.pad_or_truncate(target);
        assert_eq!(padded.len(), target);
        assert_eq!(mask.len(), target);
        let valid = n.min(target);
        assert_eq!(
            mask.iter().filter(|&&m| m == 1.0).count(),
            valid,
            "mask valid-count"
        );
        // Valid prefix must be row-identical to the source.
        for i in 0..valid {
            assert_eq!(padded.obs_row(i), batch.obs_row(i));
            assert_eq!(padded.rewards[i], batch.rewards[i]);
        }
    });
}

#[test]
fn prop_gae_matches_quadratic_reference() {
    check("gae reference", 30, |rng| {
        let n = 1 + rng.below(20);
        let gamma = rng.uniform_range(0.5, 1.0);
        let lambda = rng.uniform_range(0.0, 1.0);
        let last_value = rng.uniform_range(-1.0, 1.0);
        let mut batch = random_batch(rng, n, 1);
        compute_gae(&mut batch, gamma, lambda, last_value);

        // O(n^2) reference: adv_t = sum_k (gamma*lambda)^k delta_{t+k},
        // with the product cut at episode boundaries.
        for t in 0..n {
            let mut adv = 0.0f64;
            let mut coeff = 1.0f64;
            for k in t..n {
                let nonterminal = 1.0 - batch.dones[k] as f64;
                let next_v = if k + 1 < n {
                    batch.vf_preds[k + 1] as f64
                } else {
                    last_value as f64
                };
                let delta = batch.rewards[k] as f64
                    + gamma as f64 * nonterminal * next_v
                    - batch.vf_preds[k] as f64;
                adv += coeff * delta;
                if nonterminal == 0.0 {
                    break;
                }
                coeff *= gamma as f64 * lambda as f64;
            }
            assert!(
                (batch.advantages[t] as f64 - adv).abs() < 1e-3,
                "t={t}: {} vs {adv}",
                batch.advantages[t]
            );
        }
    });
}

// ---------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------

#[test]
fn prop_sum_tree_matches_naive_prefix_sums() {
    check("sum tree", 30, |rng| {
        let capacity = 16usize;
        let mut tree = SumTree::new(capacity);
        let mut naive = vec![0.0f64; capacity];
        for _ in 0..60 {
            let idx = rng.below(capacity);
            let p = rng.uniform() * 10.0;
            tree.set(idx, p);
            naive[idx] = p;
            let total: f64 = naive.iter().sum();
            assert!((tree.total() - total).abs() < 1e-9);
            if total > 0.0 {
                let mass = rng.uniform() * total;
                let got = tree.find_prefix(mass);
                // Naive prefix scan.
                let mut acc = 0.0;
                let mut want = capacity - 1;
                for (i, &w) in naive.iter().enumerate() {
                    acc += w;
                    if mass < acc {
                        want = i;
                        break;
                    }
                }
                assert_eq!(got, want, "mass={mass} naive={naive:?}");
            }
        }
    });
}

// ---------------------------------------------------------------------
// Buffer-first Env/Policy API: the `*_into` forms are canonical and
// the allocating wrappers must be behaviorally identical twins — same
// seed, same action sequence, bit-identical observations/rewards/dones.
// ---------------------------------------------------------------------

fn check_env_into_twins(
    name: &str,
    make: impl Fn(u64) -> Box<dyn Env>,
) {
    check(name, 10, |rng| {
        let seed = rng.next_u64();
        let mut a = make(seed); // drives reset_into / step_into
        let mut b = make(seed); // drives the allocating wrappers
        let obs_dim = a.obs_dim();
        let num_actions = a.num_actions();
        let mut buf = vec![0.0f32; obs_dim];

        a.reset_into(&mut buf);
        assert_eq!(buf, b.reset());
        for _ in 0..20 + rng.below(180) {
            let action = rng.below(num_actions) as i32;
            let (r_a, done_a) = a.step_into(action, &mut buf);
            let (obs_b, r_b, done_b) = b.step(action);
            assert_eq!(buf, obs_b);
            assert_eq!(r_a, r_b);
            assert_eq!(done_a, done_b);
            if done_a {
                if rng.chance(0.3) {
                    a.sample_task();
                    b.sample_task();
                }
                a.reset_into(&mut buf);
                assert_eq!(buf, b.reset());
            }
        }
    });
}

#[test]
fn prop_cartpole_into_forms_match_allocating_twins() {
    check_env_into_twins("cartpole into twins", |seed| {
        Box::new(CartPole::new(seed))
    });
}

#[test]
fn prop_task_cartpole_into_forms_match_allocating_twins() {
    check_env_into_twins("task cartpole into twins", |seed| {
        Box::new(TaskCartPole::new(seed))
    });
}

#[test]
fn prop_mountain_car_into_forms_match_allocating_twins() {
    check_env_into_twins("mountain car into twins", |seed| {
        Box::new(MountainCar::new(seed))
    });
}

#[test]
fn prop_dummy_env_into_forms_match_allocating_twins() {
    check_env_into_twins("dummy env into twins", |seed| {
        Box::new(DummyEnv::new(2 + (seed % 5) as usize, 25))
    });
}

#[test]
fn prop_policy_into_forms_match_allocating_twins() {
    check("policy into twins", 15, |rng| {
        // Twin policies share the construction seed, so their internal
        // action streams advance in lockstep across the two APIs.
        let mut a = DummyPolicy::new(0.01);
        let mut b = DummyPolicy::new(0.01);
        let obs_dim = 1 + rng.below(6);
        let mut actions = Vec::new();
        let mut values = Vec::new();
        for _ in 0..1 + rng.below(6) {
            let n = 1 + rng.below(16);
            let obs: Vec<f32> = (0..n * obs_dim)
                .map(|_| rng.uniform_range(-1.0, 1.0))
                .collect();
            a.compute_actions_into(&obs, n, &mut actions);
            let twin = b.compute_actions(&obs, n);
            assert_eq!(actions.len(), n);
            assert_eq!(twin.len(), n);
            for (x, y) in actions.iter().zip(&twin) {
                assert_eq!(x.action, y.action);
                assert_eq!(x.logp, y.logp);
                assert_eq!(x.value, y.value);
            }
            a.values_into(&obs, n, &mut values);
            assert_eq!(values, b.values(&obs, n));
        }
    });
}

#[test]
fn prop_replay_sample_indices_always_valid_and_weights_bounded() {
    check("replay sampling", 20, |rng| {
        let mut buf =
            PrioritizedReplayBuffer::new(32, 0.6, 0.4, rng.next_u64());
        let mut added = 0usize;
        for _ in 0..1 + rng.below(5) {
            let n = 1 + rng.below(10);
            let mut b = SampleBatchBuilder::new(1);
            for i in 0..n {
                b.add_transition(
                    &[i as f32],
                    0,
                    rng.uniform_range(-1.0, 1.0),
                    &[i as f32 + 1.0],
                    false,
                );
            }
            buf.add_batch(&b.build());
            added += n;
            // Random priority updates.
            let k = rng.below(4);
            let idxs: Vec<usize> =
                (0..k).map(|_| rng.below(added.min(32))).collect();
            let tds: Vec<f32> =
                (0..k).map(|_| rng.uniform_range(0.0, 5.0)).collect();
            buf.update_priorities(&idxs, &tds);

            let sample = buf.sample(8).expect("buffer non-empty");
            assert_eq!(sample.batch.len(), 8);
            for &idx in &sample.indices {
                assert!(idx < added.min(32).next_power_of_two().max(32));
            }
            for &w in &sample.batch.weights {
                assert!(w > 0.0 && w <= 1.0 + 1e-5, "weight {w}");
            }
        }
    });
}
