//! Allocation-freedom test for the external-episode gateway's serving
//! cycle (acceptance criterion of the flowlint PR's hot-path satellite):
//! once a shard's session table and scratch buffers are warm, a full
//! `submit_obs -> tick -> take_action` round over every live session
//! performs **zero** heap allocations.
//!
//! `EpisodeGateway::tick` carries a `// flowlint: hot-path` mark, so the
//! static lint denies obvious allocation tokens in its body; this test
//! pins the property at runtime, including the paths the lexer cannot
//! see (Vec growth inside `extend_from_slice`, the policy's
//! `compute_actions_into`, the fragment builder's column pushes).
//!
//! The warmup is sized to leave the fragment builder's columns with
//! ample doubling headroom: measured transitions are an order of
//! magnitude fewer than warmup transitions, so no column crosses a
//! growth boundary inside the measured region.  Fragments are *not*
//! drained during measurement — `SampleBatchBuilder::build` allocates
//! the batch it hands out, which is the (amortized, per-fragment) cost
//! the differential test in `tests/rollout_alloc.rs` already covers.
//!
//! The counting allocator counts per-thread (a thread-local counter),
//! so the gateway is driven directly on the test thread — not through
//! `ops::gateway_ops` — and this file holds a single test for the same
//! reason `tests/actor_alloc.rs` does.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use flowrl::env::{EpisodeGateway, GatewayConfig, SessionId};
use flowrl::policy::DummyPolicy;

struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

const OBS_DIM: usize = 8;
const SESSIONS: usize = 4;
/// Warmup serving rounds: enough transitions (~4 * 300) that every
/// builder column sits well inside a doubling boundary before the
/// measured rounds add ~4 * 32 more.
const WARMUP_ROUNDS: usize = 300;
const MEASURED_ROUNDS: usize = 32;

/// One full serving round: every session submits an observation, one
/// tick batches them through a single forward, every session takes its
/// action and logs a reward.
fn round(
    g: &mut EpisodeGateway,
    p: &mut DummyPolicy,
    ids: &[SessionId],
    now: u64,
) {
    let obs = [0.25f32; OBS_DIM];
    for &id in ids {
        g.submit_obs(id, &obs, now).unwrap();
    }
    let fill = g.tick(p, now + 1);
    assert_eq!(fill, ids.len(), "one tick must serve every pending request");
    for &id in ids {
        let out = g.take_action(id, now + 2).unwrap();
        assert!(out.is_some(), "action must be ready after the tick");
        g.log_reward(id, 1.0, now + 3).unwrap();
    }
}

#[test]
fn warm_gateway_serving_cycle_is_allocation_free() {
    let mut g = EpisodeGateway::new(GatewayConfig {
        obs_dim: OBS_DIM,
        max_sessions: SESSIONS,
        idle_deadline_ns: u64::MAX,
        forgiveness: 1,
        // Larger than every transition this test produces, so the
        // builder's preallocated columns never grow past it and
        // `drain_fragment` (which allocates) never has work to do.
        fragment: 4096,
    });
    let mut p = DummyPolicy::new(0.1);
    let ids: Vec<SessionId> =
        (0..SESSIONS).map(|_| g.start_episode(0).unwrap()).collect();

    for r in 0..WARMUP_ROUNDS {
        round(&mut g, &mut p, &ids, 10 + r as u64 * 10);
    }

    let before = allocs_here();
    for r in 0..MEASURED_ROUNDS {
        round(&mut g, &mut p, &ids, 1_000_000 + r as u64 * 10);
    }
    let allocs = allocs_here() - before;

    assert_eq!(
        allocs, 0,
        "gateway serving cycle allocated {allocs}x over {MEASURED_ROUNDS} \
         rounds of {SESSIONS} sessions — tick/submit/take grew a buffer"
    );

    // The measurement exercised what it claims to: every round batched
    // all sessions through one forward and recorded a transition per
    // session (minus each session's first submit, which has no
    // predecessor to complete).
    let stats = g.stats();
    let rounds = (WARMUP_ROUNDS + MEASURED_ROUNDS) as u64;
    assert_eq!(stats.ticks, rounds);
    assert_eq!(stats.batched_rows, rounds * SESSIONS as u64);
    assert_eq!(
        stats.transitions,
        (rounds - 1) * SESSIONS as u64,
        "every post-first submit must complete a transition"
    );
    assert_eq!(g.pending_requests(), 0);
}
