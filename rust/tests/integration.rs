//! Integration tests over the real AOT artifacts: every layer composes
//! (Pallas kernel -> JAX loss -> HLO text -> PJRT -> policies ->
//! dataflow plans).  Requires `make artifacts` to have run.

use std::path::PathBuf;

use flowrl::algorithms::{
    a2c_plan, a3c_plan, apex_plan, dqn_plan, impala_plan, maml_plan,
    multi_agent_plan, ppo_plan, EnvKind, TrainerConfig,
};
use flowrl::algorithms as algos;
use flowrl::policy::{DqnPolicy, PgLossKind, PgPolicy, Policy};
use flowrl::runtime::{TensorArg, XlaRuntime};
use flowrl::sample_batch::SampleBatchBuilder;

fn artifacts() -> PathBuf {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "run `make artifacts` before cargo test"
    );
    p
}

/// Skip (pass vacuously) when the AOT artifacts are absent — offline
/// builds have no PJRT backend, so nothing XLA-backed can run.  Every
/// test below starts with this guard.
macro_rules! require_artifacts {
    () => {
        if !PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
        {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn test_config(num_workers: usize) -> TrainerConfig {
    TrainerConfig {
        num_workers,
        num_envs_per_worker: 2,
        rollout_fragment_length: 16,
        train_batch_size: 64,
        lr: 5e-3,
        artifacts_dir: artifacts(),
        seed: 7,
        num_async: 1,
        env: EnvKind::CartPole,
        ..TrainerConfig::default()
    }
}

// ---------------------------------------------------------------------
// Runtime layer
// ---------------------------------------------------------------------

#[test]
fn pg_fwd_roundtrip_shapes_and_determinism() {
    require_artifacts!();
    let rt = XlaRuntime::load(artifacts(), &["pg_fwd"]).unwrap();
    let cfg = rt.manifest.config.clone();
    let params = rt.load_init_params("init_pg").unwrap();
    assert_eq!(params.len(), cfg.pg_param_size);
    let obs = vec![0.1f32; cfg.inf_batch * cfg.obs_dim];
    let out = rt
        .exe("pg_fwd")
        .run(&[TensorArg::F32(&params), TensorArg::F32(&obs)])
        .unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), cfg.inf_batch * cfg.num_actions);
    assert_eq!(out[1].len(), cfg.inf_batch);
    assert!(out[0].iter().all(|v| v.is_finite()));
    // Determinism: same inputs, same outputs.
    let out2 = rt
        .exe("pg_fwd")
        .run(&[TensorArg::F32(&params), TensorArg::F32(&obs)])
        .unwrap();
    assert_eq!(out[0], out2[0]);
}

#[test]
fn runtime_rejects_wrong_shapes_and_dtypes() {
    require_artifacts!();
    let rt = XlaRuntime::load(artifacts(), &["pg_fwd"]).unwrap();
    let params = rt.load_init_params("init_pg").unwrap();
    let bad_obs = vec![0.0f32; 3];
    assert!(rt
        .exe("pg_fwd")
        .run(&[TensorArg::F32(&params), TensorArg::F32(&bad_obs)])
        .is_err());
    let ints = vec![0i32; params.len()];
    assert!(rt
        .exe("pg_fwd")
        .run(&[TensorArg::I32(&ints), TensorArg::F32(&bad_obs)])
        .is_err());
}

// ---------------------------------------------------------------------
// Policy layer
// ---------------------------------------------------------------------

#[test]
fn pg_policy_learns_to_prefer_rewarded_action() {
    require_artifacts!();
    // Feed a synthetic batch where action 0 always has +1 advantage:
    // after a few a2c updates the policy must prefer action 0.
    let mut p =
        PgPolicy::create(&artifacts(), PgLossKind::A2c, 0.05, 0);
    let obs = vec![0.3f32, -0.1, 0.2, 0.05];
    for _ in 0..20 {
        let mut b = SampleBatchBuilder::new(4);
        for _ in 0..32 {
            b.add_step(&obs, 0, 1.0, false, -0.7, 0.0);
        }
        let mut batch = b.build();
        batch.advantages = vec![1.0; 32].into();
        batch.value_targets = vec![1.0; 32].into();
        let stats = p.learn_on_batch(&batch);
        assert!(stats["loss"].is_finite());
    }
    let mut zero_count = 0;
    for _ in 0..100 {
        let acts = p.compute_actions(&obs, 1);
        if acts[0].action == 0 {
            zero_count += 1;
        }
    }
    assert!(zero_count > 80, "policy did not shift: {zero_count}/100");
}

#[test]
fn dqn_policy_td_errors_and_target_sync() {
    require_artifacts!();
    let mut p = DqnPolicy::create(&artifacts(), 1e-3, 0.0, 0);
    let mut b = SampleBatchBuilder::new(4);
    for i in 0..16 {
        b.add_transition(
            &[0.1 * i as f32, 0.0, 0.0, 0.0],
            (i % 2) as i32,
            1.0,
            &[0.1 * (i + 1) as f32, 0.0, 0.0, 0.0],
            i == 15,
        );
    }
    let batch = b.build();
    let stats = p.learn_on_batch(&batch);
    assert!(stats["loss"].is_finite());
    let td = p.td_abs().unwrap();
    assert_eq!(td.len(), 16);
    assert!(td.iter().all(|t| t.is_finite() && *t >= 0.0));
    p.update_target();
    // Greedy actions must be deterministic with epsilon 0.
    let a1 = p.compute_actions(&[0.1, 0.0, 0.0, 0.0], 1)[0].action;
    let a2 = p.compute_actions(&[0.1, 0.0, 0.0, 0.0], 1)[0].action;
    assert_eq!(a1, a2);
}

// ---------------------------------------------------------------------
// Algorithm plans: every ported algorithm runs and reports sane stats
// ---------------------------------------------------------------------

fn run_plan(
    mut plan: flowrl::iter::LocalIter<flowrl::metrics::TrainResult>,
    iters: usize,
) -> flowrl::metrics::TrainResult {
    let mut last = None;
    for _ in 0..iters {
        last = plan.next();
        assert!(last.is_some(), "plan ended early");
    }
    last.unwrap()
}

#[test]
fn a2c_trains_and_reports() {
    require_artifacts!();
    let r = run_plan(a2c_plan(&test_config(2)), 3);
    assert!(r.num_env_steps_trained >= 3 * 64);
    assert!(r.learner_stats["loss"].is_finite());
    assert!(r.episodes_total > 0);
}

#[test]
fn a3c_trains_and_reports() {
    require_artifacts!();
    let r = run_plan(a3c_plan(&test_config(2)), 4);
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["loss"].is_finite());
}

#[test]
fn ppo_trains_and_reports() {
    require_artifacts!();
    let r = run_plan(ppo_plan(&test_config(2)), 3);
    assert!(r.num_env_steps_trained >= 3 * 64);
    assert!(r.learner_stats["kl"].is_finite());
}

#[test]
fn dqn_trains_and_reports() {
    require_artifacts!();
    let mut cfg = test_config(2);
    cfg.rollout_fragment_length = 32;
    let dqn_cfg = algos::dqn::DqnConfig {
        buffer_capacity: 2048,
        learning_starts: 64,
        target_update_every: 200,
        weight_sync_every: 2,
    };
    let r = run_plan(dqn_plan(&cfg, &dqn_cfg), 4);
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["loss"].is_finite());
}

#[test]
fn dqn_with_large_learning_starts_does_not_deadlock() {
    require_artifacts!();
    // Regression: with learning_starts greater than one store-round,
    // the round-robin union used to deadlock — the blocking replay
    // child starved the store child that had to fill the buffer.
    let mut cfg = test_config(2);
    cfg.rollout_fragment_length = 16;
    cfg.num_envs_per_worker = 2;
    let dqn_cfg = algos::dqn::DqnConfig {
        buffer_capacity: 4096,
        learning_starts: 300, // > 2 workers x 16 x 2 envs per round
        target_update_every: 200,
        weight_sync_every: 2,
    };
    let mut plan = dqn_plan(&cfg, &dqn_cfg);
    let mut trained = 0;
    for _ in 0..40 {
        let r = plan.next().expect("stream ended");
        trained = r.num_env_steps_trained;
        if trained > 0 {
            break;
        }
    }
    assert!(trained > 0, "never reached learning_starts");
}

#[test]
fn apex_trains_and_reports() {
    require_artifacts!();
    let mut cfg = test_config(2);
    cfg.rollout_fragment_length = 32;
    let apex_cfg = algos::apex::ApexConfig {
        dqn: algos::dqn::DqnConfig {
            buffer_capacity: 2048,
            learning_starts: 64,
            target_update_every: 200,
            weight_sync_every: usize::MAX,
        },
        num_replay_actors: 2,
        max_weight_sync_delay: 64,
        replay_queue_depth: 2,
        ..algos::apex::ApexConfig::default()
    };
    // Replay items are not-ready until learning_starts, so poll until
    // the learner has actually trained.
    let mut plan = apex_plan(&cfg, &apex_cfg);
    let mut r = Default::default();
    for _ in 0..60 {
        r = plan.next().expect("stream ended");
        if r.num_env_steps_trained > 0 {
            break;
        }
    }
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["loss"].is_finite());
}

#[test]
fn impala_trains_and_reports() {
    require_artifacts!();
    let r = run_plan(impala_plan(&test_config(2)), 3);
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["loss"].is_finite());
    assert!(r.learner_stats["entropy"].is_finite());
}

#[test]
fn maml_meta_trains_and_reports() {
    require_artifacts!();
    let cfg = test_config(2);
    let maml_cfg = algos::maml::MamlConfig { inner_steps: 1, inner_lr: 0.05 };
    let r = run_plan(maml_plan(&cfg, &maml_cfg), 2);
    assert!(r.num_env_steps_trained > 0);
    assert!(r.learner_stats["loss"].is_finite());
}

#[test]
fn checkpoint_roundtrip_through_xla_policy() {
    require_artifacts!();
    use flowrl::checkpoint::{
        checkpoint_worker_set, restore_worker_set, Checkpoint,
    };
    use flowrl::rollout::CollectMode;
    let cfg = test_config(1);
    let workers = cfg.pg_workers(PgLossKind::A2c, CollectMode::OnPolicy);
    // Train a little so weights differ from init.
    workers
        .local
        .call(|w| {
            let batch = w.sample();
            w.learn_on_batch(&batch);
        })
        .unwrap();
    let ck = checkpoint_worker_set(&workers, 16, 16);
    let path = std::env::temp_dir()
        .join(format!("flowrl_it_ckpt_{}.bin", std::process::id()));
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // A fresh worker set restored from disk must carry the weights.
    let workers2 = cfg.pg_workers(PgLossKind::A2c, CollectMode::OnPolicy);
    assert_ne!(
        workers2.local.call(|w| w.get_weights()).unwrap(),
        ck.weights["default"],
        "fresh init should differ from trained weights"
    );
    restore_worker_set(&workers2, &loaded).unwrap();
    assert_eq!(
        workers2.local.call(|w| w.get_weights()).unwrap(),
        ck.weights["default"]
    );
    assert_eq!(loaded.steps_sampled, 16);
}

#[test]
fn training_is_deterministic_for_a_seed() {
    require_artifacts!();
    // Same seed -> bit-identical learner weights after two A2C
    // iterations (deterministic envs, policies, and barrier plans).
    let run = || {
        let cfg = test_config(2);
        let mut plan = a2c_plan(&cfg);
        plan.next().unwrap();
        let r = plan.next().unwrap();
        (r.num_env_steps_trained, format!("{:?}", r.learner_stats))
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}

#[test]
fn multi_agent_union_trains_both_policies() {
    require_artifacts!();
    let mut cfg = test_config(2);
    cfg.rollout_fragment_length = 32;
    cfg.train_batch_size = 64;
    let ma_cfg = algos::multi_agent::MultiAgentConfig {
        agents_per_policy: 2,
        dqn: algos::dqn::DqnConfig {
            buffer_capacity: 2048,
            learning_starts: 32,
            target_update_every: 200,
            weight_sync_every: 2,
        },
        ppo_epochs: 1,
    };
    let mut plan = multi_agent_plan(&cfg, &ma_cfg);
    // Drive until both trainers have reported at least once.
    let mut saw_ppo = false;
    let mut saw_dqn = false;
    for _ in 0..12 {
        let r = plan.next().unwrap();
        saw_ppo |= r.learner_stats.keys().any(|k| k.starts_with("ppo/"));
        saw_dqn |= r.learner_stats.keys().any(|k| k.starts_with("dqn/"));
        if saw_ppo && saw_dqn {
            break;
        }
    }
    assert!(saw_ppo, "PPO subflow never trained");
    assert!(saw_dqn, "DQN subflow never trained");
}
