//! Fig. 14 — multi-agent multi-policy composition vs the Amdahl ideal.
//!
//! Measures sampled-step throughput of (a) the PPO trainer alone,
//! (b) the DQN trainer alone, and (c) the PPO+DQN union, on the
//! multi-agent CartPole with 4 agents per policy.  The "theoretical
//! best" for the union follows the paper's Amdahl-style combination:
//! the combined workload must process one PPO-half and one DQN-half
//! per unit of work, so
//!
//!     ideal = 1 / (0.5 / R_ppo + 0.5 / R_dqn)
//!
//! (harmonic combination: the driver serializes the two trainers'
//! driver-side work; overlap beyond that is a bonus).  Paper
//! expectation: union throughput ≈ ideal.
//!
//! Run: `cargo bench --bench fig14_union`
//! Smoke: `-- --smoke` (3 reports per trainer; artifact-gated skip).

use std::path::PathBuf;
use std::time::Instant;

use flowrl::algorithms::multi_agent::ma_worker_set;
use flowrl::algorithms::{
    multi_agent_plan, DqnConfig, MultiAgentConfig, TrainerConfig,
};
use flowrl::iter::LocalIter;
use flowrl::metrics::TrainResult;
use flowrl::ops::{
    concat_batches, create_replay_shards, parallel_ma_rollouts_from, replay,
    select_policy, store_to_replay_buffer, Reporting, TrainItem,
};

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn iters() -> usize {
    if smoke() {
        3
    } else {
        25
    }
}

fn config() -> TrainerConfig {
    TrainerConfig {
        num_workers: 2,
        rollout_fragment_length: 32,
        train_batch_size: 128,
        lr: 1e-3,
        artifacts_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts"),
        seed: 5,
        ..TrainerConfig::default()
    }
}

fn ma_cfg() -> MultiAgentConfig {
    MultiAgentConfig {
        agents_per_policy: 4,
        dqn: DqnConfig {
            buffer_capacity: 8192,
            learning_starts: 128,
            target_update_every: 500,
            weight_sync_every: 4,
        },
        ppo_epochs: 1,
    }
}

/// Sampled env-steps/s over `iters()` reports of a plan.
fn throughput(mut plan: LocalIter<TrainResult>) -> f64 {
    plan.next(); // warmup/compile
    let start = Instant::now();
    let mut first = None;
    let mut last = 0u64;
    for _ in 0..iters() {
        let r = plan.next().unwrap();
        first.get_or_insert(r.num_env_steps_sampled);
        last = r.num_env_steps_sampled;
    }
    (last - first.unwrap()) as f64 / start.elapsed().as_secs_f64()
}

/// PPO-only trainer over the multi-agent env (all agents -> "ppo").
fn ppo_alone() -> LocalIter<TrainResult> {
    let cfg = config();
    let ma = ma_cfg();
    let set = ma_worker_set(&cfg, &ma, false, true);
    let rollouts =
        parallel_ma_rollouts_from(&set).gather_async(cfg.num_async);
    let tbs = cfg.train_batch_size;
    let l = set.local.clone();
    let rs = set.remotes();
    let ppo_op = rollouts
        .filter_map(select_policy("ppo"))
        .combine(concat_batches(tbs))
        .for_each(move |batch| {
            let steps = batch.len();
            let (stats, weights) = l
                .call(move |w| {
                    (w.learn_on_batch("ppo", &batch), w.get_weights("ppo"))
                })
                .expect("learner died");
            for r in &rs {
                let wt = weights.clone();
                r.cast(move |w| w.set_weights("ppo", &wt));
            }
            TrainItem::new(stats, steps)
        });
    Reporting::new(ppo_op, &set, 1).build()
}

/// DQN-only trainer over the multi-agent env (all agents -> "dqn").
fn dqn_alone() -> LocalIter<TrainResult> {
    let cfg = config();
    let ma = ma_cfg();
    let set = ma_worker_set(&cfg, &ma, true, false);
    let local = set.local.clone();
    let rollouts =
        parallel_ma_rollouts_from(&set).gather_async(cfg.num_async);
    let obs_dim = local.call(|w| w.obs_dim()).expect("learner died");
    let service = create_replay_shards(
        1,
        obs_dim,
        ma.dqn.buffer_capacity,
        ma.dqn.learning_starts,
        64,
    );
    let mut store = store_to_replay_buffer(&service);
    let store_op = rollouts.filter_map(select_policy("dqn")).for_each(
        move |b| {
            store(b);
            TrainItem::default()
        },
    );
    let l = local.clone();
    let replay_op = replay(&service, 1).for_each(move |item| {
        let Some((sample, lease)) = item else {
            return TrainItem::default();
        };
        let steps = sample.batch.len();
        let indices = sample.indices;
        let batch = sample.batch;
        let (stats, td) = l
            .call(move |w| {
                let stats = w.learn_on_batch("dqn", &batch);
                (stats, w.policies["dqn"].td_abs().unwrap_or_default())
            })
            .expect("learner died");
        lease.update_priorities(indices, td);
        TrainItem::new(stats, steps)
    });
    let merged = flowrl::iter::concurrently(
        vec![store_op, replay_op],
        flowrl::iter::UnionMode::RoundRobin { weights: None },
        Some(vec![1]),
    );
    Reporting::new(merged, &set, 1).build()
}

fn main() {
    if !config().artifacts_dir.join("manifest.json").exists() {
        println!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    println!("# Fig. 14 — PPO+DQN union vs Amdahl ideal (sampled steps/s)");
    let r_ppo = throughput(ppo_alone());
    let r_dqn = throughput(dqn_alone());
    let r_union = throughput(multi_agent_plan(&config(), &ma_cfg()));
    let ideal = 1.0 / (0.5 / r_ppo + 0.5 / r_dqn);
    println!("| trainer | steps/s |");
    println!("|---------|---------|");
    println!("| PPO alone | {r_ppo:.0} |");
    println!("| DQN alone | {r_dqn:.0} |");
    println!("| union (measured) | {r_union:.0} |");
    println!("| union (Amdahl ideal) | {ideal:.0} |");
    println!("| measured / ideal | {:.2} |", r_union / ideal);
}
