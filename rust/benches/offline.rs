//! Offline dataflow bench: log-decode throughput and end-to-end
//! train-from-logs rate.
//!
//! Two reported ops:
//!
//! * `reader_frames_per_s` — a `LogStreamReader` draining a recorded
//!   multi-segment stream (64-row CartPole-shaped frames, rotation
//!   every 1 MiB): frames decoded per second, CRC checked.  This is
//!   the ceiling on offline ingest.
//! * `offline_dqn_steps_per_s` — `offline_dqn_plan` (dummy policy, no
//!   artifacts) training from the same logs: env steps trained per
//!   second through the logs → replay → learner dataflow.
//!
//! Runs entirely against temp files + the dummy policy, so it always
//! executes (including under `tools/ci.sh --smoke`).
//!
//! Run: `cargo bench --bench offline`
//! Smoke: `cargo bench --bench offline -- --smoke`
//! Record: `cargo bench --bench offline -- --write`
//!         (rewrites BENCH_offline.json at the repo root)

use std::path::PathBuf;
use std::time::{Duration, Instant};

use flowrl::algorithms::{
    offline_dqn_plan, DqnConfig, EnvKind, OfflineDqnConfig, TrainerConfig,
};
use flowrl::offline::{
    EpisodeLogWriter, LogStreamReader, OfflineCounters, WriterConfig,
};
use flowrl::sample_batch::SampleBatchBuilder;
use flowrl::SampleBatch;

const OBS_DIM: usize = 4;
const ROWS_PER_FRAME: usize = 64;

fn frame(i: usize) -> SampleBatch {
    let mut b = SampleBatchBuilder::new(OBS_DIM);
    let obs = [i as f32, 0.1, 0.2, 0.3];
    for r in 0..ROWS_PER_FRAME {
        b.add_transition_with_logp(
            &obs,
            (r % 2) as i32,
            1.0,
            &obs,
            r % 16 == 15,
            -0.69,
        );
    }
    b.build()
}

fn record_logs(dir: &PathBuf, frames: usize) {
    let mut w = EpisodeLogWriter::create(
        dir,
        "bench",
        WriterConfig { segment_bytes: 1 << 20 },
    )
    .expect("create log writer");
    for i in 0..frames {
        w.append(&frame(i)).expect("append");
    }
}

fn bench_reader(dir: &PathBuf, frames: usize) -> f64 {
    let counters = OfflineCounters::new();
    let mut r = LogStreamReader::follow(dir, "bench", counters.clone());
    let t0 = Instant::now();
    let mut n = 0usize;
    while n < frames {
        if r.poll().is_some() {
            n += 1;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = counters.snapshot();
    assert_eq!(stats.frames as usize, frames);
    assert_eq!(stats.corrupt_frames, 0);
    frames as f64 / elapsed
}

fn bench_offline_dqn(dir: &PathBuf, window: Duration) -> f64 {
    let config = TrainerConfig {
        env: EnvKind::Dummy,
        min_replay_shards: 1,
        ..TrainerConfig::default()
    };
    let dqn = DqnConfig {
        buffer_capacity: 65_536,
        learning_starts: 256,
        target_update_every: 512,
        weight_sync_every: 5,
    };
    let offline = OfflineDqnConfig {
        log_dir: dir.clone(),
        obs_dim: OBS_DIM,
        ..OfflineDqnConfig::default()
    };
    let mut plan = offline_dqn_plan(&config, &dqn, &offline);
    // Warm-up: first trained report means the buffer passed
    // learning-starts and the pipeline is in steady state.
    let mut report = plan.next().expect("plan is infinite");
    while report.num_env_steps_trained == 0 {
        report = plan.next().expect("plan is infinite");
    }
    let t0 = Instant::now();
    let mut trained = 0u64;
    while t0.elapsed() < window {
        trained += plan.next().expect("plan is infinite").num_env_steps_trained;
    }
    trained as f64 / t0.elapsed().as_secs_f64()
}

fn json_report(frames_per_s: f64, steps_per_s: f64, frames: usize) -> String {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"offline\",\n");
    out.push_str("  \"units\": \"mixed\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         offline -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"Offline dataflow: reader_frames_per_s = \
         LogStreamReader draining a recorded multi-segment stream \
         (64-row obs_dim-4 frames, CRC checked, 1 MiB rotation); \
         offline_dqn_steps_per_s = env steps trained per second by \
         offline_dqn_plan (dummy policy) over the same logs through \
         the logs -> replay -> learner dataflow.\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"reader_frames_per_s\": \
         \"well above any realistic rollout production rate (the log \
         source must never be the training bottleneck)\",\n    \
         \"offline_dqn_steps_per_s\": \"same order as the online \
         dqn_plan trained-step rate (the source swap is free)\"\n  },\n",
    );
    out.push_str(
        "  \"ops\": [\"reader_frames_per_s\", \
         \"offline_dqn_steps_per_s\"],\n",
    );
    out.push_str("  \"results\": [\n");
    out.push_str(&format!(
        "    {{\"op\": \"reader_frames_per_s\", \"units\": \
         \"items_per_s\", \"items_per_s\": {frames_per_s:.0}, \
         \"frames\": {frames}, \"rows_per_frame\": {ROWS_PER_FRAME}}},\n",
    ));
    out.push_str(&format!(
        "    {{\"op\": \"offline_dqn_steps_per_s\", \"units\": \
         \"steps_per_s\", \"steps_per_s\": {steps_per_s:.0}, \
         \"rows_per_frame\": {ROWS_PER_FRAME}}}\n",
    ));
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let frames = if smoke { 500 } else { 5_000 };
    let window =
        if smoke { Duration::from_millis(500) } else { Duration::from_secs(3) };

    let dir = std::env::temp_dir()
        .join(format!("flowrl_bench_offline_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    record_logs(&dir, frames);

    let frames_per_s = bench_reader(&dir, frames);
    let steps_per_s = bench_offline_dqn(&dir, window);
    let _ = std::fs::remove_dir_all(&dir);

    println!("# offline bench — log ingest + train-from-logs");
    println!("| op | rate |");
    println!("|----|------|");
    println!("| reader_frames_per_s | {frames_per_s:.0} |");
    println!("| offline_dqn_steps_per_s | {steps_per_s:.0} |");

    assert!(frames_per_s.is_finite() && frames_per_s > 0.0);
    assert!(steps_per_s.is_finite() && steps_per_s > 0.0);

    let json = json_report(frames_per_s, steps_per_s, frames);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_offline.json");
        std::fs::write(&path, &json).expect("write BENCH_offline.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
