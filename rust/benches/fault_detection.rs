//! Fault-plane bench: what does deadline supervision cost, and how
//! fast does it catch a wedged shard?
//!
//! * **hang_detection_latency** — a rollout shard is wedged with a
//!   scripted permanent `Hang` on its first sample; the reported number
//!   is ms from the gather's first pull until deadline supervision
//!   declares the shard suspect (and force-kills it).  The floor is the
//!   configured deadline itself (carried per row as `deadline_ms`) —
//!   the gap above it is the supervision machinery's own lag.
//! * **disarmed_overhead** — ns per `faults::failpoint` call with no
//!   rule armed (the steady state every hot site pays, by design one
//!   relaxed atomic load), next to a `baseline` row timing the same
//!   loop without the failpoint.
//!
//! Runs on the Dummy env/policy — no AOT artifacts, so this bench
//! always executes (including `tools/ci.sh --smoke`).
//!
//! Run: `cargo bench --bench fault_detection`
//! Smoke: `cargo bench --bench fault_detection -- --smoke`
//! Record: `cargo bench --bench fault_detection -- --write`
//!         (rewrites BENCH_faults.json at the repo root)

use std::time::{Duration, Instant};

use flowrl::actor::faults::{self, SITE_ROLLOUT_SAMPLE};
use flowrl::actor::FaultAction;
use flowrl::env::{DummyEnv, Env};
use flowrl::iter::DeadlineSupervision;
use flowrl::ops::parallel_rollouts_from;
use flowrl::policy::DummyPolicy;
use flowrl::rollout::{CollectMode, RolloutWorker, WorkerSet};

fn worker_set(n_remote: usize) -> WorkerSet {
    WorkerSet::new(n_remote, |_| {
        Box::new(|| {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(DummyPolicy::new(0.1)),
                4,
                CollectMode::OnPolicy,
            )
        })
    })
}

/// One wedge-detect-recover cycle; returns ms from first pull to the
/// suspect declaration.
fn detect_once(deadline: Duration) -> f64 {
    let set = worker_set(2);
    // `WorkerSet::new` names remotes `worker-{i}`; scope to shard 1.
    let rule = faults::inject(
        SITE_ROLLOUT_SAMPLE,
        Some("worker-1"),
        FaultAction::Hang,
    );
    let victim = set.remote(1).expect("live remote");
    let counters = set.fault_counters();
    let sup = DeadlineSupervision::with_counters(deadline, counters.clone());
    let mut it =
        parallel_rollouts_from(&set).gather_async_deadline(1, sup);
    let t0 = Instant::now();
    let mut pulls = 0u64;
    while counters.snapshot().suspects == 0 {
        it.next().expect("stream wedged behind the hung shard");
        pulls += 1;
        assert!(pulls < 10_000_000, "deadline never fired");
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    // Release the hang (the kill already panicked it into supervision)
    // and let the set drop cleanly.
    faults::clear(rule);
    assert!(
        victim.await_poisoned(Duration::from_secs(2)),
        "suspect was not force-poisoned"
    );
    ms
}

/// ns per iteration of a loop calling `failpoint` with nothing armed,
/// and of the same loop without it (the subtraction is the reader's —
/// both rows are reported).
fn disarmed_ns(iters: u64) -> (f64, f64) {
    assert!(!faults::armed(), "bench needs a disarmed registry");
    // Warm up past the registry's one-time env-schedule init.
    for _ in 0..1_000 {
        faults::failpoint(SITE_ROLLOUT_SAMPLE);
    }
    let t0 = Instant::now();
    for i in 0..iters {
        faults::failpoint(std::hint::black_box(SITE_ROLLOUT_SAMPLE));
        std::hint::black_box(i);
    }
    let with_fp = t0.elapsed().as_nanos() as f64 / iters as f64;
    let t0 = Instant::now();
    for i in 0..iters {
        std::hint::black_box(i);
    }
    let baseline = t0.elapsed().as_nanos() as f64 / iters as f64;
    (with_fp, baseline)
}

struct Report {
    deadline_ms: f64,
    detect_ms: Vec<f64>,
    disarmed_ns: f64,
    baseline_ns: f64,
}

fn measure(smoke: bool) -> Report {
    let deadline = Duration::from_millis(if smoke { 50 } else { 100 });
    let cycles = if smoke { 2 } else { 5 };
    let iters = if smoke { 1_000_000 } else { 50_000_000 };
    let detect_ms: Vec<f64> =
        (0..cycles).map(|_| detect_once(deadline)).collect();
    let (disarmed, baseline) = disarmed_ns(iters);
    Report {
        deadline_ms: deadline.as_secs_f64() * 1e3,
        detect_ms,
        disarmed_ns: disarmed,
        baseline_ns: baseline,
    }
}

fn json_report(r: &Report) -> String {
    let mean =
        r.detect_ms.iter().sum::<f64>() / r.detect_ms.len() as f64;
    let worst = r.detect_ms.iter().cloned().fold(0.0f64, f64::max);
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"faults\",\n");
    out.push_str("  \"units\": \"mixed\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         fault_detection -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"hang_detection_latency = ms from a supervised \
         gather's first pull until a shard wedged by a scripted \
         permanent Hang is declared suspect and force-killed; the floor \
         is the configured dispatch deadline (deadline_ms), the gap \
         above it is supervision lag.  disarmed_overhead = ns per \
         failpoint call with no rule armed (one relaxed atomic load by \
         design), beside a baseline row timing the same loop without \
         the call.  Dummy env, fragment 4, num_async 1.\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"hang_detection_latency\": \
         \"mean < deadline_ms + 50 ms (supervision lag, not another \
         deadline)\",\n    \"disarmed_overhead\": \"< 10 ns over \
         baseline per call\"\n  },\n",
    );
    out.push_str(
        "  \"ops\": [\"hang_detection_latency\", \
         \"disarmed_overhead\"],\n",
    );
    out.push_str("  \"results\": [\n");
    out.push_str(&format!(
        "    {{\"op\": \"hang_detection_latency\", \"units\": \
         \"ms_per_op\", \"ms_per_op\": {:.1}, \"worst_ms\": {:.1}, \
         \"deadline_ms\": {:.1}, \"cycles\": {}}},\n",
        mean,
        worst,
        r.deadline_ms,
        r.detect_ms.len()
    ));
    out.push_str(&format!(
        "    {{\"op\": \"disarmed_overhead\", \"units\": \"ns_per_op\", \
         \"ns_per_op\": {:.2}, \"mode\": \"failpoint\"}},\n",
        r.disarmed_ns
    ));
    out.push_str(&format!(
        "    {{\"op\": \"disarmed_overhead\", \"units\": \"ns_per_op\", \
         \"ns_per_op\": {:.2}, \"mode\": \"baseline\"}}\n",
        r.baseline_ns
    ));
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let r = measure(smoke);
    let mean =
        r.detect_ms.iter().sum::<f64>() / r.detect_ms.len() as f64;
    println!("# fault_detection bench");
    println!(
        "hang_detection_latency: {:.1} ms mean over {} cycles \
         (deadline {:.0} ms): {:?}",
        mean,
        r.detect_ms.len(),
        r.deadline_ms,
        r.detect_ms
            .iter()
            .map(|m| format!("{m:.1}"))
            .collect::<Vec<_>>()
    );
    println!(
        "disarmed_overhead: {:.2} ns/call (baseline loop {:.2} ns)",
        r.disarmed_ns, r.baseline_ns
    );
    // Hard floors even in smoke mode: detection happened after the
    // deadline (never before — that would be a spurious write-off) and
    // the disarmed path stayed cheap.
    for m in &r.detect_ms {
        assert!(
            *m >= r.deadline_ms * 0.9,
            "suspect declared before the deadline: {m:.1} ms"
        );
    }
    assert!(r.disarmed_ns.is_finite() && r.disarmed_ns >= 0.0);
    let json = json_report(&r);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_faults.json");
        std::fs::write(&path, &json).expect("write BENCH_faults.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
