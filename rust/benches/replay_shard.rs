//! Sharded replay service bench: add and sample throughput of the
//! registry-backed `ops::ReplayService` across shard counts.
//!
//! Two reported ops, each measured at every shard count in the sweep
//! (1/2/4; smoke runs 1/2):
//!
//! * `add_throughput` — transitions/s through `store_to_replay_buffer`'s
//!   hash-routed store path, from the first `store` call until every
//!   routed transition is visible in the pool's add gauges (driver-side
//!   routing + mailbox transfer + per-shard ring insert);
//! * `sample_throughput` — transitions/s delivered by the `replay`
//!   stream with the learner's priority round-trip included (each drawn
//!   sample's TD feedback goes back through its `ReplayLease`), pulled
//!   with two in-flight requests per shard.
//!
//! The interesting read is the *scaling shape*: add throughput should
//! grow with shards (independent rings, one mailbox each) until the
//! driver-side routing loop saturates; sample throughput bounds how
//! fast an Ape-X learner tier can be fed.
//!
//! Runs on synthetic batches — no env, no policy, no AOT artifacts, so
//! this bench always executes (including under `tools/ci.sh --smoke`).
//!
//! Run: `cargo bench --bench replay_shard`
//! Smoke: `cargo bench --bench replay_shard -- --smoke`
//! Record: `cargo bench --bench replay_shard -- --write`
//!         (rewrites BENCH_replay_shard.json at the repo root)

use std::time::{Duration, Instant};

use flowrl::ops::{create_replay_shards, replay, store_to_replay_buffer};
use flowrl::sample_batch::SampleBatchBuilder;

const OBS_DIM: usize = 8;
const FRAGMENT: usize = 32;

fn fragment_batch() -> flowrl::sample_batch::SampleBatch {
    let mut b = SampleBatchBuilder::new(OBS_DIM);
    let obs = [0.5f32; OBS_DIM];
    for i in 0..FRAGMENT {
        b.add_transition(&obs, (i % 4) as i32, 1.0, &obs, false);
    }
    b.build()
}

struct ShardPoint {
    shards: usize,
    add_items_per_s: f64,
    sample_items_per_s: f64,
    transitions: usize,
}

fn measure(shards: usize, smoke: bool) -> ShardPoint {
    let batches = if smoke { 64 } else { 2048 };
    let pulls = if smoke { 64 } else { 2048 };
    let service =
        create_replay_shards(shards, OBS_DIM, 1 << 15, 0, FRAGMENT);
    let mut store = store_to_replay_buffer(&service);
    let batch = fragment_batch();

    // --- add_throughput: route `batches` fragments across the live
    // shard set, then wait for the last cast to land in a ring (the
    // gauges make the landed count observable without a per-shard
    // call).  Column storage is shared, so the clone per store is the
    // same cheap Arc bump the rollout path does.
    let want = (batches * FRAGMENT) as u64;
    let t0 = Instant::now();
    for _ in 0..batches {
        store(batch.clone());
    }
    loop {
        let added = service.backlog_stats().added;
        if added >= want {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "store path stalled: {added}/{want} transitions landed"
        );
        std::thread::yield_now();
    }
    let add_items_per_s = want as f64 / t0.elapsed().as_secs_f64();

    // --- sample_throughput: drain the replay stream with the learner's
    // priority round-trip, 2 in-flight per shard (Ape-X's default
    // pipelining shape).
    let mut it = replay(&service, 2);
    for _ in 0..8 {
        it.next().expect("warmup pull");
    }
    let mut sampled = 0usize;
    let mut drawn = 0usize;
    let t0 = Instant::now();
    while drawn < pulls {
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "replay stream stalled after {drawn}/{pulls} samples"
        );
        if let Some((sample, lease)) = it.next().expect("replay stream") {
            sampled += sample.batch.len();
            drawn += 1;
            let tds = vec![1.0f32; sample.indices.len()];
            lease.update_priorities(sample.indices, tds);
        }
    }
    let sample_items_per_s = sampled as f64 / t0.elapsed().as_secs_f64();

    ShardPoint {
        shards,
        add_items_per_s,
        sample_items_per_s,
        transitions: want as usize,
    }
}

fn json_report(points: &[ShardPoint]) -> String {
    // Mirrors the committed BENCH_replay_shard.json schema so
    // `-- --write` preserves the regeneration command and targets.
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"replay_shard\",\n");
    out.push_str("  \"units\": \"items_per_s\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         replay_shard -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"add_throughput = transitions/s through \
         store_to_replay_buffer's hash-routed store path until the \
         last routed transition is visible in the pool gauges; \
         sample_throughput = transitions/s delivered by the replay \
         stream including the ReplayLease priority round-trip, 2 \
         in-flight per shard.  Synthetic 32-transition fragments, \
         obs_dim 8, 32k-slot rings, learning_starts 0.\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"add_throughput\": \
         \"monotone non-decreasing in shard count up to the routing \
         loop's saturation point\",\n    \"sample_throughput\": \">= \
         1.5x single-shard rate at 4 shards (independent rings must \
         parallelize)\"\n  },\n",
    );
    out.push_str(
        "  \"ops\": [\"add_throughput\", \"sample_throughput\"],\n",
    );
    out.push_str("  \"results\": [\n");
    for (i, p) in points.iter().enumerate() {
        let tail = if i + 1 == points.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"op\": \"add_throughput\", \"items_per_s\": {:.0}, \
             \"shards\": {}, \"transitions\": {}}},\n",
            p.add_items_per_s, p.shards, p.transitions
        ));
        out.push_str(&format!(
            "    {{\"op\": \"sample_throughput\", \"items_per_s\": \
             {:.0}, \"shards\": {}}}{tail}\n",
            p.sample_items_per_s, p.shards
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let sweep: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let mut points = Vec::new();
    println!("# replay_shard bench");
    println!("| shards | add items/s | sample items/s |");
    println!("|--------|-------------|----------------|");
    for &n in sweep {
        let p = measure(n, smoke);
        println!(
            "| {} | {:.0} | {:.0} |",
            p.shards, p.add_items_per_s, p.sample_items_per_s
        );
        points.push(p);
    }
    for p in &points {
        assert!(p.add_items_per_s.is_finite() && p.add_items_per_s > 0.0);
        assert!(
            p.sample_items_per_s.is_finite() && p.sample_items_per_s > 0.0
        );
    }
    let json = json_report(&points);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_replay_shard.json");
        std::fs::write(&path, &json).expect("write BENCH_replay_shard.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
