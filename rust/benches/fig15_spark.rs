//! Fig. 15 + Appendix A.1 — PPO: RLlib Flow vs the Spark-Streaming-
//! style microbatch executor, with the per-phase breakdown (init / IO /
//! sample / train) that explains the gap.
//!
//! The paper ran CartPole PPO with B=100K on m4.10xlarge machines; we
//! scale the batch to the testbed (see DESIGN.md §Substitutions) — the
//! *structure* of the result (flow wins; init+IO overheads are flat as
//! workers scale, so Spark scales worse) is the claim under test.
//!
//! Run: `cargo bench --bench fig15_spark`
//! Smoke: `-- --smoke` (1 iter, 1 worker count; artifact-gated skip).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use flowrl::algorithms::{ppo_plan_with_epochs, EnvKind, TrainerConfig};
use flowrl::baseline::{MicrobatchPpo, MicrobatchTimings};

const BATCH: usize = 2048; // paper: 100K on a cluster; scaled down

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn iters() -> usize {
    if smoke() {
        1
    } else {
        5
    }
}

fn config(num_workers: usize) -> TrainerConfig {
    TrainerConfig {
        num_workers,
        num_envs_per_worker: 4,
        rollout_fragment_length: 64,
        train_batch_size: BATCH,
        lr: 1e-3,
        artifacts_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts"),
        seed: 9,
        num_async: 1,
        env: EnvKind::CartPole,
        ..TrainerConfig::default()
    }
}

fn flow_time_per_iter(n: usize) -> Duration {
    let mut plan = ppo_plan_with_epochs(&config(n), 1);
    plan.next(); // warmup + compile
    let start = Instant::now();
    for _ in 0..iters() {
        plan.next().unwrap();
    }
    start.elapsed() / iters() as u32
}

fn spark_style(n: usize) -> MicrobatchTimings {
    let dir = std::env::temp_dir()
        .join(format!("flowrl_fig15_{}_{n}", std::process::id()));
    let mut mb = MicrobatchPpo::new(config(n), 1, &dir);
    let mut acc = MicrobatchTimings::default();
    for _ in 0..iters() {
        let t = mb.step();
        acc.init += t.init;
        acc.io += t.io;
        acc.sample += t.sample;
        acc.train += t.train;
    }
    std::fs::remove_dir_all(&dir).ok();
    MicrobatchTimings {
        init: acc.init / iters() as u32,
        io: acc.io / iters() as u32,
        sample: acc.sample / iters() as u32,
        train: acc.train / iters() as u32,
    }
}

fn main() {
    if !config(1).artifacts_dir.join("manifest.json").exists() {
        println!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    println!(
        "# Fig. 15 — PPO throughput: RLlib Flow vs Spark-Streaming-style \
         (B={BATCH}, {} iters/cell)",
        iters()
    );
    println!(
        "| workers | flow s/iter | spark s/iter | speedup | spark init | \
         spark io | spark sample | spark train |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    let worker_counts: &[usize] = if smoke() { &[1] } else { &[1, 2, 4, 8] };
    for &n in worker_counts {
        let flow = flow_time_per_iter(n);
        let sp = spark_style(n);
        let spark_total = sp.total();
        println!(
            "| {n} | {:.2} | {:.2} | {:.1}x | {:.2} | {:.3} | {:.2} | {:.2} |",
            flow.as_secs_f64(),
            spark_total.as_secs_f64(),
            spark_total.as_secs_f64() / flow.as_secs_f64(),
            sp.init.as_secs_f64(),
            sp.io.as_secs_f64(),
            sp.sample.as_secs_f64(),
            sp.train.as_secs_f64(),
        );
    }
    println!();
    println!(
        "(spark init+io are per-iteration re-initialization and \
         state-file loop-back costs — structural to the stateless \
         microbatch model, flat in worker count)"
    );
}
