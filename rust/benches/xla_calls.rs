//! Raw PJRT executable-call latencies — the L2/runtime numbers behind
//! the perf pass: how much of a training iteration is XLA dispatch vs
//! coordination.
//!
//! Run: `cargo bench --bench xla_calls`
//! Smoke: `-- --smoke` (iteration counts / 20; artifact-gated skip).

use std::path::PathBuf;
use std::time::Instant;

use flowrl::runtime::{TensorArg, XlaRuntime};

fn measure(name: &str, base_iters: usize, mut f: impl FnMut()) {
    let iters = if std::env::args().any(|a| a == "--smoke") {
        (base_iters / 20).max(3)
    } else {
        base_iters
    };
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    println!("| {name} | {iters} | {:?} |", start.elapsed() / iters as u32);
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = XlaRuntime::load(
        &dir,
        &["pg_fwd", "a3c_grad", "ppo_grad", "dqn_grad", "impala_grad",
          "adam_pg", "dqn_q_fwd"],
    )
    .expect("artifacts");
    let cfg = rt.manifest.config.clone();
    let params = rt.load_init_params("init_pg").unwrap();
    let dqn_params = rt.load_init_params("init_dqn").unwrap();

    println!("# raw XLA call latencies (CPU PJRT, interpret-lowered Pallas)");
    println!("| executable | iters | per-call |");
    println!("|---|---|---|");

    let obs8 = vec![0.1f32; cfg.inf_batch * cfg.obs_dim];
    measure("pg_fwd (B=8)", 2000, || {
        rt.exe("pg_fwd")
            .run(&[TensorArg::F32(&params), TensorArg::F32(&obs8)])
            .unwrap();
    });
    measure("dqn_q_fwd (B=8)", 2000, || {
        rt.exe("dqn_q_fwd")
            .run(&[TensorArg::F32(&dqn_params), TensorArg::F32(&obs8)])
            .unwrap();
    });

    let n = cfg.fragment;
    let obs = vec![0.1f32; n * cfg.obs_dim];
    let act = vec![0i32; n];
    let f = vec![0.5f32; n];
    measure("a3c_grad (B=64)", 500, || {
        rt.exe("a3c_grad")
            .run(&[
                TensorArg::F32(&params),
                TensorArg::F32(&obs),
                TensorArg::I32(&act),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
            ])
            .unwrap();
    });

    let n = cfg.ppo_minibatch;
    let obs = vec![0.1f32; n * cfg.obs_dim];
    let act = vec![0i32; n];
    let f = vec![0.5f32; n];
    measure("ppo_grad (B=128)", 500, || {
        rt.exe("ppo_grad")
            .run(&[
                TensorArg::F32(&params),
                TensorArg::F32(&obs),
                TensorArg::I32(&act),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
            ])
            .unwrap();
    });

    let n = cfg.dqn_minibatch;
    let obs = vec![0.1f32; n * cfg.obs_dim];
    let act = vec![0i32; n];
    let f = vec![0.5f32; n];
    measure("dqn_grad (B=64)", 500, || {
        rt.exe("dqn_grad")
            .run(&[
                TensorArg::F32(&dqn_params),
                TensorArg::F32(&dqn_params),
                TensorArg::F32(&obs),
                TensorArg::I32(&act),
                TensorArg::F32(&f),
                TensorArg::F32(&obs),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
            ])
            .unwrap();
    });

    let (t, b) = (cfg.impala_t, cfg.impala_b);
    let obs = vec![0.1f32; t * b * cfg.obs_dim];
    let boot = vec![0.1f32; b * cfg.obs_dim];
    let act = vec![0i32; t * b];
    let f = vec![0.1f32; t * b];
    measure("impala_grad (T=20,B=8)", 300, || {
        rt.exe("impala_grad")
            .run(&[
                TensorArg::F32(&params),
                TensorArg::F32(&obs),
                TensorArg::I32(&act),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
                TensorArg::F32(&f),
                TensorArg::F32(&boot),
                TensorArg::F32(&f),
            ])
            .unwrap();
    });

    let g = vec![0.001f32; params.len()];
    let m = vec![0.0f32; params.len()];
    measure("adam_pg (P=4675)", 2000, || {
        rt.exe("adam_pg")
            .run(&[
                TensorArg::F32(&params),
                TensorArg::F32(&g),
                TensorArg::F32(&m),
                TensorArg::F32(&m),
                TensorArg::ScalarF32(1.0),
                TensorArg::ScalarF32(1e-3),
            ])
            .unwrap();
    });
}
