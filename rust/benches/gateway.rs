//! External-episode gateway bench: a synthetic client swarm serving
//! its episodes through the `ops::GatewayService`, across shard counts.
//!
//! Two reported ops, each measured at every shard count in the sweep
//! (1/2/4; smoke runs 1/2), with 4 client threads per shard:
//!
//! * `sessions_held` — peak concurrent sessions observed across the
//!   live shards while the swarm runs (the serving tier must actually
//!   hold the swarm, not shed it);
//! * `p99_action_latency` — p99 of the submit→serve latency per
//!   action, measured inside the shard tick (the time an observation
//!   waits before its batched forward), from the shard gauges.
//!
//! The interesting read: p99 latency must stay bounded as the swarm
//! and shard count grow together (per-shard batching absorbs the
//! load), and `max_batch_fill > 1` (printed) confirms concurrent
//! clients actually coalesce into shared forwards.
//!
//! Runs the dummy policy — no env, no AOT artifacts, so this bench
//! always executes (including under `tools/ci.sh --smoke`).
//!
//! Run: `cargo bench --bench gateway`
//! Smoke: `cargo bench --bench gateway -- --smoke`
//! Record: `cargo bench --bench gateway -- --write`
//!         (rewrites BENCH_gateway.json at the repo root)

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::time::{Duration, Instant};

use flowrl::env::GatewayConfig;
use flowrl::ops::GatewayService;
use flowrl::policy::DummyPolicy;

const OBS_DIM: usize = 8;
const CLIENTS_PER_SHARD: usize = 4;
const EPISODE_LEN: usize = 32;

struct SwarmPoint {
    shards: usize,
    clients: usize,
    peak_sessions: usize,
    p99_us: f64,
    actions_per_s: f64,
    max_batch_fill: u64,
}

fn measure(shards: usize, smoke: bool) -> SwarmPoint {
    let episodes_per_client = if smoke { 8 } else { 64 };
    let clients = CLIENTS_PER_SHARD * shards;
    let svc = GatewayService::new(
        shards,
        GatewayConfig {
            obs_dim: OBS_DIM,
            max_sessions: 4 * clients,
            ..GatewayConfig::default()
        },
        |_slot| Box::new(DummyPolicy::new(0.01)),
    );

    let done = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let obs = vec![t as f32; OBS_DIM];
                let mut served = 0u64;
                for _ in 0..episodes_per_client {
                    let session = loop {
                        match svc.connect() {
                            Ok(s) => break s,
                            Err(_) => std::thread::sleep(
                                Duration::from_micros(100),
                            ),
                        }
                    };
                    for _ in 0..EPISODE_LEN {
                        session.request_action(&obs).expect("serve");
                        session.log_reward(1.0).expect("reward");
                        served += 1;
                    }
                    session.end(Some(&obs)).expect("end");
                }
                served
            })
        })
        .collect();

    // Sample peak concurrent sessions while the swarm runs.
    let sampler = {
        let svc = svc.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut peak = 0usize;
            while !done.load(Relaxed) {
                peak = peak.max(svc.backlog_stats().sessions);
                std::thread::sleep(Duration::from_micros(200));
            }
            peak
        })
    };

    let served: u64 = workers.into_iter().map(|h| h.join().unwrap()).sum();
    let elapsed = t0.elapsed().as_secs_f64();
    done.store(true, Relaxed);
    let peak_sessions = sampler.join().unwrap();

    let stats = svc.backlog_stats();
    SwarmPoint {
        shards,
        clients,
        peak_sessions,
        p99_us: stats.p99_action_latency_us,
        actions_per_s: served as f64 / elapsed,
        max_batch_fill: stats.max_batch_fill,
    }
}

fn json_report(points: &[SwarmPoint]) -> String {
    // Mirrors the committed BENCH_gateway.json schema so `-- --write`
    // preserves the regeneration command and targets.
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"gateway\",\n");
    out.push_str("  \"units\": \"mixed\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         gateway -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"Synthetic client swarm (4 threads per shard, \
         32-step episodes, dummy policy, obs_dim 8) serving through \
         GatewayService.  sessions_held = peak concurrent sessions \
         observed across live shards during the run; \
         p99_action_latency = p99 submit-to-serve wait per action from \
         the shard gauges (time queued before the batched forward).\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"sessions_held\": \"within \
         2x of the client-thread count at every shard count (the tier \
         holds the swarm instead of shedding it)\",\n    \
         \"p99_action_latency\": \"bounded as clients and shards grow \
         together; no super-linear blowup at 4 shards vs 1\"\n  },\n",
    );
    out.push_str(
        "  \"ops\": [\"sessions_held\", \"p99_action_latency\"],\n",
    );
    out.push_str("  \"results\": [\n");
    for (i, p) in points.iter().enumerate() {
        let tail = if i + 1 == points.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"op\": \"sessions_held\", \"units\": \"count\", \
             \"count\": {}, \"shards\": {}, \"clients\": {}}},\n",
            p.peak_sessions, p.shards, p.clients
        ));
        out.push_str(&format!(
            "    {{\"op\": \"p99_action_latency\", \"units\": \
             \"us_per_op\", \"us_per_op\": {:.1}, \"shards\": {}, \
             \"max_batch_fill\": {}}}{tail}\n",
            p.p99_us, p.shards, p.max_batch_fill
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let sweep: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let mut points = Vec::new();
    println!("# gateway bench — client swarm vs serving tier");
    println!("| shards | clients | peak sessions | p99 us | actions/s | max fill |");
    println!("|--------|---------|---------------|--------|-----------|----------|");
    for &n in sweep {
        let p = measure(n, smoke);
        println!(
            "| {} | {} | {} | {:.1} | {:.0} | {} |",
            p.shards,
            p.clients,
            p.peak_sessions,
            p.p99_us,
            p.actions_per_s,
            p.max_batch_fill
        );
        points.push(p);
    }
    for p in &points {
        assert!(p.peak_sessions >= 1, "swarm never held a session");
        assert!(p.p99_us.is_finite() && p.p99_us >= 0.0);
        assert!(p.actions_per_s.is_finite() && p.actions_per_s > 0.0);
    }
    let json = json_report(&points);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_gateway.json");
        std::fs::write(&path, &json).expect("write BENCH_gateway.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
