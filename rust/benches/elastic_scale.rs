//! Elastic scale-out bench: what does growing a `WorkerSet` under a
//! running `gather_async` cost, and what does the stream deliver while
//! the set is growing?
//!
//! Three reported ops:
//!
//! * `scale_up_latency` — ms from the `scale_to` call until the running
//!   gather yields the first completion produced by a newly added
//!   worker (registry publish -> discovery scan -> credit priming ->
//!   first sample), averaged over fresh sets;
//! * `growth_throughput` — completions/s observed by the driver over a
//!   window that spans the scale-up (the stream must not stall while
//!   membership changes);
//! * `steady_throughput` — the same window at fixed membership, as the
//!   baseline the growth window is compared against.
//!
//! Runs on the Dummy env/policy — no AOT artifacts needed, so this
//! bench always executes (including under `tools/ci.sh --smoke`).
//!
//! Run: `cargo bench --bench elastic_scale`
//! Smoke: `cargo bench --bench elastic_scale -- --smoke`
//! Record: `cargo bench --bench elastic_scale -- --write`
//!         (rewrites BENCH_elastic.json at the repo root)

use std::collections::HashSet;
use std::time::Instant;

use flowrl::env::{DummyEnv, Env};
use flowrl::ops::parallel_rollouts_from;
use flowrl::policy::DummyPolicy;
use flowrl::rollout::{CollectMode, RolloutWorker, WorkerSet};

fn worker_set(n_remote: usize) -> WorkerSet {
    WorkerSet::new(n_remote, |_| {
        Box::new(|| {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(DummyPolicy::new(0.1)),
                4,
                CollectMode::OnPolicy,
            )
        })
    })
}

struct Report {
    scale_up_latency_ms: f64,
    workers_before: usize,
    workers_after: usize,
    growth_items_per_s: f64,
    steady_items_per_s: f64,
    window_items: usize,
}

fn measure(smoke: bool) -> Report {
    let reps = if smoke { 1 } else { 5 };
    let window_items = if smoke { 64 } else { 512 };
    let (before, after) = (2usize, 6usize);

    // --- scale_up_latency: scale_to -> first completion from a new
    // worker, fresh set per rep so discovery always starts cold.
    let mut latency_ms = 0.0;
    for _ in 0..reps {
        let set = worker_set(before);
        let mut it = parallel_rollouts_from(&set).gather_async_with_source(2);
        for _ in 0..8 {
            it.next().expect("warmup item");
        }
        let t0 = Instant::now();
        let (added, _) = set.scale_to(after).expect("scale_to");
        let new_ids: HashSet<u64> = added
            .iter()
            .map(|&i| set.remote(i).expect("live remote").id())
            .collect();
        // Bounded: a discovery regression must fail the bench with a
        // diagnostic, not hang the smoke sweep until the CI job
        // timeout (the smoke run has no external `timeout` wrapper).
        let mut pulled = 0usize;
        loop {
            let (_b, src) = it.next().expect("stream under growth");
            if new_ids.contains(&src.id()) {
                break;
            }
            pulled += 1;
            assert!(
                pulled < 10_000 && t0.elapsed().as_secs() < 30,
                "grown workers never joined the stream \
                 ({pulled} items pulled without a new-worker completion)"
            );
        }
        latency_ms += t0.elapsed().as_secs_f64() * 1e3;
    }
    latency_ms /= reps as f64;

    // --- growth_throughput: completions/s over a window that spans the
    // scale-up (set keeps growing while the driver pulls).
    let growth_items_per_s = {
        let set = worker_set(before);
        let mut it = parallel_rollouts_from(&set).gather_async_with_source(2);
        for _ in 0..8 {
            it.next().expect("warmup item");
        }
        let t0 = Instant::now();
        set.scale_to(after).expect("scale_to");
        for _ in 0..window_items {
            it.next().expect("stream under growth");
        }
        window_items as f64 / t0.elapsed().as_secs_f64()
    };

    // --- steady_throughput: same window, fixed membership.
    let steady_items_per_s = {
        let set = worker_set(before);
        let mut it = parallel_rollouts_from(&set).gather_async_with_source(2);
        for _ in 0..8 {
            it.next().expect("warmup item");
        }
        let t0 = Instant::now();
        for _ in 0..window_items {
            it.next().expect("steady stream");
        }
        window_items as f64 / t0.elapsed().as_secs_f64()
    };

    Report {
        scale_up_latency_ms: latency_ms,
        workers_before: before,
        workers_after: after,
        growth_items_per_s,
        steady_items_per_s,
        window_items,
    }
}

fn json_report(r: &Report) -> String {
    // Mirrors the committed BENCH_elastic.json schema so `-- --write`
    // preserves the regeneration command and acceptance targets.
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"elastic\",\n");
    out.push_str("  \"units\": \"mixed\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         elastic_scale -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"scale_up_latency = ms from WorkerSet::scale_to \
         until the running gather_async yields the first completion \
         from a newly added worker (registry publish -> discovery scan \
         -> credit priming -> first sample); growth_throughput = \
         completions/s observed while the set grows from \
         workers_before to workers_after; steady_throughput = the same \
         pull window at fixed membership.  Dummy env/policy, fragment \
         4, num_async 2.\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"scale_up_latency\": \"< 250 \
         ms from scale_to to first new-worker completion\",\n    \
         \"growth_throughput\": \">= 0.8x steady_throughput (growth \
         must not stall the stream)\"\n  },\n",
    );
    out.push_str(
        "  \"ops\": [\"scale_up_latency\", \"growth_throughput\", \
         \"steady_throughput\"],\n",
    );
    out.push_str("  \"results\": [\n");
    out.push_str(&format!(
        "    {{\"op\": \"scale_up_latency\", \"units\": \"ms_per_op\", \
         \"ms_per_op\": {:.3}, \"workers_before\": {}, \
         \"workers_after\": {}}},\n",
        r.scale_up_latency_ms, r.workers_before, r.workers_after
    ));
    out.push_str(&format!(
        "    {{\"op\": \"growth_throughput\", \"units\": \
         \"items_per_s\", \"items_per_s\": {:.0}, \"window_items\": {}, \
         \"workers_before\": {}, \"workers_after\": {}}},\n",
        r.growth_items_per_s, r.window_items, r.workers_before,
        r.workers_after
    ));
    out.push_str(&format!(
        "    {{\"op\": \"steady_throughput\", \"units\": \
         \"items_per_s\", \"items_per_s\": {:.0}, \"window_items\": {}, \
         \"workers\": {}}}\n",
        r.steady_items_per_s, r.window_items, r.workers_before
    ));
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let r = measure(smoke);
    println!("# elastic_scale bench");
    println!(
        "scale_up_latency ({} -> {} workers): {:.2} ms",
        r.workers_before, r.workers_after, r.scale_up_latency_ms
    );
    println!(
        "growth_throughput: {:.0} items/s over {} items",
        r.growth_items_per_s, r.window_items
    );
    println!(
        "steady_throughput: {:.0} items/s over {} items",
        r.steady_items_per_s, r.window_items
    );
    // Hard floor even in smoke mode: growth must have been observed at
    // all (a gather that never discovers new shards would hang the
    // latency loop instead — bounded by the ci.sh timeout).
    assert!(r.scale_up_latency_ms.is_finite() && r.scale_up_latency_ms > 0.0);
    let json = json_report(&r);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_elastic.json");
        std::fs::write(&path, &json).expect("write BENCH_elastic.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
