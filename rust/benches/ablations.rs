//! Ablations over the design choices DESIGN.md calls out:
//!
//!  1. `num_async` (gather pipelining depth) on IMPALA end-to-end
//!     throughput — the paper's "level of asynchrony can be configured
//!     to increase pipeline parallelism" (§3).
//!  2. `round_robin_weights` rate-limiting on DQN's store:replay ratio
//!     — the Acme-style fixed-ratio knob (§2.2/§4): how the weights
//!     shift the trained:sampled balance.
//!
//! Run: `cargo bench --bench ablations`
//! Smoke: `cargo bench --bench ablations -- --smoke` (tiny iteration
//! counts; skips cleanly when the AOT artifacts are absent).

use std::path::PathBuf;
use std::time::Instant;

use flowrl::algorithms::{EnvKind, TrainerConfig};
use flowrl::iter::{concurrently, UnionMode};
use flowrl::metrics::TrainResult;
use flowrl::ops::{
    create_replay_shards, parallel_rollouts_from, replay,
    store_to_replay_buffer, Reporting, TrainItem,
};

fn config() -> TrainerConfig {
    TrainerConfig {
        num_workers: 2,
        lr: 1e-3,
        artifacts_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts"),
        seed: 13,
        env: EnvKind::CartPole,
        ..TrainerConfig::default()
    }
}

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn impala_throughput(num_async: usize, iters: usize) -> f64 {
    let mut cfg = config();
    cfg.num_async = num_async;
    let mut plan = flowrl::algorithms::impala_plan(&cfg);
    plan.next(); // warmup/compile
    let start = Instant::now();
    let mut first = None;
    let mut last = 0u64;
    for _ in 0..iters {
        let r = plan.next().unwrap();
        first.get_or_insert(r.num_env_steps_trained);
        last = r.num_env_steps_trained;
    }
    (last - first.unwrap()) as f64 / start.elapsed().as_secs_f64()
}

/// DQN store:replay with a weighted union; returns (sampled, trained)
/// after a fixed number of union pulls.
fn dqn_ratio(
    store_weight: usize,
    replay_weight: usize,
    reports: usize,
) -> (u64, u64) {
    let mut cfg = config();
    cfg.rollout_fragment_length = 16;
    cfg.num_envs_per_worker = 2;
    let workers = cfg.dqn_workers();
    let obs_dim =
        workers.local.call(|w| w.obs_dim()).expect("learner died");
    let service = create_replay_shards(1, obs_dim, 8192, 64, 64);
    let store_op = parallel_rollouts_from(&workers)
        .gather_async(1)
        .for_each(store_to_replay_buffer(&service))
        .for_each(|_| TrainItem::default());
    let replay_op = replay(&service, 1).for_each({
        let local = workers.local.clone();
        move |item| {
            let Some((sample, lease)) = item else {
                return TrainItem::default();
            };
            let steps = sample.batch.len();
            let indices = sample.indices;
            let batch = sample.batch;
            let (stats, td) = local
                .call(move |w| w.learn_and_td(&batch))
                .expect("learner died");
            lease.update_priorities(indices, td);
            TrainItem::new(stats, steps)
        }
    });
    let merged = concurrently(
        vec![store_op, replay_op],
        UnionMode::RoundRobin {
            weights: Some(vec![store_weight, replay_weight]),
        },
        None,
    );
    let mut stream = Reporting::new(merged, &workers, 1).build();
    let mut last = TrainResult::default();
    for _ in 0..reports {
        last = stream.next().unwrap();
    }
    (last.num_env_steps_sampled, last.num_env_steps_trained)
}

fn main() {
    if !config().artifacts_dir.join("manifest.json").exists() {
        println!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let (iters, reports) = if smoke() { (2, 5) } else { (30, 150) };
    let depths: &[usize] = if smoke() { &[1] } else { &[1, 2, 4] };
    let ratios: &[(usize, usize)] =
        if smoke() { &[(1, 1)] } else { &[(1, 1), (1, 4), (4, 1)] };
    println!(
        "# Ablation 1 — gather_async pipelining depth (IMPALA, {iters} iters)"
    );
    println!("| num_async | train steps/s |");
    println!("|-----------|---------------|");
    for &n in depths {
        println!("| {n} | {:.0} |", impala_throughput(n, iters));
    }

    println!();
    println!("# Ablation 2 — round_robin_weights rate limiting (DQN store:replay)");
    println!("| store:replay weights | sampled | trained | trained/sampled |");
    println!("|----------------------|---------|---------|-----------------|");
    for &(s, r) in ratios {
        let (sampled, trained) = dqn_ratio(s, r, reports);
        println!(
            "| {s}:{r} | {sampled} | {trained} | {:.2} |",
            trained as f64 / sampled.max(1) as f64
        );
    }
    println!();
    println!(
        "(the weights knob trades fresh data for replay reuse — the \
         paper's fixed-ratio progress control, §4 Concurrency)"
    );
}
