//! Autoscaler bench: what does closing the elasticity loop buy?
//!
//! An idle-learner workload (samplers burn ~2ms per env step, the
//! learner's update is microseconds) runs twice:
//!
//! * **autoscaled** — the pool starts at 1 sampler with an
//!   `actor::Autoscaler` driving `WorkerSet::scale_to` through
//!   `ops::Reporting::autoscale`; reported ops:
//!   `time_to_converge` (ms from the first report until the live pool
//!   reaches `max_workers`) and the post-convergence learner
//!   utilization (`steady_utilization`, mode "autoscaled");
//! * **fixed** — the same workload pinned at 1 sampler, same
//!   measurement window: `steady_utilization`, mode "fixed" — the
//!   baseline the autoscaled number is compared against (an idle
//!   learner is exactly what the controller exists to fix).
//!
//! Runs on the Dummy env + a sleep-knob policy — no AOT artifacts, so
//! this bench always executes (including `tools/ci.sh --smoke`).
//!
//! Run: `cargo bench --bench autoscale`
//! Smoke: `cargo bench --bench autoscale -- --smoke`
//! Record: `cargo bench --bench autoscale -- --write`
//!         (rewrites BENCH_autoscale.json at the repo root)

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use flowrl::actor::{Autoscaler, AutoscalerConfig};
use flowrl::env::{DummyEnv, Env};
use flowrl::ops::{parallel_rollouts_from, train_one_step, Reporting};
use flowrl::policy::{ActionOutput, Gradients, Policy};
use flowrl::rollout::{CollectMode, RolloutWorker, WorkerSet};
use flowrl::sample_batch::SampleBatch;

/// Sampler-side busy-work policy: `compute_actions` sleeps, the learner
/// update is effectively free — the idle-learner workload shape.
struct SlowSampler {
    step_sleep: Duration,
    weights: Vec<f32>,
}

impl Policy for SlowSampler {
    fn compute_actions_into(
        &mut self,
        _obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    ) {
        std::thread::sleep(self.step_sleep);
        out.clear();
        out.resize(n, ActionOutput { action: 0, logp: 0.0, value: 0.0 });
    }

    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        let mut stats = BTreeMap::new();
        stats.insert("loss".to_string(), 0.5);
        Gradients { flat: vec![0.0], stats, count: batch.len() }
    }

    fn apply_gradients(&mut self, _grads: &Gradients) {}

    fn get_weights(&self) -> Vec<f32> {
        self.weights.clone()
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.weights = weights.to_vec();
    }
}

fn worker_set(n_remote: usize, step_sleep_us: u64) -> WorkerSet {
    WorkerSet::new(n_remote, move |_| {
        Box::new(move || {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(SlowSampler {
                    step_sleep: Duration::from_micros(step_sleep_us),
                    weights: vec![0.0],
                }),
                4,
                CollectMode::OnPolicy,
            )
        })
    })
}

/// Learner busy fraction over `window` reports, measured from the local
/// actor's cumulative telemetry deltas.
fn learner_utilization_over(
    set: &WorkerSet,
    reports: &mut flowrl::iter::LocalIter<flowrl::metrics::TrainResult>,
    window: usize,
) -> f64 {
    let before = set.local.stats();
    for _ in 0..window {
        reports.next().expect("report stream ended early");
    }
    let after = set.local.stats();
    let busy = after.busy_ns.saturating_sub(before.busy_ns);
    let idle = after.idle_ns.saturating_sub(before.idle_ns);
    if busy + idle == 0 {
        0.0
    } else {
        busy as f64 / (busy + idle) as f64
    }
}

struct Report {
    time_to_converge_ms: f64,
    reports_to_converge: usize,
    workers_from: usize,
    workers_to: usize,
    util_autoscaled: f64,
    util_fixed: f64,
}

fn measure(smoke: bool) -> Report {
    let step_sleep_us = if smoke { 1_000 } else { 2_000 };
    let target = if smoke { 3 } else { 4 };
    let window = if smoke { 8 } else { 48 };
    let report_cap = if smoke { 60 } else { 300 };

    // --- autoscaled: converge 1 -> target, then measure steady state.
    let set = worker_set(1, step_sleep_us);
    let mut train = train_one_step(&set);
    let train_op = parallel_rollouts_from(&set)
        .gather_async(1)
        .for_each(move |b| train(b));
    let controller = Autoscaler::new(AutoscalerConfig {
        min_workers: 1,
        max_workers: target,
        learner_idle_below: 0.3,
        learner_busy_above: 0.9,
        sampler_queue_pressure: 1_000,
        shed_tolerance: u64::MAX / 2,
        cooldown_reports: 0,
        confirm_reports: 1,
        step: 1,
        ..AutoscalerConfig::default()
    });
    let mut reports =
        Reporting::new(train_op, &set, 1).autoscale(controller).build();
    let t0 = Instant::now();
    let mut reports_to_converge = 0usize;
    while set.num_live_remotes() < target {
        reports.next().expect("autoscaled stream ended early");
        reports_to_converge += 1;
        assert!(
            reports_to_converge < report_cap,
            "autoscaler never converged to {target} workers \
             ({reports_to_converge} reports)"
        );
    }
    let time_to_converge_ms = t0.elapsed().as_secs_f64() * 1e3;
    let util_autoscaled =
        learner_utilization_over(&set, &mut reports, window);

    // --- fixed baseline: same workload pinned at 1 sampler.
    let fixed = worker_set(1, step_sleep_us);
    let mut train = train_one_step(&fixed);
    let train_op = parallel_rollouts_from(&fixed)
        .gather_async(1)
        .for_each(move |b| train(b));
    let mut fixed_reports = Reporting::new(train_op, &fixed, 1).build();
    // Warm up the same number of reports the autoscaled run spent
    // converging, so both windows start past cold-start effects.
    for _ in 0..reports_to_converge.max(1) {
        fixed_reports.next().expect("fixed stream ended early");
    }
    let util_fixed =
        learner_utilization_over(&fixed, &mut fixed_reports, window);

    Report {
        time_to_converge_ms,
        reports_to_converge,
        workers_from: 1,
        workers_to: target,
        util_autoscaled,
        util_fixed,
    }
}

fn json_report(r: &Report) -> String {
    // Mirrors the committed BENCH_autoscale.json schema so `-- --write`
    // preserves the regeneration command and acceptance targets.
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"autoscale\",\n");
    out.push_str("  \"units\": \"mixed\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         autoscale -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"idle-learner workload (samplers sleep ~2ms/step, \
         learner update ~us).  time_to_converge = ms from the first \
         report until the Autoscaler has grown the live pool from \
         workers_from to workers_to through the running plan; \
         steady_utilization = learner busy fraction (percent) over the \
         post-convergence window, reported for the autoscaled pool and \
         for a fixed pool pinned at workers_from — the gap is what \
         closing the elasticity loop buys.  Dummy env, fragment 4, \
         num_async 1.\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"time_to_converge\": \"< 5000 \
         ms from first report to full pool\",\n    \
         \"steady_utilization\": \"autoscaled >= 2x fixed on the \
         idle-learner workload\"\n  },\n",
    );
    out.push_str(
        "  \"ops\": [\"time_to_converge\", \"steady_utilization\"],\n",
    );
    out.push_str("  \"results\": [\n");
    out.push_str(&format!(
        "    {{\"op\": \"time_to_converge\", \"units\": \"ms_per_op\", \
         \"ms_per_op\": {:.1}, \"reports\": {}, \"workers_from\": {}, \
         \"workers_to\": {}}},\n",
        r.time_to_converge_ms,
        r.reports_to_converge,
        r.workers_from,
        r.workers_to
    ));
    out.push_str(&format!(
        "    {{\"op\": \"steady_utilization\", \"units\": \"percent\", \
         \"percent\": {:.1}, \"mode\": \"autoscaled\", \"workers\": {}}},\n",
        r.util_autoscaled * 100.0,
        r.workers_to
    ));
    out.push_str(&format!(
        "    {{\"op\": \"steady_utilization\", \"units\": \"percent\", \
         \"percent\": {:.1}, \"mode\": \"fixed\", \"workers\": {}}}\n",
        r.util_fixed * 100.0,
        r.workers_from
    ));
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let r = measure(smoke);
    println!("# autoscale bench");
    println!(
        "time_to_converge ({} -> {} workers): {:.1} ms over {} reports",
        r.workers_from, r.workers_to, r.time_to_converge_ms,
        r.reports_to_converge
    );
    println!(
        "steady learner utilization: autoscaled {:.1}% vs fixed {:.1}%",
        r.util_autoscaled * 100.0,
        r.util_fixed * 100.0
    );
    // Hard floors even in smoke mode: convergence happened, the
    // utilizations are sane fractions.
    assert!(r.time_to_converge_ms.is_finite() && r.time_to_converge_ms > 0.0);
    assert!((0.0..=1.0).contains(&r.util_autoscaled));
    assert!((0.0..=1.0).contains(&r.util_fixed));
    let json = json_report(&r);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_autoscale.json");
        std::fs::write(&path, &json).expect("write BENCH_autoscale.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
