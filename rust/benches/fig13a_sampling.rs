//! Fig. 13a — sampling microbenchmark: data throughput with a dummy
//! policy (one trainable scalar), isolating pure system overhead.
//!
//! Compares, across worker counts:
//!   * flow (num_async=2): `ParallelRollouts(...).gather_async(2)` —
//!     RLlib Flow's pipelined, completion-queue ("batched wait") path;
//!   * flow (num_async=1): same without pipelining;
//!   * strict-order baseline: the low-level pattern that blocks on a
//!     *specific* worker's future in a fixed rotation (stragglers
//!     block the driver — the failure mode batched waits avoid).
//!
//! Paper expectation: flow >= baseline, with a small edge from the
//! pipelined wait.  Run: `cargo bench --bench fig13a_sampling`
//! Smoke: `cargo bench --bench fig13a_sampling -- --smoke` (short
//! windows, 2 worker counts — the CI liveness pass).

use std::time::{Duration, Instant};

use flowrl::actor::{spawn_group, ActorHandle};
use flowrl::env::{DummyEnv, Env};
use flowrl::ops::parallel_rollouts;
use flowrl::policy::DummyPolicy;
use flowrl::rollout::{CollectMode, RolloutWorker};

const FRAGMENT: usize = 200;
const EPISODE_LEN: usize = 100;

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn measure_window() -> Duration {
    if smoke() {
        Duration::from_millis(80)
    } else {
        Duration::from_millis(1500)
    }
}

fn warmup_window() -> Duration {
    if smoke() {
        Duration::from_millis(10)
    } else {
        Duration::from_millis(200)
    }
}

fn workers(n: usize) -> Vec<ActorHandle<RolloutWorker>> {
    spawn_group("w", n, move |i| {
        Box::new(move || {
            let envs: Vec<Box<dyn Env>> =
                vec![Box::new(DummyEnv::new(4, EPISODE_LEN))];
            let _ = i;
            RolloutWorker::new(
                envs,
                Box::new(DummyPolicy::new(0.01)),
                FRAGMENT,
                CollectMode::OnPolicy,
            )
        })
    })
}

/// Drive an iterator for the measure window, returning env-steps/s.
fn drive(mut next: impl FnMut() -> usize) -> f64 {
    // Warmup.
    let warm = Instant::now();
    while warm.elapsed() < warmup_window() {
        next();
    }
    let measure = measure_window();
    let start = Instant::now();
    let mut steps = 0usize;
    while start.elapsed() < measure {
        steps += next();
    }
    steps as f64 / start.elapsed().as_secs_f64()
}

fn flow_throughput(n_workers: usize, num_async: usize) -> f64 {
    let ws = workers(n_workers);
    let mut it = parallel_rollouts(ws).gather_async(num_async);
    drive(move || it.next().map(|b| b.len()).unwrap_or(0))
}

fn strict_order_throughput(n_workers: usize) -> f64 {
    let ws = workers(n_workers);
    // One pending sample per worker; the driver *always* waits for
    // worker (i mod n), even if others finished earlier.
    let mut pending: Vec<_> = ws
        .iter()
        .map(|w| w.call_deferred(|state| state.sample()))
        .collect();
    let mut cursor = 0usize;
    drive(move || {
        let batch = std::mem::replace(
            &mut pending[cursor],
            ws[cursor].call_deferred(|state| state.sample()),
        )
        .recv()
        .expect("worker died");
        cursor = (cursor + 1) % ws.len();
        batch.len()
    })
}

fn main() {
    println!("# Fig. 13a — sampling microbenchmark (dummy policy)");
    println!("| workers | flow async=2 (steps/s) | flow async=1 | strict-order baseline | flow/baseline |");
    println!("|---------|------------------------|--------------|-----------------------|---------------|");
    let worker_counts: &[usize] =
        if smoke() { &[1, 2] } else { &[1, 2, 4, 8, 16] };
    for &n in worker_counts {
        let flow2 = flow_throughput(n, 2);
        let flow1 = flow_throughput(n, 1);
        let strict = strict_order_throughput(n);
        println!(
            "| {n} | {flow2:.0} | {flow1:.0} | {strict:.0} | {:.2}x |",
            flow2 / strict
        );
    }
}
