//! Experience-path microbench: view-based `SampleBatch` + SoA ring
//! replay (this crate) versus the seed's copy-based implementations
//! (vendored below as `reference`), at 1k–100k rows.
//!
//! Covers the ops the zero-copy refactor targets: `concat_all`,
//! `slice`, `minibatches`, `shuffle`, and replay `add_batch`+`sample`.
//! Both implementations run in the same process on identical data, so a
//! single invocation yields the seed baseline and the post-refactor
//! numbers side by side.
//!
//! Run: `cargo bench --bench sample_batch`
//! Smoke: `cargo bench --bench sample_batch -- --smoke` (tiny sizes and
//!        timing windows — the CI path that keeps this reporter alive)
//! Record: `cargo bench --bench sample_batch -- --write`
//!         (rewrites BENCH_sample_batch.json at the repo root)

use std::hint::black_box;
use std::time::{Duration, Instant};

use flowrl::sample_batch::{SampleBatch, SampleBatchBuilder};
use flowrl::util::Rng;

const OBS_DIM: usize = 4;
const SIZES: &[usize] = &[1_000, 10_000, 100_000];
const SMOKE_SIZES: &[usize] = &[1_000];
const REPLAY_BATCH: usize = 64;

/// `-- --smoke`: tiny run that exercises every code path (CI executes
/// all benches this way so reporter mains cannot bit-rot).
fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

// ---------------------------------------------------------------------
// reference: the seed's copy-based batch + Vec<Option<Transition>> replay
// ---------------------------------------------------------------------

mod reference {
    use flowrl::util::Rng;

    #[derive(Clone, Default)]
    pub struct RefBatch {
        pub obs_dim: usize,
        pub obs: Vec<f32>,
        pub actions: Vec<i32>,
        pub rewards: Vec<f32>,
        pub dones: Vec<f32>,
        pub action_logp: Vec<f32>,
        pub vf_preds: Vec<f32>,
        pub next_obs: Vec<f32>,
    }

    impl RefBatch {
        pub fn len(&self) -> usize {
            if self.obs_dim == 0 { 0 } else { self.obs.len() / self.obs_dim }
        }

        pub fn concat_all(batches: &[RefBatch]) -> RefBatch {
            let mut out = RefBatch { obs_dim: batches[0].obs_dim, ..Default::default() };
            for b in batches {
                out.obs.extend_from_slice(&b.obs);
                out.actions.extend_from_slice(&b.actions);
                out.rewards.extend_from_slice(&b.rewards);
                out.dones.extend_from_slice(&b.dones);
                out.action_logp.extend_from_slice(&b.action_logp);
                out.vf_preds.extend_from_slice(&b.vf_preds);
                out.next_obs.extend_from_slice(&b.next_obs);
            }
            out
        }

        pub fn slice(&self, start: usize, end: usize) -> RefBatch {
            let d = self.obs_dim;
            let col = |v: &Vec<f32>| {
                if v.is_empty() { vec![] } else { v[start..end].to_vec() }
            };
            let coln = |v: &Vec<f32>| {
                if v.is_empty() { vec![] } else { v[start * d..end * d].to_vec() }
            };
            RefBatch {
                obs_dim: d,
                obs: coln(&self.obs),
                actions: self.actions[start..end].to_vec(),
                rewards: col(&self.rewards),
                dones: col(&self.dones),
                action_logp: col(&self.action_logp),
                vf_preds: col(&self.vf_preds),
                next_obs: coln(&self.next_obs),
            }
        }

        pub fn minibatches(&self, size: usize) -> Vec<RefBatch> {
            let n = self.len() / size;
            (0..n).map(|i| self.slice(i * size, (i + 1) * size)).collect()
        }

        pub fn shuffle(&mut self, rng: &mut Rng) {
            let n = self.len();
            for i in (1..n).rev() {
                let j = rng.below(i + 1);
                self.swap_rows(i, j);
            }
        }

        fn swap_rows(&mut self, i: usize, j: usize) {
            if i == j {
                return;
            }
            let d = self.obs_dim;
            for k in 0..d {
                self.obs.swap(i * d + k, j * d + k);
                if !self.next_obs.is_empty() {
                    self.next_obs.swap(i * d + k, j * d + k);
                }
            }
            let swap1 = |v: &mut Vec<f32>| {
                if !v.is_empty() {
                    v.swap(i, j)
                }
            };
            self.actions.swap(i, j);
            swap1(&mut self.rewards);
            swap1(&mut self.dones);
            swap1(&mut self.action_logp);
            swap1(&mut self.vf_preds);
        }
    }

    #[derive(Clone)]
    struct Transition {
        obs: Vec<f32>,
        action: i32,
        reward: f32,
        next_obs: Vec<f32>,
        done: f32,
    }

    /// The seed's replay storage: boxed rows, O(capacity) obs_dim
    /// rediscovery per sample (priorities elided — both replay benches
    /// exercise storage movement, uniform sampling keeps them comparable).
    pub struct RefReplay {
        capacity: usize,
        storage: Vec<Option<Transition>>,
        next_slot: usize,
        size: usize,
        rng: Rng,
    }

    impl RefReplay {
        pub fn new(capacity: usize, seed: u64) -> Self {
            RefReplay {
                capacity,
                storage: vec![None; capacity],
                next_slot: 0,
                size: 0,
                rng: Rng::new(seed),
            }
        }

        pub fn add_batch(&mut self, b: &RefBatch) {
            let d = b.obs_dim;
            for i in 0..b.len() {
                let t = Transition {
                    obs: b.obs[i * d..(i + 1) * d].to_vec(),
                    action: b.actions[i],
                    reward: b.rewards[i],
                    next_obs: b.next_obs[i * d..(i + 1) * d].to_vec(),
                    done: b.dones[i],
                };
                self.storage[self.next_slot] = Some(t);
                self.next_slot = (self.next_slot + 1) % self.capacity;
                self.size = (self.size + 1).min(self.capacity);
            }
        }

        pub fn sample(&mut self, n: usize) -> RefBatch {
            // The seed's obs_dim rediscovery scan.
            let obs_dim = self
                .storage
                .iter()
                .flatten()
                .next()
                .map(|t| t.obs.len())
                .unwrap_or(0);
            let mut out = RefBatch { obs_dim, ..Default::default() };
            for _ in 0..n {
                let idx = self.rng.below(self.size);
                let t = self.storage[idx].as_ref().unwrap();
                out.obs.extend_from_slice(&t.obs);
                out.actions.push(t.action);
                out.rewards.push(t.reward);
                out.next_obs.extend_from_slice(&t.next_obs);
                out.dones.push(t.done);
            }
            out
        }
    }
}

// ---------------------------------------------------------------------
// data + timing helpers
// ---------------------------------------------------------------------

fn view_batch(n: usize, with_next: bool) -> SampleBatch {
    let mut rng = Rng::new(7);
    let mut b = SampleBatchBuilder::with_capacity(OBS_DIM, n);
    let mut obs = [0.0f32; OBS_DIM];
    let mut next = [0.0f32; OBS_DIM];
    for i in 0..n {
        for k in 0..OBS_DIM {
            obs[k] = rng.uniform_range(-1.0, 1.0);
            next[k] = obs[k] + 1.0;
        }
        if with_next {
            b.add_transition(&obs, (i % 2) as i32, i as f32, &next, false);
        } else {
            b.add_step(&obs, (i % 2) as i32, i as f32, false, -0.5, 0.1);
        }
    }
    b.build()
}

fn ref_batch(n: usize, with_next: bool) -> reference::RefBatch {
    let v = view_batch(n, with_next);
    reference::RefBatch {
        obs_dim: OBS_DIM,
        obs: v.obs.to_vec(),
        actions: v.actions.to_vec(),
        rewards: v.rewards.to_vec(),
        dones: v.dones.to_vec(),
        action_logp: v.action_logp.to_vec(),
        vf_preds: v.vf_preds.to_vec(),
        next_obs: v.next_obs.to_vec(),
    }
}

/// Time `f` adaptively: enough iterations to fill the timing window
/// (~200ms, ~10ms under `--smoke`), report ns/op.
fn time_ns(mut f: impl FnMut()) -> f64 {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let window = if smoke() {
        Duration::from_millis(10)
    } else {
        Duration::from_millis(200)
    };
    let iters =
        (window.as_nanos() / once.as_nanos()).clamp(3, 100_000) as usize;
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

struct Row {
    op: &'static str,
    n: usize,
    copy_ns: f64,
    view_ns: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.copy_ns / self.view_ns.max(1.0)
    }
}

fn bench_all() -> Vec<Row> {
    let sizes = if smoke() { SMOKE_SIZES } else { SIZES };
    let mut rows = Vec::new();
    let mut seen_replay_sizes = std::collections::BTreeSet::new();
    for &n in sizes {
        let vb = view_batch(n, false);
        let rb = ref_batch(n, false);

        // concat of 16 shards.
        let shard = n / 16;
        let v_shards: Vec<SampleBatch> =
            (0..16).map(|i| vb.slice(i * shard, (i + 1) * shard)).collect();
        let r_shards: Vec<reference::RefBatch> =
            (0..16).map(|i| rb.slice(i * shard, (i + 1) * shard)).collect();
        rows.push(Row {
            op: "concat16",
            n,
            copy_ns: time_ns(|| {
                black_box(reference::RefBatch::concat_all(black_box(&r_shards)));
            }),
            view_ns: time_ns(|| {
                black_box(SampleBatch::concat_all(black_box(&v_shards)));
            }),
        });

        // slice half.
        rows.push(Row {
            op: "slice_half",
            n,
            copy_ns: time_ns(|| {
                black_box(black_box(&rb).slice(n / 4, n / 4 + n / 2));
            }),
            view_ns: time_ns(|| {
                black_box(black_box(&vb).slice(n / 4, n / 4 + n / 2));
            }),
        });

        // minibatches of 128 (the PPO epoch shape).
        rows.push(Row {
            op: "minibatches128",
            n,
            copy_ns: time_ns(|| {
                black_box(black_box(&rb).minibatches(128));
            }),
            view_ns: time_ns(|| {
                black_box(black_box(&vb).minibatches(128));
            }),
        });

        // shuffle (clone once per call in both arms: PPO shuffles a
        // working copy, and the copy is ~free on the view side).
        rows.push(Row {
            op: "shuffle",
            n,
            copy_ns: time_ns(|| {
                let mut b = rb.clone();
                b.shuffle(&mut Rng::new(3));
                black_box(&b);
            }),
            view_ns: time_ns(|| {
                let mut b = vb.clone();
                b.shuffle(&mut Rng::new(3));
                black_box(&b);
            }),
        });

        // replay add + sample, ring sized to the workload (transition
        // count capped so the per-iteration add stays timeable; the
        // row is labeled with the actual count, and clamped duplicates
        // are benchmarked only once).
        let n_tr = n.min(4096);
        if !seen_replay_sizes.insert(n_tr) {
            continue;
        }
        let cap = (n_tr * 2).next_power_of_two();
        let v_tr = view_batch(n_tr, true);
        let r_tr = ref_batch(n_tr, true);
        rows.push(Row {
            op: "replay_add",
            n: n_tr,
            copy_ns: time_ns(|| {
                let mut buf = reference::RefReplay::new(cap, 1);
                buf.add_batch(black_box(&r_tr));
                black_box(&buf);
            }),
            view_ns: time_ns(|| {
                let mut buf =
                    flowrl::replay::PrioritizedReplayBuffer::with_obs_dim(
                        cap, OBS_DIM, 0.6, 0.4, 1,
                    );
                buf.add_batch(black_box(&v_tr));
                black_box(&buf);
            }),
        });
        {
            let mut r_buf = reference::RefReplay::new(cap, 1);
            r_buf.add_batch(&r_tr);
            let mut v_buf = flowrl::replay::PrioritizedReplayBuffer::with_obs_dim(
                cap, OBS_DIM, 0.6, 0.4, 1,
            );
            v_buf.add_batch(&v_tr);
            rows.push(Row {
                op: "replay_sample64",
                n: n_tr,
                copy_ns: time_ns(|| {
                    black_box(r_buf.sample(REPLAY_BATCH));
                }),
                view_ns: time_ns(|| {
                    black_box(v_buf.sample(REPLAY_BATCH));
                }),
            });
        }
    }
    rows
}

fn json_report(rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"sample_batch\",\n");
    out.push_str("  \"units\": \"ns_per_op\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         sample_batch -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"copy = seed implementation (vendored reference), \
         view = Arc-view SampleBatch + SoA ring replay\",\n",
    );
    out.push_str(
        "  \"ops\": [\"concat16\", \"slice_half\", \"minibatches128\", \
         \"shuffle\", \"replay_add\", \"replay_sample64\"],\n",
    );
    out.push_str("  \"obs_dim\": 4,\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"op\": \"{}\", \"rows\": {}, \"copy_ns\": {:.0}, \
             \"view_ns\": {:.0}, \"speedup\": {:.2}}}{}\n",
            r.op,
            r.n,
            r.copy_ns,
            r.view_ns,
            r.speedup(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let rows = bench_all();
    println!("# sample_batch microbench (ns/op; speedup = copy/view)");
    println!("| op | rows | copy ns | view ns | speedup |");
    println!("|----|------|---------|---------|---------|");
    for r in &rows {
        println!(
            "| {} | {} | {:.0} | {:.0} | {:.2}x |",
            r.op,
            r.n,
            r.copy_ns,
            r.view_ns,
            r.speedup()
        );
    }
    let json = json_report(&rows);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_sample_batch.json");
        std::fs::write(&path, &json).expect("write BENCH_sample_batch.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
