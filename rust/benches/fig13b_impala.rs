//! Fig. 13b — IMPALA end-to-end throughput: the flow plan vs the
//! low-level async-pipeline baseline, identical numerics (same
//! artifacts, same workers).
//!
//! Paper expectation: similar or better throughput for the flow
//! version.  Run: `cargo bench --bench fig13b_impala`
//! Smoke: `-- --smoke` (3 iters, 1 worker count; artifact-gated skip).

use std::path::PathBuf;
use std::time::Instant;

use flowrl::algorithms::{impala_plan, EnvKind, TrainerConfig};
use flowrl::baseline::AsyncPipelineOptimizer;
use flowrl::policy::PgLossKind;
use flowrl::rollout::CollectMode;

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn iters() -> usize {
    if smoke() {
        3
    } else {
        40
    }
}

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn config(num_workers: usize) -> TrainerConfig {
    TrainerConfig {
        num_workers,
        lr: 1e-3,
        artifacts_dir: artifacts(),
        seed: 3,
        num_async: 2,
        env: EnvKind::CartPole,
        ..TrainerConfig::default()
    }
}

fn flow_throughput(n: usize) -> f64 {
    let mut plan = impala_plan(&config(n));
    plan.next(); // warmup (includes compilation)
    let start = Instant::now();
    let mut steps = 0u64;
    let mut last_trained = 0u64;
    for _ in 0..iters() {
        let r = plan.next().unwrap();
        steps += r.num_env_steps_trained - last_trained;
        last_trained = r.num_env_steps_trained;
    }
    steps as f64 / start.elapsed().as_secs_f64()
}

fn baseline_throughput(n: usize) -> f64 {
    let cfg = config(n);
    let m = flowrl::runtime::Manifest::load(artifacts().join("manifest.json"))
        .unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.rollout_fragment_length = m.config.impala_t;
    cfg2.num_envs_per_worker = m.config.impala_b;
    let workers =
        cfg2.pg_workers(PgLossKind::Impala, CollectMode::OnPolicyWithNextObs);
    let mut opt = AsyncPipelineOptimizer::new(
        workers,
        m.config.impala_t,
        m.config.impala_b,
        2,
    );
    opt.step(); // warmup
    let start = Instant::now();
    let mut last = 0u64;
    let mut steps = 0u64;
    for _ in 0..iters() {
        let r = opt.step();
        steps += r.num_env_steps_trained - last;
        last = r.num_env_steps_trained;
    }
    steps as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    if !artifacts().join("manifest.json").exists() {
        println!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    println!(
        "# Fig. 13b — IMPALA throughput (train steps/s), {} learner iters",
        iters()
    );
    println!("| workers | RLlib Flow | low-level baseline | ratio |");
    println!("|---------|------------|--------------------|-------|");
    let worker_counts: &[usize] = if smoke() { &[1] } else { &[1, 2, 4] };
    for &n in worker_counts {
        let flow = flow_throughput(n);
        let base = baseline_throughput(n);
        println!("| {n} | {flow:.0} | {base:.0} | {:.2}x |", flow / base);
    }
}
