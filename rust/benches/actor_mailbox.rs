//! Control-plane microbench: the bounded ring mailbox (this crate)
//! versus the seed's boxed-closure + unbounded-mpsc actor (vendored
//! below as `reference`), on the three hot message paths: blocking
//! `call` roundtrips, fire-and-forget `cast` streams, and pipelined
//! `call_into` completion-queue roundtrips.  A counting global
//! allocator also reports allocations-per-message for both arms — the
//! ring path must be zero at steady state (the PR's acceptance
//! criterion; also asserted by rust/tests/actor_alloc.rs).
//!
//! Run: `cargo bench --bench actor_mailbox`
//! Smoke: `cargo bench --bench actor_mailbox -- --smoke` (tiny
//!        iteration counts; the zero-allocation assertion still holds)
//! Record: `cargo bench --bench actor_mailbox -- --write`
//!         (rewrites BENCH_actor_mailbox.json at the repo root)

use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use flowrl::actor::{ActorHandle, Completion, CompletionQueue};

// ---------------------------------------------------------------------
// Counting allocator (global: the bench runs one arm at a time, so
// cross-thread noise is limited to the arm being measured).
// ---------------------------------------------------------------------

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        std::alloc::System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }
    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        std::alloc::System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// ---------------------------------------------------------------------
// reference: the seed's actor — one Box<dyn FnOnce> per message through
// an unbounded mpsc, a sync_channel(1) per call (vendored verbatim in
// spirit from the pre-refactor actor/mod.rs).
// ---------------------------------------------------------------------

mod reference {
    use std::sync::mpsc;

    type Envelope<A> = Box<dyn FnOnce(&mut A) + Send>;

    pub struct RefActor<A> {
        tx: mpsc::Sender<Envelope<A>>,
    }

    impl<A: 'static> RefActor<A> {
        pub fn spawn(init: impl FnOnce() -> A + Send + 'static) -> Self {
            let (tx, rx) = mpsc::channel::<Envelope<A>>();
            std::thread::spawn(move || {
                let mut state = init();
                while let Ok(msg) = rx.recv() {
                    msg(&mut state);
                }
            });
            RefActor { tx }
        }

        pub fn call<R, F>(&self, f: F) -> R
        where
            R: Send + 'static,
            F: FnOnce(&mut A) -> R + Send + 'static,
        {
            let (otx, orx) = mpsc::sync_channel(1);
            self.tx
                .send(Box::new(move |state: &mut A| {
                    let _ = otx.send(f(state));
                }))
                .expect("actor died");
            orx.recv().expect("actor died")
        }

        pub fn cast<F>(&self, f: F)
        where
            F: FnOnce(&mut A) + Send + 'static,
        {
            let _ = self.tx.send(Box::new(f));
        }

        pub fn call_into<R, F>(
            &self,
            tag: usize,
            out: mpsc::Sender<(usize, R)>,
            f: F,
        ) where
            R: Send + 'static,
            F: FnOnce(&mut A) -> R + Send + 'static,
        {
            let _ = self.tx.send(Box::new(move |state: &mut A| {
                let _ = out.send((tag, f(state)));
            }));
        }
    }
}

// ---------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------

struct Row {
    op: &'static str,
    boxed_ns: f64,
    boxed_allocs_per_msg: f64,
    ring_ns: f64,
    ring_allocs_per_msg: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        if self.ring_ns > 0.0 { self.boxed_ns / self.ring_ns } else { 0.0 }
    }
}

/// Time `iters` runs of `f`, also returning allocations per iteration.
fn measure(iters: u64, mut f: impl FnMut()) -> (f64, f64) {
    for _ in 0..iters / 10 + 1 {
        f(); // warmup
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = start.elapsed().as_nanos() as f64 / iters as f64;
    let allocs =
        (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / iters as f64;
    (ns, allocs)
}

fn bench_all(smoke: bool) -> Vec<Row> {
    let call_iters: u64 = if smoke { 5_000 } else { 50_000 };
    let cast_iters: u64 = if smoke { 20_000 } else { 100_000 };
    let mut rows = Vec::new();

    // --- call roundtrip ---
    let (boxed_ns, boxed_allocs) = {
        let a = reference::RefActor::spawn(|| 0u64);
        measure(call_iters, || {
            black_box(a.call(|s| {
                *s += 1;
                *s
            }));
        })
    };
    let (ring_ns, ring_allocs) = {
        let a = ActorHandle::spawn("bench-call", || 0u64);
        measure(call_iters, || {
            black_box(
                a.call(|s| {
                    *s += 1;
                    *s
                })
                .unwrap(),
            );
        })
    };
    rows.push(Row {
        op: "call_roundtrip",
        boxed_ns,
        boxed_allocs_per_msg: boxed_allocs,
        ring_ns,
        ring_allocs_per_msg: ring_allocs,
    });

    // --- cast stream: enqueue cost only, both arms symmetric ---
    // The ring actor gets a mailbox wide enough for the whole timed
    // block so its blocking send never parks (the boxed mpsc is
    // unbounded and never parks either); each arm drains with a call
    // barrier before and after the timed loop, outside the clock.
    let (boxed_ns, boxed_allocs) = {
        let a = reference::RefActor::spawn(|| 0u64);
        for _ in 0..cast_iters / 10 {
            a.cast(|s| *s += 1); // warmup
        }
        black_box(a.call(|s| *s)); // drain barrier
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let start = Instant::now();
        for _ in 0..cast_iters {
            a.cast(|s| *s += 1);
        }
        let ns = start.elapsed().as_nanos() as f64 / cast_iters as f64;
        let al = (ALLOCS.load(Ordering::Relaxed) - a0) as f64
            / cast_iters as f64;
        black_box(a.call(|s| *s)); // drain
        (ns, al)
    };
    let (ring_ns, ring_allocs) = {
        let a = ActorHandle::spawn_with_capacity(
            "bench-cast",
            cast_iters as usize + 16,
            || 0u64,
        );
        for _ in 0..cast_iters / 10 {
            a.cast(|s| *s += 1); // warmup
        }
        black_box(a.call(|s| *s).unwrap()); // drain barrier
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let start = Instant::now();
        for _ in 0..cast_iters {
            a.cast(|s| *s += 1);
        }
        let ns = start.elapsed().as_nanos() as f64 / cast_iters as f64;
        let al = (ALLOCS.load(Ordering::Relaxed) - a0) as f64
            / cast_iters as f64;
        black_box(a.call(|s| *s).unwrap()); // drain
        (ns, al)
    };
    rows.push(Row {
        op: "cast",
        boxed_ns,
        boxed_allocs_per_msg: boxed_allocs,
        ring_ns,
        ring_allocs_per_msg: ring_allocs,
    });

    // --- call_into roundtrip through the completion path ---
    let (boxed_ns, boxed_allocs) = {
        let a = reference::RefActor::spawn(|| 0u64);
        let (tx, rx) = std::sync::mpsc::channel::<(usize, u64)>();
        measure(call_iters, || {
            a.call_into(0, tx.clone(), |s| {
                *s += 1;
                *s
            });
            black_box(rx.recv().unwrap());
        })
    };
    let (ring_ns, ring_allocs) = {
        let a = ActorHandle::spawn("bench-cq", || 0u64);
        let q: CompletionQueue<u64> = CompletionQueue::bounded(8);
        measure(call_iters, || {
            a.call_into(0, &q, |s| {
                *s += 1;
                *s
            });
            match q.pop() {
                Completion::Item { value, .. } => {
                    black_box(value);
                }
                Completion::Dropped { tag } => panic!("actor died ({tag})"),
            }
        })
    };
    rows.push(Row {
        op: "call_into_roundtrip",
        boxed_ns,
        boxed_allocs_per_msg: boxed_allocs,
        ring_ns,
        ring_allocs_per_msg: ring_allocs,
    });

    rows
}

fn json_report(rows: &[Row]) -> String {
    // Mirrors the committed BENCH_actor_mailbox.json schema so a
    // `-- --write` regeneration preserves the regeneration command and
    // the acceptance targets instead of deleting them.
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"actor_mailbox\",\n");
    out.push_str("  \"units\": \"ns_per_op\",\n");
    out.push_str(
        "  \"how_to_regenerate\": \"cd rust && cargo bench --bench \
         actor_mailbox -- --write\",\n",
    );
    out.push_str(
        "  \"note\": \"boxed = seed control plane (Box<dyn FnOnce> per \
         message through unbounded mpsc, vendored reference), ring = \
         bounded ring mailbox with inline 256-byte envelopes + shared \
         bounded completion queue; cast rows time the enqueue only \
         (drain barriers outside the clock, ring mailbox sized to the \
         block so neither arm parks)\",\n",
    );
    out.push_str(
        "  \"acceptance_targets\": {\n    \"ring_allocs_per_msg\": \
         \"== 0 for call, cast, and call_into (hard-asserted by the \
         bench)\",\n    \"cast\": \">= 1.5x speedup (boxed_ns / \
         ring_ns)\",\n    \"call_roundtrip\": \">= 1.2x speedup\"\n  \
         },\n",
    );
    out.push_str(
        "  \"ops\": [\"call_roundtrip\", \"cast\", \
         \"call_into_roundtrip\"],\n",
    );
    out.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"op\": \"{}\", \"boxed_ns\": {:.0}, \
             \"boxed_allocs_per_msg\": {:.2}, \"ring_ns\": {:.0}, \
             \"ring_allocs_per_msg\": {:.2}, \"speedup\": {:.2}}}{}\n",
            r.op,
            r.boxed_ns,
            r.boxed_allocs_per_msg,
            r.ring_ns,
            r.ring_allocs_per_msg,
            r.speedup(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let write = std::env::args().any(|a| a == "--write");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rows = bench_all(smoke);
    println!("# actor_mailbox microbench (ns/op; speedup = boxed/ring)");
    println!(
        "| op | boxed ns | boxed allocs/msg | ring ns | ring allocs/msg | speedup |"
    );
    println!(
        "|----|----------|------------------|---------|-----------------|---------|"
    );
    for r in &rows {
        println!(
            "| {} | {:.0} | {:.2} | {:.0} | {:.2} | {:.2}x |",
            r.op,
            r.boxed_ns,
            r.boxed_allocs_per_msg,
            r.ring_ns,
            r.ring_allocs_per_msg,
            r.speedup()
        );
    }
    // The acceptance bar: the ring paths allocate nothing per message.
    for r in &rows {
        assert!(
            r.ring_allocs_per_msg < 0.01,
            "{}: ring path allocated {:.2}/msg",
            r.op,
            r.ring_allocs_per_msg
        );
    }
    println!("\nring steady-state allocations/msg: 0 (asserted)");
    let json = json_report(&rows);
    if write {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../BENCH_actor_mailbox.json");
        std::fs::write(&path, &json).expect("write BENCH_actor_mailbox.json");
        println!("\nwrote {}", path.display());
    } else {
        println!("\n{json}");
    }
}
