//! Iterator/actor microbenchmarks — the overhead numbers behind the
//! perf pass (EXPERIMENTS.md §Perf): actor call round-trip,
//! gather_async/gather_sync item overhead, union modes.
//!
//! Run: `cargo bench --bench iter_ops`
//! Smoke: `cargo bench --bench iter_ops -- --smoke` (iterations / 100).

use std::time::Instant;

use flowrl::actor::{spawn_group, ActorHandle};
use flowrl::iter::{concurrently, LocalIter, ParIter, UnionMode};

fn measure(name: &str, base_iters: usize, mut f: impl FnMut()) {
    let iters = if std::env::args().any(|a| a == "--smoke") {
        (base_iters / 100).max(10)
    } else {
        base_iters
    };
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed() / iters as u32;
    println!("| {name} | {iters} | {per:?} |");
}

struct Counter(u64);

fn actors(n: usize) -> Vec<ActorHandle<Counter>> {
    spawn_group("bench", n, |_| Box::new(|| Counter(0)))
}

fn main() {
    println!("# iterator/actor microbenchmarks");
    println!("| op | iters | per-op |");
    println!("|----|-------|--------|");

    let a = actors(1).remove(0);
    measure("actor call round-trip", 20_000, || {
        a.call(|c| {
            c.0 += 1;
            c.0
        })
        .unwrap();
    });

    let group = actors(4);
    let mut it = ParIter::from_actors(group.clone(), |c| {
        c.0 += 1;
        Some(c.0)
    })
    .gather_async(2);
    measure("gather_async(2) item, 4 shards", 40_000, || {
        it.next().unwrap();
    });

    let mut it1 = ParIter::from_actors(group.clone(), |c| {
        c.0 += 1;
        Some(c.0)
    })
    .gather_async(1);
    measure("gather_async(1) item, 4 shards", 40_000, || {
        it1.next().unwrap();
    });

    let mut sync_it = ParIter::from_actors(group.clone(), |c| {
        c.0 += 1;
        Some(c.0)
    })
    .gather_sync();
    measure("gather_sync round, 4 shards", 20_000, || {
        sync_it.next().unwrap();
    });

    let mut n = 0u64;
    let mut local = LocalIter::from_fn(move || {
        n += 1;
        Some(n)
    })
    .for_each(|x| x * 2)
    .filter(|x| x % 2 == 0);
    measure("LocalIter for_each+filter item", 1_000_000, || {
        local.next().unwrap();
    });

    let mut k1 = 0u64;
    let mut k2 = 0u64;
    let mut rr = concurrently(
        vec![
            LocalIter::from_fn(move || {
                k1 += 1;
                Some(k1)
            }),
            LocalIter::from_fn(move || {
                k2 += 1;
                Some(k2)
            }),
        ],
        UnionMode::RoundRobin { weights: None },
        None,
    );
    measure("union round_robin item", 1_000_000, || {
        rr.next().unwrap();
    });

    let mut k3 = 0u64;
    let mut k4 = 0u64;
    let mut au = concurrently(
        vec![
            LocalIter::from_fn(move || {
                k3 += 1;
                Some(k3)
            }),
            LocalIter::from_fn(move || {
                k4 += 1;
                Some(k4)
            }),
        ],
        UnionMode::Async { buffer: 64 },
        None,
    );
    measure("union async item", 200_000, || {
        au.next().unwrap();
    });
}
