//! Policies — the numerical core, executed via AOT-compiled XLA
//! artifacts (JAX/Pallas programs; see python/compile/).
//!
//! Every XLA-backed policy owns its own `XlaRuntime` (PJRT client +
//! compiled executables), created inside the owning actor's thread.
//! Parameters are a single flat f32 vector (the artifacts' ABI).

mod dqn;
mod dummy;
mod pg;

use std::collections::BTreeMap;

pub use dqn::DqnPolicy;
pub use dummy::DummyPolicy;
pub use pg::{PgCore, PgLossKind, PgPolicy};

use crate::sample_batch::SampleBatch;

/// Per-row output of action computation.
#[derive(Debug, Clone, Copy)]
pub struct ActionOutput {
    pub action: i32,
    /// log pi(a|s) under the acting policy.
    pub logp: f32,
    /// Value-function prediction (0 for value-free policies).
    pub value: f32,
}

/// A gradient update, flat like the parameters.
#[derive(Debug, Clone)]
pub struct Gradients {
    pub flat: Vec<f32>,
    pub stats: BTreeMap<String, f64>,
    /// Env steps that produced this gradient (for counters).
    pub count: usize,
}

/// The policy interface rollout workers and learners program against —
/// RLlib's `Policy` surface, reduced to what the ported algorithms use.
///
/// Not `Send`: XLA-backed policies hold a PJRT client (`Rc` inside);
/// they live and die on one actor thread.
/// The buffer-writing `*_into` forms are the canonical interface: the
/// rollout and gateway hot paths reuse caller-owned buffers, so
/// [`Policy::compute_actions_into`] is what every policy must
/// implement.  The allocating [`Policy::compute_actions`] is a default
/// convenience wrapper on top of it.
pub trait Policy {
    /// Batched action computation for `n` observation rows, written
    /// into a caller-owned buffer (cleared first).  Implementations
    /// reuse `out`'s capacity so the steady-state sampling loop never
    /// allocates.
    fn compute_actions_into(
        &mut self,
        obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    );

    /// Batched action computation for `n` observation rows.
    /// Convenience wrapper over [`Policy::compute_actions_into`] —
    /// allocates one `Vec` per call, so keep it off hot paths.
    fn compute_actions(&mut self, obs: &[f32], n: usize) -> Vec<ActionOutput> {
        let mut out = Vec::with_capacity(n);
        self.compute_actions_into(obs, n, &mut out);
        out
    }

    /// Gradients of the policy loss on `batch` (no apply).
    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients;

    /// Apply previously computed gradients (optimizer step).
    fn apply_gradients(&mut self, grads: &Gradients);

    /// Fused compute+apply on one batch; PPO runs its SGD epochs here.
    /// Returns training stats.
    fn learn_on_batch(&mut self, batch: &SampleBatch) -> BTreeMap<String, f64> {
        let grads = self.compute_gradients(batch);
        let stats = grads.stats.clone();
        self.apply_gradients(&grads);
        stats
    }

    /// Post-collection processing on the rollout worker (GAE for the
    /// policy-gradient family).  `last_value` bootstraps truncation.
    fn postprocess(&mut self, _batch: &mut SampleBatch, _last_value: f32) {}

    /// Value prediction for a single observation (bootstrap values).
    fn value(&mut self, _obs: &[f32]) -> f32 {
        0.0
    }

    /// Batched value predictions for `n` rows, written into a
    /// caller-owned buffer (cleared first) — the GAE bootstrap forward
    /// reuses one scratch `Vec` across fragments instead of allocating
    /// per call.  The default loops [`Policy::value`]; batched-forward
    /// policies override to run one `[n, obs_dim]` forward.
    fn values_into(&mut self, obs: &[f32], n: usize, out: &mut Vec<f32>) {
        out.clear();
        let obs_dim = obs.len() / n.max(1);
        for i in 0..n {
            out.push(self.value(&obs[i * obs_dim..(i + 1) * obs_dim]));
        }
    }

    /// Batched value predictions for `n` rows (one forward call for all
    /// bootstrap values — perf, EXPERIMENTS.md §Perf O2).  Convenience
    /// wrapper over [`Policy::values_into`].
    fn values(&mut self, obs: &[f32], n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        self.values_into(obs, n, &mut out);
        out
    }

    fn get_weights(&self) -> Vec<f32>;

    fn set_weights(&mut self, weights: &[f32]);

    /// Off-policy hooks (DQN family): sync the target network.
    fn update_target(&mut self) {}

    /// |TD| errors of the last gradient computation (DQN family) — used
    /// by `UpdateReplayPriorities`.
    fn td_abs(&self) -> Option<Vec<f32>> {
        None
    }

    /// Plain-SGD apply (MAML's inner-adaptation step).  Only the
    /// policy-gradient family implements this.
    fn sgd_apply(&mut self, _flat_grads: &[f32], _lr: f32) {
        unimplemented!("sgd_apply not supported by this policy")
    }

    /// IMPALA learner step on a time-major batch.  Only the IMPALA
    /// policy implements this.
    fn learn_impala(&mut self, _batch: &ImpalaBatch) -> BTreeMap<String, f64> {
        unimplemented!("learn_impala not supported by this policy")
    }
}

/// A time-major [T, B] learner batch for IMPALA's V-trace loss.
/// All rows are laid out t-major: index = t * b_lanes + lane.
#[derive(Debug, Clone, Default)]
pub struct ImpalaBatch {
    pub t_len: usize,
    pub b_lanes: usize,
    pub obs: Vec<f32>,
    pub actions: Vec<i32>,
    pub behaviour_logp: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<f32>,
    /// One trailing observation per lane ([B, obs_dim]).
    pub bootstrap_obs: Vec<f32>,
    pub mask: Vec<f32>,
}

/// Numerically stable log-softmax over one row of logits.
pub(crate) fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let log_sum: f32 =
        logits.iter().map(|l| (l - max).exp()).sum::<f32>().ln() + max;
    logits.iter().map(|l| l - log_sum).collect()
}

/// Sample an action index from logits; returns (action, logp).
pub(crate) fn sample_categorical(
    logits: &[f32],
    rng: &mut crate::util::Rng,
) -> (i32, f32) {
    let logp = log_softmax(logits);
    let u = rng.uniform();
    let mut cum = 0.0f64;
    for (i, lp) in logp.iter().enumerate() {
        cum += (*lp as f64).exp();
        if u < cum {
            return (i as i32, logp[i]);
        }
    }
    let last = logp.len() - 1;
    (last as i32, logp[last])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let sum: f32 = lp.iter().map(|l| l.exp()).sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(lp.iter().all(|&l| l < 0.0));
    }

    #[test]
    fn log_softmax_handles_large_logits() {
        let lp = log_softmax(&[1000.0, 1000.0]);
        // f32 carries ~1e-4 absolute error at this magnitude.
        assert!((lp[0] - (-std::f32::consts::LN_2)).abs() < 1e-3);
    }

    #[test]
    fn categorical_sampling_matches_distribution() {
        let mut rng = Rng::new(0);
        // logits -> probs [~0.09, ~0.24, ~0.67]
        let logits = [0.0f32, 1.0, 2.0];
        let mut counts = [0usize; 3];
        let n = 50_000;
        for _ in 0..n {
            let (a, logp) = sample_categorical(&logits, &mut rng);
            counts[a as usize] += 1;
            assert!(logp < 0.0);
        }
        let probs: Vec<f64> = {
            let lp = log_softmax(&logits);
            lp.iter().map(|l| (*l as f64).exp()).collect()
        };
        for i in 0..3 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - probs[i]).abs() < 0.01, "i={i} f={f} p={}", probs[i]);
        }
    }

    #[test]
    fn default_learn_on_batch_composes_grad_and_apply() {
        let mut p = DummyPolicy::new(0.1);
        let mut b = SampleBatch::new(1);
        b.obs = vec![0.0; 4].into();
        b.actions = vec![0; 4].into();
        b.rewards = vec![1.0; 4].into();
        b.dones = vec![0.0; 4].into();
        let w0 = p.get_weights()[0];
        let stats = p.learn_on_batch(&b);
        assert!(stats.contains_key("loss"));
        assert_ne!(p.get_weights()[0], w0);
    }
}
