//! DQN policy (double-Q, target network, prioritized-replay importance
//! weights), backed by the `dqn_*` XLA artifacts.

use std::collections::BTreeMap;

use crate::runtime::{TensorArg, XlaRuntime};
use crate::sample_batch::SampleBatch;
use crate::util::Rng;

use super::{ActionOutput, Gradients, Policy};

pub struct DqnPolicy {
    rt: XlaRuntime,
    params: Vec<f32>,
    target_params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: f32,
    lr: f32,
    /// Exploration epsilon (fixed per worker, Ape-X style; learner uses
    /// 0).
    pub epsilon: f64,
    rng: Rng,
    /// |TD| of the last learn_on_batch (keyed to the replayed rows) —
    /// picked up by `UpdateReplayPriorities`.
    pub last_td_abs: Vec<f32>,
    /// Reused padded-observation buffer for `q_values` (one inference
    /// batch wide).
    pad_scratch: Vec<f32>,
    /// All-ones loss mask for exactly-sized batches.
    ones: Vec<f32>,
    /// Reused importance-weight buffer for `compute_gradients`.
    weights_scratch: Vec<f32>,
    /// Reused flat Q-value output buffer for `q_values` — the greedy
    /// action loop allocates nothing once this is warm.
    q_scratch: Vec<f32>,
}

impl DqnPolicy {
    pub const ARTIFACTS: &'static [&'static str] =
        &["dqn_q_fwd", "dqn_grad", "adam_dqn"];

    pub fn new(rt: XlaRuntime, lr: f32, epsilon: f64, seed: u64) -> Self {
        let params = rt.load_init_params("init_dqn").expect("init_dqn.bin");
        let n = params.len();
        let pad = rt.manifest.config.inf_batch * rt.manifest.config.obs_dim;
        let mb = rt.manifest.config.dqn_minibatch;
        DqnPolicy {
            rt,
            target_params: params.clone(),
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0.0,
            lr,
            epsilon,
            rng: Rng::new(seed),
            last_td_abs: Vec::new(),
            pad_scratch: vec![0.0; pad],
            ones: vec![1.0; mb],
            weights_scratch: Vec::with_capacity(mb),
            q_scratch: Vec::new(),
        }
    }

    /// Build inside the owning actor thread.
    pub fn create(
        artifacts_dir: &std::path::Path,
        lr: f32,
        epsilon: f64,
        seed: u64,
    ) -> Self {
        let rt = XlaRuntime::load(artifacts_dir, Self::ARTIFACTS)
            .expect("load dqn artifacts");
        Self::new(rt, lr, epsilon, seed)
    }

    /// Q-values for `n` rows, written flat row-major
    /// `[n * num_actions]` into `out` (cleared first; padded/chunked to
    /// the artifact batch; the pad buffer is a reused scratch — no
    /// per-row Vecs, no per-call output allocation once `out` is warm).
    fn q_values_into(&mut self, obs: &[f32], n: usize, out: &mut Vec<f32>) {
        let (bi, od, na) = {
            let cfg = &self.rt.manifest.config;
            (cfg.inf_batch, cfg.obs_dim, cfg.num_actions)
        };
        out.clear();
        out.reserve(n * na);
        for chunk_start in (0..n).step_by(bi) {
            let rows = (n - chunk_start).min(bi);
            self.pad_scratch[..rows * od]
                .copy_from_slice(&obs[chunk_start * od..(chunk_start + rows) * od]);
            self.pad_scratch[rows * od..].fill(0.0);
            let chunk = self
                .rt
                .exe("dqn_q_fwd")
                .run(&[
                    TensorArg::F32(&self.params),
                    TensorArg::F32(&self.pad_scratch),
                ])
                .expect("dqn_q_fwd");
            out.extend_from_slice(&chunk[0][..rows * na]);
        }
    }
}

impl Policy for DqnPolicy {
    fn compute_actions_into(
        &mut self,
        obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    ) {
        let na = self.rt.manifest.config.num_actions;
        let mut q = std::mem::take(&mut self.q_scratch);
        self.q_values_into(obs, n, &mut q);
        let epsilon = self.epsilon;
        let rng = &mut self.rng;
        out.clear();
        out.reserve(n);
        for i in 0..n {
            let row = &q[i * na..(i + 1) * na];
            let action = if rng.chance(epsilon) {
                rng.below(na) as i32
            } else {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j as i32)
                    .unwrap()
            };
            out.push(ActionOutput { action, logp: 0.0, value: 0.0 });
        }
        self.q_scratch = q;
    }

    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        let count = batch.len();
        let mb = self.rt.manifest.config.dqn_minibatch;
        // Fast path: exactly-sized batches (every replay sample) skip
        // the pad copy, and the all-ones mask is a reused buffer.
        let (owned, mask_owned);
        let (b, mask): (&SampleBatch, &[f32]) = if count == mb {
            (batch, self.ones.as_slice())
        } else {
            let (padded, m) = batch.pad_or_truncate(mb);
            owned = padded;
            mask_owned = m;
            (&owned, mask_owned.as_slice())
        };
        // Importance weights travel in the batch (prioritized replay);
        // plain batches weight every row 1.  The staging buffer is
        // reused across calls.
        self.weights_scratch.clear();
        if b.weights.is_empty() {
            self.weights_scratch.resize(b.len(), 1.0);
        } else {
            self.weights_scratch.extend_from_slice(&b.weights);
        }
        self.weights_scratch.resize(mb, 0.0);
        let out = self
            .rt
            .exe("dqn_grad")
            .run(&[
                TensorArg::F32(&self.params),
                TensorArg::F32(&self.target_params),
                TensorArg::F32(&b.obs),
                TensorArg::I32(&b.actions),
                TensorArg::F32(&b.rewards),
                TensorArg::F32(&b.next_obs),
                TensorArg::F32(&b.dones),
                TensorArg::F32(&self.weights_scratch),
                TensorArg::F32(mask),
            ])
            .expect("dqn_grad");
        let mut it = out.into_iter();
        let flat = it.next().unwrap();
        let loss = it.next().unwrap()[0];
        self.last_td_abs = it.next().unwrap();
        self.last_td_abs.truncate(count.min(mb));
        let mut stats = BTreeMap::new();
        stats.insert("loss".to_string(), loss as f64);
        stats.insert(
            "mean_td_abs".to_string(),
            self.last_td_abs.iter().map(|t| *t as f64).sum::<f64>()
                / self.last_td_abs.len().max(1) as f64,
        );
        Gradients { flat, stats, count }
    }

    fn apply_gradients(&mut self, grads: &Gradients) {
        self.t += 1.0;
        let out = self
            .rt
            .exe("adam_dqn")
            .run(&[
                TensorArg::F32(&self.params),
                TensorArg::F32(&grads.flat),
                TensorArg::F32(&self.m),
                TensorArg::F32(&self.v),
                TensorArg::ScalarF32(self.t),
                TensorArg::ScalarF32(self.lr),
            ])
            .expect("adam_dqn");
        let mut it = out.into_iter();
        self.params = it.next().unwrap();
        self.m = it.next().unwrap();
        self.v = it.next().unwrap();
    }

    fn get_weights(&self) -> Vec<f32> {
        self.params.clone()
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.params.clear();
        self.params.extend_from_slice(weights);
    }

    fn update_target(&mut self) {
        self.target_params.clone_from(&self.params);
    }

    fn td_abs(&self) -> Option<Vec<f32>> {
        Some(self.last_td_abs.clone())
    }
}
