//! DQN policy (double-Q, target network, prioritized-replay importance
//! weights), backed by the `dqn_*` XLA artifacts.

use std::collections::BTreeMap;

use crate::runtime::{TensorArg, XlaRuntime};
use crate::sample_batch::SampleBatch;
use crate::util::Rng;

use super::{ActionOutput, Gradients, Policy};

pub struct DqnPolicy {
    rt: XlaRuntime,
    params: Vec<f32>,
    target_params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: f32,
    lr: f32,
    /// Exploration epsilon (fixed per worker, Ape-X style; learner uses
    /// 0).
    pub epsilon: f64,
    rng: Rng,
    /// |TD| of the last learn_on_batch (keyed to the replayed rows) —
    /// picked up by `UpdateReplayPriorities`.
    pub last_td_abs: Vec<f32>,
}

impl DqnPolicy {
    pub const ARTIFACTS: &'static [&'static str] =
        &["dqn_q_fwd", "dqn_grad", "adam_dqn"];

    pub fn new(rt: XlaRuntime, lr: f32, epsilon: f64, seed: u64) -> Self {
        let params = rt.load_init_params("init_dqn").expect("init_dqn.bin");
        let n = params.len();
        DqnPolicy {
            rt,
            target_params: params.clone(),
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0.0,
            lr,
            epsilon,
            rng: Rng::new(seed),
            last_td_abs: Vec::new(),
        }
    }

    /// Build inside the owning actor thread.
    pub fn create(
        artifacts_dir: &std::path::Path,
        lr: f32,
        epsilon: f64,
        seed: u64,
    ) -> Self {
        let rt = XlaRuntime::load(artifacts_dir, Self::ARTIFACTS)
            .expect("load dqn artifacts");
        Self::new(rt, lr, epsilon, seed)
    }

    /// Q-values for `n` rows (padded/chunked to the artifact batch).
    fn q_values(&self, obs: &[f32], n: usize) -> Vec<Vec<f32>> {
        let cfg = &self.rt.manifest.config;
        let (bi, od, na) = (cfg.inf_batch, cfg.obs_dim, cfg.num_actions);
        let mut out_rows = Vec::with_capacity(n);
        let mut padded = vec![0.0f32; bi * od];
        for chunk_start in (0..n).step_by(bi) {
            let rows = (n - chunk_start).min(bi);
            padded[..rows * od]
                .copy_from_slice(&obs[chunk_start * od..(chunk_start + rows) * od]);
            padded[rows * od..].fill(0.0);
            let out = self
                .rt
                .exe("dqn_q_fwd")
                .run(&[TensorArg::F32(&self.params), TensorArg::F32(&padded)])
                .expect("dqn_q_fwd");
            for r in 0..rows {
                out_rows.push(out[0][r * na..(r + 1) * na].to_vec());
            }
        }
        out_rows
    }
}

impl Policy for DqnPolicy {
    fn compute_actions(&mut self, obs: &[f32], n: usize) -> Vec<ActionOutput> {
        let q = self.q_values(obs, n);
        q.into_iter()
            .map(|row| {
                let action = if self.rng.chance(self.epsilon) {
                    self.rng.below(row.len()) as i32
                } else {
                    row.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i as i32)
                        .unwrap()
                };
                ActionOutput { action, logp: 0.0, value: 0.0 }
            })
            .collect()
    }

    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        let count = batch.len();
        let cfg = &self.rt.manifest.config;
        let mb = cfg.dqn_minibatch;
        let (b, mask) = batch.pad_or_truncate(mb);
        // Importance weights travel in the batch (prioritized replay);
        // plain batches weight every row 1.
        let mut weights = if b.weights.is_empty() {
            vec![1.0; b.len()]
        } else {
            b.weights.to_vec()
        };
        weights.resize(mb, 0.0);
        let out = self
            .rt
            .exe("dqn_grad")
            .run(&[
                TensorArg::F32(&self.params),
                TensorArg::F32(&self.target_params),
                TensorArg::F32(&b.obs),
                TensorArg::I32(&b.actions),
                TensorArg::F32(&b.rewards),
                TensorArg::F32(&b.next_obs),
                TensorArg::F32(&b.dones),
                TensorArg::F32(&weights),
                TensorArg::F32(&mask),
            ])
            .expect("dqn_grad");
        let mut it = out.into_iter();
        let flat = it.next().unwrap();
        let loss = it.next().unwrap()[0];
        self.last_td_abs = it.next().unwrap();
        self.last_td_abs.truncate(count.min(mb));
        let mut stats = BTreeMap::new();
        stats.insert("loss".to_string(), loss as f64);
        stats.insert(
            "mean_td_abs".to_string(),
            self.last_td_abs.iter().map(|t| *t as f64).sum::<f64>()
                / self.last_td_abs.len().max(1) as f64,
        );
        Gradients { flat, stats, count }
    }

    fn apply_gradients(&mut self, grads: &Gradients) {
        self.t += 1.0;
        let out = self
            .rt
            .exe("adam_dqn")
            .run(&[
                TensorArg::F32(&self.params),
                TensorArg::F32(&grads.flat),
                TensorArg::F32(&self.m),
                TensorArg::F32(&self.v),
                TensorArg::ScalarF32(self.t),
                TensorArg::ScalarF32(self.lr),
            ])
            .expect("adam_dqn");
        let mut it = out.into_iter();
        self.params = it.next().unwrap();
        self.m = it.next().unwrap();
        self.v = it.next().unwrap();
    }

    fn get_weights(&self) -> Vec<f32> {
        self.params.clone()
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.params.clear();
        self.params.extend_from_slice(weights);
    }

    fn update_target(&mut self) {
        self.target_params.clone_from(&self.params);
    }

    fn td_abs(&self) -> Option<Vec<f32>> {
        Some(self.last_td_abs.clone())
    }
}
