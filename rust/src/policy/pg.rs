//! The policy-gradient family (A2C / A3C / PPO / IMPALA), backed by the
//! `pg_*` XLA artifacts: a shared-trunk actor-critic MLP whose layers
//! are the Pallas `fused_linear` kernel.

use std::collections::BTreeMap;

use crate::runtime::{TensorArg, XlaRuntime};
use crate::sample_batch::{
    compute_gae, standardize_advantages, SampleBatch,
};
use crate::util::Rng;

use super::{sample_categorical, ActionOutput, Gradients, Policy};

/// Which loss artifact drives `compute_gradients` / `learn_on_batch`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PgLossKind {
    /// `a2c_grad` on the concatenated train batch (A2C).
    A2c,
    /// `a3c_grad` on per-worker fragments (A3C computes grads on
    /// workers).
    A3c,
    /// `ppo_grad` with SGD epochs over shuffled minibatches.
    Ppo { epochs: usize },
    /// `impala_grad` on [T, B] learner batches with V-trace.
    Impala,
}

/// Shared state: runtime, flat parameters, Adam moments.
pub struct PgCore {
    pub rt: XlaRuntime,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: f32,
    pub lr: f32,
    pub rng: Rng,
    /// Reused padded-observation buffer for `forward` (one inference
    /// batch wide) — no per-forward allocation on the rollout hot loop.
    pad_scratch: Vec<f32>,
}

impl PgCore {
    pub fn new(rt: XlaRuntime, lr: f32, seed: u64) -> Self {
        let params = rt.load_init_params("init_pg").expect("init_pg.bin");
        let n = params.len();
        let pad = rt.manifest.config.inf_batch * rt.manifest.config.obs_dim;
        PgCore {
            rt,
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0.0,
            lr,
            rng: Rng::new(seed),
            pad_scratch: vec![0.0; pad],
        }
    }

    /// Artifact names a PG policy needs, by loss kind.  `sgd_pg` is
    /// always loaded (MAML's inner-adaptation step reuses any PG loss).
    pub fn artifact_names(kind: PgLossKind) -> Vec<&'static str> {
        let grad = match kind {
            PgLossKind::A2c => "a2c_grad",
            PgLossKind::A3c => "a3c_grad",
            PgLossKind::Ppo { .. } => "ppo_grad",
            PgLossKind::Impala => "impala_grad",
        };
        vec!["pg_fwd", grad, "adam_pg", "sgd_pg"]
    }

    /// Forward pass into **caller-provided output scratch**: row-major
    /// logits `[n * num_actions]` and values `[n]` are written into
    /// `logits`/`values` (cleared first; their storage is reused once
    /// warm), padded/chunked to the artifact's static batch.  With the
    /// pad buffer already a reused scratch (perf O3), the policy's hot
    /// inference loop allocates nothing per forward at steady state.
    pub fn forward(
        &mut self,
        obs: &[f32],
        n: usize,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) {
        let (bi, od, na) = {
            let cfg = &self.rt.manifest.config;
            (cfg.inf_batch, cfg.obs_dim, cfg.num_actions)
        };
        assert_eq!(obs.len(), n * od);
        logits.clear();
        logits.reserve(n * na);
        values.clear();
        values.reserve(n);
        for chunk_start in (0..n).step_by(bi) {
            let rows = (n - chunk_start).min(bi);
            self.pad_scratch[..rows * od]
                .copy_from_slice(&obs[chunk_start * od..(chunk_start + rows) * od]);
            self.pad_scratch[rows * od..].fill(0.0);
            let out = self
                .rt
                .exe("pg_fwd")
                .run(&[
                    TensorArg::F32(&self.params),
                    TensorArg::F32(&self.pad_scratch),
                ])
                .expect("pg_fwd");
            logits.extend_from_slice(&out[0][..rows * na]);
            values.extend_from_slice(&out[1][..rows]);
        }
    }

    /// One Adam step (grad-clip + bias correction happen in the
    /// artifact).
    pub fn adam_step(&mut self, grads: &[f32]) {
        self.t += 1.0;
        let out = self
            .rt
            .exe("adam_pg")
            .run(&[
                TensorArg::F32(&self.params),
                TensorArg::F32(grads),
                TensorArg::F32(&self.m),
                TensorArg::F32(&self.v),
                TensorArg::ScalarF32(self.t),
                TensorArg::ScalarF32(self.lr),
            ])
            .expect("adam_pg");
        let mut it = out.into_iter();
        self.params = it.next().unwrap();
        self.m = it.next().unwrap();
        self.v = it.next().unwrap();
    }
}

/// A policy-gradient policy with a pluggable loss artifact.
pub struct PgPolicy {
    core: PgCore,
    kind: PgLossKind,
    minibatch: usize,
    /// All-ones loss mask for exactly-sized batches — reused across
    /// every minibatch instead of a `vec![1.0; n]` per gradient call.
    ones: Vec<f32>,
    /// Reused forward-pass output buffers (`PgCore::forward` writes
    /// into caller scratch): the action-sampling hot loop allocates no
    /// logits/values vectors once these are warm.
    logits_scratch: Vec<f32>,
    values_scratch: Vec<f32>,
}

impl PgPolicy {
    pub fn new(core: PgCore, kind: PgLossKind) -> Self {
        let cfg = &core.rt.manifest.config;
        let minibatch = match kind {
            PgLossKind::A2c => cfg.a2c_train_batch,
            PgLossKind::A3c => cfg.fragment,
            PgLossKind::Ppo { .. } => cfg.ppo_minibatch,
            PgLossKind::Impala => cfg.impala_t * cfg.impala_b,
        };
        PgPolicy {
            core,
            kind,
            minibatch,
            ones: vec![1.0; minibatch],
            logits_scratch: Vec::new(),
            values_scratch: Vec::new(),
        }
    }

    /// Build inside the owning actor thread.
    pub fn create(
        artifacts_dir: &std::path::Path,
        kind: PgLossKind,
        lr: f32,
        seed: u64,
    ) -> Self {
        let rt = XlaRuntime::load(artifacts_dir, &PgCore::artifact_names(kind))
            .expect("load pg artifacts");
        Self::new(PgCore::new(rt, lr, seed), kind)
    }

    pub fn loss_kind(&self) -> PgLossKind {
        self.kind
    }

    fn grad_exe(&self) -> &'static str {
        match self.kind {
            PgLossKind::A2c => "a2c_grad",
            PgLossKind::A3c => "a3c_grad",
            PgLossKind::Ppo { .. } => "ppo_grad",
            PgLossKind::Impala => "impala_grad",
        }
    }

    /// a2c/a3c/ppo gradient over (a padded view of) `batch`.
    fn grad_on(&mut self, batch: &SampleBatch) -> Gradients {
        let count = batch.len();
        // Fast path: exactly-sized batches (every PPO minibatch) go to
        // the executable without the pad copy (perf O4), and the
        // all-ones mask is the policy's reused buffer — the hot learner
        // loop allocates nothing here.
        let (owned, mask_owned);
        let (b, mask): (&SampleBatch, &[f32]) = if count == self.minibatch {
            (batch, self.ones.as_slice())
        } else {
            let (padded, m) = batch.pad_or_truncate(self.minibatch);
            owned = padded;
            mask_owned = m;
            (&owned, mask_owned.as_slice())
        };
        let exe = self.core.rt.exe(self.grad_exe());
        let out = match self.kind {
            PgLossKind::Ppo { .. } => exe
                .run(&[
                    TensorArg::F32(&self.core.params),
                    TensorArg::F32(&b.obs),
                    TensorArg::I32(&b.actions),
                    TensorArg::F32(&b.action_logp),
                    TensorArg::F32(&b.advantages),
                    TensorArg::F32(&b.value_targets),
                    TensorArg::F32(mask),
                ])
                .expect("ppo_grad"),
            PgLossKind::A2c | PgLossKind::A3c => exe
                .run(&[
                    TensorArg::F32(&self.core.params),
                    TensorArg::F32(&b.obs),
                    TensorArg::I32(&b.actions),
                    TensorArg::F32(&b.advantages),
                    TensorArg::F32(&b.value_targets),
                    TensorArg::F32(mask),
                ])
                .expect("a2c/a3c_grad"),
            PgLossKind::Impala => panic!("use learn_on_impala_batch"),
        };
        let mut stats = BTreeMap::new();
        let names = &exe.spec().outputs;
        for (i, name) in names.iter().enumerate().skip(1) {
            stats.insert(name.clone(), out[i][0] as f64);
        }
        Gradients { flat: out.into_iter().next().unwrap(), stats, count }
    }

    pub fn config(&self) -> &crate::runtime::RunConfig {
        &self.core.rt.manifest.config
    }
}

impl Policy for PgPolicy {
    fn compute_actions_into(
        &mut self,
        obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    ) {
        let na = self.core.rt.manifest.config.num_actions;
        // Forward into the policy-owned scratches (taken locally so the
        // sampling loop can borrow the rng mutably).
        let mut logits = std::mem::take(&mut self.logits_scratch);
        let mut values = std::mem::take(&mut self.values_scratch);
        self.core.forward(obs, n, &mut logits, &mut values);
        out.clear();
        out.reserve(n);
        for i in 0..n {
            let row = &logits[i * na..(i + 1) * na];
            let (action, logp) = sample_categorical(row, &mut self.core.rng);
            out.push(ActionOutput { action, logp, value: values[i] });
        }
        self.logits_scratch = logits;
        self.values_scratch = values;
    }

    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        self.grad_on(batch)
    }

    fn apply_gradients(&mut self, grads: &Gradients) {
        self.core.adam_step(&grads.flat);
    }

    fn learn_on_batch(&mut self, batch: &SampleBatch) -> BTreeMap<String, f64> {
        match self.kind {
            PgLossKind::Ppo { epochs } => {
                // PPO: SGD epochs over shuffled fixed-size minibatches.
                let mut stats = BTreeMap::new();
                let mut working = batch.clone();
                for _ in 0..epochs {
                    working.shuffle(&mut self.core.rng);
                    let minibatches = working.minibatches(self.minibatch);
                    if minibatches.is_empty() {
                        // Batch smaller than one minibatch: pad it.
                        let g = self.grad_on(&working);
                        stats = g.stats.clone();
                        self.apply_gradients(&g);
                        continue;
                    }
                    for mb in &minibatches {
                        let g = self.grad_on(mb);
                        stats = g.stats.clone();
                        self.apply_gradients(&g);
                    }
                }
                stats
            }
            _ => {
                let g = self.grad_on(batch);
                let stats = g.stats.clone();
                self.apply_gradients(&g);
                stats
            }
        }
    }

    fn postprocess(&mut self, batch: &mut SampleBatch, last_value: f32) {
        let cfg = &self.core.rt.manifest.config;
        compute_gae(batch, cfg.gamma, cfg.gae_lambda, last_value);
        if matches!(self.kind, PgLossKind::Ppo { .. }) {
            standardize_advantages(batch);
        }
    }

    fn value(&mut self, obs: &[f32]) -> f32 {
        let mut logits = std::mem::take(&mut self.logits_scratch);
        let mut values = std::mem::take(&mut self.values_scratch);
        self.core.forward(obs, 1, &mut logits, &mut values);
        let v = values[0];
        self.logits_scratch = logits;
        self.values_scratch = values;
        v
    }

    fn values_into(&mut self, obs: &[f32], n: usize, out: &mut Vec<f32>) {
        // One [n, obs_dim] forward; values land straight in the caller's
        // buffer (the GAE bootstrap reuses one scratch per fragment) and
        // only the logits buffer is recycled here.
        let mut logits = std::mem::take(&mut self.logits_scratch);
        self.core.forward(obs, n, &mut logits, out);
        self.logits_scratch = logits;
    }

    fn get_weights(&self) -> Vec<f32> {
        self.core.params.clone()
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.core.params.clear();
        self.core.params.extend_from_slice(weights);
    }

    fn sgd_apply(&mut self, flat_grads: &[f32], lr: f32) {
        let out = self
            .core
            .rt
            .exe("sgd_pg")
            .run(&[
                TensorArg::F32(&self.core.params),
                TensorArg::F32(flat_grads),
                TensorArg::ScalarF32(lr),
            ])
            .expect("sgd_pg");
        self.core.params = out.into_iter().next().unwrap();
    }

    fn learn_impala(
        &mut self,
        batch: &super::ImpalaBatch,
    ) -> BTreeMap<String, f64> {
        assert_eq!(self.kind, PgLossKind::Impala);
        let cfg = &self.core.rt.manifest.config;
        assert_eq!((batch.t_len, batch.b_lanes), (cfg.impala_t, cfg.impala_b));
        let exe = self.core.rt.exe("impala_grad");
        let out = exe
            .run(&[
                TensorArg::F32(&self.core.params),
                TensorArg::F32(&batch.obs),
                TensorArg::I32(&batch.actions),
                TensorArg::F32(&batch.behaviour_logp),
                TensorArg::F32(&batch.rewards),
                TensorArg::F32(&batch.dones),
                TensorArg::F32(&batch.bootstrap_obs),
                TensorArg::F32(&batch.mask),
            ])
            .expect("impala_grad");
        let mut stats = BTreeMap::new();
        for (i, name) in exe.spec().outputs.iter().enumerate().skip(1) {
            stats.insert(name.clone(), out[i][0] as f64);
        }
        self.core.adam_step(&out[0]);
        stats
    }
}
