//! The dummy policy of the paper's sampling microbenchmark (Fig. 13a):
//! a single trainable scalar, random actions — so end-to-end throughput
//! measures pure system overhead, not numerics.

use std::collections::BTreeMap;

use crate::sample_batch::SampleBatch;
use crate::util::Rng;

use super::{ActionOutput, Gradients, Policy};

pub struct DummyPolicy {
    weight: f32,
    lr: f32,
    rng: Rng,
}

impl DummyPolicy {
    pub fn new(lr: f32) -> Self {
        DummyPolicy { weight: 0.0, lr, rng: Rng::new(0) }
    }
}

impl Policy for DummyPolicy {
    fn compute_actions_into(
        &mut self,
        _obs: &[f32],
        n: usize,
        out: &mut Vec<ActionOutput>,
    ) {
        out.clear();
        for _ in 0..n {
            out.push(ActionOutput {
                action: self.rng.below(2) as i32,
                logp: -std::f32::consts::LN_2,
                value: 0.0,
            });
        }
    }

    fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        // "Loss" = w * mean(reward): gradient is mean reward.
        let n = batch.len().max(1);
        let grad = batch.rewards.iter().sum::<f32>() / n as f32;
        let mut stats = BTreeMap::new();
        stats.insert("loss".to_string(), (self.weight * grad) as f64);
        Gradients { flat: vec![grad], stats, count: batch.len() }
    }

    fn apply_gradients(&mut self, grads: &Gradients) {
        self.weight -= self.lr * grads.flat[0];
    }

    fn get_weights(&self) -> Vec<f32> {
        vec![self.weight]
    }

    fn set_weights(&mut self, weights: &[f32]) {
        self.weight = weights[0];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actions_are_valid() {
        let mut p = DummyPolicy::new(0.1);
        let acts = p.compute_actions(&[0.0; 8], 4);
        assert_eq!(acts.len(), 4);
        assert!(acts.iter().all(|a| a.action == 0 || a.action == 1));
    }

    #[test]
    fn gradient_is_mean_reward() {
        let mut p = DummyPolicy::new(1.0);
        let mut b = SampleBatch::new(1);
        b.obs = vec![0.0; 4].into();
        b.rewards = vec![1.0, 2.0, 3.0, 6.0].into();
        let g = p.compute_gradients(&b);
        assert_eq!(g.flat, vec![3.0]);
        p.apply_gradients(&g);
        assert_eq!(p.get_weights(), vec![-3.0]);
    }

    #[test]
    fn set_weights_roundtrip() {
        let mut p = DummyPolicy::new(0.1);
        p.set_weights(&[42.0]);
        assert_eq!(p.get_weights(), vec![42.0]);
    }
}
