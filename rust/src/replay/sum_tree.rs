//! Segment tree over priorities: O(log n) update and prefix-sum sampling.

/// A fixed-capacity binary sum tree.  Leaves hold priorities; internal
/// nodes hold subtree sums, so sampling an index proportional to
/// priority is a single root-to-leaf descent.
#[derive(Debug, Clone)]
pub struct SumTree {
    capacity: usize,
    /// 1-indexed heap layout: nodes[1] is the root, leaves start at
    /// `capacity`.
    nodes: Vec<f64>,
}

impl SumTree {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0 && capacity.is_power_of_two(),
                "capacity must be a power of two, got {capacity}");
        SumTree { capacity, nodes: vec![0.0; 2 * capacity] }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn total(&self) -> f64 {
        self.nodes[1]
    }

    pub fn get(&self, idx: usize) -> f64 {
        self.nodes[self.capacity + idx]
    }

    pub fn set(&mut self, idx: usize, priority: f64) {
        assert!(idx < self.capacity);
        assert!(priority >= 0.0 && priority.is_finite());
        let mut i = self.capacity + idx;
        self.nodes[i] = priority;
        while i > 1 {
            i /= 2;
            self.nodes[i] = self.nodes[2 * i] + self.nodes[2 * i + 1];
        }
    }

    /// Index of the leaf where the prefix sum reaches `mass`
    /// (`mass` in [0, total)).
    pub fn find_prefix(&self, mass: f64) -> usize {
        debug_assert!(mass >= 0.0);
        let mut i = 1;
        let mut mass = mass.min(self.total() * (1.0 - 1e-12));
        while i < self.capacity {
            let left = self.nodes[2 * i];
            if mass < left {
                i = 2 * i;
            } else {
                mass -= left;
                i = 2 * i + 1;
            }
        }
        i - self.capacity
    }

    /// Maximum leaf priority (new items get max priority on insert).
    pub fn max_priority(&self) -> f64 {
        self.nodes[self.capacity..]
            .iter()
            .cloned()
            .fold(0.0, f64::max)
    }

    /// Minimum non-zero leaf priority over the first `n` leaves (for the
    /// importance-weight normalization term).
    pub fn min_priority(&self, n: usize) -> f64 {
        self.nodes[self.capacity..self.capacity + n]
            .iter()
            .cloned()
            .filter(|p| *p > 0.0)
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_tracks_updates() {
        let mut t = SumTree::new(8);
        t.set(0, 1.0);
        t.set(3, 2.0);
        assert_eq!(t.total(), 3.0);
        t.set(0, 0.5);
        assert_eq!(t.total(), 2.5);
    }

    #[test]
    fn find_prefix_picks_correct_leaf() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 2.0);
        t.set(2, 3.0);
        // Cumulative: [0,1), [1,3), [3,6)
        assert_eq!(t.find_prefix(0.5), 0);
        assert_eq!(t.find_prefix(1.0), 1);
        assert_eq!(t.find_prefix(2.9), 1);
        assert_eq!(t.find_prefix(3.0), 2);
        assert_eq!(t.find_prefix(5.999), 2);
    }

    #[test]
    fn find_prefix_at_total_stays_in_range() {
        let mut t = SumTree::new(4);
        t.set(1, 2.0);
        let idx = t.find_prefix(t.total());
        assert_eq!(idx, 1);
    }

    #[test]
    fn max_and_min_priority() {
        let mut t = SumTree::new(8);
        assert_eq!(t.max_priority(), 0.0);
        t.set(2, 4.0);
        t.set(5, 0.25);
        assert_eq!(t.max_priority(), 4.0);
        assert_eq!(t.min_priority(8), 0.25);
        assert_eq!(t.min_priority(3), 4.0); // leaf 5 out of range
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_capacity_rejected() {
        SumTree::new(6);
    }

    #[test]
    fn sampling_distribution_matches_priorities() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 3.0);
        let mut rng = crate::util::Rng::new(0);
        let mut counts = [0usize; 2];
        let n = 40_000;
        for _ in 0..n {
            let mass = rng.uniform() * t.total();
            counts[t.find_prefix(mass)] += 1;
        }
        let f0 = counts[0] as f64 / n as f64;
        assert!((f0 - 0.25).abs() < 0.02, "f0={f0}");
    }
}
