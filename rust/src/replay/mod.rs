//! Prioritized experience replay (Schaul et al. 2016) — the replay-actor
//! substrate for DQN and Ape-X (paper Fig. 10, `create_colocated
//! (ReplayActor)`).

mod buffer;
mod sum_tree;

pub use buffer::{PrioritizedReplayBuffer, ReplayActorState, ReplaySample};
pub use sum_tree::SumTree;
