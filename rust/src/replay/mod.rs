//! Prioritized experience replay (Schaul et al. 2016) — the replay-actor
//! substrate for DQN and Ape-X (paper Fig. 10, `create_colocated
//! (ReplayActor)`).

mod buffer;
mod sum_tree;

pub use buffer::{
    PrioritizedReplayBuffer, ReplayActorState, ReplaySample, ReplayShardGauge,
};
pub use sum_tree::SumTree;

/// Aggregated backlog telemetry over a replay-shard pool, computed each
/// report by `ops::ReplayService::backlog_stats` and attached to
/// `TrainResult::replay`.  This is the autoscaler's control input for
/// the replay pool: mailbox depth (add/sample traffic the shards cannot
/// drain), ring fill (capacity pressure), and the not-ready poll rate
/// (shards idling below `learning_starts` — the inflow is spread too
/// thin).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReplayBacklogStats {
    /// Live shards at snapshot time.
    pub live_shards: usize,
    /// Registry slots consumed (tombstones included).
    pub slots: usize,
    /// Deepest current mailbox across live shards.
    pub max_queue_len: usize,
    /// Highest lifetime mailbox high-water mark across live shards.
    pub max_queue_hwm: usize,
    /// Highest ring fill fraction (len / capacity, 0..=1) across live
    /// shards.
    pub max_ring_fill: f64,
    /// Transitions stored across shard incarnations (gauge sum — a
    /// restarted shard restarts its contribution from zero).
    pub added: u64,
    /// Transitions replayed across shard incarnations (gauge sum).
    pub sampled: u64,
    /// Lifetime batches routed by `store_to_replay_buffer` (service
    /// counter — survives shard restarts).
    pub stores: u64,
    /// Lifetime samples yielded by the `replay` stream.
    pub samples: u64,
    /// Lifetime not-ready polls (buffer below learning-starts).
    pub not_ready: u64,
    /// Priority updates applied to the producing shard incarnation.
    pub priority_applied: u64,
    /// Priority updates discarded because the producing incarnation was
    /// restarted (epoch moved) or its slot retired.
    pub priority_discarded: u64,
}
