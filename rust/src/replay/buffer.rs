//! The prioritized replay buffer and the replay-actor state wrapper.

use crate::sample_batch::SampleBatch;
use crate::util::Rng;

use super::SumTree;

/// A replayed minibatch plus the bookkeeping needed to update priorities
/// after the learner computes TD errors.
#[derive(Debug, Clone)]
pub struct ReplaySample {
    /// The replayed rows; importance-sampling weights (normalized to
    /// max 1) ride in `batch.weights`.
    pub batch: SampleBatch,
    /// Buffer slot of each sampled row (send back with new priorities).
    pub indices: Vec<usize>,
}

/// Proportional prioritized replay over single transitions.
///
/// alpha exponentiates TD-error priorities; beta anneals the
/// importance-correction (we keep it fixed per-buffer, as RLlib does for
/// Ape-X's default config).
pub struct PrioritizedReplayBuffer {
    capacity: usize,
    alpha: f64,
    beta: f64,
    tree: SumTree,
    storage: Vec<Option<Transition>>,
    next_slot: usize,
    size: usize,
    rng: Rng,
    eps: f64,
}

#[derive(Debug, Clone)]
struct Transition {
    obs: Vec<f32>,
    action: i32,
    reward: f32,
    next_obs: Vec<f32>,
    done: f32,
}

impl PrioritizedReplayBuffer {
    pub fn new(capacity: usize, alpha: f64, beta: f64, seed: u64) -> Self {
        let capacity = capacity.next_power_of_two();
        PrioritizedReplayBuffer {
            capacity,
            alpha,
            beta,
            tree: SumTree::new(capacity),
            storage: vec![None; capacity],
            next_slot: 0,
            size: 0,
            rng: Rng::new(seed),
            eps: 1e-6,
        }
    }

    pub fn len(&self) -> usize {
        self.size
    }

    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Add every transition of `batch` (requires next_obs column), with
    /// max priority so new experience is replayed at least once soon.
    pub fn add_batch(&mut self, batch: &SampleBatch) {
        assert!(!batch.next_obs.is_empty(), "replay needs next_obs");
        let max_p = self.tree.max_priority().max(1.0);
        for i in 0..batch.len() {
            let t = Transition {
                obs: batch.obs_row(i).to_vec(),
                action: batch.actions[i],
                reward: batch.rewards[i],
                next_obs: batch.next_obs_row(i).to_vec(),
                done: batch.dones[i],
            };
            self.storage[self.next_slot] = Some(t);
            self.tree.set(self.next_slot, max_p);
            self.next_slot = (self.next_slot + 1) % self.capacity;
            self.size = (self.size + 1).min(self.capacity);
        }
    }

    /// Sample `n` transitions proportional to priority.
    pub fn sample(&mut self, n: usize) -> Option<ReplaySample> {
        if self.size == 0 || self.tree.total() <= 0.0 {
            return None;
        }
        let obs_dim = self.storage.iter().flatten().next()?.obs.len();
        let mut batch = SampleBatch::new(obs_dim);
        let mut indices = Vec::with_capacity(n);

        let total = self.tree.total();
        let min_prob = self.tree.min_priority(self.capacity) / total;
        let max_weight = (min_prob * self.size as f64).powf(-self.beta);

        for _ in 0..n {
            let mass = self.rng.uniform() * total;
            let idx = self.tree.find_prefix(mass);
            let t = self.storage[idx].as_ref().expect("sampled empty slot");
            batch.obs.extend_from_slice(&t.obs);
            batch.actions.push(t.action);
            batch.rewards.push(t.reward);
            batch.next_obs.extend_from_slice(&t.next_obs);
            batch.dones.push(t.done);
            let prob = self.tree.get(idx) / total;
            let w = (prob * self.size as f64).powf(-self.beta) / max_weight;
            batch.weights.push(w as f32);
            indices.push(idx);
        }
        Some(ReplaySample { batch, indices })
    }

    /// Update priorities after the learner reports |TD| errors.
    pub fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) {
        for (&idx, &td) in indices.iter().zip(td_abs) {
            if self.storage[idx].is_some() {
                let p = (td.abs() as f64 + self.eps).powf(self.alpha);
                self.tree.set(idx, p);
            }
        }
    }
}

/// Replay actor state: a buffer plus counters, matching the paper's
/// `ReplayActor` interface (`add_batch`, `replay`, `update_priorities`).
pub struct ReplayActorState {
    pub buffer: PrioritizedReplayBuffer,
    /// Replay starts only after this many transitions are stored
    /// (learning-starts threshold).
    pub learning_starts: usize,
    pub replay_batch_size: usize,
    pub num_added: usize,
    pub num_sampled: usize,
}

impl ReplayActorState {
    pub fn new(
        capacity: usize,
        learning_starts: usize,
        replay_batch_size: usize,
        seed: u64,
    ) -> Self {
        ReplayActorState {
            buffer: PrioritizedReplayBuffer::new(capacity, 0.6, 0.4, seed),
            learning_starts,
            replay_batch_size,
            num_added: 0,
            num_sampled: 0,
        }
    }

    pub fn add_batch(&mut self, batch: &SampleBatch) {
        self.num_added += batch.len();
        self.buffer.add_batch(batch);
    }

    /// One replayed minibatch, or None before learning_starts.
    pub fn replay(&mut self) -> Option<ReplaySample> {
        if self.num_added < self.learning_starts {
            return None;
        }
        let s = self.buffer.sample(self.replay_batch_size)?;
        self.num_sampled += s.batch.len();
        Some(s)
    }

    pub fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) {
        self.buffer.update_priorities(indices, td_abs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn transitions(n: usize, reward_base: f32) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition(
                &[i as f32, 0.0],
                (i % 2) as i32,
                reward_base + i as f32,
                &[i as f32 + 1.0, 0.0],
                i == n - 1,
            );
        }
        b.build()
    }

    #[test]
    fn sample_before_any_add_is_none() {
        let mut buf = PrioritizedReplayBuffer::new(16, 0.6, 0.4, 0);
        assert!(buf.sample(4).is_none());
    }

    #[test]
    fn sample_returns_requested_count() {
        let mut buf = PrioritizedReplayBuffer::new(16, 0.6, 0.4, 0);
        buf.add_batch(&transitions(5, 0.0));
        let s = buf.sample(8).unwrap();
        assert_eq!(s.batch.len(), 8);
        assert_eq!(s.indices.len(), 8);
        assert_eq!(s.batch.weights.len(), 8);
        assert!(s.indices.iter().all(|&i| i < 5));
        assert!(s.batch.weights.iter().all(|&w| w > 0.0 && w <= 1.0 + 1e-5));
    }

    #[test]
    fn capacity_wraps_oldest_first() {
        let mut buf = PrioritizedReplayBuffer::new(4, 0.6, 0.4, 0);
        buf.add_batch(&transitions(6, 0.0)); // slots 0..3 then wrap 0,1
        assert_eq!(buf.len(), 4);
        // Rewards present must be from the last 4 transitions {2,3,4,5}.
        let s = buf.sample(32).unwrap();
        for r in s.batch.rewards {
            assert!(r >= 2.0 && r <= 5.0, "stale transition {r}");
        }
    }

    #[test]
    fn high_priority_sampled_more() {
        let mut buf = PrioritizedReplayBuffer::new(8, 1.0, 0.4, 1);
        buf.add_batch(&transitions(4, 0.0));
        // Make slot 0 dominate.
        buf.update_priorities(&[0, 1, 2, 3], &[100.0, 0.01, 0.01, 0.01]);
        let s = buf.sample(1000).unwrap();
        let zero_frac = s.indices.iter().filter(|&&i| i == 0).count() as f64
            / 1000.0;
        assert!(zero_frac > 0.9, "zero_frac={zero_frac}");
    }

    #[test]
    fn weights_correct_for_bias() {
        let mut buf = PrioritizedReplayBuffer::new(8, 1.0, 1.0, 2);
        buf.add_batch(&transitions(2, 0.0));
        buf.update_priorities(&[0, 1], &[4.0, 1.0]);
        let s = buf.sample(500).unwrap();
        // With beta=1, w_i ∝ 1/p_i; idx 0 has 4x priority → 1/4 weight.
        for (idx, w) in s.indices.iter().zip(&s.batch.weights) {
            if *idx == 0 {
                assert!((w - 0.25).abs() < 0.01, "w0={w}");
            } else {
                assert!((w - 1.0).abs() < 0.01, "w1={w}");
            }
        }
    }

    #[test]
    fn replay_actor_gates_on_learning_starts() {
        let mut ra = ReplayActorState::new(64, 10, 4, 0);
        ra.add_batch(&transitions(5, 0.0));
        assert!(ra.replay().is_none());
        ra.add_batch(&transitions(5, 0.0));
        let s = ra.replay().unwrap();
        assert_eq!(s.batch.len(), 4);
        assert_eq!(ra.num_sampled, 4);
    }
}
