//! The prioritized replay buffer and the replay-actor state wrapper.

use crate::sample_batch::{FCol, ICol, SampleBatch};
use crate::util::Rng;

use super::SumTree;

/// A replayed minibatch plus the bookkeeping needed to update priorities
/// after the learner computes TD errors.
#[derive(Debug, Clone)]
pub struct ReplaySample {
    /// The replayed rows; importance-sampling weights (normalized to
    /// max 1) ride in `batch.weights`.
    pub batch: SampleBatch,
    /// Buffer slot of each sampled row (send back with new priorities).
    pub indices: Vec<usize>,
}

/// Proportional prioritized replay over single transitions.
///
/// alpha exponentiates TD-error priorities; beta anneals the
/// importance-correction (we keep it fixed per-buffer, as RLlib does for
/// Ape-X's default config).
///
/// Storage is struct-of-arrays ring columns preallocated to
/// `capacity * obs_dim` (`obs`, `next_obs`) and `capacity` (scalars) —
/// the former `Vec<Option<Transition>>` cost two heap vectors per stored
/// transition and an O(capacity) scan per `sample()` call just to
/// rediscover `obs_dim`.  Samples gather into a scratch batch whose
/// storage is reclaimed from the previous sample once the learner drops
/// it, so steady-state replay allocates nothing.
pub struct PrioritizedReplayBuffer {
    capacity: usize,
    /// Row width of `obs`/`next_obs`.  0 = not yet known (columns are
    /// allocated lazily on the first `add_batch`); fixed thereafter.
    obs_dim: usize,
    alpha: f64,
    beta: f64,
    tree: SumTree,
    obs: Vec<f32>,
    next_obs: Vec<f32>,
    actions: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
    next_slot: usize,
    size: usize,
    rng: Rng,
    eps: f64,
    /// Column handles of the last emitted sample; its storage is reused
    /// for the next sample once the learner has dropped its copy.
    scratch: Option<SampleBatch>,
}

impl PrioritizedReplayBuffer {
    /// A buffer that learns `obs_dim` from the first `add_batch`.
    pub fn new(capacity: usize, alpha: f64, beta: f64, seed: u64) -> Self {
        let capacity = capacity.next_power_of_two();
        PrioritizedReplayBuffer {
            capacity,
            obs_dim: 0,
            alpha,
            beta,
            tree: SumTree::new(capacity),
            obs: Vec::new(),
            next_obs: Vec::new(),
            actions: Vec::new(),
            rewards: Vec::new(),
            dones: Vec::new(),
            next_slot: 0,
            size: 0,
            rng: Rng::new(seed),
            eps: 1e-6,
            scratch: None,
        }
    }

    /// A buffer with ring columns preallocated for `obs_dim`-wide rows
    /// (the constructor the dataflow operators use; avoids the lazy
    /// first-add allocation).
    pub fn with_obs_dim(
        capacity: usize,
        obs_dim: usize,
        alpha: f64,
        beta: f64,
        seed: u64,
    ) -> Self {
        assert!(obs_dim > 0, "obs_dim must be positive");
        let mut buf = Self::new(capacity, alpha, beta, seed);
        buf.allocate(obs_dim);
        buf
    }

    fn allocate(&mut self, obs_dim: usize) {
        self.obs_dim = obs_dim;
        self.obs = vec![0.0; self.capacity * obs_dim];
        self.next_obs = vec![0.0; self.capacity * obs_dim];
        self.actions = vec![0; self.capacity];
        self.rewards = vec![0.0; self.capacity];
        self.dones = vec![0.0; self.capacity];
    }

    pub fn len(&self) -> usize {
        self.size
    }

    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// The observation row width, 0 before anything is stored.
    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    /// Ring capacity in transitions (rounded up to a power of two by the
    /// constructors) — the denominator of the backlog gauge's ring-fill
    /// fraction.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Add every transition of `batch` (requires next_obs column), with
    /// max priority so new experience is replayed at least once soon.
    pub fn add_batch(&mut self, batch: &SampleBatch) {
        if batch.is_empty() {
            return;
        }
        assert!(!batch.next_obs.is_empty(), "replay needs next_obs");
        if self.obs_dim == 0 {
            self.allocate(batch.obs_dim);
        }
        assert_eq!(batch.obs_dim, self.obs_dim, "obs_dim mismatch");
        let d = self.obs_dim;
        let max_p = self.tree.max_priority().max(1.0);
        for i in 0..batch.len() {
            let s = self.next_slot;
            self.obs[s * d..(s + 1) * d].copy_from_slice(batch.obs_row(i));
            self.next_obs[s * d..(s + 1) * d]
                .copy_from_slice(batch.next_obs_row(i));
            self.actions[s] = batch.actions[i];
            self.rewards[s] = batch.rewards[i];
            self.dones[s] = batch.dones[i];
            self.tree.set(s, max_p);
            self.next_slot = (s + 1) % self.capacity;
            self.size = (self.size + 1).min(self.capacity);
        }
    }

    /// Reclaim the previous sample's column storage (empty vectors with
    /// capacity intact in the steady state, fresh ones otherwise).
    #[allow(clippy::type_complexity)]
    fn take_scratch(
        &mut self,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        match self.scratch.take() {
            Some(mut prev) => (
                prev.obs.take_vec(),
                prev.actions.take_vec(),
                prev.rewards.take_vec(),
                prev.next_obs.take_vec(),
                prev.dones.take_vec(),
                prev.weights.take_vec(),
            ),
            None => Default::default(),
        }
    }

    /// Sample `n` transitions proportional to priority.
    pub fn sample(&mut self, n: usize) -> Option<ReplaySample> {
        if self.size == 0 || self.tree.total() <= 0.0 {
            return None;
        }
        let d = self.obs_dim;
        let (mut obs, mut actions, mut rewards, mut next_obs, mut dones, mut weights) =
            self.take_scratch();
        obs.reserve(n * d);
        next_obs.reserve(n * d);
        actions.reserve(n);
        rewards.reserve(n);
        dones.reserve(n);
        weights.reserve(n);
        let mut indices = Vec::with_capacity(n);

        let total = self.tree.total();
        let min_prob = self.tree.min_priority(self.capacity) / total;
        let max_weight = (min_prob * self.size as f64).powf(-self.beta);

        for _ in 0..n {
            let mass = self.rng.uniform() * total;
            let idx = self.tree.find_prefix(mass);
            obs.extend_from_slice(&self.obs[idx * d..(idx + 1) * d]);
            actions.push(self.actions[idx]);
            rewards.push(self.rewards[idx]);
            next_obs.extend_from_slice(&self.next_obs[idx * d..(idx + 1) * d]);
            dones.push(self.dones[idx]);
            let prob = self.tree.get(idx) / total;
            let w = (prob * self.size as f64).powf(-self.beta) / max_weight;
            weights.push(w as f32);
            indices.push(idx);
        }
        let mut batch = SampleBatch::new(d);
        batch.obs = FCol::from_vec(obs);
        batch.actions = ICol::from_vec(actions);
        batch.rewards = FCol::from_vec(rewards);
        batch.next_obs = FCol::from_vec(next_obs);
        batch.dones = FCol::from_vec(dones);
        batch.weights = FCol::from_vec(weights);
        self.scratch = Some(batch.clone());
        Some(ReplaySample { batch, indices })
    }

    /// Update priorities after the learner reports |TD| errors.  Slots
    /// that were never filled (index beyond the current size) are
    /// ignored, matching the old `Option`-storage guard.
    pub fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) {
        for (&idx, &td) in indices.iter().zip(td_abs) {
            if idx < self.size {
                let p = (td.abs() as f64 + self.eps).powf(self.alpha);
                self.tree.set(idx, p);
            }
        }
    }
}

/// Lock-free backlog gauge of one replay-shard *slot*, shared between
/// the shard incarnation (which publishes after every `add_batch` /
/// `replay`) and the service's backlog aggregation.  Reading through
/// the gauge instead of a blocking `call` matters precisely when it
/// matters most: a backlogged shard would queue the telemetry request
/// behind the very backlog being measured.
///
/// A restarted incarnation re-publishes from its own (empty) state, so
/// the gauge always describes the slot's **current** incarnation.
#[derive(Debug, Default)]
pub struct ReplayShardGauge {
    pub num_added: std::sync::atomic::AtomicU64,
    pub num_sampled: std::sync::atomic::AtomicU64,
    /// Transitions currently resident in the ring.
    pub len: std::sync::atomic::AtomicU64,
    pub capacity: std::sync::atomic::AtomicU64,
}

impl ReplayShardGauge {
    /// Ring occupancy fraction (0..=1; 0 before the first publish).
    pub fn ring_fill(&self) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        let cap = self.capacity.load(Relaxed);
        if cap == 0 {
            0.0
        } else {
            self.len.load(Relaxed) as f64 / cap as f64
        }
    }
}

/// Replay actor state: a buffer plus counters, matching the paper's
/// `ReplayActor` interface (`add_batch`, `replay`, `update_priorities`).
pub struct ReplayActorState {
    pub buffer: PrioritizedReplayBuffer,
    /// Replay starts only after this many transitions are stored
    /// (learning-starts threshold).
    pub learning_starts: usize,
    pub replay_batch_size: usize,
    pub num_added: usize,
    pub num_sampled: usize,
    /// Slot gauge published after every mutation (None for standalone
    /// actors outside a `ReplayService`).
    gauge: Option<std::sync::Arc<ReplayShardGauge>>,
}

impl ReplayActorState {
    pub fn new(
        capacity: usize,
        obs_dim: usize,
        learning_starts: usize,
        replay_batch_size: usize,
        seed: u64,
    ) -> Self {
        ReplayActorState {
            buffer: PrioritizedReplayBuffer::with_obs_dim(
                capacity, obs_dim, 0.6, 0.4, seed,
            ),
            learning_starts,
            replay_batch_size,
            num_added: 0,
            num_sampled: 0,
            gauge: None,
        }
    }

    /// Attach a slot gauge (builder style, used by the replay-shard
    /// factory).  Publishes immediately so the gauge reflects this
    /// incarnation — on a restart that resets the slot's reading to an
    /// empty ring rather than leaving the dead incarnation's numbers up.
    pub fn with_gauge(
        mut self,
        gauge: std::sync::Arc<ReplayShardGauge>,
    ) -> Self {
        self.gauge = Some(gauge);
        self.publish_gauge();
        self
    }

    fn publish_gauge(&self) {
        use std::sync::atomic::Ordering::Relaxed;
        if let Some(g) = &self.gauge {
            g.num_added.store(self.num_added as u64, Relaxed);
            g.num_sampled.store(self.num_sampled as u64, Relaxed);
            g.len.store(self.buffer.len() as u64, Relaxed);
            g.capacity.store(self.buffer.capacity() as u64, Relaxed);
        }
    }

    pub fn add_batch(&mut self, batch: &SampleBatch) {
        self.num_added += batch.len();
        self.buffer.add_batch(batch);
        self.publish_gauge();
    }

    /// One replayed minibatch, or None before learning_starts.
    pub fn replay(&mut self) -> Option<ReplaySample> {
        if self.num_added < self.learning_starts {
            return None;
        }
        let s = self.buffer.sample(self.replay_batch_size)?;
        self.num_sampled += s.batch.len();
        self.publish_gauge();
        Some(s)
    }

    pub fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) {
        self.buffer.update_priorities(indices, td_abs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn transitions(n: usize, reward_base: f32) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition(
                &[i as f32, 0.0],
                (i % 2) as i32,
                reward_base + i as f32,
                &[i as f32 + 1.0, 0.0],
                i == n - 1,
            );
        }
        b.build()
    }

    #[test]
    fn sample_before_any_add_is_none() {
        let mut buf = PrioritizedReplayBuffer::new(16, 0.6, 0.4, 0);
        assert!(buf.sample(4).is_none());
    }

    #[test]
    fn sample_returns_requested_count() {
        let mut buf = PrioritizedReplayBuffer::new(16, 0.6, 0.4, 0);
        buf.add_batch(&transitions(5, 0.0));
        assert_eq!(buf.obs_dim(), 2);
        let s = buf.sample(8).unwrap();
        assert_eq!(s.batch.len(), 8);
        assert_eq!(s.indices.len(), 8);
        assert_eq!(s.batch.weights.len(), 8);
        assert!(s.indices.iter().all(|&i| i < 5));
        assert!(s.batch.weights.iter().all(|&w| w > 0.0 && w <= 1.0 + 1e-5));
    }

    #[test]
    fn sampled_rows_are_consistent_transitions() {
        let mut buf = PrioritizedReplayBuffer::new(16, 0.6, 0.4, 1);
        buf.add_batch(&transitions(6, 0.0));
        let s = buf.sample(32).unwrap();
        for i in 0..s.batch.len() {
            // Row invariant from `transitions`: next_obs = obs + 1.
            assert_eq!(s.batch.obs_row(i)[0] + 1.0, s.batch.next_obs_row(i)[0]);
            assert_eq!(s.batch.rewards[i], s.batch.obs_row(i)[0]);
        }
    }

    #[test]
    fn capacity_wraps_oldest_first() {
        let mut buf = PrioritizedReplayBuffer::new(4, 0.6, 0.4, 0);
        buf.add_batch(&transitions(6, 0.0)); // slots 0..3 then wrap 0,1
        assert_eq!(buf.len(), 4);
        // Rewards present must be from the last 4 transitions {2,3,4,5}.
        let s = buf.sample(32).unwrap();
        for &r in &s.batch.rewards {
            assert!((2.0..=5.0).contains(&r), "stale transition {r}");
        }
    }

    #[test]
    fn high_priority_sampled_more() {
        let mut buf = PrioritizedReplayBuffer::new(8, 1.0, 0.4, 1);
        buf.add_batch(&transitions(4, 0.0));
        // Make slot 0 dominate.
        buf.update_priorities(&[0, 1, 2, 3], &[100.0, 0.01, 0.01, 0.01]);
        let s = buf.sample(1000).unwrap();
        let zero_frac = s.indices.iter().filter(|&&i| i == 0).count() as f64
            / 1000.0;
        assert!(zero_frac > 0.9, "zero_frac={zero_frac}");
    }

    #[test]
    fn weights_correct_for_bias() {
        let mut buf = PrioritizedReplayBuffer::new(8, 1.0, 1.0, 2);
        buf.add_batch(&transitions(2, 0.0));
        buf.update_priorities(&[0, 1], &[4.0, 1.0]);
        let s = buf.sample(500).unwrap();
        // With beta=1, w_i ∝ 1/p_i; idx 0 has 4x priority → 1/4 weight.
        for (idx, w) in s.indices.iter().zip(&s.batch.weights) {
            if *idx == 0 {
                assert!((w - 0.25).abs() < 0.01, "w0={w}");
            } else {
                assert!((w - 1.0).abs() < 0.01, "w1={w}");
            }
        }
    }

    #[test]
    fn scratch_batch_is_reused_when_learner_drops_sample() {
        let mut buf = PrioritizedReplayBuffer::with_obs_dim(16, 2, 0.6, 0.4, 3);
        buf.add_batch(&transitions(8, 0.0));
        let first = buf.sample(4).unwrap();
        let ptr = first.batch.obs.as_slice().as_ptr();
        drop(first); // learner done with it
        let second = buf.sample(4).unwrap();
        assert_eq!(
            second.batch.obs.as_slice().as_ptr(),
            ptr,
            "steady-state sample should reuse the scratch allocation"
        );
    }

    #[test]
    fn scratch_falls_back_when_sample_still_held() {
        let mut buf = PrioritizedReplayBuffer::with_obs_dim(16, 2, 0.6, 0.4, 4);
        buf.add_batch(&transitions(8, 0.0));
        let held = buf.sample(4).unwrap();
        let snapshot = held.batch.rewards.to_vec();
        let _second = buf.sample(4).unwrap();
        // The held sample's rows were not overwritten by the next one.
        assert_eq!(held.batch.rewards.to_vec(), snapshot);
    }

    #[test]
    fn gauge_tracks_ring_fill_and_counters() {
        use std::sync::atomic::Ordering::Relaxed;
        let g = std::sync::Arc::new(ReplayShardGauge::default());
        let mut ra =
            ReplayActorState::new(8, 2, 0, 4, 0).with_gauge(g.clone());
        assert_eq!(g.capacity.load(Relaxed), 8);
        assert_eq!(g.ring_fill(), 0.0, "fresh incarnation publishes empty");
        ra.add_batch(&transitions(4, 0.0));
        assert_eq!(g.num_added.load(Relaxed), 4);
        assert_eq!(g.len.load(Relaxed), 4);
        assert!((g.ring_fill() - 0.5).abs() < 1e-12);
        ra.replay().unwrap();
        assert_eq!(g.num_sampled.load(Relaxed), 4);
        // A fresh incarnation attached to the same gauge resets it.
        let _ra2 = ReplayActorState::new(8, 2, 0, 4, 1).with_gauge(g.clone());
        assert_eq!(g.num_added.load(Relaxed), 0);
        assert_eq!(g.ring_fill(), 0.0);
    }

    #[test]
    fn replay_actor_gates_on_learning_starts() {
        let mut ra = ReplayActorState::new(64, 2, 10, 4, 0);
        ra.add_batch(&transitions(5, 0.0));
        assert!(ra.replay().is_none());
        ra.add_batch(&transitions(5, 0.0));
        let s = ra.replay().unwrap();
        assert_eq!(s.batch.len(), 4);
        assert_eq!(ra.num_sampled, 4);
    }
}
