//! Multi-agent batches: one `SampleBatch` per policy id.
//!
//! The multi-agent composition experiment (paper Fig. 11/12/14) routes
//! per-policy sub-batches to different training subflows (`Select`).

use std::collections::BTreeMap;

use super::SampleBatch;

pub type PolicyId = String;

/// Experiences grouped by the policy that produced them.  BTreeMap keeps
/// iteration deterministic across workers.
#[derive(Debug, Clone, Default)]
pub struct MultiAgentBatch {
    pub policy_batches: BTreeMap<PolicyId, SampleBatch>,
}

impl MultiAgentBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_single(policy_id: &str, batch: SampleBatch) -> Self {
        let mut policy_batches = BTreeMap::new();
        policy_batches.insert(policy_id.to_string(), batch);
        MultiAgentBatch { policy_batches }
    }

    /// Total env steps across all policies.
    pub fn count(&self) -> usize {
        self.policy_batches.values().map(|b| b.len()).sum()
    }

    /// Steps collected for one policy (0 if absent).
    pub fn policy_count(&self, policy_id: &str) -> usize {
        self.policy_batches.get(policy_id).map_or(0, |b| b.len())
    }

    /// The sub-batch for one policy, if present.
    pub fn select(&self, policy_id: &str) -> Option<&SampleBatch> {
        self.policy_batches.get(policy_id)
    }

    /// Merge by concatenating per-policy batches.  Groups by *borrowed*
    /// policy id and collects `&SampleBatch`s, so the only allocations
    /// are the per-policy ref vectors and the output columns — no
    /// cloned batch structs, and one id `String` per output key.
    pub fn concat_all(batches: &[MultiAgentBatch]) -> MultiAgentBatch {
        let mut grouped: BTreeMap<&str, Vec<&SampleBatch>> = BTreeMap::new();
        for ma in batches {
            for (pid, b) in &ma.policy_batches {
                grouped.entry(pid.as_str()).or_default().push(b);
            }
        }
        MultiAgentBatch {
            policy_batches: grouped
                .into_iter()
                .map(|(pid, bs)| {
                    (pid.to_string(), SampleBatch::concat_all_refs(&bs))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn mk(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(1);
        for i in 0..n {
            b.add_step(&[i as f32], 0, 0.0, false, 0.0, 0.0);
        }
        b.build()
    }

    #[test]
    fn count_sums_policies() {
        let mut ma = MultiAgentBatch::new();
        ma.policy_batches.insert("ppo".into(), mk(3));
        ma.policy_batches.insert("dqn".into(), mk(2));
        assert_eq!(ma.count(), 5);
        assert_eq!(ma.policy_count("ppo"), 3);
        assert_eq!(ma.policy_count("nope"), 0);
    }

    #[test]
    fn concat_groups_by_policy() {
        let a = MultiAgentBatch::from_single("ppo", mk(2));
        let mut b = MultiAgentBatch::from_single("ppo", mk(1));
        b.policy_batches.insert("dqn".into(), mk(4));
        let c = MultiAgentBatch::concat_all(&[a, b]);
        assert_eq!(c.policy_count("ppo"), 3);
        assert_eq!(c.policy_count("dqn"), 4);
    }

    #[test]
    fn select_returns_policy_view() {
        let ma = MultiAgentBatch::from_single("dqn", mk(2));
        assert_eq!(ma.select("dqn").unwrap().len(), 2);
        assert!(ma.select("ppo").is_none());
    }
}
