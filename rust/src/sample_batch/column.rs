//! Shared-storage batch columns: `Arc`-backed flat buffers with
//! (offset, len) windows and copy-on-write mutation.
//!
//! This is the zero-copy substrate of the experience path.  A
//! [`Col<T>`] behaves like a `Vec<T>` at every call site (it derefs to
//! `[T]`, supports `push`/`extend_from_slice`/`resize`/indexed writes,
//! compares against `Vec<T>`), but:
//!
//! * `clone()` is a reference-count bump — batches crossing operator
//!   boundaries (store-to-replay pass-through, `select_policy`,
//!   `duplicate`) no longer deep-copy their columns;
//! * [`Col::view`] produces a sub-range window over the *same* storage —
//!   `SampleBatch::slice`/`minibatches` are O(1) per column;
//! * any mutation first ensures unique, full-range ownership (copying
//!   only when the storage is actually shared or windowed), so views can
//!   never observe writes through a sibling — value semantics are
//!   preserved exactly, only the copies are lazy;
//! * [`Col::take_vec`] recovers the backing `Vec` (capacity included)
//!   when this handle is the last one — the reuse hook behind the replay
//!   scratch batch and the rollout builder's fragment recycling.

use std::sync::Arc;

/// An `f32` column ([`FCol`]) or `i32` column ([`ICol`]).
pub struct Col<T> {
    data: Arc<Vec<T>>,
    off: usize,
    len: usize,
}

pub type FCol = Col<f32>;
pub type ICol = Col<i32>;

impl<T> Clone for Col<T> {
    fn clone(&self) -> Self {
        Col { data: Arc::clone(&self.data), off: self.off, len: self.len }
    }
}

impl<T> Default for Col<T> {
    fn default() -> Self {
        Col { data: Arc::new(Vec::new()), off: 0, len: 0 }
    }
}

impl<T: Copy> Col<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap an owned vector without copying.
    pub fn from_vec(v: Vec<T>) -> Self {
        let len = v.len();
        Col { data: Arc::new(v), off: 0, len }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.data[self.off..self.off + self.len]
    }

    /// An O(1) sub-range view sharing this column's storage.
    pub fn view(&self, start: usize, end: usize) -> Self {
        assert!(start <= end && end <= self.len, "view out of range");
        Col {
            data: Arc::clone(&self.data),
            off: self.off + start,
            len: end - start,
        }
    }

    /// True when this handle aliases no other (unique, full-range).
    fn is_owned(&mut self) -> bool {
        self.off == 0
            && self.len == self.data.len()
            && Arc::get_mut(&mut self.data).is_some()
    }

    /// Copy-on-write: ensure unique, full-range ownership.
    fn make_owned(&mut self) {
        if self.is_owned() {
            return;
        }
        let v: Vec<T> = self.as_slice().to_vec();
        self.off = 0;
        self.len = v.len();
        self.data = Arc::new(v);
    }

    /// The owned backing vector (after copy-on-write).  Callers must
    /// restore the `len` invariant — use the public mutators instead.
    fn owned_vec(&mut self) -> &mut Vec<T> {
        self.make_owned();
        Arc::get_mut(&mut self.data).expect("unique after make_owned")
    }

    pub fn push(&mut self, value: T) {
        self.owned_vec().push(value);
        self.len += 1;
    }

    pub fn extend_from_slice(&mut self, other: &[T]) {
        self.owned_vec().extend_from_slice(other);
        self.len += other.len();
    }

    pub fn resize(&mut self, new_len: usize, value: T) {
        self.owned_vec().resize(new_len, value);
        self.len = new_len;
    }

    pub fn truncate(&mut self, new_len: usize) {
        if new_len >= self.len {
            return;
        }
        // A pure window shrink: no copy needed even when shared.
        self.len = new_len;
    }

    pub fn clear(&mut self) {
        self.truncate(0);
    }

    pub fn reserve(&mut self, additional: usize) {
        self.owned_vec().reserve(additional);
    }

    /// Copy this column's window into a fresh `Vec`.
    /// (Also reachable as the slice method via deref; kept inherent so
    /// call sites read naturally.)
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    /// Recover the backing vector for reuse, leaving this column empty.
    ///
    /// When this handle is the last reference the full backing `Vec`
    /// comes back *cleared but with capacity intact* (the steady-state,
    /// allocation-free path); otherwise a fresh empty `Vec` is returned
    /// and the shared storage stays untouched.
    pub fn take_vec(&mut self) -> Vec<T> {
        let col = std::mem::take(self);
        match Arc::try_unwrap(col.data) {
            Ok(mut v) => {
                v.clear();
                v
            }
            Err(_) => Vec::new(),
        }
    }
}

impl<T: Copy> std::ops::Deref for Col<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy> std::ops::DerefMut for Col<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.make_owned();
        Arc::get_mut(&mut self.data)
            .expect("unique after make_owned")
            .as_mut_slice()
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for Col<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: Copy + PartialEq> PartialEq for Col<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + PartialEq> PartialEq<Vec<T>> for Col<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + PartialEq> PartialEq<Col<T>> for Vec<T> {
    fn eq(&self, other: &Col<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + PartialEq> PartialEq<&[T]> for Col<T> {
    fn eq(&self, other: &&[T]) -> bool {
        self.as_slice() == *other
    }
}

impl<T: Copy> From<Vec<T>> for Col<T> {
    fn from(v: Vec<T>) -> Self {
        Col::from_vec(v)
    }
}

impl<T: Copy> FromIterator<T> for Col<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        Col::from_vec(iter.into_iter().collect())
    }
}

impl<'a, T: Copy> IntoIterator for &'a Col<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<'a, T: Copy> IntoIterator for &'a mut Col<T> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        use std::ops::DerefMut;
        self.deref_mut().iter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_aliases_without_copy() {
        let a = FCol::from_vec(vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        let v = a.view(1, 4);
        assert_eq!(&v[..], &[1.0, 2.0, 3.0]);
        // Shared storage: three handles (a, v) over one allocation.
        assert_eq!(v.len(), 3);
        let vv = v.view(1, 3);
        assert_eq!(&vv[..], &[2.0, 3.0]);
    }

    #[test]
    fn write_through_view_copies_not_aliases() {
        let a = FCol::from_vec(vec![0.0, 1.0, 2.0, 3.0]);
        let mut v = a.view(0, 2);
        v[0] = 99.0;
        assert_eq!(&v[..], &[99.0, 1.0]);
        assert_eq!(&a[..], &[0.0, 1.0, 2.0, 3.0], "parent must not see write");
    }

    #[test]
    fn push_after_clone_diverges() {
        let mut a = FCol::from_vec(vec![1.0]);
        let b = a.clone();
        a.push(2.0);
        assert_eq!(a, vec![1.0, 2.0]);
        assert_eq!(b, vec![1.0]);
    }

    #[test]
    fn unique_mutation_is_in_place() {
        let mut a = FCol::from_vec(Vec::with_capacity(64));
        let ptr = a.data.as_ptr();
        for i in 0..32 {
            a.push(i as f32);
        }
        // No reallocation happened: same backing Vec throughout.
        assert_eq!(a.data.as_ptr(), ptr);
        assert_eq!(a.len(), 32);
    }

    #[test]
    fn take_vec_recovers_capacity_when_unique() {
        let mut a = FCol::from_vec(Vec::with_capacity(128));
        a.extend_from_slice(&[1.0, 2.0]);
        let v = a.take_vec();
        assert!(v.capacity() >= 128);
        assert!(v.is_empty());
        assert!(a.is_empty());
    }

    #[test]
    fn take_vec_backs_off_when_shared() {
        let mut a = FCol::from_vec(vec![1.0, 2.0]);
        let keep = a.clone();
        let v = a.take_vec();
        assert!(v.is_empty());
        assert_eq!(keep, vec![1.0, 2.0], "shared storage untouched");
    }

    #[test]
    fn truncate_and_clear_are_window_ops() {
        let mut a = FCol::from_vec(vec![1.0, 2.0, 3.0]);
        let b = a.clone();
        a.truncate(1);
        assert_eq!(a, vec![1.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0]);
        a.clear();
        assert!(a.is_empty());
    }

    #[test]
    fn vec_like_traits() {
        let a: FCol = (0..3).map(|i| i as f32).collect();
        assert_eq!(a, vec![0.0, 1.0, 2.0]);
        let from: FCol = vec![5.0].into();
        assert_eq!(from[0], 5.0);
        let mut m = a.clone();
        for x in &mut m {
            *x += 1.0;
        }
        assert_eq!(m, vec![1.0, 2.0, 3.0]);
        assert_eq!(a.iter().sum::<f32>(), 3.0);
        let mut sorted = m.clone();
        sorted.sort_by(f32::total_cmp);
        assert_eq!(sorted, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn icol_works_too() {
        let mut a = ICol::from_vec(vec![1, 2, 3]);
        let v = a.view(1, 3);
        assert_eq!(&v[..], &[2, 3]);
        a.push(4);
        assert_eq!(a, vec![1, 2, 3, 4]);
    }
}
