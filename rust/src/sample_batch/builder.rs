//! Step-wise batch construction in the rollout hot loop.

use super::column::{FCol, ICol};
use super::SampleBatch;

/// Appends one environment transition at a time; columns are preallocated
/// to the expected fragment length so the hot loop never reallocates.
///
/// `build()` freezes the staged columns into a [`SampleBatch`]
/// (zero-copy: the vectors move into shared storage) while keeping a
/// handle to them.  Once every consumer of the previous fragment has
/// dropped it — the steady state of `RolloutWorker::sample`, where
/// per-env segments die right after `concat_all` — the next fragment
/// reclaims the same allocations, so a long-running worker builds every
/// fragment after the first without touching the allocator.
#[derive(Debug)]
pub struct SampleBatchBuilder {
    obs_dim: usize,
    capacity: usize,
    obs: Vec<f32>,
    actions: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
    action_logp: Vec<f32>,
    vf_preds: Vec<f32>,
    next_obs: Vec<f32>,
    /// Column handles of the last built batch, reclaimed (capacity and
    /// all) by the next fragment once consumers dropped theirs.
    retained: Option<SampleBatch>,
}

impl SampleBatchBuilder {
    pub fn new(obs_dim: usize) -> Self {
        Self::with_capacity(obs_dim, 64)
    }

    pub fn with_capacity(obs_dim: usize, capacity: usize) -> Self {
        SampleBatchBuilder {
            obs_dim,
            capacity,
            obs: Vec::with_capacity(capacity * obs_dim),
            actions: Vec::with_capacity(capacity),
            rewards: Vec::with_capacity(capacity),
            dones: Vec::with_capacity(capacity),
            action_logp: Vec::with_capacity(capacity),
            vf_preds: Vec::with_capacity(capacity),
            next_obs: Vec::new(),
            retained: None,
        }
    }

    /// Recover the previous fragment's allocations if its consumers are
    /// done with them (cheap no-op branch in the steady state).
    fn reclaim(&mut self) {
        let Some(mut prev) = self.retained.take() else {
            return;
        };
        if self.obs.capacity() == 0 {
            self.obs = prev.obs.take_vec();
        }
        if self.actions.capacity() == 0 {
            self.actions = prev.actions.take_vec();
        }
        if self.rewards.capacity() == 0 {
            self.rewards = prev.rewards.take_vec();
        }
        if self.dones.capacity() == 0 {
            self.dones = prev.dones.take_vec();
        }
        if self.action_logp.capacity() == 0 {
            self.action_logp = prev.action_logp.take_vec();
        }
        if self.vf_preds.capacity() == 0 {
            self.vf_preds = prev.vf_preds.take_vec();
        }
        if self.next_obs.capacity() == 0 {
            self.next_obs = prev.next_obs.take_vec();
        }
    }

    /// Append an on-policy transition (policy-gradient family).
    pub fn add_step(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        done: bool,
        action_logp: f32,
        vf_pred: f32,
    ) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        self.reclaim();
        self.obs.extend_from_slice(obs);
        self.actions.push(action);
        self.rewards.push(reward);
        self.dones.push(if done { 1.0 } else { 0.0 });
        self.action_logp.push(action_logp);
        self.vf_preds.push(vf_pred);
    }

    /// Append an on-policy transition that also records next_obs
    /// (IMPALA fragments bootstrap from the trailing observation; the
    /// multi-agent worker records full rows so any policy can consume
    /// its sub-batch).
    #[allow(clippy::too_many_arguments)]
    pub fn add_step_with_next(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        action_logp: f32,
        vf_pred: f32,
    ) {
        self.add_step(obs, action, reward, done, action_logp, vf_pred);
        self.next_obs.extend_from_slice(next_obs);
    }

    /// Append an off-policy transition (DQN family, with next_obs).
    pub fn add_transition(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        self.reclaim();
        self.obs.extend_from_slice(obs);
        self.actions.push(action);
        self.rewards.push(reward);
        self.next_obs.extend_from_slice(next_obs);
        self.dones.push(if done { 1.0 } else { 0.0 });
    }

    /// Append an off-policy transition *with* the behavior policy's
    /// action log-probability — the schema episode logging wants:
    /// DQN-shaped rows (next_obs, no vf columns) that still carry the
    /// logp off-policy evaluation needs (`ops::ope_estimate`).
    pub fn add_transition_with_logp(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        action_logp: f32,
    ) {
        self.add_transition(obs, action, reward, next_obs, done);
        self.action_logp.push(action_logp);
    }

    pub fn len(&self) -> usize {
        if self.obs_dim == 0 {
            0
        } else {
            self.obs.len() / self.obs_dim
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Finish the batch, leaving the builder reusable.  The staged
    /// columns move into the batch without copying; their capacity
    /// returns to the builder once the batch's consumers drop it.
    pub fn build(&mut self) -> SampleBatch {
        let mut b = SampleBatch::new(self.obs_dim);
        b.obs = FCol::from_vec(std::mem::take(&mut self.obs));
        b.actions = ICol::from_vec(std::mem::take(&mut self.actions));
        b.rewards = FCol::from_vec(std::mem::take(&mut self.rewards));
        b.dones = FCol::from_vec(std::mem::take(&mut self.dones));
        b.action_logp =
            FCol::from_vec(std::mem::take(&mut self.action_logp));
        b.vf_preds = FCol::from_vec(std::mem::take(&mut self.vf_preds));
        b.next_obs = FCol::from_vec(std::mem::take(&mut self.next_obs));
        self.retained = Some(b.clone());
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_resets_builder() {
        let mut b = SampleBatchBuilder::new(2);
        b.add_step(&[1.0, 2.0], 0, 1.0, false, -0.7, 0.5);
        let first = b.build();
        assert_eq!(first.len(), 1);
        assert!(b.is_empty());
        b.add_step(&[3.0, 4.0], 1, 2.0, true, -0.1, 0.2);
        let second = b.build();
        assert_eq!(second.len(), 1);
        assert_eq!(second.obs_row(0), &[3.0, 4.0]);
        assert_eq!(second.dones, vec![1.0]);
        // Earlier fragments are untouched by builder reuse.
        assert_eq!(first.obs_row(0), &[1.0, 2.0]);
    }

    #[test]
    fn add_transition_fills_next_obs() {
        let mut b = SampleBatchBuilder::new(2);
        b.add_transition(&[1.0, 2.0], 1, 0.5, &[3.0, 4.0], false);
        let batch = b.build();
        assert_eq!(batch.next_obs_row(0), &[3.0, 4.0]);
        assert!(batch.action_logp.is_empty());
    }

    #[test]
    fn builder_reuses_capacity_when_fragment_dropped() {
        let mut b = SampleBatchBuilder::with_capacity(2, 8);
        b.add_step(&[1.0, 2.0], 0, 1.0, false, 0.0, 0.0);
        let cap_before = {
            drop(b.build()); // consumer finished with the fragment
            // Trigger reclaim, then inspect staged capacity.
            b.add_step(&[5.0, 6.0], 0, 1.0, false, 0.0, 0.0);
            b.obs.capacity()
        };
        // The original 8x2 reservation came back instead of a fresh
        // 1-element allocation.
        assert!(cap_before >= 16, "capacity {cap_before} not reclaimed");
        let batch = b.build();
        assert_eq!(batch.obs_row(0), &[5.0, 6.0]);
    }

    #[test]
    fn builder_allocates_fresh_when_fragment_still_live() {
        let mut b = SampleBatchBuilder::new(1);
        b.add_step(&[1.0], 0, 1.0, false, 0.0, 0.0);
        let held = b.build(); // keep the fragment alive
        b.add_step(&[2.0], 0, 2.0, false, 0.0, 0.0);
        let next = b.build();
        assert_eq!(held.obs_row(0), &[1.0]);
        assert_eq!(next.obs_row(0), &[2.0]);
    }
}
