//! Step-wise batch construction in the rollout hot loop.

use super::SampleBatch;

/// Appends one environment transition at a time; columns are preallocated
/// to the expected fragment length so the hot loop never reallocates.
#[derive(Debug)]
pub struct SampleBatchBuilder {
    batch: SampleBatch,
    capacity: usize,
}

impl SampleBatchBuilder {
    pub fn new(obs_dim: usize) -> Self {
        Self::with_capacity(obs_dim, 64)
    }

    pub fn with_capacity(obs_dim: usize, capacity: usize) -> Self {
        let mut batch = SampleBatch::new(obs_dim);
        batch.obs.reserve(capacity * obs_dim);
        batch.actions.reserve(capacity);
        batch.rewards.reserve(capacity);
        batch.dones.reserve(capacity);
        batch.action_logp.reserve(capacity);
        batch.vf_preds.reserve(capacity);
        SampleBatchBuilder { batch, capacity }
    }

    /// Append an on-policy transition (policy-gradient family).
    pub fn add_step(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        done: bool,
        action_logp: f32,
        vf_pred: f32,
    ) {
        debug_assert_eq!(obs.len(), self.batch.obs_dim);
        self.batch.obs.extend_from_slice(obs);
        self.batch.actions.push(action);
        self.batch.rewards.push(reward);
        self.batch.dones.push(if done { 1.0 } else { 0.0 });
        self.batch.action_logp.push(action_logp);
        self.batch.vf_preds.push(vf_pred);
    }

    /// Append an on-policy transition that also records next_obs
    /// (IMPALA fragments bootstrap from the trailing observation; the
    /// multi-agent worker records full rows so any policy can consume
    /// its sub-batch).
    #[allow(clippy::too_many_arguments)]
    pub fn add_step_with_next(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        action_logp: f32,
        vf_pred: f32,
    ) {
        self.add_step(obs, action, reward, done, action_logp, vf_pred);
        self.batch.next_obs.extend_from_slice(next_obs);
    }

    /// Append an off-policy transition (DQN family, with next_obs).
    pub fn add_transition(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) {
        debug_assert_eq!(obs.len(), self.batch.obs_dim);
        self.batch.obs.extend_from_slice(obs);
        self.batch.actions.push(action);
        self.batch.rewards.push(reward);
        self.batch.next_obs.extend_from_slice(next_obs);
        self.batch.dones.push(if done { 1.0 } else { 0.0 });
    }

    pub fn len(&self) -> usize {
        self.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batch.is_empty()
    }

    /// Finish the batch, leaving the builder reusable (columns cleared,
    /// capacity retained).
    pub fn build(&mut self) -> SampleBatch {
        let obs_dim = self.batch.obs_dim;
        let done = std::mem::replace(&mut self.batch, SampleBatch::new(obs_dim));
        self.batch.obs.reserve(self.capacity * obs_dim);
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_resets_builder() {
        let mut b = SampleBatchBuilder::new(2);
        b.add_step(&[1.0, 2.0], 0, 1.0, false, -0.7, 0.5);
        let first = b.build();
        assert_eq!(first.len(), 1);
        assert!(b.is_empty());
        b.add_step(&[3.0, 4.0], 1, 2.0, true, -0.1, 0.2);
        let second = b.build();
        assert_eq!(second.len(), 1);
        assert_eq!(second.obs_row(0), &[3.0, 4.0]);
        assert_eq!(second.dones, vec![1.0]);
    }

    #[test]
    fn add_transition_fills_next_obs() {
        let mut b = SampleBatchBuilder::new(2);
        b.add_transition(&[1.0, 2.0], 1, 0.5, &[3.0, 4.0], false);
        let batch = b.build();
        assert_eq!(batch.next_obs_row(0), &[3.0, 4.0]);
        assert!(batch.action_logp.is_empty());
    }
}
