//! The core columnar batch.

use super::column::{FCol, ICol};
use crate::util::Rng;

/// A batch of `len` experience rows stored column-wise in shared
/// [`FCol`]/[`ICol`] storage.
///
/// Fixed RL columns (obs/actions/rewards/dones) are always present;
/// algorithm-specific columns (action log-probs, value predictions,
/// advantages, value targets) are optional and filled by the collecting
/// worker or post-processing (`compute_gae`).
///
/// `slice`/`minibatches` return **views** (offset+len windows over the
/// same storage); `clone` bumps reference counts.  Mutation is
/// copy-on-write per column, so views keep value semantics while the
/// steady-state hot path (concat → slice → minibatch → learner) never
/// copies a column more than once.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SampleBatch {
    /// Row-major observations, `len * obs_dim` values.
    pub obs: FCol,
    pub obs_dim: usize,
    pub actions: ICol,
    pub rewards: FCol,
    /// 1.0 where the episode terminated at this step.
    pub dones: FCol,
    /// log pi(a|s) under the behaviour policy at collection time.
    pub action_logp: FCol,
    /// Value-function predictions at collection time.
    pub vf_preds: FCol,
    /// GAE advantages (filled by post-processing).
    pub advantages: FCol,
    /// Value-function regression targets (filled by post-processing).
    pub value_targets: FCol,
    /// Next-step observations (filled for off-policy/DQN batches).
    pub next_obs: FCol,
    /// Per-row importance weights (prioritized replay); empty = all 1.
    pub weights: FCol,
}

impl SampleBatch {
    pub fn new(obs_dim: usize) -> Self {
        SampleBatch { obs_dim, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        if self.obs_dim == 0 {
            0
        } else {
            self.obs.len() / self.obs_dim
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Observation row `i` as a slice.
    pub fn obs_row(&self, i: usize) -> &[f32] {
        &self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]
    }

    pub fn next_obs_row(&self, i: usize) -> &[f32] {
        &self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim]
    }

    /// Concatenate batches (all must share obs_dim and column presence).
    ///
    /// Every output column is sized exactly once and filled in a single
    /// pass; a 1-batch concat is a pure reference-count bump.
    pub fn concat_all(batches: &[SampleBatch]) -> SampleBatch {
        assert!(!batches.is_empty());
        if batches.len() == 1 {
            return batches[0].clone();
        }
        let refs: Vec<&SampleBatch> = batches.iter().collect();
        Self::concat_all_refs(&refs)
    }

    /// [`SampleBatch::concat_all`] over borrowed batches — callers that
    /// group batches (e.g. `MultiAgentBatch::concat_all` bucketing by
    /// policy id) collect `&SampleBatch`s instead of cloning every
    /// batch struct into intermediate grouping vectors.
    pub fn concat_all_refs(batches: &[&SampleBatch]) -> SampleBatch {
        assert!(!batches.is_empty());
        if batches.len() == 1 {
            return batches[0].clone();
        }
        let obs_dim = batches[0].obs_dim;
        for b in batches {
            assert_eq!(b.obs_dim, obs_dim, "obs_dim mismatch in concat");
        }
        fn cat_f(
            batches: &[&SampleBatch],
            get: fn(&SampleBatch) -> &FCol,
        ) -> FCol {
            let total: usize = batches.iter().map(|b| get(b).len()).sum();
            let mut v = Vec::with_capacity(total);
            for b in batches {
                v.extend_from_slice(get(b));
            }
            FCol::from_vec(v)
        }
        let actions = {
            let total: usize = batches.iter().map(|b| b.actions.len()).sum();
            let mut v = Vec::with_capacity(total);
            for b in batches {
                v.extend_from_slice(&b.actions);
            }
            ICol::from_vec(v)
        };
        SampleBatch {
            obs: cat_f(batches, |b| &b.obs),
            obs_dim,
            actions,
            rewards: cat_f(batches, |b| &b.rewards),
            dones: cat_f(batches, |b| &b.dones),
            action_logp: cat_f(batches, |b| &b.action_logp),
            vf_preds: cat_f(batches, |b| &b.vf_preds),
            advantages: cat_f(batches, |b| &b.advantages),
            value_targets: cat_f(batches, |b| &b.value_targets),
            next_obs: cat_f(batches, |b| &b.next_obs),
            weights: cat_f(batches, |b| &b.weights),
        }
    }

    /// Rows `[start, end)` as a **view** sharing this batch's storage
    /// (O(1) per column; absent columns stay absent).
    pub fn slice(&self, start: usize, end: usize) -> SampleBatch {
        let d = self.obs_dim;
        let col = |c: &FCol| {
            if c.is_empty() {
                FCol::new()
            } else {
                c.view(start, end)
            }
        };
        let coln = |c: &FCol| {
            if c.is_empty() {
                FCol::new()
            } else {
                c.view(start * d, end * d)
            }
        };
        SampleBatch {
            obs: coln(&self.obs),
            obs_dim: d,
            actions: if self.actions.is_empty() {
                ICol::new()
            } else {
                self.actions.view(start, end)
            },
            rewards: col(&self.rewards),
            dones: col(&self.dones),
            action_logp: col(&self.action_logp),
            vf_preds: col(&self.vf_preds),
            advantages: col(&self.advantages),
            value_targets: col(&self.value_targets),
            next_obs: coln(&self.next_obs),
            weights: col(&self.weights),
        }
    }

    /// Fisher–Yates row shuffle (used between PPO epochs): builds the
    /// permutation index first, then gathers every column in one pass —
    /// instead of the O(n) per-element row swaps of the copy era.
    ///
    /// Consumes randomness identically to the former in-place version,
    /// so seeded runs stay bit-reproducible.
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let n = self.len();
        if n <= 1 {
            return;
        }
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        let d = self.obs_dim;
        let gather = |c: &FCol| -> FCol {
            if c.is_empty() {
                return FCol::new();
            }
            let mut v = Vec::with_capacity(n);
            for &p in &perm {
                v.push(c[p]);
            }
            FCol::from_vec(v)
        };
        let gather_rows = |c: &FCol| -> FCol {
            if c.is_empty() {
                return FCol::new();
            }
            let mut v = Vec::with_capacity(n * d);
            for &p in &perm {
                v.extend_from_slice(&c[p * d..(p + 1) * d]);
            }
            FCol::from_vec(v)
        };
        self.obs = gather_rows(&self.obs);
        self.next_obs = gather_rows(&self.next_obs);
        if !self.actions.is_empty() {
            let mut v = Vec::with_capacity(n);
            for &p in &perm {
                v.push(self.actions[p]);
            }
            self.actions = ICol::from_vec(v);
        }
        self.rewards = gather(&self.rewards);
        self.dones = gather(&self.dones);
        self.action_logp = gather(&self.action_logp);
        self.vf_preds = gather(&self.vf_preds);
        self.advantages = gather(&self.advantages);
        self.value_targets = gather(&self.value_targets);
        self.weights = gather(&self.weights);
    }

    /// Fixed-size minibatch views for SGD epochs; the tail shorter than
    /// `size` is dropped (standard PPO practice with shuffled rows).
    /// Each minibatch aliases this batch's storage — no copies.
    pub fn minibatches(&self, size: usize) -> Vec<SampleBatch> {
        let n = self.len() / size;
        (0..n).map(|i| self.slice(i * size, (i + 1) * size)).collect()
    }

    /// Pad (repeat-last-row padding, mask 0) or truncate to exactly `n`
    /// rows, returning the mask column.  Static-shape HLO artifacts
    /// require exact row counts; the mask keeps padding out of losses.
    ///
    /// Truncation is a view; padding copies once into exactly-sized
    /// columns.  Padding an *empty* batch zero-fills **every** column of
    /// the schema (including the optional ones: logp, vf_preds,
    /// advantages, value_targets, next_obs, weights) so column presence
    /// never changes under padding — downstream consumers that expect
    /// e.g. `weights` or `action_logp` see zeros, not a vanished column.
    pub fn pad_or_truncate(&self, n: usize) -> (SampleBatch, Vec<f32>) {
        let len = self.len();
        if len >= n {
            return (self.slice(0, n), vec![1.0; n]);
        }
        let d = self.obs_dim;
        if len == 0 {
            let mut out = SampleBatch::new(d);
            out.obs = FCol::from_vec(vec![0.0; n * d]);
            out.actions = ICol::from_vec(vec![0; n]);
            out.rewards = FCol::from_vec(vec![0.0; n]);
            out.dones = FCol::from_vec(vec![0.0; n]);
            out.action_logp = FCol::from_vec(vec![0.0; n]);
            out.vf_preds = FCol::from_vec(vec![0.0; n]);
            out.advantages = FCol::from_vec(vec![0.0; n]);
            out.value_targets = FCol::from_vec(vec![0.0; n]);
            out.next_obs = FCol::from_vec(vec![0.0; n * d]);
            out.weights = FCol::from_vec(vec![0.0; n]);
            return (out, vec![0.0; n]);
        }
        let last = len - 1;
        let pad_f = |src: &FCol, width: usize| -> FCol {
            if src.is_empty() {
                return FCol::new();
            }
            let mut v = Vec::with_capacity(n * width);
            v.extend_from_slice(src);
            let tail = &src[last * width..len * width];
            for _ in len..n {
                v.extend_from_slice(tail);
            }
            FCol::from_vec(v)
        };
        let actions = if self.actions.is_empty() {
            ICol::new()
        } else {
            let mut v = Vec::with_capacity(n);
            v.extend_from_slice(&self.actions);
            for _ in len..n {
                v.push(self.actions[last]);
            }
            ICol::from_vec(v)
        };
        let out = SampleBatch {
            obs: pad_f(&self.obs, d),
            obs_dim: d,
            actions,
            rewards: pad_f(&self.rewards, 1),
            dones: pad_f(&self.dones, 1),
            action_logp: pad_f(&self.action_logp, 1),
            vf_preds: pad_f(&self.vf_preds, 1),
            advantages: pad_f(&self.advantages, 1),
            value_targets: pad_f(&self.value_targets, 1),
            next_obs: pad_f(&self.next_obs, d),
            weights: pad_f(&self.weights, 1),
        };
        let mut mask = vec![1.0; len];
        mask.resize(n, 0.0);
        (out, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn mk(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_step(
                &[i as f32, -(i as f32)],
                (i % 2) as i32,
                i as f32,
                i == n - 1,
                -0.5,
                0.1 * i as f32,
            );
        }
        b.build()
    }

    #[test]
    fn len_counts_rows() {
        assert_eq!(mk(5).len(), 5);
        assert!(SampleBatch::new(4).is_empty());
    }

    #[test]
    fn concat_preserves_order_and_len() {
        let a = mk(3);
        let b = mk(2);
        let c = SampleBatch::concat_all(&[a.clone(), b.clone()]);
        assert_eq!(c.len(), 5);
        assert_eq!(c.obs_row(0), a.obs_row(0));
        assert_eq!(c.obs_row(3), b.obs_row(0));
        assert_eq!(c.rewards[..3], a.rewards[..]);
    }

    #[test]
    fn concat_of_one_is_zero_copy() {
        let a = mk(4);
        let c = SampleBatch::concat_all(std::slice::from_ref(&a));
        assert_eq!(c, a);
    }

    #[test]
    fn slice_extracts_rows() {
        let b = mk(6);
        let s = b.slice(2, 5);
        assert_eq!(s.len(), 3);
        assert_eq!(s.obs_row(0), b.obs_row(2));
        assert_eq!(s.actions[0], b.actions[2]);
        assert_eq!(s.rewards, b.rewards[2..5].to_vec());
    }

    #[test]
    fn slice_views_do_not_leak_writes() {
        let b = mk(6);
        let mut s = b.slice(1, 3);
        s.rewards[0] = 1234.0;
        assert_eq!(s.rewards[0], 1234.0);
        assert_eq!(b.rewards[1], 1.0, "parent sees no write through view");
        assert_eq!(b.rewards.len(), 6);
    }

    #[test]
    fn minibatches_drop_tail() {
        let b = mk(10);
        let mbs = b.minibatches(4);
        assert_eq!(mbs.len(), 2);
        assert!(mbs.iter().all(|m| m.len() == 4));
    }

    #[test]
    fn minibatches_are_views_row_identical_to_slices() {
        let b = mk(9);
        for (i, mb) in b.minibatches(3).iter().enumerate() {
            for r in 0..3 {
                assert_eq!(mb.obs_row(r), b.obs_row(i * 3 + r));
                assert_eq!(mb.rewards[r], b.rewards[i * 3 + r]);
                assert_eq!(mb.actions[r], b.actions[i * 3 + r]);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let b0 = mk(20);
        let mut b = b0.clone();
        b.shuffle(&mut Rng::new(1));
        assert_eq!(b.len(), 20);
        let mut r0 = b0.rewards.to_vec();
        let mut r1 = b.rewards.to_vec();
        r0.sort_by(f32::total_cmp);
        r1.sort_by(f32::total_cmp);
        assert_eq!(r0, r1);
        assert_ne!(b.rewards, b0.rewards); // overwhelmingly likely
        // Row integrity: obs[0] must equal i where rewards == i.
        for i in 0..20 {
            assert_eq!(b.obs_row(i)[0], b.rewards[i]);
        }
    }

    #[test]
    fn shuffle_of_view_leaves_parent_intact() {
        let b = mk(12);
        let mut s = b.slice(2, 10);
        s.shuffle(&mut Rng::new(3));
        assert_eq!(s.len(), 8);
        for i in 0..12 {
            assert_eq!(b.obs_row(i)[0], i as f32, "parent reordered");
        }
        // The view still holds exactly rows 2..10, permuted.
        let mut rows = s.rewards.to_vec();
        rows.sort_by(f32::total_cmp);
        assert_eq!(rows, (2..10).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn pad_extends_with_mask_zero() {
        let b = mk(3);
        let (p, mask) = b.pad_or_truncate(5);
        assert_eq!(p.len(), 5);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
        assert_eq!(p.obs_row(4), b.obs_row(2)); // repeat-last padding
        assert_eq!(p.action_logp.len(), 5); // optional cols padded too
    }

    #[test]
    fn truncate_keeps_prefix() {
        let b = mk(8);
        let (p, mask) = b.pad_or_truncate(4);
        assert_eq!(p.len(), 4);
        assert_eq!(mask, vec![1.0; 4]);
        assert_eq!(p.obs_row(3), b.obs_row(3));
    }

    #[test]
    fn pad_empty_batch_is_all_masked_zeros() {
        let b = SampleBatch::new(2);
        let (p, mask) = b.pad_or_truncate(3);
        assert_eq!(p.len(), 3);
        assert_eq!(mask, vec![0.0; 3]);
        assert!(p.obs.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_empty_batch_keeps_full_schema() {
        // Regression (satellite fix): padding an empty batch used to
        // zero-fill only the fixed columns, silently dropping optional
        // columns a downstream consumer (dqn_grad's weights, ppo_grad's
        // action_logp) expects.  All columns must be present and zero.
        let b = SampleBatch::new(2);
        let (p, _mask) = b.pad_or_truncate(4);
        assert_eq!(p.action_logp.len(), 4);
        assert_eq!(p.vf_preds.len(), 4);
        assert_eq!(p.advantages.len(), 4);
        assert_eq!(p.value_targets.len(), 4);
        assert_eq!(p.weights.len(), 4);
        assert_eq!(p.next_obs.len(), 4 * 2);
        assert!(p.weights.iter().all(|&w| w == 0.0));
    }
}
