//! The core columnar batch.

use crate::util::Rng;

/// A batch of `len` experience rows stored column-wise.
///
/// Fixed RL columns (obs/actions/rewards/dones) are always present;
/// algorithm-specific columns (action log-probs, value predictions,
/// advantages, value targets) are optional and filled by the collecting
/// worker or post-processing (`compute_gae`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SampleBatch {
    /// Row-major observations, `len * obs_dim` values.
    pub obs: Vec<f32>,
    pub obs_dim: usize,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    /// 1.0 where the episode terminated at this step.
    pub dones: Vec<f32>,
    /// log pi(a|s) under the behaviour policy at collection time.
    pub action_logp: Vec<f32>,
    /// Value-function predictions at collection time.
    pub vf_preds: Vec<f32>,
    /// GAE advantages (filled by post-processing).
    pub advantages: Vec<f32>,
    /// Value-function regression targets (filled by post-processing).
    pub value_targets: Vec<f32>,
    /// Next-step observations (filled for off-policy/DQN batches).
    pub next_obs: Vec<f32>,
    /// Per-row importance weights (prioritized replay); empty = all 1.
    pub weights: Vec<f32>,
}

impl SampleBatch {
    pub fn new(obs_dim: usize) -> Self {
        SampleBatch { obs_dim, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        if self.obs_dim == 0 {
            0
        } else {
            self.obs.len() / self.obs_dim
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Observation row `i` as a slice.
    pub fn obs_row(&self, i: usize) -> &[f32] {
        &self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]
    }

    pub fn next_obs_row(&self, i: usize) -> &[f32] {
        &self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim]
    }

    /// Concatenate batches (all must share obs_dim and column presence).
    pub fn concat_all(batches: &[SampleBatch]) -> SampleBatch {
        assert!(!batches.is_empty());
        let mut out = SampleBatch::new(batches[0].obs_dim);
        for b in batches {
            assert_eq!(b.obs_dim, out.obs_dim, "obs_dim mismatch in concat");
            out.obs.extend_from_slice(&b.obs);
            out.actions.extend_from_slice(&b.actions);
            out.rewards.extend_from_slice(&b.rewards);
            out.dones.extend_from_slice(&b.dones);
            out.action_logp.extend_from_slice(&b.action_logp);
            out.vf_preds.extend_from_slice(&b.vf_preds);
            out.advantages.extend_from_slice(&b.advantages);
            out.value_targets.extend_from_slice(&b.value_targets);
            out.next_obs.extend_from_slice(&b.next_obs);
            out.weights.extend_from_slice(&b.weights);
        }
        out
    }

    /// Rows `[start, end)` as a new batch.
    pub fn slice(&self, start: usize, end: usize) -> SampleBatch {
        let d = self.obs_dim;
        let col = |v: &Vec<f32>| {
            if v.is_empty() { vec![] } else { v[start..end].to_vec() }
        };
        let coln = |v: &Vec<f32>| {
            if v.is_empty() { vec![] } else { v[start * d..end * d].to_vec() }
        };
        SampleBatch {
            obs: coln(&self.obs),
            obs_dim: d,
            actions: if self.actions.is_empty() {
                vec![]
            } else {
                self.actions[start..end].to_vec()
            },
            rewards: col(&self.rewards),
            dones: col(&self.dones),
            action_logp: col(&self.action_logp),
            vf_preds: col(&self.vf_preds),
            advantages: col(&self.advantages),
            value_targets: col(&self.value_targets),
            next_obs: coln(&self.next_obs),
            weights: col(&self.weights),
        }
    }

    /// In-place Fisher–Yates row shuffle (used between PPO epochs).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let n = self.len();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            self.swap_rows(i, j);
        }
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        if i == j {
            return;
        }
        let d = self.obs_dim;
        for k in 0..d {
            self.obs.swap(i * d + k, j * d + k);
            if !self.next_obs.is_empty() {
                self.next_obs.swap(i * d + k, j * d + k);
            }
        }
        let swap1 = |v: &mut Vec<f32>| {
            if !v.is_empty() {
                v.swap(i, j)
            }
        };
        self.actions.swap(i, j);
        swap1(&mut self.rewards);
        swap1(&mut self.dones);
        swap1(&mut self.action_logp);
        swap1(&mut self.vf_preds);
        swap1(&mut self.advantages);
        swap1(&mut self.value_targets);
        swap1(&mut self.weights);
    }

    /// Fixed-size minibatch views for SGD epochs; the tail shorter than
    /// `size` is dropped (standard PPO practice with shuffled rows).
    pub fn minibatches(&self, size: usize) -> Vec<SampleBatch> {
        let n = self.len() / size;
        (0..n).map(|i| self.slice(i * size, (i + 1) * size)).collect()
    }

    /// Pad (repeat-last-row padding, mask 0) or truncate to exactly `n`
    /// rows, returning the mask column.  Static-shape HLO artifacts
    /// require exact row counts; the mask keeps padding out of losses.
    pub fn pad_or_truncate(&self, n: usize) -> (SampleBatch, Vec<f32>) {
        let len = self.len();
        if len >= n {
            return (self.slice(0, n), vec![1.0; n]);
        }
        if len == 0 {
            // Nothing to repeat: pad fixed columns with zeros, mask all 0.
            let mut out = SampleBatch::new(self.obs_dim);
            out.obs = vec![0.0; n * self.obs_dim];
            out.actions = vec![0; n];
            out.rewards = vec![0.0; n];
            out.dones = vec![0.0; n];
            return (out, vec![0.0; n]);
        }
        let mut out = self.clone();
        let mut mask = vec![1.0; len];
        let last = len.saturating_sub(1);
        for _ in len..n {
            for k in 0..self.obs_dim {
                out.obs.push(self.obs[last * self.obs_dim + k]);
                if !self.next_obs.is_empty() {
                    out.next_obs.push(self.next_obs[last * self.obs_dim + k]);
                }
            }
            out.actions.push(*self.actions.get(last).unwrap_or(&0));
            let push1 = |src: &Vec<f32>, dst: &mut Vec<f32>| {
                if !src.is_empty() {
                    dst.push(src[last]);
                }
            };
            push1(&self.rewards, &mut out.rewards);
            push1(&self.dones, &mut out.dones);
            push1(&self.action_logp, &mut out.action_logp);
            push1(&self.vf_preds, &mut out.vf_preds);
            push1(&self.advantages, &mut out.advantages);
            push1(&self.value_targets, &mut out.value_targets);
            push1(&self.weights, &mut out.weights);
            mask.push(0.0);
        }
        (out, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn mk(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_step(
                &[i as f32, -(i as f32)],
                (i % 2) as i32,
                i as f32,
                i == n - 1,
                -0.5,
                0.1 * i as f32,
            );
        }
        b.build()
    }

    #[test]
    fn len_counts_rows() {
        assert_eq!(mk(5).len(), 5);
        assert!(SampleBatch::new(4).is_empty());
    }

    #[test]
    fn concat_preserves_order_and_len() {
        let a = mk(3);
        let b = mk(2);
        let c = SampleBatch::concat_all(&[a.clone(), b.clone()]);
        assert_eq!(c.len(), 5);
        assert_eq!(c.obs_row(0), a.obs_row(0));
        assert_eq!(c.obs_row(3), b.obs_row(0));
        assert_eq!(c.rewards[..3], a.rewards[..]);
    }

    #[test]
    fn slice_extracts_rows() {
        let b = mk(6);
        let s = b.slice(2, 5);
        assert_eq!(s.len(), 3);
        assert_eq!(s.obs_row(0), b.obs_row(2));
        assert_eq!(s.actions[0], b.actions[2]);
        assert_eq!(s.rewards, b.rewards[2..5].to_vec());
    }

    #[test]
    fn minibatches_drop_tail() {
        let b = mk(10);
        let mbs = b.minibatches(4);
        assert_eq!(mbs.len(), 2);
        assert!(mbs.iter().all(|m| m.len() == 4));
    }

    #[test]
    fn shuffle_is_permutation() {
        let b0 = mk(20);
        let mut b = b0.clone();
        b.shuffle(&mut Rng::new(1));
        assert_eq!(b.len(), 20);
        let mut r0 = b0.rewards.clone();
        let mut r1 = b.rewards.clone();
        r0.sort_by(f32::total_cmp);
        r1.sort_by(f32::total_cmp);
        assert_eq!(r0, r1);
        assert_ne!(b.rewards, b0.rewards); // overwhelmingly likely
        // Row integrity: obs[0] must equal i where rewards == i.
        for i in 0..20 {
            assert_eq!(b.obs_row(i)[0], b.rewards[i]);
        }
    }

    #[test]
    fn pad_extends_with_mask_zero() {
        let b = mk(3);
        let (p, mask) = b.pad_or_truncate(5);
        assert_eq!(p.len(), 5);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
        assert_eq!(p.obs_row(4), b.obs_row(2)); // repeat-last padding
    }

    #[test]
    fn truncate_keeps_prefix() {
        let b = mk(8);
        let (p, mask) = b.pad_or_truncate(4);
        assert_eq!(p.len(), 4);
        assert_eq!(mask, vec![1.0; 4]);
        assert_eq!(p.obs_row(3), b.obs_row(3));
    }

    #[test]
    fn pad_empty_batch_is_all_masked_zeros() {
        let b = SampleBatch::new(2);
        let (p, mask) = b.pad_or_truncate(3);
        assert_eq!(p.len(), 3);
        assert_eq!(mask, vec![0.0; 3]);
        assert!(p.obs.iter().all(|&x| x == 0.0));
    }
}
