//! Generalized Advantage Estimation (Schulman et al. 2016) — the standard
//! post-processing step for the policy-gradient family, run on the
//! rollout worker right after collection (so advantages use the
//! collecting policy's value predictions, matching RLlib).

use super::SampleBatch;

/// Fill `advantages` and `value_targets` in place.
///
/// `last_value` bootstraps the value beyond the fragment when the final
/// step did not terminate (fragment truncation); it is ignored when
/// `dones` ends the episode.  Advantages are left unnormalized — the
/// per-algorithm plan decides whether to standardize (PPO does, A2C
/// does not), mirroring RLlib's `Postprocessing` defaults.
pub fn compute_gae(
    batch: &mut SampleBatch,
    gamma: f32,
    lambda: f32,
    last_value: f32,
) {
    let n = batch.len();
    assert_eq!(batch.vf_preds.len(), n, "GAE needs vf_preds");
    batch.advantages.resize(n, 0.0);
    batch.value_targets.resize(n, 0.0);
    // One copy-on-write/ownership check per column, not per element:
    // grab the mutable slices once, then index plain slices in the loop.
    let advantages = &mut batch.advantages[..];
    let value_targets = &mut batch.value_targets[..];
    let mut gae = 0.0f32;
    for t in (0..n).rev() {
        let nonterminal = 1.0 - batch.dones[t];
        let next_value = if t + 1 < n {
            batch.vf_preds[t + 1]
        } else {
            last_value
        };
        let delta = batch.rewards[t] + gamma * nonterminal * next_value
            - batch.vf_preds[t];
        gae = delta + gamma * lambda * nonterminal * gae;
        advantages[t] = gae;
        value_targets[t] = gae + batch.vf_preds[t];
    }
}

/// Standardize advantages to zero mean / unit variance (PPO convention).
pub fn standardize_advantages(batch: &mut SampleBatch) {
    let n = batch.advantages.len();
    if n == 0 {
        return;
    }
    let mean: f32 = batch.advantages.iter().sum::<f32>() / n as f32;
    let var: f32 = batch
        .advantages
        .iter()
        .map(|a| (a - mean) * (a - mean))
        .sum::<f32>()
        / n as f32;
    let std = var.sqrt().max(1e-6);
    for a in &mut batch.advantages {
        *a = (*a - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn batch_with(rewards: &[f32], dones: &[f32], values: &[f32]) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(1);
        for i in 0..rewards.len() {
            b.add_step(&[0.0], 0, rewards[i], dones[i] > 0.5, 0.0, values[i]);
        }
        b.build()
    }

    #[test]
    fn terminal_step_ignores_bootstrap() {
        let mut b = batch_with(&[1.0], &[1.0], &[0.0]);
        compute_gae(&mut b, 0.99, 0.95, 1000.0);
        assert!((b.advantages[0] - 1.0).abs() < 1e-6);
        assert!((b.value_targets[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn truncated_step_uses_bootstrap() {
        let mut b = batch_with(&[1.0], &[0.0], &[0.0]);
        compute_gae(&mut b, 0.5, 1.0, 10.0);
        // delta = 1 + 0.5*10 - 0 = 6
        assert!((b.advantages[0] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn lambda_zero_is_one_step_td() {
        let mut b = batch_with(&[1.0, 1.0], &[0.0, 1.0], &[0.5, 0.25]);
        compute_gae(&mut b, 0.9, 0.0, 0.0);
        // t=1 terminal: delta = 1 - 0.25
        assert!((b.advantages[1] - 0.75).abs() < 1e-6);
        // t=0: delta = 1 + 0.9*0.25 - 0.5
        assert!((b.advantages[0] - 0.725).abs() < 1e-6);
    }

    #[test]
    fn lambda_one_is_discounted_return_minus_value() {
        let mut b = batch_with(&[1.0, 2.0, 3.0], &[0.0, 0.0, 1.0], &[0.1, 0.2, 0.3]);
        let g = 0.9f32;
        compute_gae(&mut b, g, 1.0, 0.0);
        let ret0 = 1.0 + g * 2.0 + g * g * 3.0;
        assert!((b.advantages[0] - (ret0 - 0.1)).abs() < 1e-5);
        assert!((b.value_targets[0] - ret0).abs() < 1e-5);
    }

    #[test]
    fn episode_boundary_resets_accumulator() {
        // Two one-step episodes; the second's GAE must not leak into the
        // first... and vice versa.
        let mut b = batch_with(&[5.0, 7.0], &[1.0, 1.0], &[0.0, 0.0]);
        compute_gae(&mut b, 0.99, 0.95, 0.0);
        assert!((b.advantages[0] - 5.0).abs() < 1e-6);
        assert!((b.advantages[1] - 7.0).abs() < 1e-6);
    }

    #[test]
    fn standardize_zero_mean_unit_std() {
        let mut b = batch_with(&[1.0, 2.0, 3.0, 4.0], &[0.0; 4], &[0.0; 4]);
        compute_gae(&mut b, 0.99, 0.95, 0.0);
        standardize_advantages(&mut b);
        let n = b.advantages.len() as f32;
        let mean: f32 = b.advantages.iter().sum::<f32>() / n;
        let var: f32 =
            b.advantages.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }
}
