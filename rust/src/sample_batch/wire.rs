//! `sample_batch::wire` — the crate's ONE binary codec substrate.
//!
//! Two durable byte formats exist in the crate: learner checkpoints
//! (`crate::checkpoint`) and episode-log frames (`crate::offline`).
//! Both are built from the helpers here — little-endian fixed-width
//! integers, packed LE `f32`/`i32` slices, CRC-32 (IEEE) framing — so
//! endianness, framing, and integrity checking cannot drift between
//! them.
//!
//! Layouts:
//!
//! * **Frame** (the episode-log record): `u32 payload_len | u32
//!   crc32(payload) | payload` — length-prefixed so a reader can skip a
//!   corrupt payload without losing framing, CRC'd so corruption is
//!   *detected* rather than decoded.
//! * **Batch payload** ([`encode_batch`]/[`decode_batch`]): `u32
//!   obs_dim`, then the ten [`SampleBatch`] columns in fixed schema
//!   order, each as `u32 count | packed LE values` (`count == 0` ⇒ the
//!   column is absent, mirroring the in-memory empty-column
//!   convention).  Column order: obs, actions (i32), rewards, dones,
//!   action_logp, vf_preds, advantages, value_targets, next_obs,
//!   weights.
//! * **Checkpoint** (v1, unchanged bytes): see `crate::checkpoint` —
//!   its reads/writes go through [`read_u32`]/[`read_u64`]/
//!   [`read_f32s`]/[`write_f32s`] here.

use std::io::{self, Read, Write};

use super::batch::SampleBatch;
use super::column::{FCol, ICol};

/// Bytes of the `len | crc` frame header.
pub const FRAME_HEADER_BYTES: usize = 8;

/// Upper bound on a sane frame payload (a fragment batch is KBs; 64 MiB
/// of claimed payload means the length word is garbage, not data).
pub const MAX_FRAME_BYTES: u32 = 64 * 1024 * 1024;

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, init/xorout 0xFFFFFFFF) — the zlib
// polynomial, table-driven, built once.
// ---------------------------------------------------------------------

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

/// CRC-32 (IEEE) of `data` — the integrity check under every log frame.
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Buffer-building primitives (encoder side).
// ---------------------------------------------------------------------

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append `count | packed LE f32s`.
pub fn put_f32_col(out: &mut Vec<u8>, vals: &[f32]) {
    put_u32(out, vals.len() as u32);
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Append `count | packed LE i32s`.
pub fn put_i32_col(out: &mut Vec<u8>, vals: &[i32]) {
    put_u32(out, vals.len() as u32);
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

// ---------------------------------------------------------------------
// Stream primitives (shared with the checkpoint format).
// ---------------------------------------------------------------------

pub fn read_u32(r: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read `n` packed LE f32s.
pub fn read_f32s(r: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read `n` packed LE i32s.
pub fn read_i32s(r: &mut impl Read, n: usize) -> io::Result<Vec<i32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write one tensor as a single contiguous packed-LE-f32 slice,
/// assembled in a caller-reused scratch buffer — the checkpoint path's
/// one-buffered-write-per-policy idiom, shared so the log writer's
/// framing uses identical byte packing.
pub fn write_f32s(
    w: &mut impl Write,
    vals: &[f32],
    scratch: &mut Vec<u8>,
) -> io::Result<()> {
    scratch.clear();
    scratch.reserve(vals.len() * 4);
    for v in vals {
        scratch.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(scratch)
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

// ---------------------------------------------------------------------
// The batch payload codec.
// ---------------------------------------------------------------------

/// Encode `batch` into the wire payload layout (appended to `out`,
/// which callers reuse across frames — steady-state logging allocates
/// only on capacity growth).
pub fn encode_batch(batch: &SampleBatch, out: &mut Vec<u8>) {
    put_u32(out, batch.obs_dim as u32);
    put_f32_col(out, &batch.obs);
    put_i32_col(out, &batch.actions);
    put_f32_col(out, &batch.rewards);
    put_f32_col(out, &batch.dones);
    put_f32_col(out, &batch.action_logp);
    put_f32_col(out, &batch.vf_preds);
    put_f32_col(out, &batch.advantages);
    put_f32_col(out, &batch.value_targets);
    put_f32_col(out, &batch.next_obs);
    put_f32_col(out, &batch.weights);
}

fn read_f32_col(r: &mut impl Read, max: usize) -> io::Result<Vec<f32>> {
    let n = read_u32(r)? as usize;
    if n > max {
        return Err(bad(format!("implausible column length {n}")));
    }
    read_f32s(r, n)
}

/// Decode one batch payload (the inverse of [`encode_batch`]).  Every
/// length word is bounds-checked against the payload size before
/// allocation, so a corrupt-but-CRC-colliding payload errors instead of
/// OOMing the reader.
pub fn decode_batch(payload: &[u8]) -> io::Result<SampleBatch> {
    let max = payload.len() / 4 + 1;
    let r = &mut &payload[..];
    let obs_dim = read_u32(r)? as usize;
    let obs = read_f32_col(r, max)?;
    let n_actions = read_u32(r)? as usize;
    if n_actions > max {
        return Err(bad(format!("implausible column length {n_actions}")));
    }
    let actions = read_i32s(r, n_actions)?;
    let rewards = read_f32_col(r, max)?;
    let dones = read_f32_col(r, max)?;
    let action_logp = read_f32_col(r, max)?;
    let vf_preds = read_f32_col(r, max)?;
    let advantages = read_f32_col(r, max)?;
    let value_targets = read_f32_col(r, max)?;
    let next_obs = read_f32_col(r, max)?;
    let weights = read_f32_col(r, max)?;
    if !r.is_empty() {
        return Err(bad(format!("{} trailing payload bytes", r.len())));
    }
    if obs_dim == 0 && !obs.is_empty() {
        return Err(bad("obs present with obs_dim 0"));
    }
    if obs_dim != 0 && obs.len() % obs_dim != 0 {
        return Err(bad(format!(
            "obs length {} not a multiple of obs_dim {obs_dim}",
            obs.len()
        )));
    }
    Ok(SampleBatch {
        obs: FCol::from_vec(obs),
        obs_dim,
        actions: ICol::from_vec(actions),
        rewards: FCol::from_vec(rewards),
        dones: FCol::from_vec(dones),
        action_logp: FCol::from_vec(action_logp),
        vf_preds: FCol::from_vec(vf_preds),
        advantages: FCol::from_vec(advantages),
        value_targets: FCol::from_vec(value_targets),
        next_obs: FCol::from_vec(next_obs),
        weights: FCol::from_vec(weights),
    })
}

// ---------------------------------------------------------------------
// The frame codec (length-prefixed + CRC).
// ---------------------------------------------------------------------

/// Wrap `payload` into one log frame: `len | crc | payload`, appended
/// to `out`.
pub fn encode_frame(payload: &[u8], out: &mut Vec<u8>) {
    put_u32(out, payload.len() as u32);
    put_u32(out, crc32(payload));
    out.extend_from_slice(payload);
}

/// What [`try_decode_frame`] saw at a buffer position.
#[derive(Debug, PartialEq)]
pub enum FrameStatus {
    /// A complete, CRC-clean frame: (payload range start, end, total
    /// frame bytes consumed).
    Ok { payload_start: usize, payload_end: usize, consumed: usize },
    /// Not enough bytes yet for the header or the claimed payload —
    /// the writer may still be appending; re-try with more bytes.
    Incomplete,
    /// Header present but the CRC does not match the payload: skip
    /// `consumed` bytes (framing is intact — the length word passed the
    /// plausibility bound).
    BadCrc { consumed: usize },
    /// The length word itself is implausible (> [`MAX_FRAME_BYTES`]):
    /// framing is lost and the rest of this segment cannot be trusted.
    BadLength,
}

/// Inspect `buf` for one frame starting at offset 0 without copying.
pub fn try_decode_frame(buf: &[u8]) -> FrameStatus {
    if buf.len() < FRAME_HEADER_BYTES {
        return FrameStatus::Incomplete;
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len > MAX_FRAME_BYTES {
        return FrameStatus::BadLength;
    }
    let crc = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let end = FRAME_HEADER_BYTES + len as usize;
    if buf.len() < end {
        return FrameStatus::Incomplete;
    }
    let payload = &buf[FRAME_HEADER_BYTES..end];
    if crc32(payload) != crc {
        return FrameStatus::BadCrc { consumed: end };
    }
    FrameStatus::Ok {
        payload_start: FRAME_HEADER_BYTES,
        payload_end: end,
        consumed: end,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    #[test]
    fn crc32_matches_ieee_check_value() {
        // The canonical CRC-32/ISO-HDLC check vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn transitions_batch(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(3);
        for i in 0..n {
            b.add_transition(
                &[i as f32, 1.0, -2.0],
                (i % 2) as i32,
                0.5 * i as f32,
                &[i as f32 + 1.0, 1.0, -2.0],
                i == n - 1,
            );
        }
        b.build()
    }

    #[test]
    fn batch_roundtrip_transitions_schema() {
        let batch = transitions_batch(5);
        let mut payload = Vec::new();
        encode_batch(&batch, &mut payload);
        let back = decode_batch(&payload).unwrap();
        assert_eq!(back, batch);
        // Optional columns absent on both sides.
        assert!(back.action_logp.is_empty());
        assert!(back.advantages.is_empty());
    }

    #[test]
    fn batch_roundtrip_all_columns() {
        let mut b = SampleBatchBuilder::new(2);
        b.add_step_with_next(&[0.0, 1.0], 1, 1.0, &[1.0, 2.0], false, -0.7, 0.3);
        b.add_step_with_next(&[1.0, 2.0], 0, 0.0, &[2.0, 3.0], true, -0.1, 0.9);
        let mut batch = b.build();
        batch.advantages = vec![0.25, -0.5].into();
        batch.value_targets = vec![1.0, 2.0].into();
        batch.weights = vec![0.5, 2.0].into();
        let mut payload = Vec::new();
        encode_batch(&batch, &mut payload);
        assert_eq!(decode_batch(&payload).unwrap(), batch);
    }

    #[test]
    fn empty_batch_roundtrip() {
        let batch = SampleBatch::new(4);
        let mut payload = Vec::new();
        encode_batch(&batch, &mut payload);
        let back = decode_batch(&payload).unwrap();
        assert_eq!(back, batch);
        assert_eq!(back.len(), 0);
    }

    #[test]
    fn decode_rejects_truncation_and_trailing_garbage() {
        let mut payload = Vec::new();
        encode_batch(&transitions_batch(3), &mut payload);
        assert!(decode_batch(&payload[..payload.len() - 1]).is_err());
        assert!(decode_batch(&payload[..5]).is_err());
        let mut extra = payload.clone();
        extra.push(0xAB);
        assert!(decode_batch(&extra).is_err());
    }

    #[test]
    fn decode_rejects_implausible_column_length() {
        // A length word far beyond the payload must error before
        // allocating.
        let mut payload = Vec::new();
        put_u32(&mut payload, 4); // obs_dim
        put_u32(&mut payload, u32::MAX); // obs count: garbage
        assert!(decode_batch(&payload).is_err());
    }

    #[test]
    fn decode_rejects_ragged_obs() {
        let mut payload = Vec::new();
        put_u32(&mut payload, 4); // obs_dim
        put_f32_col(&mut payload, &[0.0; 6]); // 6 % 4 != 0
        for _ in 0..9 {
            put_u32(&mut payload, 0); // remaining columns empty
        }
        assert!(decode_batch(&payload).is_err());
    }

    #[test]
    fn frame_roundtrip_and_corruption_detection() {
        let mut payload = Vec::new();
        encode_batch(&transitions_batch(4), &mut payload);
        let mut frame = Vec::new();
        encode_frame(&payload, &mut frame);
        match try_decode_frame(&frame) {
            FrameStatus::Ok { payload_start, payload_end, consumed } => {
                assert_eq!(consumed, frame.len());
                assert_eq!(&frame[payload_start..payload_end], &payload[..]);
            }
            other => panic!("expected Ok, got {other:?}"),
        }
        // Truncated tail: incomplete, not an error.
        assert_eq!(
            try_decode_frame(&frame[..frame.len() - 3]),
            FrameStatus::Incomplete
        );
        assert_eq!(try_decode_frame(&frame[..4]), FrameStatus::Incomplete);
        // One flipped payload byte: BadCrc with intact framing.
        let mut corrupt = frame.clone();
        let n = corrupt.len();
        corrupt[n - 1] ^= 0x40;
        assert_eq!(
            try_decode_frame(&corrupt),
            FrameStatus::BadCrc { consumed: frame.len() }
        );
        // Garbage length word: framing lost.
        let mut torn = frame;
        torn[3] = 0xFF;
        assert_eq!(try_decode_frame(&torn), FrameStatus::BadLength);
    }

    #[test]
    fn stream_helpers_roundtrip() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 7);
        let mut scratch = Vec::new();
        write_f32s(&mut buf, &[1.5, -2.25, 1e9], &mut scratch).unwrap();
        put_i32_col(&mut buf, &[-1, 0, i32::MAX]);
        let r = &mut &buf[..];
        assert_eq!(read_u32(r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64(r).unwrap(), u64::MAX - 7);
        assert_eq!(read_f32s(r, 3).unwrap(), vec![1.5, -2.25, 1e9]);
        let n = read_u32(r).unwrap() as usize;
        assert_eq!(read_i32s(r, n).unwrap(), vec![-1, 0, i32::MAX]);
        assert!(r.is_empty());
    }
}
