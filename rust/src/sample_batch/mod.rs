//! Columnar experience batches — the data items flowing through every
//! dataflow edge (the `T` in `ParIter[T]` / `Iter[T]`).
//!
//! Mirrors RLlib's `SampleBatch` / `MultiAgentBatch`, with a zero-copy
//! twist: columns are [`FCol`]/[`ICol`] — `Arc`-shared flat storage plus
//! an (offset, len) window — so `slice`/`minibatches` return views,
//! `clone` is a reference-count bump, and marshaling into XLA literals
//! stays a flat-slice operation with no per-row allocation.  Mutation is
//! copy-on-write per column, which keeps value semantics at every
//! operator boundary while making the steady-state experience path
//! (concat → slice → minibatch → learner) allocation-free.

mod batch;
mod builder;
mod column;
mod gae;
mod multi_agent;
pub mod wire;

pub use batch::SampleBatch;
pub use builder::SampleBatchBuilder;
pub use column::{Col, FCol, ICol};
pub use gae::{compute_gae, standardize_advantages};
pub use multi_agent::MultiAgentBatch;
