//! Columnar experience batches — the data items flowing through every
//! dataflow edge (the `T` in `ParIter[T]` / `Iter[T]`).
//!
//! Mirrors RLlib's `SampleBatch` / `MultiAgentBatch`: column-oriented so
//! that concat/slice/shuffle and marshaling into XLA literals are flat
//! `Vec<f32>` operations with no per-row allocation.

mod batch;
mod builder;
mod gae;
mod multi_agent;

pub use batch::SampleBatch;
pub use builder::SampleBatchBuilder;
pub use gae::{compute_gae, standardize_advantages};
pub use multi_agent::MultiAgentBatch;
