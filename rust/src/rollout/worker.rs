//! Single-policy rollout worker + the local/remote `WorkerSet`.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::actor::{
    faults, spawn_group, ActorHandle, FaultCounters, FaultStats,
    ShardRegistry, WeightCastStats, WeightCaster, DEFAULT_CAST_WATERMARK,
};
use crate::env::Env;
use crate::metrics::EpisodeRecord;
use crate::policy::{ActionOutput, Gradients, Policy};
use crate::sample_batch::{SampleBatch, SampleBatchBuilder};
use crate::util::Backoff;

/// What the worker records per transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CollectMode {
    /// On-policy: logp + value predictions, postprocessed (GAE) at
    /// fragment end (A2C/A3C/PPO).
    OnPolicy,
    /// On-policy + next_obs (IMPALA learner batches bootstrap from the
    /// fragment's trailing observation).
    OnPolicyWithNextObs,
    /// Raw (s, a, r, s', done) transitions for replay (DQN/Ape-X).
    Transitions,
    /// Transitions plus the behavior policy's action logp — the schema
    /// episode logging records so `ops::ope_estimate` can importance-
    /// weight against the logged behavior policy.
    TransitionsWithLogp,
}

/// A rollout worker: a vectorized set of env instances stepped in
/// lockstep with one policy.  Lives on an actor thread; every public
/// method is a "remote method" in the paper's sense.
pub struct RolloutWorker {
    envs: Vec<Box<dyn Env>>,
    pub policy: Box<dyn Policy>,
    mode: CollectMode,
    fragment: usize,
    /// Flat `[n_envs, obs_dim]` SoA buffer of current observations —
    /// fed to `compute_actions` directly and updated in place by
    /// `Env::step_into` / `reset_into`, so the steady-state sampling
    /// loop performs no per-env-per-step heap allocation.
    obs: Vec<f32>,
    builders: Vec<SampleBatchBuilder>,
    ep_reward: Vec<f64>,
    ep_len: Vec<usize>,
    episodes: Vec<EpisodeRecord>,
    pub num_steps_sampled: usize,
    /// One-row staging for an env's next observation: the builder needs
    /// the env's *current* row intact while recording the transition.
    next_obs_scratch: Vec<f32>,
    /// Reused output buffer for batched action computation.
    actions_scratch: Vec<ActionOutput>,
    /// Reused output buffer for the per-fragment GAE bootstrap forward.
    values_scratch: Vec<f32>,
    /// Optional episode-log sink: every sampled fragment is also
    /// appended as one durable CRC-framed record (`offline` subsystem).
    log_sink: Option<crate::offline::EpisodeLogWriter>,
}

impl RolloutWorker {
    pub fn new(
        envs: Vec<Box<dyn Env>>,
        policy: Box<dyn Policy>,
        fragment: usize,
        mode: CollectMode,
    ) -> Self {
        assert!(!envs.is_empty());
        let obs_dim = envs[0].obs_dim();
        let mut envs = envs;
        let n = envs.len();
        let mut obs = vec![0.0; n * obs_dim];
        for (e, env) in envs.iter_mut().enumerate() {
            env.reset_into(&mut obs[e * obs_dim..(e + 1) * obs_dim]);
        }
        RolloutWorker {
            builders: (0..n)
                .map(|_| SampleBatchBuilder::with_capacity(obs_dim, fragment))
                .collect(),
            envs,
            policy,
            mode,
            fragment,
            obs,
            ep_reward: vec![0.0; n],
            ep_len: vec![0; n],
            episodes: Vec::new(),
            num_steps_sampled: 0,
            next_obs_scratch: vec![0.0; obs_dim],
            actions_scratch: Vec::with_capacity(n),
            values_scratch: Vec::with_capacity(n),
            log_sink: None,
        }
    }

    /// Tap this worker's sampled fragments into an episode-log stream:
    /// every `sample()` return value is also appended to `sink` as one
    /// durable frame.  A write failure is counted on the writer, never
    /// surfaced into the sampling path — logging is a tap, not a gate.
    pub fn set_log_sink(&mut self, sink: crate::offline::EpisodeLogWriter) {
        self.log_sink = Some(sink);
    }

    pub fn num_envs(&self) -> usize {
        self.envs.len()
    }

    pub fn obs_dim(&self) -> usize {
        self.envs[0].obs_dim()
    }

    /// Collect one fragment: `fragment` steps from every env, post-
    /// processed per env segment (GAE bootstrap from the policy's value
    /// of the trailing observation).  The paper's `worker.sample()`.
    // flowlint: hot-path (allocs amortize to zero per sample; pinned by tests/rollout_alloc.rs)
    pub fn sample(&mut self) -> SampleBatch {
        faults::failpoint(faults::SITE_ROLLOUT_SAMPLE);
        let n_envs = self.envs.len();
        let obs_dim = self.obs_dim();
        let mut actions = std::mem::take(&mut self.actions_scratch);
        for _ in 0..self.fragment {
            // Batched action computation straight off the flat obs
            // buffer; the action buffer's capacity is reused per step.
            self.policy.compute_actions_into(&self.obs, n_envs, &mut actions);
            for e in 0..n_envs {
                let a = actions[e];
                let row = e * obs_dim..(e + 1) * obs_dim;
                let (reward, done) = self.envs[e]
                    .step_into(a.action, &mut self.next_obs_scratch);
                // flowlint: allow(hot-path-alloc) -- Range clone is a stack copy, not a heap allocation
                let cur = &self.obs[row.clone()];
                match self.mode {
                    CollectMode::OnPolicy => self.builders[e].add_step(
                        cur, a.action, reward, done, a.logp, a.value,
                    ),
                    CollectMode::OnPolicyWithNextObs => {
                        self.builders[e].add_step_with_next(
                            cur, a.action, reward, &self.next_obs_scratch,
                            done, a.logp, a.value,
                        )
                    }
                    CollectMode::Transitions => self.builders[e]
                        .add_transition(
                            cur, a.action, reward, &self.next_obs_scratch,
                            done,
                        ),
                    CollectMode::TransitionsWithLogp => self.builders[e]
                        .add_transition_with_logp(
                            cur, a.action, reward, &self.next_obs_scratch,
                            done, a.logp,
                        ),
                }
                self.ep_reward[e] += reward as f64;
                self.ep_len[e] += 1;
                self.num_steps_sampled += 1;
                if done {
                    self.episodes.push(EpisodeRecord {
                        reward: self.ep_reward[e],
                        length: self.ep_len[e],
                    });
                    self.ep_reward[e] = 0.0;
                    self.ep_len[e] = 0;
                    self.envs[e].reset_into(&mut self.obs[row]);
                } else {
                    self.obs[row].copy_from_slice(&self.next_obs_scratch);
                }
            }
        }
        self.actions_scratch = actions;
        // Per-env segments: postprocess (GAE) with a bootstrap value of
        // the trailing obs, then concatenate env-major.  All bootstrap
        // values come from one batched forward (perf O2) straight off
        // the flat obs buffer, into a scratch reused across fragments.
        let mut last_values = std::mem::take(&mut self.values_scratch);
        self.policy.values_into(&self.obs, n_envs, &mut last_values);
        let mut segments = Vec::with_capacity(n_envs);
        for e in 0..n_envs {
            let mut seg = self.builders[e].build();
            self.policy.postprocess(&mut seg, last_values[e]);
            segments.push(seg);
        }
        self.values_scratch = last_values;
        let batch = SampleBatch::concat_all(&segments);
        if let Some(sink) = self.log_sink.as_mut() {
            // Failed appends are counted on the writer; sampling never
            // stalls on the log tap.
            let _ = sink.append(&batch);
        }
        batch
    }

    /// The paper's `worker.compute_gradients(worker.sample.remote())`
    /// fusion: sample a fragment and compute gradients locally (A3C).
    pub fn sample_and_compute_gradients(&mut self) -> Gradients {
        let batch = self.sample();
        self.policy.compute_gradients(&batch)
    }

    pub fn compute_gradients(&mut self, batch: &SampleBatch) -> Gradients {
        self.policy.compute_gradients(batch)
    }

    pub fn apply_gradients(&mut self, grads: &Gradients) {
        self.policy.apply_gradients(grads);
    }

    pub fn learn_on_batch(
        &mut self,
        batch: &SampleBatch,
    ) -> std::collections::BTreeMap<String, f64> {
        self.policy.learn_on_batch(batch)
    }

    /// Learn and report per-row |TD| errors (DQN family; used by
    /// `UpdateReplayPriorities`).
    pub fn learn_and_td(
        &mut self,
        batch: &SampleBatch,
    ) -> (std::collections::BTreeMap<String, f64>, Vec<f32>) {
        let stats = self.policy.learn_on_batch(batch);
        let td = self.policy.td_abs().unwrap_or_default();
        (stats, td)
    }

    /// Resample the task of every env (meta-learning workers) and reset.
    pub fn sample_task(&mut self) {
        let obs_dim = self.obs_dim();
        for (e, env) in self.envs.iter_mut().enumerate() {
            env.sample_task();
            env.reset_into(&mut self.obs[e * obs_dim..(e + 1) * obs_dim]);
            self.ep_reward[e] = 0.0;
            self.ep_len[e] = 0;
        }
    }

    pub fn get_weights(&self) -> Vec<f32> {
        self.policy.get_weights()
    }

    pub fn set_weights(&mut self, weights: &[f32]) {
        self.policy.set_weights(weights);
    }

    /// Drain finished-episode records (for metrics reporting).
    pub fn pop_episodes(&mut self) -> Vec<EpisodeRecord> {
        std::mem::take(&mut self.episodes)
    }
}

/// The worker factory a [`WorkerSet`] retains so dead workers can be
/// respawned in place (and new capacity spawned by `add_worker`).
type WorkerFactory<W> =
    Box<dyn FnMut(usize) -> Box<dyn FnOnce() -> W + Send> + Send>;

/// The spawn-and-sync protocol of a [`WorkerSet`]: push the learner's
/// current state (its policy weights) into a freshly spawned worker's
/// mailbox **before the worker is published** — FIFO per mailbox
/// guarantees the applies run before any gather dispatch reaches it.
/// `(local, fresh)`; errors when the learner is unavailable (a worker
/// spawned with blank weights would sample garbage).
type SyncFn<W> = Box<
    dyn Fn(
            &ActorHandle<W>,
            &ActorHandle<W>,
        ) -> crate::util::error::Result<()>
        + Send
        + Sync,
>;

/// Lifetime scale-event counters for one [`WorkerSet`], shared with the
/// metrics-reporting operators (an `Arc` of these rides into the
/// reporting closure, so scale events taken after plan build still show
/// up in every `TrainResult`).
#[derive(Debug, Default)]
pub struct ScaleCounters {
    added: std::sync::atomic::AtomicU64,
    removed: std::sync::atomic::AtomicU64,
}

impl ScaleCounters {
    fn note_added(&self) {
        self.added.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    fn note_removed(&self) {
        self.removed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Snapshot against the registry's current membership.
    pub fn stats(&self, live: usize, slots: usize) -> ScaleStats {
        ScaleStats {
            added: self.added.load(std::sync::atomic::Ordering::Relaxed),
            removed: self.removed.load(std::sync::atomic::Ordering::Relaxed),
            live,
            slots,
        }
    }
}

/// Point-in-time scale summary attached to `TrainResult::scale`:
/// workers added/removed over the set's lifetime plus the registry's
/// current live membership and total slot (tag-space) usage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScaleStats {
    /// Workers added (`add_worker`/`scale_to` upward), lifetime.
    pub added: u64,
    /// Workers removed (`remove_worker`/`scale_to` downward), lifetime.
    pub removed: u64,
    /// Live (non-tombstoned) remote workers right now.
    pub live: usize,
    /// Registry slots consumed (monotone; tombstones are reused before
    /// new slots are grown).
    pub slots: usize,
}

/// Bounded-backoff restart policy for [`WorkerSet::restart_dead_with_policy`].
///
/// Unbounded in-place respawn turns a crash-looping worker (bad env
/// seed, poisoned weights, injected fault) into an infinite
/// spawn-crash-spawn cycle that burns an actor thread's setup cost per
/// iteration and floods the registry with epoch bumps.  The policy
/// bounds it three ways:
///
/// * **Backoff** — restart `k` of a slot waits `backoff_base * 2^k`
///   (capped at `backoff_cap`) after restart `k-1`; a death inside the
///   window is *deferred*, not serviced, so the caller's supervision
///   loop stays non-blocking.
/// * **Budget + breaker** — after `max_restarts` restarts without a
///   quiet period, the breaker trips: the slot is tombstoned (exactly
///   like [`WorkerSet::remove_worker`], so gathers drain it and its
///   queue budget is reclaimed) and the lost capacity is left to the
///   autoscaler / a later `add_worker` to backfill with a fresh budget.
/// * **Amnesty** — a slot that stayed healthy for `reset_after` since
///   its last restart gets its budget and backoff refunded: rare
///   unrelated crashes never accumulate into a breaker trip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPolicy {
    /// Restarts allowed per slot before the breaker trips.
    pub max_restarts: u32,
    /// Delay before the first re-restart; doubles per restart.
    pub backoff_base: Duration,
    /// Upper bound on the per-restart delay.
    pub backoff_cap: Duration,
    /// Healthy time since the last restart that refunds the budget.
    pub reset_after: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 3,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_secs(2),
            reset_after: Duration::from_secs(30),
        }
    }
}

/// What one [`WorkerSet::restart_dead_with_policy`] pass did, per slot.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RestartReport {
    /// Slots respawned and republished this pass.
    pub restarted: Vec<usize>,
    /// Dead slots inside their backoff window — call again later.
    pub deferred: Vec<usize>,
    /// Slots whose breaker tripped this pass: tombstoned, not respawned.
    pub tripped: Vec<usize>,
}

impl RestartReport {
    /// True when the pass neither acted nor left anything pending.
    pub fn is_empty(&self) -> bool {
        self.restarted.is_empty()
            && self.deferred.is_empty()
            && self.tripped.is_empty()
    }
}

/// Per-slot restart ledger behind [`RestartPolicy`].
struct SlotRestart {
    backoff: Backoff,
    restarts: u32,
    last_restart: Instant,
    next_attempt: Instant,
}

impl SlotRestart {
    fn new(policy: &RestartPolicy) -> Self {
        let now = Instant::now();
        SlotRestart {
            backoff: Backoff::new(policy.backoff_base, policy.backoff_cap),
            restarts: 0,
            last_restart: now,
            // The first restart of a slot is immediate.
            next_attempt: now,
        }
    }
}

/// The local (learner) worker plus remote rollout workers — RLlib's
/// `WorkerSet`.  All of them are actors; "local" only means "the one
/// the trainer ops message for learning".
///
/// The remotes live behind a [`ShardRegistry`]: dataflow plans built
/// over the set (`ops::parallel_rollouts_from`, or any
/// `ParIter::from_registry(set.registry().clone(), ..)`) resolve shard
/// index -> handle through it on every dispatch, so a remote replaced
/// by [`WorkerSet::restart_dead`] rejoins **running** gathers live —
/// the paper's fault-tolerance model (§3) without a plan rebuild:
/// rollout workers hold no durable state, recovery is "make a new one,
/// hand it the learner's weights, publish it".
///
/// Weight broadcasts go through shared [`WeightCaster`]s: versioned
/// casts with drop-oldest coalescing and watermark-gated load shedding,
/// so a slow or dying remote can never stall the learner behind a
/// mailbox full of superseded parameter vectors.
///
/// **Genericity.** The scale machinery (registry, factory respawn,
/// spawn-and-sync, caster lane attach, scale counters) is generic over
/// the worker state type `W`: [`WorkerSet::with_protocol`] builds a set
/// for any actor type given a *sync protocol* (how to push the
/// learner's state into a fresh worker before it is published).
/// [`WorkerSet::new`] is the single-policy `RolloutWorker`
/// instantiation; `algorithms::ma_worker_set` builds the
/// [`MultiAgentRolloutWorker`](crate::rollout::MultiAgentRolloutWorker)
/// one (per-policy weight pushes, per-policy casters) — multi-agent
/// plans get the same `scale_to`/restart/autoscale machinery.
///
/// **Cloning.** A `WorkerSet` clone shares all state (registry,
/// factory, casters, counters) — the handle semantics of
/// [`ActorHandle`], so reporting operators and autoscaler drivers can
/// hold the set inside a plan closure.
pub struct WorkerSet<W: 'static = RolloutWorker> {
    pub local: ActorHandle<W>,
    inner: std::sync::Arc<SetInner<W>>,
}

struct SetInner<W: 'static> {
    local: ActorHandle<W>,
    /// Actor-name prefix for respawned/added remotes ("worker" ->
    /// "worker-3").
    remote_prefix: String,
    registry: ShardRegistry<W>,
    /// Casters whose lanes must be attached when a worker is spawned
    /// into a slot (the single default caster for `RolloutWorker` sets;
    /// one per policy for multi-agent sets — see
    /// [`WorkerSet::register_caster`]).
    casters: std::sync::Mutex<Vec<std::sync::Arc<WeightCaster<W>>>>,
    sync: SyncFn<W>,
    factory: std::sync::Mutex<WorkerFactory<W>>,
    scale: std::sync::Arc<ScaleCounters>,
    /// Suspect/forced-restart/breaker-trip totals, shared with deadline
    /// supervision (`DeadlineSupervision::with_counters`) and the
    /// metrics reporting operators.
    faults: std::sync::Arc<FaultCounters>,
    /// Per-slot [`RestartPolicy`] ledgers (guarded by `factory`'s lock
    /// discipline: only taken while serialized on a scale operation).
    restart_state: std::sync::Mutex<HashMap<usize, SlotRestart>>,
}

impl<W: 'static> Clone for WorkerSet<W> {
    fn clone(&self) -> Self {
        WorkerSet { local: self.local.clone(), inner: self.inner.clone() }
    }
}

impl<W: 'static> WorkerSet<W> {
    /// Spawn 1 local + `num_remote` remote workers of any actor type.
    /// `make(i)` builds worker `i` on its actor thread (i = 0 is the
    /// local/learner worker); `sync(local, fresh)` pushes the learner's
    /// current state into a fresh worker's mailbox (before publication)
    /// and is what `restart_dead`/`add_worker` run on every spawn.
    /// Actors are named `{local_name}` and `{remote_prefix}-{i}`.
    ///
    /// No caster is registered; callers that broadcast weights register
    /// theirs with [`WorkerSet::register_caster`] so replacements'
    /// lanes are attached on spawn.
    pub fn with_protocol(
        local_name: &str,
        remote_prefix: &str,
        num_remote: usize,
        make: impl FnMut(usize) -> Box<dyn FnOnce() -> W + Send>
            + Send
            + 'static,
        sync: impl Fn(
                &ActorHandle<W>,
                &ActorHandle<W>,
            ) -> crate::util::error::Result<()>
            + Send
            + Sync
            + 'static,
    ) -> Self {
        let mut make: WorkerFactory<W> = Box::new(make);
        let local = {
            let init = make(0);
            ActorHandle::spawn(local_name, move || init())
        };
        let remotes = spawn_group(remote_prefix, num_remote, |i| make(i + 1));
        let registry = ShardRegistry::new(remotes);
        WorkerSet {
            local: local.clone(),
            inner: std::sync::Arc::new(SetInner {
                local,
                remote_prefix: remote_prefix.to_string(),
                registry,
                casters: std::sync::Mutex::new(Vec::new()),
                sync: Box::new(sync),
                factory: std::sync::Mutex::new(make),
                scale: std::sync::Arc::new(ScaleCounters::default()),
                faults: std::sync::Arc::new(FaultCounters::default()),
                restart_state: std::sync::Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Register a weight caster whose lane should be attached whenever
    /// this set spawns a worker into a slot (`restart_dead` /
    /// `add_worker`): the caster version read *before* the sync
    /// protocol fetches the learner's state is marked applied, so a
    /// broadcast racing the fetch is redelivered rather than silently
    /// skipped.
    pub fn register_caster(
        &self,
        caster: std::sync::Arc<WeightCaster<W>>,
    ) {
        self.inner.casters.lock().unwrap().push(caster);
    }

    /// Counters of the set's **sole** broadcast lane, when it has
    /// exactly one registered caster (the default lane of
    /// [`WorkerSet::new`]).  `None` on caster-less protocol sets and on
    /// multi-caster (per-policy) sets, whose lanes version and shed
    /// independently — a single `WeightCastStats` would misattribute
    /// them.  The non-panicking gauge `ops::Reporting` attaches to
    /// `TrainResult::weight_casts`.
    pub fn sole_caster_stats(&self) -> Option<WeightCastStats> {
        let casters = self.inner.casters.lock().unwrap();
        if casters.len() == 1 {
            Some(casters[0].stats())
        } else {
            None
        }
    }

    /// The elastic shard table behind the remotes.  Plans that gather
    /// through a clone of it adopt restarted workers live.
    pub fn registry(&self) -> &ShardRegistry<W> {
        &self.inner.registry
    }

    /// Registry slots consumed (tombstoned slots included) — the bound
    /// on remote indices.  See [`Self::num_live_remotes`] for current
    /// live capacity.
    pub fn num_remotes(&self) -> usize {
        self.inner.registry.len()
    }

    /// Live (non-tombstoned) remote workers — the number `scale_to`
    /// targets.
    pub fn num_live_remotes(&self) -> usize {
        self.inner.registry.num_live()
    }

    /// Snapshot of the current incarnation behind every **live** remote
    /// index.  For plan-building prefer gathering through
    /// [`Self::registry`] — a snapshot goes stale at the next
    /// `restart_dead`/`scale_to`.
    pub fn remotes(&self) -> Vec<ActorHandle<W>> {
        self.inner.registry.handles()
    }

    /// The current incarnation behind remote index `i`, or `None` if
    /// the slot was tombstoned by [`Self::remove_worker`] — a
    /// scaled-down set must never panic its driver for asking.
    pub fn remote(&self, i: usize) -> Option<ActorHandle<W>> {
        self.inner.registry.get_live(i).map(|(h, _)| h)
    }

    /// The shared lifetime scale counters (cloned into the metrics
    /// reporting closure so `TrainResult::scale` reflects events taken
    /// after plan build).
    pub fn scale_counters(&self) -> std::sync::Arc<ScaleCounters> {
        self.inner.scale.clone()
    }

    /// Current scale summary: lifetime add/remove counts + live/slot
    /// membership.
    pub fn scale_stats(&self) -> ScaleStats {
        self.inner
            .scale
            .stats(self.inner.registry.num_live(), self.inner.registry.len())
    }

    /// Indices of remotes whose current incarnation has panicked.
    pub fn poisoned_indices(&self) -> Vec<usize> {
        self.inner.registry.poisoned_indices()
    }

    /// The one spawn-and-sync step both recovery (`restart_dead`) and
    /// scale-up (`add_worker`) share: read every registered caster's
    /// version (BEFORE the sync protocol fetches learner state — see
    /// `WeightCaster::attach`), spawn slot `idx`'s incarnation from the
    /// retained factory (factory index `idx + 1`; 0 is the local
    /// worker), and run the sync protocol so the learner's state is in
    /// the fresh mailbox before anything else.  Returns the handle plus
    /// the (caster, version) attach list for after publication.
    #[allow(clippy::type_complexity)]
    fn spawn_synced(
        &self,
        factory: &mut WorkerFactory<W>,
        idx: usize,
    ) -> crate::util::error::Result<(
        ActorHandle<W>,
        Vec<(std::sync::Arc<WeightCaster<W>>, u64)>,
    )> {
        // Probe the learner BEFORE invoking the factory: spawning (and
        // immediately discarding) a full worker per call just to learn
        // the learner is gone would waste an actor thread + init every
        // retry.  The sync protocol below remains the authoritative
        // check for the probe-then-die race.
        if self.inner.local.is_poisoned() {
            return Err(crate::util::error::Error::msg(
                "learner is dead (poisoned)",
            ));
        }
        let attach: Vec<_> = self
            .inner
            .casters
            .lock()
            .unwrap()
            .iter()
            .map(|c| (c.clone(), c.stats().version))
            .collect();
        let init = (&mut **factory)(idx + 1);
        let fresh = ActorHandle::spawn(
            &format!("{}-{idx}", self.inner.remote_prefix),
            move || init(),
        );
        (self.inner.sync)(&self.inner.local, &fresh)?;
        Ok((fresh, attach))
    }

    /// Respawn every poisoned remote from the retained factory, run the
    /// sync protocol (the learner's current state lands in the fresh
    /// mailbox first), **publish it into the registry** — running
    /// gathers adopt it on their next dispatch (credits held by the
    /// dead incarnation retire via its epoch-tagged death notices) —
    /// and return the restarted indices.
    ///
    /// If the **learner** (local) worker is itself dead, nothing is
    /// restarted and an empty list is returned: replacements without
    /// the learner's weights would sample garbage, and learner recovery
    /// is the checkpoint layer's job, not respawn-blank.  (Note that a
    /// just-killed worker publishes its poisoned flag asynchronously —
    /// see `ActorHandle::await_poisoned`.)
    pub fn restart_dead(&self) -> Vec<usize> {
        let dead = self.poisoned_indices();
        if dead.is_empty() {
            return dead;
        }
        let mut factory = self.inner.factory.lock().unwrap();
        let mut restarted = Vec::new();
        for &i in &dead {
            match self.spawn_synced(&mut factory, i) {
                Ok((fresh, attach)) => {
                    let ep = self.inner.registry.publish(i, fresh);
                    for (caster, v) in attach {
                        caster.attach(i, ep, v);
                    }
                    restarted.push(i);
                }
                // Learner dead: don't respawn samplers with blank
                // weights; surface "nothing (more) restarted" instead.
                Err(_) => break,
            }
        }
        restarted
    }

    /// The shared fault ledger: suspects noted by deadline supervision
    /// built over this set's counters
    /// ([`crate::iter::DeadlineSupervision::with_counters`]), plus the
    /// forced restarts and breaker trips taken by
    /// [`Self::restart_dead_with_policy`].  Cloned into the metrics
    /// reporting closure so `TrainResult::faults` reflects events taken
    /// after plan build.
    pub fn fault_counters(&self) -> std::sync::Arc<FaultCounters> {
        self.inner.faults.clone()
    }

    /// Point-in-time copy of [`Self::fault_counters`].
    pub fn fault_stats(&self) -> FaultStats {
        self.inner.faults.snapshot()
    }

    /// [`Self::restart_dead`] under a [`RestartPolicy`]: respawn dead
    /// remotes with exponential backoff and a per-slot budget, tripping
    /// a circuit breaker — tombstone the slot instead of respawning —
    /// on a crash loop.  Non-blocking: a death inside its backoff
    /// window is reported as *deferred*; drive this from a supervision
    /// loop (e.g. each `TrainResult` tick) and the deferred slots are
    /// serviced once their window closes.
    ///
    /// Stops early (remaining dead slots unreported) if the learner is
    /// dead, matching [`Self::restart_dead`]: blank-weight respawns are
    /// never correct.
    pub fn restart_dead_with_policy(
        &self,
        policy: &RestartPolicy,
    ) -> RestartReport {
        let dead = self.poisoned_indices();
        let mut report = RestartReport::default();
        if dead.is_empty() {
            return report;
        }
        let mut factory = self.inner.factory.lock().unwrap();
        let mut states = self.inner.restart_state.lock().unwrap();
        let now = Instant::now();
        for &i in &dead {
            let st = states
                .entry(i)
                .or_insert_with(|| SlotRestart::new(policy));
            // Amnesty: a long healthy run since the last restart
            // refunds the budget and the backoff.
            if st.restarts > 0
                && now.duration_since(st.last_restart) >= policy.reset_after
            {
                st.backoff.reset();
                st.restarts = 0;
            }
            if st.restarts >= policy.max_restarts {
                // Circuit breaker: the slot is crash-looping — retire
                // it (inline: `remove_worker` would re-take the factory
                // lock) so gathers drain it and its queue budget is
                // reclaimed; the autoscaler or a later `add_worker`
                // backfills with a fresh budget.
                states.remove(&i);
                if self.inner.registry.retire(i).is_some() {
                    self.inner.scale.note_removed();
                    self.inner.faults.note_breaker_trip();
                    report.tripped.push(i);
                }
                continue;
            }
            if now < st.next_attempt {
                report.deferred.push(i);
                continue;
            }
            match self.spawn_synced(&mut factory, i) {
                Ok((fresh, attach)) => {
                    let ep = self.inner.registry.publish(i, fresh);
                    for (caster, v) in attach {
                        caster.attach(i, ep, v);
                    }
                    st.restarts += 1;
                    st.last_restart = now;
                    st.next_attempt = now + st.backoff.next_delay();
                    self.inner.faults.note_forced_restart();
                    report.restarted.push(i);
                }
                // Learner dead: stop, exactly like `restart_dead`.
                Err(_) => break,
            }
        }
        report
    }

    /// Add one remote worker under live traffic: spawn it from the
    /// retained factory, run the sync protocol (the learner's
    /// **current** state lands in its mailbox before it is published —
    /// FIFO per mailbox, so the applies run before any gather dispatch
    /// reaches it), attach its lane on every registered
    /// [`WeightCaster`], and publish it into the registry — running
    /// `gather_async` streams prime credits for it mid-stream,
    /// `gather_sync` admits it at the next round boundary.
    ///
    /// Tombstoned slots (earlier `remove_worker`s) are reused before
    /// new tag space is grown.  Returns the worker's shard index.
    /// Fails if the learner is dead (a blank-weight worker would sample
    /// garbage) or the registry hit the 16-bit shard-tag bound.
    pub fn add_worker(&self) -> crate::util::error::Result<usize> {
        // The factory lock serializes the set's own scale operations;
        // the registry index is still taken from publish/grow itself
        // (authoritative even if another holder of the shared registry
        // grew it concurrently).
        let mut factory = self.inner.factory.lock().unwrap();
        let reuse = self.inner.registry.retired_indices().first().copied();
        let slot_hint = reuse.unwrap_or_else(|| self.inner.registry.len());
        let (fresh, attach) = self
            .spawn_synced(&mut factory, slot_hint)
            .map_err(|e| {
                crate::util::error::Error::msg(format!("add_worker: {e}"))
            })?;
        let (idx, epoch) = match reuse {
            Some(i) => (i, self.inner.registry.publish(i, fresh)),
            None => {
                let i = self.inner.registry.grow(fresh).map_err(|e| {
                    crate::util::error::Error::msg(format!("add_worker: {e}"))
                })?;
                (i, 0)
            }
        };
        for (caster, v) in attach {
            caster.attach(idx, epoch, v);
        }
        self.inner.scale.note_added();
        Ok(idx)
    }

    /// Remove remote `i` under live traffic (the tombstone path): the
    /// registry drops its handle, running gathers stop dispatching to
    /// the index and drain its in-flight completions by epoch/mode
    /// (reusing the dead-incarnation discard machinery), weight casts
    /// skip the slot, and the worker's actor thread exits once its
    /// mailbox drains.  Returns `false` if the slot was already
    /// tombstoned.  The slot is reused by a later [`Self::add_worker`].
    pub fn remove_worker(&self, i: usize) -> bool {
        // Serialize with add_worker's slot choice.
        let _factory = self.inner.factory.lock().unwrap();
        match self.inner.registry.retire(i) {
            Some(_handle) => {
                // Dropping `_handle` releases the registry's (last
                // long-lived) reference; in-flight messages still
                // execute because their envelopes are already queued.
                self.inner.scale.note_removed();
                true
            }
            None => false,
        }
    }

    /// Scale the live remote count to exactly `n` (>= 1), adding
    /// workers ([`Self::add_worker`]) or tombstoning the highest live
    /// indices ([`Self::remove_worker`]) as needed — all without
    /// rebuilding any running plan.  Returns the indices added and
    /// removed.  Driven manually, or automatically by an
    /// [`Autoscaler`](crate::actor::Autoscaler) through the metrics
    /// reporting operators.
    pub fn scale_to(
        &self,
        n: usize,
    ) -> crate::util::error::Result<(Vec<usize>, Vec<usize>)> {
        assert!(n >= 1, "scale_to(0) would end every stream");
        let mut added = Vec::new();
        let mut removed = Vec::new();
        while self.inner.registry.num_live() < n {
            added.push(self.add_worker()?);
        }
        while self.inner.registry.num_live() > n {
            let idx = *self
                .inner
                .registry
                .live_indices()
                .last()
                .expect("num_live > n >= 1 implies a live index");
            if self.remove_worker(idx) {
                removed.push(idx);
            }
        }
        Ok((added, removed))
    }
}

impl<W: super::WorkerMetrics + 'static> WorkerSet<W> {
    /// Total episodes + sampled-step counters drained from all workers.
    /// Dead workers contribute nothing instead of panicking the driver.
    pub fn collect_metrics(&self) -> (Vec<EpisodeRecord>, usize) {
        let mut episodes = Vec::new();
        let mut steps = 0;
        let replies: Vec<_> = std::iter::once(self.local.clone())
            .chain(self.inner.registry.handles())
            .map(|h| h.call_deferred(|w| w.drain_metrics()))
            .collect();
        for r in replies {
            if let Ok((eps, s)) = r.recv() {
                episodes.extend(eps);
                steps += s;
            }
        }
        (episodes, steps)
    }
}

impl WorkerSet<RolloutWorker> {
    /// Spawn 1 local + `num_remote` remote single-policy rollout
    /// workers.  `make(i)` builds worker i on its actor thread (i = 0
    /// is the local worker).  The sync protocol pushes the learner's
    /// full weight vector; one default [`WeightCaster`] is registered
    /// and shared by `sync_weights`, `TrainOneStep`, and the DQN-family
    /// plans, so the weight version is monotone across all of them.
    pub fn new(
        num_remote: usize,
        make: impl FnMut(usize) -> Box<dyn FnOnce() -> RolloutWorker + Send>
            + Send
            + 'static,
    ) -> Self {
        let set = WorkerSet::with_protocol(
            "local_worker",
            "worker",
            num_remote,
            make,
            |local: &ActorHandle<RolloutWorker>,
             fresh: &ActorHandle<RolloutWorker>| {
                let weights: std::sync::Arc<[f32]> = local
                    .call(|w| w.get_weights())
                    .map_err(|e| {
                        crate::util::error::Error::msg(format!(
                            "learner is dead ({e})"
                        ))
                    })?
                    .into();
                fresh.cast(move |w| w.set_weights(&weights));
                Ok(())
            },
        );
        set.register_caster(std::sync::Arc::new(WeightCaster::new(
            set.registry().clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut RolloutWorker, p: &[f32]| w.set_weights(p),
        )));
        set
    }

    /// The default versioned weight-broadcast channel to the remotes
    /// (the caster [`WorkerSet::new`] registered).  Panics with a
    /// diagnostic on a set built through [`WorkerSet::with_protocol`]
    /// without one.
    pub fn caster(&self) -> std::sync::Arc<WeightCaster<RolloutWorker>> {
        self.inner
            .casters
            .lock()
            .unwrap()
            .first()
            .cloned()
            .expect(
                "no WeightCaster registered on this WorkerSet \
                 (with_protocol registers none — use WorkerSet::new, or \
                 register_caster before the reporting operators run)",
            )
    }

    /// Broadcast-policy counters (versions published, casts enqueued /
    /// coalesced / shed).
    pub fn weight_cast_stats(&self) -> WeightCastStats {
        self.caster().stats()
    }

    /// Broadcast the local worker's weights to all remotes, blocking
    /// until every **responsive** live remote applied them — the
    /// sync-barrier path.  One shared `Arc<[f32]>` travels to every
    /// remote; the per-remote cost is a pointer clone, not a
    /// parameter-vector copy.  Dead remotes are skipped, a remote
    /// removed or killed mid-barrier is dropped from the wait set, and
    /// a remote whose mailbox is **full** at dispatch gets the
    /// coalescing non-blocking apply and is not waited on (it catches
    /// up when it drains) — the barrier never wedges behind a stalled
    /// worker (see `WeightCaster::broadcast_sync`).
    pub fn sync_weights(&self) {
        let weights: std::sync::Arc<[f32]> = self
            .local
            .call(|w| w.get_weights())
            .expect("local (learner) worker died")
            .into();
        self.caster().broadcast_sync(weights);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{CartPole, DummyEnv};
    use crate::policy::DummyPolicy;

    fn dummy_worker(num_envs: usize, fragment: usize) -> RolloutWorker {
        let envs: Vec<Box<dyn Env>> = (0..num_envs)
            .map(|_| Box::new(DummyEnv::new(4, 10)) as Box<dyn Env>)
            .collect();
        RolloutWorker::new(
            envs,
            Box::new(DummyPolicy::new(0.1)),
            fragment,
            CollectMode::OnPolicy,
        )
    }

    #[test]
    fn sample_returns_full_fragment() {
        let mut w = dummy_worker(2, 16);
        let batch = w.sample();
        assert_eq!(batch.len(), 32); // fragment x num_envs
        assert_eq!(w.num_steps_sampled, 32);
    }

    #[test]
    fn episodes_recorded_with_rewards() {
        let mut w = dummy_worker(1, 25); // DummyEnv episode length 10
        w.sample();
        let eps = w.pop_episodes();
        assert_eq!(eps.len(), 2); // 25 steps -> 2 completed episodes
        assert!(eps.iter().all(|e| e.length == 10 && e.reward == 10.0));
        assert!(w.pop_episodes().is_empty()); // drained
    }

    #[test]
    fn transitions_mode_fills_next_obs() {
        let envs: Vec<Box<dyn Env>> =
            vec![Box::new(CartPole::new(0)) as Box<dyn Env>];
        let mut w = RolloutWorker::new(
            envs,
            Box::new(DummyPolicy::new(0.1)),
            8,
            CollectMode::Transitions,
        );
        let batch = w.sample();
        assert_eq!(batch.len(), 8);
        assert_eq!(batch.next_obs.len(), 8 * 4);
        assert!(batch.action_logp.is_empty());
    }

    #[test]
    fn worker_set_sync_weights() {
        let set = WorkerSet::new(3, |_| {
            Box::new(|| dummy_worker(1, 4))
        });
        set.local.call(|w| w.set_weights(&[0.75])).unwrap();
        set.sync_weights();
        for r in set.remotes() {
            assert_eq!(r.call(|w| w.get_weights()).unwrap(), vec![0.75]);
        }
        assert_eq!(set.weight_cast_stats().version, 1);
    }

    #[test]
    fn worker_set_restarts_poisoned_remotes() {
        let set = WorkerSet::new(3, |_| Box::new(|| dummy_worker(1, 4)));
        set.local.call(|w| w.set_weights(&[0.5])).unwrap();
        // Kill remote 1 (the poisoned flag publishes asynchronously).
        let victim = set.remote(1).expect("live remote");
        let _ = victim.call(|_| -> () { panic!("sim fault") });
        assert!(victim.await_poisoned(std::time::Duration::from_secs(2)));
        assert_eq!(set.poisoned_indices(), vec![1]);
        // Metrics collection and weight sync survive the dead worker.
        set.sync_weights();
        let (_eps, _steps) = set.collect_metrics();

        let restarted = set.restart_dead();
        assert_eq!(restarted, vec![1]);
        // The registry now serves the replacement incarnation.
        assert_eq!(set.registry().epoch(1), 1);
        let fresh = set.remote(1).expect("live remote");
        assert_ne!(fresh.id(), victim.id());
        assert!(!fresh.is_poisoned());
        // The replacement runs and carries the learner's weights.
        assert_eq!(fresh.call(|w| w.get_weights()).unwrap(), vec![0.5]);
        assert_eq!(fresh.call(|w| w.sample().len()).unwrap(), 4);
        assert!(set.restart_dead().is_empty());
    }

    #[test]
    fn restart_dead_refuses_when_learner_is_dead() {
        let set = WorkerSet::new(2, |_| Box::new(|| dummy_worker(1, 4)));
        let w0 = set.remote(0).expect("live remote");
        let _ = w0.call(|_| -> () { panic!("worker fault") });
        let _ = set.local.call(|_| -> () { panic!("learner fault") });
        assert!(w0.await_poisoned(std::time::Duration::from_secs(2)));
        assert!(set.local.await_poisoned(std::time::Duration::from_secs(2)));
        // No blank-weight respawns: learner recovery is checkpoint-level.
        assert!(set.restart_dead().is_empty());
        assert_eq!(set.poisoned_indices(), vec![0]);
    }

    #[test]
    fn add_worker_spawns_with_learner_weights() {
        let set = WorkerSet::new(1, |_| Box::new(|| dummy_worker(1, 4)));
        set.local.call(|w| w.set_weights(&[0.375])).unwrap();
        let idx = set.add_worker().unwrap();
        assert_eq!(idx, 1);
        assert_eq!(set.num_remotes(), 2);
        assert_eq!(set.num_live_remotes(), 2);
        // The weights landed before any other message could.
        let fresh = set.remote(1).expect("live remote");
        assert_eq!(fresh.call(|w| w.get_weights()).unwrap(), vec![0.375]);
        assert_eq!(fresh.call(|w| w.sample().len()).unwrap(), 4);
        let sc = set.scale_stats();
        assert_eq!((sc.added, sc.removed, sc.live, sc.slots), (1, 0, 2, 2));
    }

    #[test]
    fn remove_worker_tombstones_and_slot_is_reused() {
        let set = WorkerSet::new(3, |_| Box::new(|| dummy_worker(1, 4)));
        assert!(set.remove_worker(1));
        assert!(!set.remove_worker(1), "double-remove is a no-op");
        assert_eq!(set.num_live_remotes(), 2);
        assert_eq!(set.num_remotes(), 3, "tombstones keep the slot");
        // Weight syncs and metrics skip the tombstone.
        set.sync_weights();
        let (_eps, _steps) = set.collect_metrics();
        // The next add reuses slot 1 instead of growing tag space,
        // bumping its epoch so running gathers rejoin it.
        let idx = set.add_worker().unwrap();
        assert_eq!(idx, 1);
        assert_eq!(set.num_remotes(), 3);
        assert_eq!(set.registry().epoch(1), 1);
        let sc = set.scale_stats();
        assert_eq!((sc.added, sc.removed, sc.live, sc.slots), (1, 1, 3, 3));
    }

    #[test]
    fn scale_to_reaches_target_in_both_directions() {
        let set = WorkerSet::new(2, |_| Box::new(|| dummy_worker(1, 4)));
        let (added, removed) = set.scale_to(5).unwrap();
        assert_eq!(added, vec![2, 3, 4]);
        assert!(removed.is_empty());
        assert_eq!(set.num_live_remotes(), 5);
        let (added, removed) = set.scale_to(2).unwrap();
        assert!(added.is_empty());
        assert_eq!(removed, vec![4, 3, 2]);
        assert_eq!(set.num_live_remotes(), 2);
        // Idempotent at target.
        assert_eq!(set.scale_to(2).unwrap(), (vec![], vec![]));
        let sc = set.scale_stats();
        assert_eq!((sc.added, sc.removed, sc.live, sc.slots), (3, 3, 2, 5));
    }

    #[test]
    fn add_worker_refuses_when_learner_is_dead() {
        let set = WorkerSet::new(1, |_| Box::new(|| dummy_worker(1, 4)));
        let _ = set.local.call(|_| -> () { panic!("learner fault") });
        assert!(set
            .local
            .await_poisoned(std::time::Duration::from_secs(2)));
        let err = set.add_worker().unwrap_err();
        assert!(err.to_string().contains("learner is dead"), "{err}");
        assert_eq!(set.num_live_remotes(), 1);
    }

    #[test]
    fn restart_policy_backs_off_and_trips_breaker() {
        let set = WorkerSet::new(2, |_| Box::new(|| dummy_worker(1, 4)));
        let policy = RestartPolicy {
            max_restarts: 2,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(4),
            reset_after: Duration::from_secs(3600),
        };
        let mut restarts = 0;
        let mut trips = 0;
        let mut deferrals = 0;
        for _round in 0..16 {
            let Some(h) = set.remote(0) else { break };
            let _ = h.call(|_| -> () { panic!("crash loop") });
            assert!(h.await_poisoned(Duration::from_secs(2)));
            // Drive the policy until it acts on this death: deferred
            // while the backoff window is open (non-blocking), then
            // restarted — or breaker-tripped once the budget is spent.
            loop {
                let r = set.restart_dead_with_policy(&policy);
                restarts += r.restarted.len();
                trips += r.tripped.len();
                deferrals += r.deferred.len();
                if !r.restarted.is_empty() || !r.tripped.is_empty() {
                    break;
                }
                assert!(
                    !r.deferred.is_empty(),
                    "death neither restarted, deferred, nor tripped"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            if trips > 0 {
                break;
            }
        }
        assert_eq!(restarts, 2, "restart budget");
        assert_eq!(trips, 1, "breaker trips exactly once");
        assert!(deferrals >= 1, "backoff never deferred a restart");
        assert!(set.remote(0).is_none(), "tripped slot must be tombstoned");
        assert_eq!(set.num_live_remotes(), 1);
        let fs = set.fault_stats();
        assert_eq!(fs.forced_restarts, 2);
        assert_eq!(fs.breaker_trips, 1);
        // Nothing left to service: the pass is a clean no-op.
        assert!(set.restart_dead_with_policy(&policy).is_empty());
        // The tombstone is backfillable with a fresh budget.
        assert_eq!(set.add_worker().unwrap(), 0);
        assert_eq!(set.num_live_remotes(), 2);
        assert!(set.remote(0).unwrap().call(|w| w.sample().len()).is_ok());
    }

    #[test]
    fn restart_policy_amnesties_after_quiet_period() {
        let set = WorkerSet::new(1, |_| Box::new(|| dummy_worker(1, 4)));
        let policy = RestartPolicy {
            max_restarts: 1,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(1),
            reset_after: Duration::from_millis(50),
        };
        // Three rare crashes separated by healthy runs longer than
        // `reset_after`: each refunds the one-restart budget, so the
        // breaker never trips.
        for round in 0..3 {
            let h = set.remote(0).expect("slot must stay live");
            let _ = h.call(|_| -> () { panic!("rare crash") });
            assert!(h.await_poisoned(Duration::from_secs(2)));
            let r = set.restart_dead_with_policy(&policy);
            assert_eq!(r.restarted, vec![0], "round {round}");
            std::thread::sleep(Duration::from_millis(60));
        }
        let fs = set.fault_stats();
        assert_eq!(fs.forced_restarts, 3);
        assert_eq!(fs.breaker_trips, 0);
    }

    #[test]
    fn injected_sample_fault_poisons_like_a_crash() {
        let id = faults::inject(
            faults::SITE_ROLLOUT_SAMPLE,
            Some("flt-sample-w"),
            crate::actor::FaultAction::PanicOnce,
        );
        let h = ActorHandle::spawn("flt-sample-w", || dummy_worker(1, 4));
        assert!(h.call(|w| w.sample().len()).is_err());
        assert!(h.await_poisoned(Duration::from_secs(2)));
        faults::clear(id);
    }

    #[test]
    fn worker_set_collect_metrics_drains() {
        let set = WorkerSet::new(2, |_| Box::new(|| dummy_worker(1, 20)));
        for r in set.remotes() {
            r.cast(|w| {
                w.sample();
            });
        }
        let (eps, steps) = set.collect_metrics();
        assert_eq!(steps, 40);
        assert_eq!(eps.len(), 4); // 2 workers x 2 episodes each
        let (eps2, steps2) = set.collect_metrics();
        assert!(eps2.is_empty());
        assert_eq!(steps2, 0);
    }
}
