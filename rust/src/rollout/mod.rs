//! Rollout workers — the actors that own envs + policies and produce
//! experience batches (the paper's `RolloutActor`s from
//! `create_rollout_workers()`).

mod multi_agent;
mod worker;

pub use multi_agent::MultiAgentRolloutWorker;
pub use worker::{
    CollectMode, RestartPolicy, RestartReport, RolloutWorker, ScaleCounters,
    ScaleStats, WorkerSet,
};

use crate::metrics::EpisodeRecord;

/// What every worker type a [`WorkerSet`] can own exposes to the
/// metrics layer: drain finished-episode records + the sampled-step
/// counter (resetting it).  Lets `WorkerSet::collect_metrics` and the
/// reporting operators stay generic over single- and multi-agent
/// workers.
pub trait WorkerMetrics {
    fn drain_metrics(&mut self) -> (Vec<EpisodeRecord>, usize);
}

impl WorkerMetrics for RolloutWorker {
    fn drain_metrics(&mut self) -> (Vec<EpisodeRecord>, usize) {
        let eps = self.pop_episodes();
        let steps = self.num_steps_sampled;
        self.num_steps_sampled = 0;
        (eps, steps)
    }
}

impl WorkerMetrics for MultiAgentRolloutWorker {
    fn drain_metrics(&mut self) -> (Vec<EpisodeRecord>, usize) {
        let eps = self.pop_episodes();
        let steps = self.num_steps_sampled;
        self.num_steps_sampled = 0;
        (eps, steps)
    }
}
