//! Rollout workers — the actors that own envs + policies and produce
//! experience batches (the paper's `RolloutActor`s from
//! `create_rollout_workers()`).

mod multi_agent;
mod worker;

pub use multi_agent::MultiAgentRolloutWorker;
pub use worker::{
    CollectMode, RolloutWorker, ScaleCounters, ScaleStats, WorkerSet,
};
