//! Multi-agent rollout worker: one multi-agent env, several policies,
//! per-policy sub-batch routing — the substrate for the PPO+DQN
//! composition experiment (paper §5.3, Fig. 11/12/14).

use std::collections::BTreeMap;

use crate::env::MultiAgentCartPole;
use crate::metrics::EpisodeRecord;
use crate::policy::{ActionOutput, Policy};
use crate::sample_batch::{MultiAgentBatch, SampleBatch, SampleBatchBuilder};

pub struct MultiAgentRolloutWorker {
    env: MultiAgentCartPole,
    pub policies: BTreeMap<String, Box<dyn Policy>>,
    fragment: usize,
    obs: BTreeMap<usize, Vec<f32>>,
    builders: BTreeMap<usize, SampleBatchBuilder>,
    ep_reward: BTreeMap<usize, f64>,
    ep_len: BTreeMap<usize, usize>,
    episodes: Vec<EpisodeRecord>,
    pub num_steps_sampled: usize,
    /// Agent→policy grouping, computed once: the mapping is fixed at
    /// env construction, so rebuilding it per step was pure churn.
    by_policy: BTreeMap<String, Vec<usize>>,
    /// Per-policy reusable scratches for the batched per-step forward:
    /// flattened `[agents, obs_dim]` observations and the action
    /// outputs — no per-policy-per-step heap allocation.
    obs_scratch: BTreeMap<String, Vec<f32>>,
    actions_scratch: BTreeMap<String, Vec<ActionOutput>>,
    /// Per-agent action outputs of the current step, indexed by agent.
    outputs: Vec<ActionOutput>,
}

impl MultiAgentRolloutWorker {
    pub fn new(
        mut env: MultiAgentCartPole,
        policies: BTreeMap<String, Box<dyn Policy>>,
        fragment: usize,
    ) -> Self {
        let obs = env.reset_all();
        let obs_dim = env.obs_dim();
        let n = env.num_agents();
        let mut by_policy: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for agent in 0..n {
            let pid = env.policy_for(agent);
            assert!(
                policies.contains_key(&pid),
                "no policy '{pid}' for agent {agent}"
            );
            by_policy.entry(pid).or_default().push(agent);
        }
        let obs_scratch = by_policy
            .iter()
            .map(|(pid, agents)| {
                (pid.clone(), Vec::with_capacity(agents.len() * obs_dim))
            })
            .collect();
        let actions_scratch = by_policy
            .iter()
            .map(|(pid, agents)| {
                (pid.clone(), Vec::with_capacity(agents.len()))
            })
            .collect();
        MultiAgentRolloutWorker {
            builders: (0..n)
                .map(|a| (a, SampleBatchBuilder::with_capacity(obs_dim, fragment)))
                .collect(),
            ep_reward: (0..n).map(|a| (a, 0.0)).collect(),
            ep_len: (0..n).map(|a| (a, 0)).collect(),
            env,
            policies,
            fragment,
            obs,
            episodes: Vec::new(),
            num_steps_sampled: 0,
            by_policy,
            obs_scratch,
            actions_scratch,
            outputs: vec![
                ActionOutput { action: 0, logp: 0.0, value: 0.0 };
                n
            ],
        }
    }

    /// Collect a fragment across all agents, grouped by policy id.
    /// Every policy's `compute_actions_into` is batched over its agents
    /// per step through reusable per-policy scratches; sub-batches are
    /// postprocessed by their owning policy.
    pub fn sample(&mut self) -> MultiAgentBatch {
        let n = self.env.num_agents();
        for _ in 0..self.fragment {
            let mut actions: BTreeMap<usize, i32> = BTreeMap::new();
            for (pid, agents) in &self.by_policy {
                let obs_flat = self.obs_scratch.get_mut(pid).unwrap();
                obs_flat.clear();
                for &a in agents {
                    obs_flat.extend_from_slice(&self.obs[&a]);
                }
                let outs = self.actions_scratch.get_mut(pid).unwrap();
                self.policies
                    .get_mut(pid)
                    .unwrap()
                    .compute_actions_into(obs_flat, agents.len(), outs);
                for (&a, out) in agents.iter().zip(outs.iter()) {
                    actions.insert(a, out.action);
                    self.outputs[a] = *out;
                }
            }
            let results = self.env.step_all(&actions);
            for (agent, (next_obs, reward, done)) in results {
                let out = self.outputs[agent];
                self.builders.get_mut(&agent).unwrap().add_step_with_next(
                    &self.obs[&agent],
                    out.action,
                    reward,
                    &next_obs,
                    done,
                    out.logp,
                    out.value,
                );
                *self.ep_reward.get_mut(&agent).unwrap() += reward as f64;
                *self.ep_len.get_mut(&agent).unwrap() += 1;
                self.num_steps_sampled += 1;
                if done {
                    self.episodes.push(EpisodeRecord {
                        reward: self.ep_reward[&agent],
                        length: self.ep_len[&agent],
                    });
                    self.ep_reward.insert(agent, 0.0);
                    self.ep_len.insert(agent, 0);
                }
                self.obs.insert(agent, next_obs);
            }
        }
        // Build per-agent segments, postprocess with the owning policy,
        // then group by policy id.
        let mut grouped: BTreeMap<String, Vec<SampleBatch>> = BTreeMap::new();
        for agent in 0..n {
            let mut seg = self.builders.get_mut(&agent).unwrap().build();
            let pid = self.env.policy_for(agent);
            let policy = self.policies.get_mut(&pid).unwrap();
            let last_value = policy.value(&self.obs[&agent]);
            policy.postprocess(&mut seg, last_value);
            grouped.entry(pid).or_default().push(seg);
        }
        MultiAgentBatch {
            policy_batches: grouped
                .into_iter()
                .map(|(pid, segs)| (pid, SampleBatch::concat_all(&segs)))
                .collect(),
        }
    }

    pub fn obs_dim(&self) -> usize {
        self.env.obs_dim()
    }

    pub fn learn_on_batch(
        &mut self,
        policy_id: &str,
        batch: &SampleBatch,
    ) -> BTreeMap<String, f64> {
        self.policies
            .get_mut(policy_id)
            .unwrap_or_else(|| panic!("unknown policy '{policy_id}'"))
            .learn_on_batch(batch)
    }

    pub fn update_target(&mut self, policy_id: &str) {
        self.policies.get_mut(policy_id).unwrap().update_target();
    }

    pub fn get_weights(&self, policy_id: &str) -> Vec<f32> {
        self.policies[policy_id].get_weights()
    }

    pub fn set_weights(&mut self, policy_id: &str, weights: &[f32]) {
        self.policies.get_mut(policy_id).unwrap().set_weights(weights);
    }

    pub fn pop_episodes(&mut self) -> Vec<EpisodeRecord> {
        std::mem::take(&mut self.episodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::DummyPolicy;

    fn make_worker(num_agents: usize, fragment: usize) -> MultiAgentRolloutWorker {
        let env = MultiAgentCartPole::new(num_agents, 0, |i| {
            if i % 2 == 0 { "even".into() } else { "odd".into() }
        });
        let mut policies: BTreeMap<String, Box<dyn Policy>> = BTreeMap::new();
        policies.insert("even".into(), Box::new(DummyPolicy::new(0.1)));
        policies.insert("odd".into(), Box::new(DummyPolicy::new(0.1)));
        MultiAgentRolloutWorker::new(env, policies, fragment)
    }

    #[test]
    fn sample_routes_agents_to_policies() {
        let mut w = make_worker(4, 10);
        let ma = w.sample();
        // 2 agents per policy x 10 steps.
        assert_eq!(ma.policy_count("even"), 20);
        assert_eq!(ma.policy_count("odd"), 20);
        assert_eq!(ma.count(), 40);
        assert_eq!(w.num_steps_sampled, 40);
    }

    #[test]
    fn sub_batches_have_full_columns() {
        let mut w = make_worker(2, 5);
        let ma = w.sample();
        let b = ma.select("even").unwrap();
        assert_eq!(b.len(), 5);
        assert_eq!(b.next_obs.len(), 5 * 4);
        assert_eq!(b.action_logp.len(), 5);
    }

    #[test]
    fn learn_on_batch_dispatches() {
        let mut w = make_worker(2, 5);
        let ma = w.sample();
        let stats = w.learn_on_batch("odd", ma.select("odd").unwrap());
        assert!(stats.contains_key("loss"));
    }

    #[test]
    #[should_panic(expected = "no policy")]
    fn missing_policy_panics_at_construction() {
        let env = MultiAgentCartPole::new(2, 0, |_| "nope".into());
        MultiAgentRolloutWorker::new(env, BTreeMap::new(), 4);
    }
}
