//! flowrl — CLI launcher for the ported algorithm suite.
//!
//! ```bash
//! flowrl train ppo --workers 4 --iters 50 --batch 512
//! flowrl train apex --workers 8 --iters 100
//! flowrl list
//! ```

use std::process::exit;

use flowrl::algorithms::{
    a2c_plan, a3c_plan, apex_plan, dqn_plan, impala_plan, maml_plan,
    multi_agent_plan, ppo_plan, ApexConfig, DqnConfig, MamlConfig,
    MultiAgentConfig, TrainerConfig,
};

const ALGOS: &[&str] =
    &["a2c", "a3c", "ppo", "dqn", "apex", "impala", "maml", "multi_agent"];

fn usage() -> ! {
    eprintln!(
        "usage: flowrl <command>\n\
         \n\
         commands:\n\
         \x20 train <algo> [--workers N] [--envs N] [--iters N]\n\
         \x20       [--batch N] [--fragment N] [--lr F] [--seed N]\n\
         \x20       [--artifacts DIR] [--env cartpole|mountain_car] [--quiet]\n\
         \x20 list                 list available algorithms\n\
         \n\
         algorithms: {}",
        ALGOS.join(", ")
    );
    exit(2)
}

struct Args {
    algo: String,
    config: TrainerConfig,
    iters: usize,
    quiet: bool,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(String::as_str) {
        Some("list") => {
            for a in ALGOS {
                println!("{a}");
            }
            exit(0)
        }
        Some("train") => {}
        _ => usage(),
    }
    let algo = argv.get(1).cloned().unwrap_or_else(|| usage());
    if !ALGOS.contains(&algo.as_str()) {
        eprintln!("unknown algorithm '{algo}'");
        usage();
    }
    let mut config = TrainerConfig::default();
    let mut iters = 20usize;
    let mut quiet = false;
    let mut i = 2;
    let next_val = |i: &mut usize| -> String {
        *i += 1;
        argv.get(*i).cloned().unwrap_or_else(|| usage())
    };
    while i < argv.len() {
        match argv[i].as_str() {
            "--workers" => config.num_workers = next_val(&mut i).parse().unwrap(),
            "--envs" => {
                config.num_envs_per_worker = next_val(&mut i).parse().unwrap()
            }
            "--iters" => iters = next_val(&mut i).parse().unwrap(),
            "--batch" => {
                config.train_batch_size = next_val(&mut i).parse().unwrap()
            }
            "--fragment" => {
                config.rollout_fragment_length =
                    next_val(&mut i).parse().unwrap()
            }
            "--lr" => config.lr = next_val(&mut i).parse().unwrap(),
            "--seed" => config.seed = next_val(&mut i).parse().unwrap(),
            "--artifacts" => {
                config.artifacts_dir = next_val(&mut i).into()
            }
            "--env" => {
                config.env = match next_val(&mut i).as_str() {
                    "cartpole" => flowrl::algorithms::EnvKind::CartPole,
                    "mountain_car" => {
                        flowrl::algorithms::EnvKind::MountainCar
                    }
                    "dummy" => flowrl::algorithms::EnvKind::Dummy,
                    other => {
                        eprintln!("unknown env '{other}'");
                        usage()
                    }
                }
            }
            "--quiet" => quiet = true,
            other => {
                eprintln!("unknown flag '{other}'");
                usage()
            }
        }
        i += 1;
    }
    Args { algo, config, iters, quiet }
}

fn main() {
    let args = parse_args();
    let cfg = &args.config;
    let mut plan = match args.algo.as_str() {
        "a2c" => a2c_plan(cfg),
        "a3c" => a3c_plan(cfg),
        "ppo" => ppo_plan(cfg),
        "dqn" => dqn_plan(cfg, &DqnConfig::default()),
        "apex" => apex_plan(cfg, &ApexConfig::default()),
        "impala" => impala_plan(cfg),
        "maml" => maml_plan(cfg, &MamlConfig::default()),
        "multi_agent" => multi_agent_plan(cfg, &MultiAgentConfig::default()),
        _ => unreachable!(),
    };
    let start = std::time::Instant::now();
    for i in 1..=args.iters {
        let r = plan.next().expect("training stream ended");
        if !args.quiet || i == args.iters {
            println!("iter {i:4}  {r}");
        }
    }
    eprintln!("done in {:?}", start.elapsed());
}
