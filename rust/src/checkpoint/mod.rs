//! Checkpointing — the paper's fault-tolerance model (§3 Consistency
//! and Durability): RL tolerates message/data loss, so the *only*
//! durable state is a periodic checkpoint of the learner's parameters
//! and counters; on a fault the whole computation restarts from it and
//! everything else (in-flight batches, replay contents, iterator
//! positions) is discarded.  This is why the programming model can skip
//! state serialization and logging on the hot path.
//!
//! Format (version-tagged, little-endian; all integer/tensor packing
//! goes through the crate's shared codec, [`crate::sample_batch::wire`],
//! which the episode-log frame format also builds on):
//! ```text
//! magic "FLRLCKPT" | u32 version | u64 steps_sampled | u64 steps_trained
//! | u32 n_policies | n x { u32 name_len | name | u32 len | f32[len] }
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::sample_batch::wire;
use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

const MAGIC: &[u8; 8] = b"FLRLCKPT";
const VERSION: u32 = 1;

/// A point-in-time snapshot of trainable state.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Checkpoint {
    pub steps_sampled: u64,
    pub steps_trained: u64,
    /// Flat parameter vectors by policy id ("default" for single-policy
    /// trainers).
    pub weights: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn single(weights: Vec<f32>) -> Self {
        let mut map = BTreeMap::new();
        map.insert("default".to_string(), weights);
        Checkpoint { steps_sampled: 0, steps_trained: 0, weights: map }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Write-then-rename for atomicity: a fault mid-write must not
        // destroy the previous checkpoint.
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {}", tmp.display()))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&self.steps_sampled.to_le_bytes())?;
            f.write_all(&self.steps_trained.to_le_bytes())?;
            f.write_all(&(self.weights.len() as u32).to_le_bytes())?;
            // Each weight tensor is serialized as ONE contiguous
            // byte-slice (little-endian f32s assembled in a reused
            // buffer) instead of one write_all per element — a learner
            // checkpoint is a single buffered write per policy.
            let mut scratch: Vec<u8> = Vec::new();
            for (name, w) in &self.weights {
                f.write_all(&(name.len() as u32).to_le_bytes())?;
                f.write_all(name.as_bytes())?;
                f.write_all(&(w.len() as u32).to_le_bytes())?;
                wire::write_f32s(&mut f, w, &mut scratch)?;
            }
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref()).with_context(|| {
                format!("opening checkpoint {}", path.as_ref().display())
            })?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a flowrl checkpoint (bad magic)");
        }
        let version = wire::read_u32(&mut f)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let steps_sampled = wire::read_u64(&mut f)?;
        let steps_trained = wire::read_u64(&mut f)?;
        let n = wire::read_u32(&mut f)? as usize;
        let mut weights = BTreeMap::new();
        for _ in 0..n {
            let name_len = wire::read_u32(&mut f)? as usize;
            if name_len > 4096 {
                bail!("implausible policy-name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let len = wire::read_u32(&mut f)? as usize;
            let w = wire::read_f32s(&mut f, len)?;
            weights.insert(String::from_utf8(name)?, w);
        }
        Ok(Checkpoint { steps_sampled, steps_trained, weights })
    }
}

/// Checkpoint the single-policy learner of a `WorkerSet`.
pub fn checkpoint_worker_set(
    workers: &crate::rollout::WorkerSet,
    steps_sampled: u64,
    steps_trained: u64,
) -> Checkpoint {
    let weights = workers
        .local
        .call(|w| w.get_weights())
        .expect("local (learner) worker died");
    let mut ck = Checkpoint::single(weights);
    ck.steps_sampled = steps_sampled;
    ck.steps_trained = steps_trained;
    ck
}

/// Restore a checkpoint into every worker of a set (learner + remotes).
pub fn restore_worker_set(
    workers: &crate::rollout::WorkerSet,
    ck: &Checkpoint,
) -> Result<()> {
    let w = ck
        .weights
        .get("default")
        .ok_or_else(|| anyhow!("no 'default' policy in checkpoint"))?
        .clone();
    let wl = w.clone();
    workers
        .local
        .call(move |state| state.set_weights(&wl))
        .map_err(|e| anyhow!("restoring into local worker: {e}"))?;
    for r in workers.remotes() {
        let wr = w.clone();
        r.cast(move |state| state.set_weights(&wr));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("flowrl_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut ck = Checkpoint::single(vec![1.0, -2.5, 3.25]);
        ck.steps_sampled = 12345;
        ck.steps_trained = 678;
        ck.weights.insert("dqn".into(), vec![0.5; 10]);
        let path = tmp("roundtrip.ckpt");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn large_tensor_contiguous_write_roundtrip() {
        // The contiguous-slice serialization must be bit-exact for a
        // realistically sized parameter vector (and stay in the v1
        // format: header unchanged, payload = packed LE f32s).
        let n = 200_000;
        let w: Vec<f32> = (0..n).map(|i| (i as f32).sin() * 1e3).collect();
        let mut ck = Checkpoint::single(w.clone());
        ck.steps_sampled = 9;
        let path = tmp("large.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // magic(8) + version(4) + counters(16) + n_policies(4)
        //   + name_len(4) + "default"(7) + len(4) + payload.
        assert_eq!(bytes.len(), 8 + 4 + 16 + 4 + 4 + 7 + 4 + n * 4);
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.weights["default"], w);
        assert_eq!(loaded.steps_sampled, 9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_weights_roundtrip() {
        let ck = Checkpoint::default();
        let path = tmp("empty.ckpt");
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_and_wrong_magic() {
        let path = tmp("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err}").contains("magic"));
        std::fs::remove_file(&path).ok();
        assert!(Checkpoint::load(tmp("missing.ckpt")).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let ck = Checkpoint::single(vec![1.0; 100]);
        let path = tmp("trunc.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_under_existing_file() {
        // Saving over an existing checkpoint leaves no .tmp and the new
        // content wins.
        let path = tmp("atomic.ckpt");
        Checkpoint::single(vec![1.0]).save(&path).unwrap();
        Checkpoint::single(vec![2.0]).save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.weights["default"], vec![2.0]);
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn worker_set_checkpoint_restore() {
        use crate::env::{DummyEnv, Env};
        use crate::policy::DummyPolicy;
        use crate::rollout::{CollectMode, RolloutWorker, WorkerSet};
        let set = WorkerSet::new(2, |_| {
            Box::new(|| {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    8,
                    CollectMode::OnPolicy,
                )
            })
        });
        set.local.call(|w| w.set_weights(&[0.875])).unwrap();
        let ck = checkpoint_worker_set(&set, 100, 50);
        assert_eq!(ck.weights["default"], vec![0.875]);

        // Simulate a restart: fresh workers, restore.
        let set2 = WorkerSet::new(2, |_| {
            Box::new(|| {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    8,
                    CollectMode::OnPolicy,
                )
            })
        });
        restore_worker_set(&set2, &ck).unwrap();
        assert_eq!(set2.local.call(|w| w.get_weights()).unwrap(), vec![0.875]);
        for r in set2.remotes() {
            assert_eq!(r.call(|w| w.get_weights()).unwrap(), vec![0.875]);
        }
    }
}
