//! Per-actor telemetry: queue depth (current + high-water), messages
//! processed, busy/idle time, and supervision state — the observability
//! half of the control plane.  Counters are plain atomics updated on the
//! send/receive/execute paths (no locks, no allocation), and every
//! spawned actor registers its counters in a process-wide registry so
//! `StandardMetricsReporting` can report pipeline health without any
//! per-plan plumbing.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

/// Live counters for one actor; shared between its handles, its thread,
/// and the registry.
pub struct ActorTelemetry {
    name: Arc<str>,
    id: u64,
    messages: AtomicU64,
    queue_len: AtomicUsize,
    queue_hwm: AtomicUsize,
    busy_ns: AtomicU64,
    idle_ns: AtomicU64,
    poisoned: AtomicBool,
    /// Condvar gate behind `ActorHandle::await_poisoned`: `poisoned` is
    /// the lock-free gauge, this pair is the *wakeup* — waiters park on
    /// the condvar and `note_poisoned` releases them immediately
    /// instead of leaving them on a 1ms poll loop.
    poison_gate: Mutex<bool>,
    poison_cv: Condvar,
}

impl ActorTelemetry {
    pub(crate) fn new(name: &str, id: u64) -> Self {
        ActorTelemetry {
            name: Arc::from(name),
            id,
            messages: AtomicU64::new(0),
            queue_len: AtomicUsize::new(0),
            queue_hwm: AtomicUsize::new(0),
            busy_ns: AtomicU64::new(0),
            idle_ns: AtomicU64::new(0),
            poisoned: AtomicBool::new(false),
            poison_gate: Mutex::new(false),
            poison_cv: Condvar::new(),
        }
    }

    /// The actor's name as the shared `Arc` (the fault plane's
    /// per-thread context holds one).
    pub(crate) fn name_arc(&self) -> Arc<str> {
        self.name.clone()
    }

    pub(crate) fn note_enqueue(&self, depth_now: usize) {
        self.queue_len.store(depth_now, Ordering::Relaxed);
        self.queue_hwm.fetch_max(depth_now, Ordering::Relaxed);
    }

    /// A message left the queue for execution.  The processed counter
    /// increments HERE (not after execution) so that by the time a
    /// caller observes a message's reply, the counter already covers
    /// it.
    pub(crate) fn note_dequeue(&self, depth_now: usize) {
        self.queue_len.store(depth_now, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_busy(&self, busy_ns: u64) {
        self.busy_ns.fetch_add(busy_ns, Ordering::Relaxed);
    }

    pub(crate) fn note_idle(&self, idle_ns: u64) {
        self.idle_ns.fetch_add(idle_ns, Ordering::Relaxed);
    }

    pub(crate) fn note_poisoned(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        self.queue_len.store(0, Ordering::Relaxed);
        *self.poison_gate.lock().unwrap() = true;
        self.poison_cv.notify_all();
    }

    pub(crate) fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Condvar-backed timed wait for the poison flag: returns true as
    /// soon as `note_poisoned` fires (no poll tick), false if `timeout`
    /// elapses first.
    pub(crate) fn await_poisoned(&self, timeout: Duration) -> bool {
        if self.is_poisoned() {
            return true;
        }
        let deadline = Instant::now() + timeout;
        let mut gate = self.poison_gate.lock().unwrap();
        while !*gate {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            gate = self
                .poison_cv
                .wait_timeout(gate, deadline - now)
                .unwrap()
                .0;
        }
        true
    }

    /// Current mailbox depth (relaxed): the gauge the weight-cast
    /// eviction policy reads per broadcast, without snapshotting.
    pub(crate) fn queue_len(&self) -> usize {
        self.queue_len.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> ActorStatsSnapshot {
        ActorStatsSnapshot {
            name: self.name.to_string(),
            id: self.id,
            messages_processed: self.messages.load(Ordering::Relaxed),
            queue_len: self.queue_len.load(Ordering::Relaxed),
            queue_hwm: self.queue_hwm.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            idle_ns: self.idle_ns.load(Ordering::Relaxed),
            poisoned: self.poisoned.load(Ordering::SeqCst),
        }
    }
}

/// A point-in-time copy of one actor's counters (the item type carried
/// by `TrainResult::actor_stats`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ActorStatsSnapshot {
    pub name: String,
    pub id: u64,
    pub messages_processed: u64,
    /// Mailbox depth at snapshot time.
    pub queue_len: usize,
    /// Mailbox depth high-water mark since spawn.
    pub queue_hwm: usize,
    /// Nanoseconds spent executing messages.
    pub busy_ns: u64,
    /// Nanoseconds spent waiting for messages.
    pub idle_ns: u64,
    pub poisoned: bool,
}

impl ActorStatsSnapshot {
    /// Fraction of observed time spent executing messages (0 when the
    /// actor has not run yet).  A starved pipeline stage shows up as a
    /// low-utilization learner behind a high-utilization sampler (or
    /// vice versa).
    pub fn utilization(&self) -> f64 {
        let total = self.busy_ns + self.idle_ns;
        if total == 0 {
            0.0
        } else {
            self.busy_ns as f64 / total as f64
        }
    }
}

fn registry() -> &'static Mutex<Vec<Weak<ActorTelemetry>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Weak<ActorTelemetry>>>> =
        OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

pub(crate) fn register(t: &Arc<ActorTelemetry>) {
    let mut reg = registry().lock().unwrap();
    // Opportunistic compaction so the registry does not grow without
    // bound across many short-lived actors.
    reg.retain(|w| w.strong_count() > 0);
    reg.push(Arc::downgrade(t));
}

/// Snapshot every live actor's counters (dead actors' entries are
/// dropped once their last handle and thread are gone).
pub fn all_actor_stats() -> Vec<ActorStatsSnapshot> {
    let reg = registry().lock().unwrap();
    reg.iter()
        .filter_map(|w| w.upgrade())
        .map(|t| t.snapshot())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let t = ActorTelemetry::new("worker", 3);
        t.note_enqueue(2);
        t.note_enqueue(5);
        t.note_dequeue(4);
        t.note_busy(1_000);
        t.note_idle(3_000);
        let s = t.snapshot();
        assert_eq!(s.name, "worker");
        assert_eq!(s.id, 3);
        assert_eq!(s.messages_processed, 1);
        assert_eq!(s.queue_len, 4);
        assert_eq!(s.queue_hwm, 5);
        assert_eq!(s.busy_ns, 1_000);
        assert_eq!(s.idle_ns, 3_000);
        assert!(!s.poisoned);
        assert!((s.utilization() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn utilization_of_fresh_actor_is_zero() {
        let t = ActorTelemetry::new("fresh", 0);
        assert_eq!(t.snapshot().utilization(), 0.0);
    }

    #[test]
    fn await_poisoned_wakes_on_note_not_on_a_poll_tick() {
        let t = Arc::new(ActorTelemetry::new("gate", 9));
        let t2 = t.clone();
        let waiter = std::thread::spawn(move || {
            assert!(t2.await_poisoned(Duration::from_secs(5)));
        });
        std::thread::sleep(Duration::from_millis(20));
        t.note_poisoned();
        waiter.join().unwrap();
        // Already-poisoned short-circuits.
        assert!(t.await_poisoned(Duration::ZERO));
    }

    #[test]
    fn await_poisoned_times_out_when_healthy() {
        let t = ActorTelemetry::new("gate-timeout", 10);
        let start = Instant::now();
        assert!(!t.await_poisoned(Duration::from_millis(30)));
        assert!(start.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn registry_serves_live_actors_only() {
        let t = Arc::new(ActorTelemetry::new("reg-test-live", 77));
        register(&t);
        {
            let gone = Arc::new(ActorTelemetry::new("reg-test-gone", 78));
            register(&gone);
        }
        let stats = all_actor_stats();
        assert!(stats.iter().any(|s| s.name == "reg-test-live"));
        assert!(!stats.iter().any(|s| s.name == "reg-test-gone"));
    }
}
