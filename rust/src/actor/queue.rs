//! The shared completion queue — the `ray.wait` analog every sequencing
//! operator rides.
//!
//! Producers (actor threads executing `call_into` messages, or `union`
//! driver threads) push tagged values; one consumer pops them in
//! completion order.  The queue is **bounded**: a push blocks while the
//! queue is at capacity (or, in per-tag mode, while that tag's credit is
//! exhausted), which is what turns `num_async` and `Union::buffer` from
//! best-effort hints into real flow-control knobs — a producer that gets
//! ahead of the consumer parks on its own thread and its mailbox fills
//! behind it.
//!
//! Every submission is guaranteed **exactly one** completion: either an
//! [`Completion::Item`] (the value) or a [`Completion::Dropped`] death
//! notice, delivered by the [`CqGuard`] captured in the message when the
//! closure is destroyed without completing (actor poisoned before or
//! during execution, or the message was never accepted).  Consumers that
//! count submissions against completions can therefore never hang on a
//! dead producer.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// One completion popped from the queue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Completion<T> {
    /// A produced value.
    Item { tag: usize, value: T },
    /// The producer's message was destroyed without producing: the actor
    /// died (panicked) or the queue's submission never ran.
    Dropped { tag: usize },
}

impl<T> Completion<T> {
    /// The submission tag, value or death notice alike.  Epoch-aware
    /// consumers (the shard-registry gathers) pack (shard, incarnation)
    /// into it and decode before attributing the completion.
    pub fn tag(&self) -> usize {
        match self {
            Completion::Item { tag, .. } | Completion::Dropped { tag } => *tag,
        }
    }
}

struct PerTag {
    credit: usize,
    counts: Vec<usize>,
}

struct CqState<T> {
    items: VecDeque<(usize, T)>,
    /// Death notices; kept out-of-band and unbounded so a guard firing
    /// during unwind can never block.
    dropped: Vec<usize>,
    cap: usize,
    per_tag: Option<PerTag>,
    closed: bool,
}

struct CqInner<T> {
    state: Mutex<CqState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// A cloneable handle to a shared bounded completion queue.
pub struct CompletionQueue<T> {
    inner: Arc<CqInner<T>>,
}

impl<T> Clone for CompletionQueue<T> {
    fn clone(&self) -> Self {
        CompletionQueue { inner: self.inner.clone() }
    }
}

impl<T: Send + 'static> CompletionQueue<T> {
    /// A queue holding at most `cap` buffered items (any tag mix).
    pub fn bounded(cap: usize) -> Self {
        assert!(cap >= 1);
        CompletionQueue {
            inner: Arc::new(CqInner {
                state: Mutex::new(CqState {
                    items: VecDeque::with_capacity(cap),
                    dropped: Vec::new(),
                    cap,
                    per_tag: None,
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            }),
        }
    }

    /// A queue where each tag in `0..tags` may buffer at most `credit`
    /// items — `union`'s per-child backpressure.
    pub fn per_tag(tags: usize, credit: usize) -> Self {
        assert!(tags >= 1 && credit >= 1);
        let cap = tags * credit;
        CompletionQueue {
            inner: Arc::new(CqInner {
                state: Mutex::new(CqState {
                    items: VecDeque::with_capacity(cap),
                    dropped: Vec::new(),
                    cap,
                    per_tag: Some(PerTag { credit, counts: vec![0; tags] }),
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            }),
        }
    }

    /// Blocking push; parks while the queue (or this tag's credit) is
    /// full.  Returns `false` — and drops `value` — if the queue was
    /// closed by the consumer.
    pub fn push(&self, tag: usize, value: T) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            let full = st.items.len() >= st.cap
                || st
                    .per_tag
                    .as_ref()
                    .map_or(false, |p| p.counts[tag] >= p.credit);
            if !full {
                st.items.push_back((tag, value));
                if let Some(p) = st.per_tag.as_mut() {
                    p.counts[tag] += 1;
                }
                drop(st);
                self.inner.not_empty.notify_one();
                return true;
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking death notice; never parks (unwind-safe).
    pub fn push_dropped(&self, tag: usize) {
        let mut st = self.inner.state.lock().unwrap();
        if st.closed {
            return;
        }
        st.dropped.push(tag);
        drop(st);
        self.inner.not_empty.notify_one();
    }

    /// Blocking pop.  Buffered **items drain before death notices**: a
    /// value completed before its producer died must not be masked by
    /// the (out-of-band, unordered) notice — a poisoned producer can
    /// never enqueue again, so every buffered item predates its notice.
    /// The caller is responsible for knowing a completion is
    /// outstanding; popping with nothing in flight parks forever.
    pub fn pop(&self) -> Completion<T> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some((tag, value)) = st.items.pop_front() {
                if let Some(p) = st.per_tag.as_mut() {
                    p.counts[tag] -= 1;
                }
                drop(st);
                self.inner.not_full.notify_all();
                return Completion::Item { tag, value };
            }
            if let Some(tag) = st.dropped.pop() {
                return Completion::Dropped { tag };
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// [`Self::pop`] with a deadline: `None` if nothing completed
    /// within `timeout` — the wait primitive under the gathers'
    /// deadline supervision (a shard that answers wakes the consumer
    /// immediately; a wedged one lets the timeout fire so the consumer
    /// can declare it suspect instead of parking forever).  Same
    /// items-before-notices drain order as [`Self::pop`].
    pub fn pop_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Option<Completion<T>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some((tag, value)) = st.items.pop_front() {
                if let Some(p) = st.per_tag.as_mut() {
                    p.counts[tag] -= 1;
                }
                drop(st);
                self.inner.not_full.notify_all();
                return Some(Completion::Item { tag, value });
            }
            if let Some(tag) = st.dropped.pop() {
                return Some(Completion::Dropped { tag });
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            st = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap()
                .0;
        }
    }

    /// Non-blocking pop (same items-before-notices order as [`pop`]).
    pub fn try_pop(&self) -> Option<Completion<T>> {
        let mut st = self.inner.state.lock().unwrap();
        if let Some((tag, value)) = st.items.pop_front() {
            if let Some(p) = st.per_tag.as_mut() {
                p.counts[tag] -= 1;
            }
            drop(st);
            self.inner.not_full.notify_all();
            return Some(Completion::Item { tag, value });
        }
        if let Some(tag) = st.dropped.pop() {
            return Some(Completion::Dropped { tag });
        }
        None
    }

    /// Raise the queue bound by `extra` slots, waking producers parked
    /// on the old bound.  Used by the elastic gathers to extend the
    /// in-flight budget when the shard registry grows mid-stream.
    /// Only meaningful for [`CompletionQueue::bounded`] queues; per-tag
    /// credits are per *tag*, not total, and are unaffected.
    pub fn add_capacity(&self, extra: usize) {
        let mut st = self.inner.state.lock().unwrap();
        st.cap += extra;
        drop(st);
        self.inner.not_full.notify_all();
    }

    /// Lower the queue bound by `extra` slots (never below 1) — the
    /// reverse of [`CompletionQueue::add_capacity`].  The elastic
    /// gathers return a tombstoned shard's in-flight budget once its
    /// last epoch completion drains, so repeated grow/retire cycles no
    /// longer inflate the bound without limit.  Items already buffered
    /// above the new bound are unaffected; producers simply block until
    /// the queue drains back under it.
    pub fn remove_capacity(&self, extra: usize) {
        let mut st = self.inner.state.lock().unwrap();
        st.cap = st.cap.saturating_sub(extra).max(1);
    }

    /// The current bound on buffered items (the in-flight budget the
    /// elastic gathers grow and reclaim).
    pub fn capacity(&self) -> usize {
        self.inner.state.lock().unwrap().cap
    }

    /// Close the queue: pending and future pushes return `false` so
    /// detached producers can exit when the consumer abandons the
    /// stream.
    pub fn close(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_full.notify_all();
        self.inner.not_empty.notify_all();
    }

    /// Buffered item count (excluding death notices).
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Captured inside a `call_into` message: guarantees the exactly-one-
/// completion contract.  `complete` delivers the value; destruction
/// without completion (actor death, message dropped, panic mid-call)
/// delivers a death notice instead.
pub(crate) struct CqGuard<T: Send + 'static> {
    q: CompletionQueue<T>,
    tag: usize,
    armed: bool,
}

impl<T: Send + 'static> CqGuard<T> {
    pub(crate) fn new(q: CompletionQueue<T>, tag: usize) -> Self {
        CqGuard { q, tag, armed: true }
    }

    pub(crate) fn complete(mut self, value: T) {
        self.armed = false;
        self.q.push(self.tag, value);
    }
}

impl<T: Send + 'static> Drop for CqGuard<T> {
    fn drop(&mut self) {
        if self.armed {
            self.q.push_dropped(self.tag);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_per_queue() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(8);
        q.push(0, 1);
        q.push(1, 2);
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 1 });
        assert_eq!(q.pop(), Completion::Item { tag: 1, value: 2 });
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn bounded_push_blocks_until_pop() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(1);
        q.push(0, 1);
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            q2.push(0, 2); // blocks until the main thread pops
            true
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!t.is_finished(), "push did not block at capacity");
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 1 });
        assert!(t.join().unwrap());
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 2 });
    }

    #[test]
    fn per_tag_credit_blocks_only_that_tag() {
        let q: CompletionQueue<i32> = CompletionQueue::per_tag(2, 1);
        q.push(0, 10);
        // Tag 0's credit is spent; tag 1 still goes through.
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            q2.push(0, 11);
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!t.is_finished(), "tag-0 push should block");
        q.push(1, 20);
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 10 });
        t.join().unwrap();
    }

    #[test]
    fn guard_drop_emits_death_notice() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(4);
        let g = CqGuard::new(q.clone(), 7);
        drop(g);
        assert_eq!(q.pop(), Completion::Dropped { tag: 7 });
        let g = CqGuard::new(q.clone(), 8);
        g.complete(42);
        assert_eq!(q.pop(), Completion::Item { tag: 8, value: 42 });
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn completed_items_drain_before_death_notices() {
        // A value completed before the producer died must surface, not
        // be masked by the notice.
        let q: CompletionQueue<i32> = CompletionQueue::bounded(4);
        let g_ok = CqGuard::new(q.clone(), 0);
        g_ok.complete(41);
        let g_dead = CqGuard::new(q.clone(), 0);
        drop(g_dead); // death notice for the same tag
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 41 });
        assert_eq!(q.pop(), Completion::Dropped { tag: 0 });
    }

    #[test]
    fn capacity_grows_and_reclaims() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(1);
        assert_eq!(q.capacity(), 1);
        q.add_capacity(2);
        assert_eq!(q.capacity(), 3);
        q.push(0, 1);
        q.push(0, 2);
        q.push(0, 3);
        // Reclaim under buffered items: the bound drops, the items stay.
        q.remove_capacity(2);
        assert_eq!(q.capacity(), 1);
        assert_eq!(q.len(), 3);
        // A push at the reclaimed bound blocks again until drained under
        // it — the grow/retire cycle restored the original backpressure.
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(0, 4));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!t.is_finished(), "push ignored the reclaimed bound");
        for want in [1, 2, 3] {
            assert_eq!(q.pop(), Completion::Item { tag: 0, value: want });
        }
        assert!(t.join().unwrap());
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 4 });
    }

    #[test]
    fn remove_capacity_floors_at_one() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(2);
        q.remove_capacity(100);
        assert_eq!(q.capacity(), 1);
        // Still a working single-slot queue.
        q.push(0, 9);
        assert_eq!(q.pop(), Completion::Item { tag: 0, value: 9 });
    }

    #[test]
    fn pop_timeout_expires_on_empty_and_wakes_on_push() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(4);
        let start = std::time::Instant::now();
        assert!(q.pop_timeout(std::time::Duration::from_millis(30)).is_none());
        assert!(start.elapsed() >= std::time::Duration::from_millis(30));
        // A push mid-wait wakes the consumer well before the deadline.
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            q2.push(3, 99);
        });
        assert_eq!(
            q.pop_timeout(std::time::Duration::from_secs(5)),
            Some(Completion::Item { tag: 3, value: 99 })
        );
        t.join().unwrap();
        // Death notices surface through the timed pop too.
        q.push_dropped(5);
        assert_eq!(
            q.pop_timeout(std::time::Duration::from_secs(5)),
            Some(Completion::Dropped { tag: 5 })
        );
    }

    #[test]
    fn close_unblocks_producers() {
        let q: CompletionQueue<i32> = CompletionQueue::bounded(1);
        q.push(0, 1);
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(0, 2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(!t.join().unwrap(), "push must fail after close");
    }
}
