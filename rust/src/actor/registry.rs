//! The elastic shard registry + versioned weight casts — the two pieces
//! that make the control plane *elastic* instead of restart-on-rebuild.
//!
//! [`ShardRegistry`] is a versioned shard-index -> handle indirection.
//! A dataflow plan built over a registry (see `ParIter::from_registry`)
//! resolves each dispatch through the registry instead of cloning actor
//! handles at plan-build time, so an owner that replaces a dead actor
//! (`WorkerSet::restart_dead`) can [`ShardRegistry::publish`] the
//! replacement and **running** gathers pick it up on their next dispatch
//! — no plan rebuild.  Every slot carries an **epoch** (incarnation
//! number) so a gather can tell a completion of the dead incarnation
//! from one of its replacement: stale death notices must not retire the
//! fresh actor, and stale items must not be attributed to it.
//!
//! [`WeightCaster`] turns weight broadcasts into *versioned casts* with
//! a drop-oldest eviction policy driven by the per-actor queue-depth
//! telemetry: the newest parameter vector lives in one shared slot, each
//! recipient holds at most one queued "apply latest" envelope
//! (superseded broadcasts coalesce into it), and a recipient whose
//! mailbox depth exceeds the watermark is never blocked on — the cast is
//! shed and the worker catches up on the next broadcast.  The learner
//! therefore never stalls behind an overloaded or dying rollout worker.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::ActorHandle;

// ---------------------------------------------------------------------
// ShardRegistry
// ---------------------------------------------------------------------

struct Slot<A> {
    handle: ActorHandle<A>,
    epoch: u64,
}

struct RegistryInner<A> {
    slots: Mutex<Vec<Slot<A>>>,
    /// Bumped on every publish — a cheap "anything changed?" gate so
    /// gathers only rescan their dead shards when a replacement could
    /// actually have appeared.
    version: AtomicU64,
}

/// A cloneable, versioned shard-index -> actor-handle table.  All clones
/// share the same slots: a `publish` through one is visible to every
/// holder (the running gathers) on their next `get`.
pub struct ShardRegistry<A: 'static> {
    inner: Arc<RegistryInner<A>>,
    len: usize,
}

impl<A: 'static> Clone for ShardRegistry<A> {
    fn clone(&self) -> Self {
        ShardRegistry { inner: self.inner.clone(), len: self.len }
    }
}

impl<A: 'static> std::fmt::Debug for ShardRegistry<A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ShardRegistry(len={}, version={})",
            self.len,
            self.version()
        )
    }
}

impl<A: 'static> ShardRegistry<A> {
    /// Wrap a fixed-size set of shard actors (epoch 0 each).  The shard
    /// *count* is immutable; the handle behind each index is not.
    pub fn new(handles: Vec<ActorHandle<A>>) -> Self {
        let len = handles.len();
        let slots = handles
            .into_iter()
            .map(|handle| Slot { handle, epoch: 0 })
            .collect();
        ShardRegistry {
            inner: Arc::new(RegistryInner {
                slots: Mutex::new(slots),
                version: AtomicU64::new(0),
            }),
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The current incarnation behind `idx`: (handle clone, epoch).
    pub fn get(&self, idx: usize) -> (ActorHandle<A>, u64) {
        let slots = self.inner.slots.lock().unwrap();
        let s = &slots[idx];
        (s.handle.clone(), s.epoch)
    }

    /// The current epoch of `idx` without cloning the handle.
    pub fn epoch(&self, idx: usize) -> u64 {
        self.inner.slots.lock().unwrap()[idx].epoch
    }

    /// Replace the incarnation behind `idx`, bumping its epoch and the
    /// registry version.  Returns the new epoch.  In-flight work on the
    /// old incarnation resolves under the old epoch and is discarded by
    /// epoch-aware consumers.
    pub fn publish(&self, idx: usize, handle: ActorHandle<A>) -> u64 {
        let epoch = {
            let mut slots = self.inner.slots.lock().unwrap();
            let s = &mut slots[idx];
            s.handle = handle;
            s.epoch += 1;
            s.epoch
        };
        self.inner.version.fetch_add(1, Ordering::Release);
        epoch
    }

    /// Publish counter (any index).  Consumers cache the last value they
    /// acted on and rescan only when it moves.
    pub fn version(&self) -> u64 {
        self.inner.version.load(Ordering::Acquire)
    }

    /// Snapshot of the current handle behind every index.
    pub fn handles(&self) -> Vec<ActorHandle<A>> {
        let slots = self.inner.slots.lock().unwrap();
        slots.iter().map(|s| s.handle.clone()).collect()
    }

    /// Indices whose *current* incarnation is poisoned.
    pub fn poisoned_indices(&self) -> Vec<usize> {
        let slots = self.inner.slots.lock().unwrap();
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.handle.is_poisoned())
            .map(|(i, _)| i)
            .collect()
    }
}

// ---------------------------------------------------------------------
// WeightCaster
// ---------------------------------------------------------------------

/// Mailbox depth beyond which a broadcast refuses to block on a
/// recipient: above it the cast is non-blocking and sheds on `Full`
/// (the worker is overloaded; it will pick up the newest weights from
/// the shared slot whenever its queued apply — or the next broadcast —
/// runs).
pub const DEFAULT_CAST_WATERMARK: usize = 8;

/// The per-incarnation cells an apply closure captures.  A republished
/// slot gets **fresh** cells (not a reset): envelopes still queued on
/// the previous incarnation hold clones of the old `Arc`s, so whatever
/// they do after the swap can never mark the replacement as pending or
/// as having applied a version it did not.
#[derive(Clone)]
struct LaneCells {
    /// True while an "apply latest weights" envelope is queued in (or
    /// executing on) this recipient's mailbox.  While set, broadcasts
    /// coalesce: the queued envelope reads the newest slot anyway.
    pending: Arc<AtomicBool>,
    /// Highest weight version this recipient has applied.
    applied: Arc<AtomicU64>,
}

impl LaneCells {
    fn fresh() -> Self {
        LaneCells {
            pending: Arc::new(AtomicBool::new(false)),
            applied: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// Per-recipient broadcast lane: the current incarnation's cells plus
/// the registry epoch they belong to.
struct Lane {
    cells: Mutex<LaneCells>,
    epoch: AtomicU64,
}

/// Point-in-time counters for one caster (attached to `TrainResult` by
/// the metrics operators).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WeightCastStats {
    /// Newest published weight version.
    pub version: u64,
    /// Apply envelopes actually enqueued.
    pub enqueued: u64,
    /// Broadcasts absorbed by an already-queued apply (drop-oldest:
    /// the queued apply delivers the newer version instead).
    pub coalesced: u64,
    /// Broadcasts dropped entirely because the recipient was over the
    /// watermark *and* its mailbox was full (load shedding).
    pub shed: u64,
}

/// Versioned weight broadcasts over a [`ShardRegistry`], with
/// drop-oldest coalescing and watermark-gated load shedding.
///
/// Invariants:
/// * at most **one** apply envelope is queued per recipient at a time —
///   a weight storm can never fill a worker's mailbox;
/// * an apply envelope always installs the **newest** slot contents at
///   execution time, and skips entirely if the recipient has already
///   applied that version (monotonic, idempotent);
/// * `broadcast` never blocks on a recipient whose queue depth exceeds
///   the watermark — overloaded workers shed superseded versions
///   instead of backpressuring the learner.
pub struct WeightCaster<A: 'static> {
    registry: ShardRegistry<A>,
    /// (version, weights) — the newest published parameters.
    slot: Arc<Mutex<(u64, Arc<[f32]>)>>,
    version: AtomicU64,
    lanes: Vec<Lane>,
    watermark: usize,
    apply: Arc<dyn Fn(&mut A, &[f32]) + Send + Sync>,
    enqueued: AtomicU64,
    coalesced: AtomicU64,
    shed: AtomicU64,
}

impl<A: 'static> WeightCaster<A> {
    /// `apply` installs a parameter vector into a recipient's state
    /// (e.g. `|w, p| w.set_weights(p)`); it runs on the actor thread.
    pub fn new(
        registry: ShardRegistry<A>,
        watermark: usize,
        apply: impl Fn(&mut A, &[f32]) + Send + Sync + 'static,
    ) -> Self {
        let lanes = (0..registry.len())
            .map(|_| Lane {
                cells: Mutex::new(LaneCells::fresh()),
                epoch: AtomicU64::new(0),
            })
            .collect();
        WeightCaster {
            registry,
            slot: Arc::new(Mutex::new((0, Arc::from(Vec::<f32>::new())))),
            version: AtomicU64::new(0),
            lanes,
            watermark,
            apply: Arc::new(apply),
            enqueued: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn registry(&self) -> &ShardRegistry<A> {
        &self.registry
    }

    pub fn watermark(&self) -> usize {
        self.watermark
    }

    pub fn stats(&self) -> WeightCastStats {
        WeightCastStats {
            version: self.version.load(Ordering::Relaxed),
            enqueued: self.enqueued.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
        }
    }

    /// Publish `weights` as the newest version.  The slot write happens
    /// *before* any lane is examined, so a concurrent apply that clears
    /// its pending flag either reads this version or a newer one.
    fn publish_version(&self, weights: Arc<[f32]>) -> u64 {
        let v = self.version.fetch_add(1, Ordering::SeqCst) + 1;
        let mut slot = self.slot.lock().unwrap();
        // Versions are monotone per caster, but under concurrent
        // broadcasts only the newest may stay in the slot.
        if v > slot.0 {
            *slot = (v, weights);
        }
        v
    }

    /// The envelope body queued on a recipient: clear the pending flag
    /// *first* (so a broadcast racing with us enqueues a fresh apply
    /// rather than losing its version), then install the newest slot
    /// contents unless this recipient already has them.
    fn apply_closure(
        &self,
        cells: &LaneCells,
    ) -> impl FnOnce(&mut A) + Send + 'static {
        let pending = cells.pending.clone();
        let applied = cells.applied.clone();
        let slot = self.slot.clone();
        let apply = self.apply.clone();
        move |state: &mut A| {
            pending.store(false, Ordering::SeqCst);
            let (v, weights) = {
                let s = slot.lock().unwrap();
                (s.0, s.1.clone())
            };
            if applied.fetch_max(v, Ordering::SeqCst) < v {
                apply(state, &weights);
            }
        }
    }

    /// The lane's cells for registry epoch `epoch`, swapping in
    /// **fresh** cells if the slot was republished since we last
    /// looked: envelopes still queued on the previous incarnation hold
    /// the old `Arc`s and can no longer touch this lane's state.  The
    /// lane epoch is monotone (`fetch_max`), so a broadcast that read
    /// the registry just before a publish can never regress the lane
    /// and wipe a newer incarnation's cells.  Callers that must keep
    /// the cells stable across their enqueue decision hold `guard`.
    fn refresh_cells(
        &self,
        guard: &mut LaneCells,
        lane: &Lane,
        epoch: u64,
    ) {
        if lane.epoch.fetch_max(epoch, Ordering::SeqCst) < epoch {
            *guard = LaneCells::fresh();
        }
    }

    fn lane_cells(&self, idx: usize, epoch: u64) -> LaneCells {
        let lane = &self.lanes[idx];
        let mut cells = lane.cells.lock().unwrap();
        self.refresh_cells(&mut cells, lane, epoch);
        cells.clone()
    }

    /// The effective depth threshold for `recipient`: the configured
    /// watermark, but never at-or-above the mailbox capacity — a
    /// recipient whose mailbox is *full* must always take the
    /// non-blocking path, or a tiny mailbox (capacity <= watermark)
    /// could park the learner.
    fn effective_watermark(&self, capacity: usize) -> usize {
        self.watermark.min(capacity.saturating_sub(1))
    }

    /// Fire-and-forget broadcast of a new weight version to every
    /// current incarnation.  Returns the published version.
    ///
    /// Per-lane delivery runs under that lane's lock, serializing
    /// concurrent broadcasters: a broadcast that coalesces on an
    /// already-pending lane can never race a shed that clears the flag
    /// with no apply queued (the coalesce waits until the shed — and
    /// its flag clear — is complete, then enqueues its own apply).
    /// The apply envelopes themselves never take the lane lock.
    pub fn broadcast(&self, weights: Arc<[f32]>) -> u64 {
        let v = self.publish_version(weights);
        for idx in 0..self.lanes.len() {
            let (handle, epoch) = self.registry.get(idx);
            let lane = &self.lanes[idx];
            let mut cells = lane.cells.lock().unwrap();
            self.refresh_cells(&mut cells, lane, epoch);
            if handle.is_poisoned() {
                // Dead recipient: nothing to deliver to, and not an
                // overload signal — `shed` stays untouched (deaths are
                // reported via actor_stats/`dead=`).  The replacement
                // resyncs via the lane's fresh cells.
                continue;
            }
            if cells.pending.swap(true, Ordering::SeqCst) {
                // An apply is already queued; it reads the slot (>= v)
                // when it runs.  The superseded broadcast is dropped —
                // drop-oldest by construction.
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let body = self.apply_closure(&cells);
            let threshold =
                self.effective_watermark(handle.mailbox_capacity());
            if handle.queue_len() > threshold {
                // Overloaded (or full) mailbox: never block the
                // learner on it.
                match handle.try_cast(body) {
                    Ok(()) => {
                        self.enqueued.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        cells.pending.store(false, Ordering::SeqCst);
                        self.shed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            } else {
                // Shallow mailbox: a (briefly) blocking cast preserves
                // the barrier plans' send-order guarantee.  Blocks at
                // most other broadcasters of this same lane, never the
                // recipient (applies don't take the lane lock).
                handle.cast(body);
                self.enqueued.fetch_add(1, Ordering::Relaxed);
            }
        }
        v
    }

    /// Broadcast and **block until every live recipient has applied**
    /// the published version (the `sync_weights` barrier).  Dead
    /// recipients are skipped; shedding does not apply — this path is
    /// the explicit synchronization point, so it queues a dedicated
    /// apply per recipient and waits on the replies.
    pub fn broadcast_sync(&self, weights: Arc<[f32]>) -> u64 {
        let v = self.publish_version(weights);
        let replies: Vec<_> = (0..self.lanes.len())
            .map(|idx| {
                let (handle, epoch) = self.registry.get(idx);
                let cells = self.lane_cells(idx, epoch);
                let applied = cells.applied.clone();
                let slot = self.slot.clone();
                let apply = self.apply.clone();
                handle.call_deferred(move |state: &mut A| {
                    let (sv, w) = {
                        let s = slot.lock().unwrap();
                        (s.0, s.1.clone())
                    };
                    if applied.fetch_max(sv, Ordering::SeqCst) < sv {
                        apply(state, &w);
                    }
                })
            })
            .collect();
        for r in replies {
            // Err = recipient died mid-sync; skipped, like sync_weights
            // always skipped dead remotes.
            let _ = r.recv();
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::spawn_group;

    struct W {
        weights: Vec<f32>,
        applies: usize,
    }

    fn group(n: usize) -> Vec<ActorHandle<W>> {
        spawn_group("reg-w", n, |_| {
            Box::new(|| W { weights: vec![], applies: 0 })
        })
    }

    #[test]
    fn publish_bumps_epoch_and_version() {
        let reg = ShardRegistry::new(group(2));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.version(), 0);
        assert_eq!(reg.epoch(0), 0);
        let (h0, e0) = reg.get(0);
        assert_eq!(e0, 0);
        let fresh = group(1).remove(0);
        let fresh_id = fresh.id();
        let e1 = reg.publish(0, fresh);
        assert_eq!(e1, 1);
        assert_eq!(reg.version(), 1);
        assert_eq!(reg.epoch(0), 1);
        let (h0b, e) = reg.get(0);
        assert_eq!(e, 1);
        assert_eq!(h0b.id(), fresh_id);
        assert_ne!(h0b.id(), h0.id());
        // Index 1 untouched.
        assert_eq!(reg.epoch(1), 0);
    }

    #[test]
    fn clones_share_publishes() {
        let reg = ShardRegistry::new(group(1));
        let view = reg.clone();
        let fresh = group(1).remove(0);
        let id = fresh.id();
        reg.publish(0, fresh);
        assert_eq!(view.version(), 1);
        assert_eq!(view.get(0).0.id(), id);
    }

    #[test]
    fn poisoned_indices_track_current_incarnation() {
        let reg = ShardRegistry::new(group(2));
        let (h1, _) = reg.get(1);
        let _ = h1.call(|_| -> () { panic!("die") });
        assert!(h1.await_poisoned(std::time::Duration::from_secs(2)));
        assert_eq!(reg.poisoned_indices(), vec![1]);
        reg.publish(1, group(1).remove(0));
        assert!(reg.poisoned_indices().is_empty());
    }

    #[test]
    fn broadcast_applies_newest_version() {
        let reg = ShardRegistry::new(group(3));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
                w.applies += 1;
            },
        );
        let v1 = caster.broadcast(vec![1.0].into());
        assert_eq!(v1, 1);
        let v2 = caster.broadcast(vec![2.0].into());
        assert_eq!(v2, 2);
        for i in 0..3 {
            let (h, _) = reg.get(i);
            // Drain: by the time a call returns, queued applies ran.
            let w = h.call(|w| w.weights.clone()).unwrap();
            assert_eq!(w, vec![2.0], "worker {i} missed the newest cast");
        }
        let s = caster.stats();
        assert_eq!(s.version, 2);
        assert!(s.enqueued >= 3, "{s:?}");
        assert_eq!(s.enqueued + s.coalesced + s.shed, 6, "{s:?}");
    }

    #[test]
    fn storm_coalesces_to_one_pending_apply_per_recipient() {
        // Park the single recipient so applies cannot run, then storm
        // broadcasts: all but the first must coalesce (or shed), and
        // when the recipient wakes it applies only the newest version.
        let reg = ShardRegistry::new(group(1));
        let caster = WeightCaster::new(reg.clone(), 4, |w: &mut W, p| {
            w.weights.clear();
            w.weights.extend_from_slice(p);
            w.applies += 1;
        });
        let (h, _) = reg.get(0);
        let gate = h.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(60));
        });
        for k in 1..=50 {
            caster.broadcast(vec![k as f32].into());
        }
        gate.recv().unwrap();
        let (weights, applies) =
            h.call(|w| (w.weights.clone(), w.applies)).unwrap();
        assert_eq!(weights, vec![50.0], "stale version survived");
        assert!(applies <= 3, "applies={applies}: storm was not coalesced");
        let s = caster.stats();
        assert!(s.coalesced + s.shed >= 47, "{s:?}");
    }

    #[test]
    fn broadcast_never_blocks_on_overloaded_recipient() {
        // A recipient with a tiny mailbox, parked so it drains nothing:
        // broadcasts beyond the watermark must return promptly (shed),
        // not park the broadcaster.
        let slow = ActorHandle::spawn_with_capacity("reg-slow", 2, || W {
            weights: vec![],
            applies: 0,
        });
        let reg = ShardRegistry::new(vec![slow.clone()]);
        let caster = WeightCaster::new(reg, 1, |w: &mut W, p| {
            w.weights.clear();
            w.weights.extend_from_slice(p);
        });
        let gate = slow.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(80));
        });
        // Fill the mailbox past the watermark with unrelated casts.
        while slow.try_cast(|_| {}).is_ok() {}
        let start = std::time::Instant::now();
        for k in 1..=20 {
            caster.broadcast(vec![k as f32].into());
        }
        assert!(
            start.elapsed() < std::time::Duration::from_millis(50),
            "broadcast blocked on an overloaded recipient"
        );
        assert!(caster.stats().shed + caster.stats().coalesced >= 19);
        gate.recv().unwrap();
    }

    #[test]
    fn republished_lane_resyncs_replacement() {
        let reg = ShardRegistry::new(group(1));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        caster.broadcast(vec![1.0].into());
        let (old, _) = reg.get(0);
        let _ = old.call(|_| -> () { panic!("die") });
        assert!(old.await_poisoned(std::time::Duration::from_secs(2)));
        // Replacement arrives with blank weights.
        reg.publish(0, group(1).remove(0));
        caster.broadcast(vec![2.0].into());
        let (fresh, _) = reg.get(0);
        assert_eq!(
            fresh.call(|w| w.weights.clone()).unwrap(),
            vec![2.0],
            "replacement did not receive the post-publish broadcast"
        );
    }

    #[test]
    fn broadcast_sync_blocks_until_applied() {
        let reg = ShardRegistry::new(group(2));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        caster.broadcast_sync(vec![7.5].into());
        for i in 0..2 {
            // No drain call needed: sync already waited.
            let (h, _) = reg.get(i);
            let snap = h.try_cast(|_| {});
            assert!(snap.is_ok());
            assert_eq!(h.call(|w| w.weights.clone()).unwrap(), vec![7.5]);
        }
    }
}
