//! The elastic shard registry + versioned weight casts — the two pieces
//! that make the control plane *elastic* instead of restart-on-rebuild.
//!
//! [`ShardRegistry`] is a versioned shard-index -> handle indirection.
//! A dataflow plan built over a registry (see `ParIter::from_registry`)
//! resolves each dispatch through the registry instead of cloning actor
//! handles at plan-build time, so an owner that replaces a dead actor
//! (`WorkerSet::restart_dead`) can [`ShardRegistry::publish`] the
//! replacement and **running** gathers pick it up on their next dispatch
//! — no plan rebuild.  Every slot carries an **epoch** (incarnation
//! number) so a gather can tell a completion of the dead incarnation
//! from one of its replacement: stale death notices must not retire the
//! fresh actor, and stale items must not be attributed to it.
//!
//! Membership is **growable and shrinkable under live traffic**:
//!
//! * [`ShardRegistry::grow`] appends a fresh slot (epoch 0) with an
//!   atomic len bump, guarded so shard indices never overflow the
//!   16-bit shard field of the `(epoch << 16) | shard` completion tag
//!   ([`MAX_SHARDS`]).  Running gathers discover the new index through
//!   the publish counter and prime credits for it mid-stream (async) or
//!   admit it at the next round boundary (sync).
//! * [`ShardRegistry::retire`] tombstones a slot: the registry drops its
//!   handle (so the actor thread can exit once in-flight work drains),
//!   gathers stop dispatching to the index and discard its in-flight
//!   completions through the same epoch/mode machinery that discards a
//!   dead incarnation's.  A later `publish` into the slot (epoch bump)
//!   rejoins it.
//!
//! [`WeightCaster`] turns weight broadcasts into *versioned casts* with
//! a drop-oldest eviction policy driven by the per-actor queue-depth
//! telemetry: the newest parameter vector lives in one shared slot, each
//! recipient holds at most one queued "apply latest" envelope
//! (superseded broadcasts coalesce into it), and a recipient whose
//! mailbox depth exceeds the watermark — or whose applied version lags
//! the published one by more than [`WeightCaster::stale_after`] — is
//! never blocked on: the cast is shed and the worker catches up on the
//! next broadcast.  The learner therefore never stalls behind an
//! overloaded, stale, or dying rollout worker.  Lanes grow with the
//! registry, so freshly added shards receive broadcasts without caster
//! reconstruction.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{faults, ActorHandle, Reply};

// ---------------------------------------------------------------------
// ShardRegistry
// ---------------------------------------------------------------------

/// Hard bound on registry size: gather completion tags pack the shard
/// index into the low bits (see [`crate::actor::tags`]), so index
/// `MAX_SHARDS` would alias epoch bits and corrupt completion
/// attribution.  [`ShardRegistry::grow`] refuses to cross it.
pub use super::tags::MAX_SHARDS;

/// The error [`ShardRegistry::grow`] returns at the tag-space bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryFull {
    /// The registry's configured shard cap (<= [`MAX_SHARDS`]).
    pub max_shards: usize,
}

impl std::fmt::Display for RegistryFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "shard registry is full: {} slots would overflow the 16-bit \
             shard tag space",
            self.max_shards
        )
    }
}

impl std::error::Error for RegistryFull {}

struct Slot<A> {
    /// `None` = tombstoned ([`ShardRegistry::retire`]): the registry
    /// holds no handle, so the retired actor's thread can exit once its
    /// remaining senders drop and its mailbox drains.
    handle: Option<ActorHandle<A>>,
    epoch: u64,
}

struct RegistryInner<A> {
    slots: Mutex<Vec<Slot<A>>>,
    /// Bumped on every publish/grow/retire — a cheap "anything
    /// changed?" gate so gathers only rescan membership when it could
    /// actually have moved.
    version: AtomicU64,
    max_shards: usize,
    /// Lifetime membership counters (for `TrainResult` scale events).
    grown: AtomicU64,
    retired: AtomicU64,
}

/// A cloneable, versioned shard-index -> actor-handle table.  All clones
/// share the same slots: a `publish`/`grow`/`retire` through one is
/// visible to every holder (the running gathers) on their next `get` /
/// membership scan.
pub struct ShardRegistry<A: 'static> {
    inner: Arc<RegistryInner<A>>,
}

impl<A: 'static> Clone for ShardRegistry<A> {
    fn clone(&self) -> Self {
        ShardRegistry { inner: self.inner.clone() }
    }
}

impl<A: 'static> std::fmt::Debug for ShardRegistry<A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ShardRegistry(len={}, live={}, version={})",
            self.len(),
            self.num_live(),
            self.version()
        )
    }
}

impl<A: 'static> ShardRegistry<A> {
    /// Wrap an initial set of shard actors (epoch 0 each).  The shard
    /// count can later [`ShardRegistry::grow`] up to [`MAX_SHARDS`].
    pub fn new(handles: Vec<ActorHandle<A>>) -> Self {
        Self::with_max_shards(handles, MAX_SHARDS)
    }

    /// [`ShardRegistry::new`] with a lower growth cap — the guard path
    /// is identical to the production [`MAX_SHARDS`] one, so tests can
    /// exercise tag-space exhaustion without 65k actor threads.
    pub fn with_max_shards(
        handles: Vec<ActorHandle<A>>,
        max_shards: usize,
    ) -> Self {
        let max_shards = max_shards.min(MAX_SHARDS);
        assert!(
            handles.len() <= max_shards,
            "initial shard count {} exceeds the {max_shards}-slot cap",
            handles.len()
        );
        let slots = handles
            .into_iter()
            .map(|handle| Slot { handle: Some(handle), epoch: 0 })
            .collect();
        ShardRegistry {
            inner: Arc::new(RegistryInner {
                slots: Mutex::new(slots),
                version: AtomicU64::new(0),
                max_shards,
                grown: AtomicU64::new(0),
                retired: AtomicU64::new(0),
            }),
        }
    }

    /// Total slot count, tombstoned slots included — the bound on shard
    /// indices (and therefore on tag space consumed).  Monotone.
    pub fn len(&self) -> usize {
        self.inner.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slots currently holding an incarnation (not tombstoned).
    pub fn num_live(&self) -> usize {
        let slots = self.inner.slots.lock().unwrap();
        slots.iter().filter(|s| s.handle.is_some()).count()
    }

    /// Indices of live (non-tombstoned) slots, ascending.
    pub fn live_indices(&self) -> Vec<usize> {
        let slots = self.inner.slots.lock().unwrap();
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.handle.is_some())
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices of tombstoned slots, ascending (reusable by `publish`).
    pub fn retired_indices(&self) -> Vec<usize> {
        let slots = self.inner.slots.lock().unwrap();
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.handle.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// The current incarnation behind `idx`: (handle clone, epoch).
    /// Panics on a tombstoned slot — callers that race membership
    /// changes use [`ShardRegistry::get_live`].
    pub fn get(&self, idx: usize) -> (ActorHandle<A>, u64) {
        self.get_live(idx)
            .unwrap_or_else(|| panic!("shard slot {idx} is retired"))
    }

    /// The current incarnation behind `idx`, or `None` if the slot is
    /// tombstoned.
    pub fn get_live(&self, idx: usize) -> Option<(ActorHandle<A>, u64)> {
        let slots = self.inner.slots.lock().unwrap();
        let s = &slots[idx];
        s.handle.as_ref().map(|h| (h.clone(), s.epoch))
    }

    /// The current epoch of `idx` without cloning the handle (epochs
    /// survive tombstoning; only `publish` moves them).
    pub fn epoch(&self, idx: usize) -> u64 {
        self.inner.slots.lock().unwrap()[idx].epoch
    }

    /// True if `idx` is currently tombstoned ([`ShardRegistry::retire`]).
    pub fn is_retired(&self, idx: usize) -> bool {
        self.inner.slots.lock().unwrap()[idx].handle.is_none()
    }

    /// Replace (or revive) the incarnation behind `idx`, bumping its
    /// epoch and the registry version.  Returns the new epoch.
    /// In-flight work on the old incarnation resolves under the old
    /// epoch and is discarded by epoch-aware consumers.  Publishing
    /// into a tombstoned slot rejoins it (the scale-up slot-reuse
    /// path).
    pub fn publish(&self, idx: usize, handle: ActorHandle<A>) -> u64 {
        let epoch = {
            let mut slots = self.inner.slots.lock().unwrap();
            let s = &mut slots[idx];
            s.handle = Some(handle);
            s.epoch += 1;
            s.epoch
        };
        self.inner.version.fetch_add(1, Ordering::Release);
        epoch
    }

    /// Append a fresh slot (epoch 0) for `handle`, returning its shard
    /// index — the atomic len bump + epoch-0 publish behind
    /// `WorkerSet::add_worker`.  Fails with [`RegistryFull`] instead of
    /// handing out an index that would overflow the 16-bit shard field
    /// of gather completion tags.
    pub fn grow(
        &self,
        handle: ActorHandle<A>,
    ) -> Result<usize, RegistryFull> {
        let idx = {
            let mut slots = self.inner.slots.lock().unwrap();
            if slots.len() >= self.inner.max_shards {
                return Err(RegistryFull {
                    max_shards: self.inner.max_shards,
                });
            }
            slots.push(Slot { handle: Some(handle), epoch: 0 });
            slots.len() - 1
        };
        self.inner.grown.fetch_add(1, Ordering::Relaxed);
        self.inner.version.fetch_add(1, Ordering::Release);
        Ok(idx)
    }

    /// Tombstone slot `idx`, returning the handle it held (`None` if it
    /// was already tombstoned).  The epoch is untouched: in-flight
    /// submissions to the retired incarnation stay attributable and are
    /// drained/discarded by the gathers' existing epoch machinery.  The
    /// returned handle is the registry's only reference — once the
    /// caller drops it (and any in-flight messages execute) the actor
    /// thread exits.
    pub fn retire(&self, idx: usize) -> Option<ActorHandle<A>> {
        let handle = {
            let mut slots = self.inner.slots.lock().unwrap();
            slots[idx].handle.take()
        };
        if handle.is_some() {
            self.inner.retired.fetch_add(1, Ordering::Relaxed);
            self.inner.version.fetch_add(1, Ordering::Release);
        }
        handle
    }

    /// Lifetime membership counters: slots grown and incarnations
    /// retired (tombstoned) since construction.
    pub fn membership_counters(&self) -> (u64, u64) {
        (
            self.inner.grown.load(Ordering::Relaxed),
            self.inner.retired.load(Ordering::Relaxed),
        )
    }

    /// Publish counter (any index).  Consumers cache the last value they
    /// acted on and rescan membership only when it moves.
    pub fn version(&self) -> u64 {
        self.inner.version.load(Ordering::Acquire)
    }

    /// Snapshot of the current handle behind every **live** index.
    pub fn handles(&self) -> Vec<ActorHandle<A>> {
        let slots = self.inner.slots.lock().unwrap();
        slots.iter().filter_map(|s| s.handle.clone()).collect()
    }

    /// Indices whose *current* incarnation is poisoned (tombstoned
    /// slots excluded — a removed worker is not restartable).
    pub fn poisoned_indices(&self) -> Vec<usize> {
        let slots = self.inner.slots.lock().unwrap();
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.handle.as_ref().is_some_and(|h| h.is_poisoned())
            })
            .map(|(i, _)| i)
            .collect()
    }
}

// ---------------------------------------------------------------------
// WeightCaster
// ---------------------------------------------------------------------

/// Mailbox depth beyond which a broadcast refuses to block on a
/// recipient: above it the cast is non-blocking and sheds on `Full`
/// (the worker is overloaded; it will pick up the newest weights from
/// the shared slot whenever its queued apply — or the next broadcast —
/// runs).
pub const DEFAULT_CAST_WATERMARK: usize = 8;

/// Default staleness bound: a recipient whose applied weight version
/// lags the published one by more than this many versions is treated
/// like an overloaded one — casts to it never block the learner and
/// shed on `Full` (counted separately as `shed_stale`).
pub const DEFAULT_STALE_VERSIONS: u64 = 8;

/// The per-incarnation cells an apply closure captures.  A republished
/// slot gets **fresh** cells (not a reset): envelopes still queued on
/// the previous incarnation hold clones of the old `Arc`s, so whatever
/// they do after the swap can never mark the replacement as pending or
/// as having applied a version it did not.
#[derive(Clone)]
struct LaneCells {
    /// True while an "apply latest weights" envelope is queued in (or
    /// executing on) this recipient's mailbox.  While set, broadcasts
    /// coalesce: the queued envelope reads the newest slot anyway.
    pending: Arc<AtomicBool>,
    /// Highest weight version this recipient has applied.
    applied: Arc<AtomicU64>,
}

impl LaneCells {
    fn fresh() -> Self {
        LaneCells {
            pending: Arc::new(AtomicBool::new(false)),
            applied: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// Per-recipient broadcast lane: the current incarnation's cells plus
/// the registry epoch they belong to.
struct Lane {
    cells: Mutex<LaneCells>,
    epoch: AtomicU64,
}

impl Lane {
    fn fresh() -> Self {
        Lane {
            cells: Mutex::new(LaneCells::fresh()),
            epoch: AtomicU64::new(0),
        }
    }
}

/// Point-in-time counters for one caster (attached to `TrainResult` by
/// the metrics operators).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WeightCastStats {
    /// Newest published weight version.
    pub version: u64,
    /// Apply envelopes actually enqueued.
    pub enqueued: u64,
    /// Broadcasts absorbed by an already-queued apply (drop-oldest:
    /// the queued apply delivers the newer version instead).
    pub coalesced: u64,
    /// Broadcasts dropped entirely because the recipient was over the
    /// watermark (or too stale) *and* its mailbox was full (load
    /// shedding).
    pub shed: u64,
    /// The subset of `shed` that hit a recipient already lagging the
    /// published version by more than `stale_after` — the "this worker
    /// is falling behind, not just momentarily busy" alarm.
    pub shed_stale: u64,
    /// The caster's configured staleness bound (versions of lag beyond
    /// which casts never block).
    pub stale_after: u64,
}

/// Versioned weight broadcasts over a [`ShardRegistry`], with
/// drop-oldest coalescing and watermark/staleness-gated load shedding.
///
/// Invariants:
/// * at most **one** apply envelope is queued per recipient at a time —
///   a weight storm can never fill a worker's mailbox;
/// * an apply envelope always installs the **newest** slot contents at
///   execution time, and skips entirely if the recipient has already
///   applied that version (monotonic, idempotent);
/// * `broadcast` never blocks on a recipient whose queue depth exceeds
///   the watermark **or** whose applied version lags the published one
///   by more than `stale_after` — overloaded/lagging workers shed
///   superseded versions instead of backpressuring the learner;
/// * lanes grow lazily with the registry, so shards added by
///   `ShardRegistry::grow` receive broadcasts without caster rebuild,
///   and tombstoned slots are skipped.
pub struct WeightCaster<A: 'static> {
    registry: ShardRegistry<A>,
    /// (version, weights) — the newest published parameters.
    slot: Arc<Mutex<(u64, Arc<[f32]>)>>,
    version: AtomicU64,
    /// Grow-only; index-aligned with the registry's slots.
    lanes: Mutex<Vec<Arc<Lane>>>,
    watermark: usize,
    stale_after: u64,
    apply: Arc<dyn Fn(&mut A, &[f32]) + Send + Sync>,
    enqueued: AtomicU64,
    coalesced: AtomicU64,
    shed: AtomicU64,
    shed_stale: AtomicU64,
}

impl<A: 'static> WeightCaster<A> {
    /// `apply` installs a parameter vector into a recipient's state
    /// (e.g. `|w, p| w.set_weights(p)`); it runs on the actor thread.
    /// Staleness shedding defaults to [`DEFAULT_STALE_VERSIONS`].
    pub fn new(
        registry: ShardRegistry<A>,
        watermark: usize,
        apply: impl Fn(&mut A, &[f32]) + Send + Sync + 'static,
    ) -> Self {
        Self::with_staleness(
            registry,
            watermark,
            DEFAULT_STALE_VERSIONS,
            apply,
        )
    }

    /// [`WeightCaster::new`] with an explicit staleness bound: casts to
    /// a recipient lagging more than `stale_after` versions never block
    /// the broadcaster (and shed on `Full`).
    pub fn with_staleness(
        registry: ShardRegistry<A>,
        watermark: usize,
        stale_after: u64,
        apply: impl Fn(&mut A, &[f32]) + Send + Sync + 'static,
    ) -> Self {
        let lanes = (0..registry.len()).map(|_| Arc::new(Lane::fresh()));
        WeightCaster {
            lanes: Mutex::new(lanes.collect()),
            registry,
            slot: Arc::new(Mutex::new((0, Arc::from(Vec::<f32>::new())))),
            version: AtomicU64::new(0),
            watermark,
            stale_after,
            apply: Arc::new(apply),
            enqueued: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            shed_stale: AtomicU64::new(0),
        }
    }

    pub fn registry(&self) -> &ShardRegistry<A> {
        &self.registry
    }

    pub fn watermark(&self) -> usize {
        self.watermark
    }

    /// The configured staleness bound (versions of lag beyond which
    /// casts to a recipient never block).
    pub fn stale_after(&self) -> u64 {
        self.stale_after
    }

    pub fn stats(&self) -> WeightCastStats {
        WeightCastStats {
            // SeqCst to pair with `publish_version`'s fetch_add: a
            // caller that observed a broadcast return must read a
            // version at least that new here (the autoscaler and the
            // sync_weights barrier both compare against it).
            version: self.version.load(Ordering::SeqCst),
            enqueued: self.enqueued.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            shed_stale: self.shed_stale.load(Ordering::Relaxed),
            stale_after: self.stale_after,
        }
    }

    /// The lane behind `idx`, growing the lane table to the registry's
    /// current width on demand (shards added by `grow` get lanes the
    /// first time anyone addresses them).
    fn lane(&self, idx: usize) -> Arc<Lane> {
        let mut lanes = self.lanes.lock().unwrap();
        while lanes.len() <= idx {
            lanes.push(Arc::new(Lane::fresh()));
        }
        lanes[idx].clone()
    }

    /// Register incarnation `epoch` of shard `idx` as already carrying
    /// the weights of version `up_to` (the owner synced it out-of-band,
    /// e.g. `WorkerSet::add_worker` pushing the learner's parameters
    /// before the first dispatch): ensures the lane exists, refreshes
    /// it to `epoch`, and marks `up_to` applied so the next broadcast
    /// of that version does not redundantly re-deliver.
    ///
    /// `up_to` must be a version the caller read **before** fetching
    /// the weights it pushed — marking the *current* version here would
    /// race a broadcast published between the fetch and this call and
    /// leave the recipient silently one version stale.  Conservative
    /// (older) values only cost one redundant redelivery.
    pub fn attach(&self, idx: usize, epoch: u64, up_to: u64) {
        let lane = self.lane(idx);
        let mut cells = lane.cells.lock().unwrap();
        self.refresh_cells(&mut cells, &lane, epoch);
        cells.applied.fetch_max(up_to, Ordering::SeqCst);
    }

    /// The applied weight version of every lane, index-aligned with the
    /// registry (the scale-out soak asserts convergence through this).
    pub fn applied_versions(&self) -> Vec<u64> {
        let width = self.registry.len();
        (0..width)
            .map(|idx| {
                let lane = self.lane(idx);
                let cells = lane.cells.lock().unwrap();
                cells.applied.load(Ordering::SeqCst)
            })
            .collect()
    }

    /// Publish `weights` as the newest version.  The slot write happens
    /// *before* any lane is examined, so a concurrent apply that clears
    /// its pending flag either reads this version or a newer one.
    fn publish_version(&self, weights: Arc<[f32]>) -> u64 {
        let v = self.version.fetch_add(1, Ordering::SeqCst) + 1;
        let mut slot = self.slot.lock().unwrap();
        // Versions are monotone per caster, but under concurrent
        // broadcasts only the newest may stay in the slot.
        if v > slot.0 {
            *slot = (v, weights);
        }
        v
    }

    /// The envelope body queued on a recipient: clear the pending flag
    /// *first* (so a broadcast racing with us enqueues a fresh apply
    /// rather than losing its version), then install the newest slot
    /// contents unless this recipient already has them.
    fn apply_closure(
        &self,
        cells: &LaneCells,
    ) -> impl FnOnce(&mut A) + Send + 'static {
        let pending = cells.pending.clone();
        let applied = cells.applied.clone();
        let slot = self.slot.clone();
        let apply = self.apply.clone();
        move |state: &mut A| {
            pending.store(false, Ordering::SeqCst);
            let (v, weights) = {
                let s = slot.lock().unwrap();
                (s.0, s.1.clone())
            };
            if applied.fetch_max(v, Ordering::SeqCst) < v {
                apply(state, &weights);
            }
        }
    }

    /// The lane's cells for registry epoch `epoch`, swapping in
    /// **fresh** cells if the slot was republished since we last
    /// looked: envelopes still queued on the previous incarnation hold
    /// the old `Arc`s and can no longer touch this lane's state.  The
    /// lane epoch is monotone (`fetch_max`), so a broadcast that read
    /// the registry just before a publish can never regress the lane
    /// and wipe a newer incarnation's cells.  Callers that must keep
    /// the cells stable across their enqueue decision hold `guard`.
    fn refresh_cells(&self, guard: &mut LaneCells, lane: &Lane, epoch: u64) {
        if lane.epoch.fetch_max(epoch, Ordering::SeqCst) < epoch {
            *guard = LaneCells::fresh();
        }
    }

    fn lane_cells(&self, idx: usize, epoch: u64) -> LaneCells {
        let lane = self.lane(idx);
        let mut cells = lane.cells.lock().unwrap();
        self.refresh_cells(&mut cells, &lane, epoch);
        cells.clone()
    }

    /// The effective depth threshold for `recipient`: the configured
    /// watermark, but never at-or-above the mailbox capacity — a
    /// recipient whose mailbox is *full* must always take the
    /// non-blocking path, or a tiny mailbox (capacity <= watermark)
    /// could park the learner.
    fn effective_watermark(&self, capacity: usize) -> usize {
        self.watermark.min(capacity.saturating_sub(1))
    }

    /// Fire-and-forget broadcast of a new weight version to every
    /// current live incarnation (tombstoned slots are skipped; shards
    /// grown since the last broadcast get lanes on the fly).  Returns
    /// the published version.
    ///
    /// Per-lane delivery runs under that lane's lock, serializing
    /// concurrent broadcasters: a broadcast that coalesces on an
    /// already-pending lane can never race a shed that clears the flag
    /// with no apply queued (the coalesce waits until the shed — and
    /// its flag clear — is complete, then enqueues its own apply).
    /// The apply envelopes themselves never take the lane lock.
    pub fn broadcast(&self, weights: Arc<[f32]>) -> u64 {
        let v = self.publish_version(weights);
        for idx in 0..self.registry.len() {
            let Some((handle, epoch)) = self.registry.get_live(idx) else {
                // Tombstoned slot: the worker was removed; nothing to
                // deliver and nothing to count.
                continue;
            };
            if faults::send_failpoint(faults::SITE_CASTER_LANE, handle.name())
                .is_some()
            {
                // Injected lane loss (drop or artificial full-mailbox):
                // the cast to this recipient is shed, exactly like a
                // real overload — the worker catches up on the next
                // broadcast.
                self.shed.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let lane = self.lane(idx);
            let mut cells = lane.cells.lock().unwrap();
            self.refresh_cells(&mut cells, &lane, epoch);
            if handle.is_poisoned() {
                // Dead recipient: nothing to deliver to, and not an
                // overload signal — `shed` stays untouched (deaths are
                // reported via actor_stats/`dead=`).  The replacement
                // resyncs via the lane's fresh cells.
                continue;
            }
            // Staleness gate: a recipient already lagging more than
            // `stale_after` versions is falling behind — treat it like
            // an overloaded one and never block the learner on it.
            let lag = v.saturating_sub(cells.applied.load(Ordering::SeqCst));
            let stale = lag > self.stale_after;
            if cells.pending.swap(true, Ordering::SeqCst) {
                // An apply is already queued; it reads the slot (>= v)
                // when it runs.  The superseded broadcast is dropped —
                // drop-oldest by construction.
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let body = self.apply_closure(&cells);
            let threshold =
                self.effective_watermark(handle.mailbox_capacity());
            if stale || handle.queue_len() > threshold {
                // Overloaded, stale, or full mailbox: never block the
                // learner on it.
                // flowlint: allow(lock-discipline) -- lane lock serializes broadcasters only; non-blocking send, and apply envelopes never take the lane lock
                match handle.try_cast(body) {
                    Ok(()) => {
                        self.enqueued.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        cells.pending.store(false, Ordering::SeqCst);
                        self.shed.fetch_add(1, Ordering::Relaxed);
                        if stale {
                            self.shed_stale.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            } else {
                // Shallow mailbox: a (briefly) blocking cast preserves
                // the barrier plans' send-order guarantee.  Blocks at
                // most other broadcasters of this same lane, never the
                // recipient (applies don't take the lane lock).
                // flowlint: allow(lock-discipline) -- below-watermark cast; can only block other broadcasters of this lane, and applies never take the lane lock
                handle.cast(body);
                self.enqueued.fetch_add(1, Ordering::Relaxed);
            }
        }
        v
    }

    /// Broadcast and **block until every waited-on live recipient has
    /// applied** the published version (the `sync_weights` barrier).
    /// Dead and tombstoned recipients are skipped at dispatch, and —
    /// the part the first version got wrong — the **wait set is not
    /// frozen at entry**: a recipient that is killed, removed
    /// (`ShardRegistry::retire`), or replaced (`publish`) *mid-barrier*
    /// is dropped from the wait set instead of wedging `sync_weights`
    /// forever behind a worker that will never drain its mailbox.
    /// Likewise a recipient whose mailbox is already **full** at
    /// dispatch gets the coalescing non-blocking apply (and no wait)
    /// rather than parking the broadcaster in a blocking send.
    ///
    /// Applies are versioned and idempotent, so an apply that still
    /// executes after its recipient left the wait set (e.g. a retired
    /// worker draining its mailbox on the way out) is harmless.
    pub fn broadcast_sync(&self, weights: Arc<[f32]>) -> u64 {
        struct Pending<A: 'static> {
            idx: usize,
            epoch: u64,
            handle: ActorHandle<A>,
            reply: Reply<()>,
        }
        let v = self.publish_version(weights);
        let mut pending: Vec<Pending<A>> = Vec::new();
        for idx in 0..self.registry.len() {
            let Some((handle, epoch)) = self.registry.get_live(idx) else {
                continue; // tombstoned
            };
            if handle.is_poisoned() {
                continue; // dead: skipped, like sync_weights always did
            }
            if faults::send_failpoint(faults::SITE_CASTER_LANE, handle.name())
                .is_some()
            {
                // Injected lane loss: shed the cast and do not wait on
                // this recipient — the barrier must not wedge behind an
                // injected fault any more than behind a real one.
                self.shed.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let cells = self.lane_cells(idx, epoch);
            let applied = cells.applied.clone();
            let slot = self.slot.clone();
            let apply = self.apply.clone();
            // Non-blocking enqueue: the room check and the ring write
            // are one atomic operation, so a producer racing us can
            // never leave the barrier parked in a blocking send that
            // mid-barrier removal cannot unwedge.
            match handle.try_call_deferred(move |state: &mut A| {
                let (sv, w) = {
                    let s = slot.lock().unwrap();
                    (s.0, s.1.clone())
                };
                if applied.fetch_max(sv, Ordering::SeqCst) < sv {
                    apply(state, &w);
                }
            }) {
                Ok(reply) => {
                    pending.push(Pending { idx, epoch, handle, reply });
                }
                Err(_) => {
                    // Full mailbox (or a just-died recipient): fall
                    // back to the coalescing one-pending-apply path
                    // (under the lane lock, same discipline as
                    // `broadcast`) and do not wait on this recipient —
                    // it catches up when it drains.
                    let lane = self.lane(idx);
                    let mut cells = lane.cells.lock().unwrap();
                    self.refresh_cells(&mut cells, &lane, epoch);
                    if cells.pending.swap(true, Ordering::SeqCst) {
                        self.coalesced.fetch_add(1, Ordering::Relaxed);
                    } else {
                        let body = self.apply_closure(&cells);
                        // flowlint: allow(lock-discipline) -- non-blocking fallback under the lane lock, same discipline as broadcast's shed path
                        match handle.try_cast(body) {
                            Ok(()) => {
                                self.enqueued
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                cells
                                    .pending
                                    .store(false, Ordering::SeqCst);
                                self.shed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
        }
        // Sweep the wait set instead of blocking on each reply in turn:
        // membership can move under the barrier, and a removed slot's
        // apply may legitimately never run (its actor exits with the
        // envelope still queued behind a stalled message).  Each pass
        // parks (condvar, 1ms bound) on the first pending reply, so a
        // prompt apply wakes the barrier immediately — no spin, no
        // poll-tick latency on the healthy path.
        while !pending.is_empty() {
            let _ = pending[0]
                .reply
                .recv_timeout(std::time::Duration::from_millis(1));
            pending.retain(|p| {
                if p.reply.try_recv().is_some() {
                    return false; // applied (or resolved via death guard)
                }
                if p.handle.is_poisoned() {
                    return false; // killed mid-barrier
                }
                match self.registry.get_live(p.idx) {
                    // Removed mid-barrier: stop waiting on it.
                    None => false,
                    // Replaced mid-barrier: the old incarnation's apply
                    // no longer gates anything.
                    Some((_, ep)) if ep != p.epoch => false,
                    Some(_) => true,
                }
            });
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::spawn_group;

    struct W {
        weights: Vec<f32>,
        applies: usize,
    }

    fn group(n: usize) -> Vec<ActorHandle<W>> {
        spawn_group("reg-w", n, |_| {
            Box::new(|| W { weights: vec![], applies: 0 })
        })
    }

    #[test]
    fn publish_bumps_epoch_and_version() {
        let reg = ShardRegistry::new(group(2));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.version(), 0);
        assert_eq!(reg.epoch(0), 0);
        let (h0, e0) = reg.get(0);
        assert_eq!(e0, 0);
        let fresh = group(1).remove(0);
        let fresh_id = fresh.id();
        let e1 = reg.publish(0, fresh);
        assert_eq!(e1, 1);
        assert_eq!(reg.version(), 1);
        assert_eq!(reg.epoch(0), 1);
        let (h0b, e) = reg.get(0);
        assert_eq!(e, 1);
        assert_eq!(h0b.id(), fresh_id);
        assert_ne!(h0b.id(), h0.id());
        // Index 1 untouched.
        assert_eq!(reg.epoch(1), 0);
    }

    #[test]
    fn clones_share_publishes() {
        let reg = ShardRegistry::new(group(1));
        let view = reg.clone();
        let fresh = group(1).remove(0);
        let id = fresh.id();
        reg.publish(0, fresh);
        assert_eq!(view.version(), 1);
        assert_eq!(view.get(0).0.id(), id);
    }

    #[test]
    fn grow_appends_epoch_zero_slots() {
        let reg = ShardRegistry::new(group(2));
        let view = reg.clone();
        let fresh = group(1).remove(0);
        let id = fresh.id();
        let idx = reg.grow(fresh).unwrap();
        assert_eq!(idx, 2);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.num_live(), 3);
        assert_eq!(reg.epoch(2), 0);
        assert_eq!(reg.version(), 1, "grow must move the publish counter");
        // Clones share growth (that is how running gathers discover it).
        assert_eq!(view.len(), 3);
        assert_eq!(view.get(2).0.id(), id);
        assert_eq!(reg.membership_counters(), (1, 0));
    }

    #[test]
    fn grow_refuses_beyond_tag_space() {
        let reg = ShardRegistry::with_max_shards(group(2), 3);
        assert_eq!(reg.grow(group(1).remove(0)).unwrap(), 2);
        // A 4th slot would exceed the cap: error out, nothing corrupted.
        let err = reg.grow(group(1).remove(0)).unwrap_err();
        assert_eq!(err, RegistryFull { max_shards: 3 });
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.num_live(), 3);
        assert!(err.to_string().contains("16-bit"));
        // Existing slots unharmed.
        assert_eq!(reg.epoch(2), 0);
        assert!(reg.get_live(2).is_some());
    }

    #[test]
    fn retire_tombstones_and_publish_revives() {
        let reg = ShardRegistry::new(group(3));
        let (h1, _) = reg.get(1);
        let taken = reg.retire(1).expect("slot 1 was live");
        assert_eq!(taken.id(), h1.id());
        assert_eq!(reg.version(), 1);
        assert!(reg.is_retired(1));
        assert_eq!(reg.num_live(), 2);
        assert_eq!(reg.len(), 3, "tombstones keep their index");
        assert_eq!(reg.live_indices(), vec![0, 2]);
        assert_eq!(reg.retired_indices(), vec![1]);
        assert!(reg.get_live(1).is_none());
        assert_eq!(reg.handles().len(), 2);
        // Double-retire is a no-op.
        assert!(reg.retire(1).is_none());
        assert_eq!(reg.version(), 1);
        assert_eq!(reg.membership_counters(), (0, 1));
        // Epoch survives the tombstone; publish into the slot revives
        // it with a bumped epoch (the rejoin signal gathers watch).
        assert_eq!(reg.epoch(1), 0);
        let ep = reg.publish(1, group(1).remove(0));
        assert_eq!(ep, 1);
        assert!(!reg.is_retired(1));
        assert_eq!(reg.num_live(), 3);
    }

    #[test]
    fn poisoned_indices_track_current_incarnation() {
        let reg = ShardRegistry::new(group(2));
        let (h1, _) = reg.get(1);
        let _ = h1.call(|_| -> () { panic!("die") });
        assert!(h1.await_poisoned(std::time::Duration::from_secs(2)));
        assert_eq!(reg.poisoned_indices(), vec![1]);
        reg.publish(1, group(1).remove(0));
        assert!(reg.poisoned_indices().is_empty());
    }

    #[test]
    fn retired_slot_is_not_poisoned() {
        let reg = ShardRegistry::new(group(2));
        let (h0, _) = reg.get(0);
        let _ = h0.call(|_| -> () { panic!("die") });
        assert!(h0.await_poisoned(std::time::Duration::from_secs(2)));
        // Removing the dead worker clears it from the restartable set.
        reg.retire(0);
        assert!(reg.poisoned_indices().is_empty());
    }

    #[test]
    fn injected_lane_fault_sheds_cast_without_stalling() {
        let remotes = spawn_group("cast-flt-w", 2, |_| {
            Box::new(|| W { weights: vec![], applies: 0 })
        });
        let reg = ShardRegistry::new(remotes);
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
                w.applies += 1;
            },
        );
        let id = faults::inject(
            faults::SITE_CASTER_LANE,
            Some("cast-flt-w-1"),
            faults::FaultAction::DropReply,
        );
        // The barrier must complete off the healthy lane instead of
        // wedging behind the injected loss.
        caster.broadcast_sync(vec![1.0].into());
        let (h0, _) = reg.get(0);
        assert_eq!(h0.call(|w| w.weights.clone()).unwrap(), vec![1.0]);
        let (h1, _) = reg.get(1);
        assert!(h1.call(|w| w.weights.clone()).unwrap().is_empty());
        assert_eq!(caster.stats().shed, 1, "injected loss counts as shed");
        faults::clear(id);
        // The next broadcast heals the lane.
        caster.broadcast_sync(vec![2.0].into());
        assert_eq!(h1.call(|w| w.weights.clone()).unwrap(), vec![2.0]);
    }

    #[test]
    fn stats_version_joins_the_publish_total_order() {
        // Regression for a Relaxed `stats()` read of `version`: the
        // counter is published with a SeqCst fetch_add, and readers
        // (autoscaler, staleness gates) rely on it being monotone in
        // the same total order — it must never appear to run backwards
        // under racing broadcasts, and a caller that observed
        // `broadcast` return `v` must read at least `v`.
        let reg = ShardRegistry::new(group(1));
        let caster = Arc::new(WeightCaster::new(
            reg,
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
                w.applies += 1;
            },
        ));
        let c = caster.clone();
        let t = std::thread::spawn(move || {
            for _ in 0..64 {
                c.broadcast(vec![1.0].into());
            }
        });
        let mut last = 0;
        loop {
            let v = caster.stats().version;
            assert!(v >= last, "stats().version ran backwards: {v} < {last}");
            last = v;
            if v >= 64 {
                break;
            }
            std::thread::yield_now();
        }
        t.join().unwrap();
        let v = caster.broadcast(vec![2.0].into());
        assert!(caster.stats().version >= v);
    }

    #[test]
    fn broadcast_applies_newest_version() {
        let reg = ShardRegistry::new(group(3));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
                w.applies += 1;
            },
        );
        let v1 = caster.broadcast(vec![1.0].into());
        assert_eq!(v1, 1);
        let v2 = caster.broadcast(vec![2.0].into());
        assert_eq!(v2, 2);
        for i in 0..3 {
            let (h, _) = reg.get(i);
            // Drain: by the time a call returns, queued applies ran.
            let w = h.call(|w| w.weights.clone()).unwrap();
            assert_eq!(w, vec![2.0], "worker {i} missed the newest cast");
        }
        let s = caster.stats();
        assert_eq!(s.version, 2);
        assert!(s.enqueued >= 3, "{s:?}");
        assert_eq!(s.enqueued + s.coalesced + s.shed, 6, "{s:?}");
    }

    #[test]
    fn broadcast_reaches_grown_shards_without_rebuild() {
        let reg = ShardRegistry::new(group(1));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        caster.broadcast(vec![1.0].into());
        let idx = reg.grow(group(1).remove(0)).unwrap();
        // The next broadcast must cover the new lane.
        caster.broadcast(vec![2.0].into());
        let (h, _) = reg.get(idx);
        assert_eq!(h.call(|w| w.weights.clone()).unwrap(), vec![2.0]);
        assert_eq!(caster.applied_versions().len(), 2);
    }

    #[test]
    fn broadcast_skips_tombstoned_slots() {
        let reg = ShardRegistry::new(group(2));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        reg.retire(0);
        caster.broadcast_sync(vec![3.0].into());
        let (h, _) = reg.get(1);
        assert_eq!(h.call(|w| w.weights.clone()).unwrap(), vec![3.0]);
        let s = caster.stats();
        // The tombstoned slot neither received nor counted as shed.
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn attach_marks_synced_version_applied() {
        let reg = ShardRegistry::new(group(1));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
                w.applies += 1;
            },
        );
        caster.broadcast_sync(vec![1.0].into());
        // A replacement synced out-of-band registers as carrying v1...
        let v = caster.stats().version;
        let ep = reg.publish(0, group(1).remove(0));
        caster.attach(0, ep, v);
        assert_eq!(caster.applied_versions(), vec![1]);
        // ...and a same-version broadcast does not re-apply on it.
        caster.broadcast_sync(vec![2.0].into());
        let (h, _) = reg.get(0);
        assert_eq!(h.call(|w| w.applies).unwrap(), 1, "v2 applies once");
    }

    #[test]
    fn storm_coalesces_to_one_pending_apply_per_recipient() {
        // Park the single recipient so applies cannot run, then storm
        // broadcasts: all but the first must coalesce (or shed), and
        // when the recipient wakes it applies only the newest version.
        let reg = ShardRegistry::new(group(1));
        let caster = WeightCaster::new(reg.clone(), 4, |w: &mut W, p| {
            w.weights.clear();
            w.weights.extend_from_slice(p);
            w.applies += 1;
        });
        let (h, _) = reg.get(0);
        let gate = h.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(60));
        });
        for k in 1..=50 {
            caster.broadcast(vec![k as f32].into());
        }
        gate.recv().unwrap();
        let (weights, applies) =
            h.call(|w| (w.weights.clone(), w.applies)).unwrap();
        assert_eq!(weights, vec![50.0], "stale version survived");
        assert!(applies <= 3, "applies={applies}: storm was not coalesced");
        let s = caster.stats();
        assert!(s.coalesced + s.shed >= 47, "{s:?}");
    }

    #[test]
    fn broadcast_never_blocks_on_overloaded_recipient() {
        // A recipient with a tiny mailbox, parked so it drains nothing:
        // broadcasts beyond the watermark must return promptly (shed),
        // not park the broadcaster.
        let slow = ActorHandle::spawn_with_capacity("reg-slow", 2, || W {
            weights: vec![],
            applies: 0,
        });
        let reg = ShardRegistry::new(vec![slow.clone()]);
        let caster = WeightCaster::new(reg, 1, |w: &mut W, p| {
            w.weights.clear();
            w.weights.extend_from_slice(p);
        });
        let gate = slow.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(80));
        });
        // Fill the mailbox past the watermark with unrelated casts.
        while slow.try_cast(|_| {}).is_ok() {}
        let start = std::time::Instant::now();
        for k in 1..=20 {
            caster.broadcast(vec![k as f32].into());
        }
        assert!(
            start.elapsed() < std::time::Duration::from_millis(50),
            "broadcast blocked on an overloaded recipient"
        );
        assert!(caster.stats().shed + caster.stats().coalesced >= 19);
        gate.recv().unwrap();
    }

    #[test]
    fn sheds_count_staleness_once_lag_exceeds_bound() {
        // A parked recipient with a full tiny mailbox: every broadcast
        // beyond the first sheds.  With stale_after = 3, sheds that
        // land while the recipient lags > 3 versions count as
        // shed_stale — the "worker is falling behind" alarm.
        let slow = ActorHandle::spawn_with_capacity("reg-stale", 2, || W {
            weights: vec![],
            applies: 0,
        });
        let reg = ShardRegistry::new(vec![slow.clone()]);
        let caster =
            WeightCaster::with_staleness(reg, 1, 3, |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            });
        let gate = slow.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(200));
        });
        // Wait until the actor has dequeued the gate (it is now parked
        // inside it), so the fill below reaches a *full* mailbox and
        // every broadcast deterministically sheds.
        while slow.queue_len() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        while slow.try_cast(|_| {}).is_ok() {}
        for k in 1..=20 {
            caster.broadcast(vec![k as f32].into());
        }
        let s = caster.stats();
        assert_eq!(s.stale_after, 3);
        assert!(s.shed >= 10, "{s:?}");
        // Sheds at versions 1..=4 had lag <= stale_after (applied = 0);
        // later ones are stale.  Coalesced broadcasts never reach the
        // stale accounting, so bound loosely from below.
        assert!(s.shed_stale >= s.shed.saturating_sub(4), "{s:?}");
        assert!(s.shed_stale <= s.shed, "{s:?}");
        gate.recv().unwrap();
    }

    #[test]
    fn fresh_recipients_do_not_count_as_stale() {
        // Recipients that apply promptly keep lag <= 1: shed_stale must
        // stay zero no matter how many versions are broadcast.
        let reg = ShardRegistry::new(group(2));
        let caster =
            WeightCaster::with_staleness(reg.clone(), 8, 3, |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            });
        for k in 1..=10 {
            caster.broadcast_sync(vec![k as f32].into());
        }
        assert_eq!(caster.stats().shed_stale, 0);
    }

    #[test]
    fn republished_lane_resyncs_replacement() {
        let reg = ShardRegistry::new(group(1));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        caster.broadcast(vec![1.0].into());
        let (old, _) = reg.get(0);
        let _ = old.call(|_| -> () { panic!("die") });
        assert!(old.await_poisoned(std::time::Duration::from_secs(2)));
        // Replacement arrives with blank weights.
        reg.publish(0, group(1).remove(0));
        caster.broadcast(vec![2.0].into());
        let (fresh, _) = reg.get(0);
        assert_eq!(
            fresh.call(|w| w.weights.clone()).unwrap(),
            vec![2.0],
            "replacement did not receive the post-publish broadcast"
        );
    }

    #[test]
    fn broadcast_sync_survives_removal_mid_barrier() {
        // Recipient 0 is parked inside a gate message that blocks on a
        // channel, so the barrier's apply queues behind it and cannot
        // run.  Retiring the slot mid-barrier must release
        // `broadcast_sync`: the gate only opens AFTER the barrier
        // returns, so the old frozen-wait-set behavior deadlocks here
        // instead of passing by luck.
        let reg = ShardRegistry::new(group(2));
        let caster = Arc::new(WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p: &[f32]| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        ));
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        let (h0, _) = reg.get(0);
        let parked = h0.call_deferred(move |_| {
            let _ = gate_rx.recv();
        });
        // Wait until the actor has dequeued the gate (it is now parked
        // inside it) so the barrier apply lands *behind* it.
        while h0.queue_len() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let c2 = caster.clone();
        let barrier =
            std::thread::spawn(move || c2.broadcast_sync(vec![4.0].into()));
        // Let the barrier dispatch, then remove the wedged slot.
        std::thread::sleep(std::time::Duration::from_millis(30));
        reg.retire(0);
        let v = barrier.join().expect("barrier wedged on a removed worker");
        assert_eq!(v, 1);
        // The surviving recipient applied synchronously.
        let (h1, _) = reg.get(1);
        assert_eq!(h1.call(|w| w.weights.clone()).unwrap(), vec![4.0]);
        // Open the gate; the retired actor drains and exits (its late,
        // idempotent apply is harmless).
        gate_tx.send(()).unwrap();
        parked.recv().unwrap();
    }

    #[test]
    fn broadcast_sync_skips_full_mailboxes_instead_of_blocking() {
        // A recipient with a tiny, already-full mailbox whose actor is
        // parked: broadcast_sync must fall back to the non-blocking
        // coalescing path for it (no barrier wait), not park the
        // broadcaster inside a blocking send.
        let slow = ActorHandle::spawn_with_capacity("reg-sync-full", 2, || {
            W { weights: vec![], applies: 0 }
        });
        let reg = ShardRegistry::new(vec![slow.clone()]);
        let caster = WeightCaster::new(
            reg,
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p: &[f32]| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        let gate = slow.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(120));
        });
        while slow.queue_len() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        while slow.try_cast(|_| {}).is_ok() {}
        let start = std::time::Instant::now();
        caster.broadcast_sync(vec![5.0].into());
        assert!(
            start.elapsed() < std::time::Duration::from_millis(80),
            "broadcast_sync blocked on a full mailbox"
        );
        let s = caster.stats();
        assert_eq!(s.coalesced + s.shed, 1, "{s:?}");
        gate.recv().unwrap();
    }

    #[test]
    fn broadcast_sync_blocks_until_applied() {
        let reg = ShardRegistry::new(group(2));
        let caster = WeightCaster::new(
            reg.clone(),
            DEFAULT_CAST_WATERMARK,
            |w: &mut W, p| {
                w.weights.clear();
                w.weights.extend_from_slice(p);
            },
        );
        caster.broadcast_sync(vec![7.5].into());
        for i in 0..2 {
            // No drain call needed: sync already waited.
            let (h, _) = reg.get(i);
            let snap = h.try_cast(|_| {});
            assert!(snap.is_ok());
            assert_eq!(h.call(|w| w.weights.clone()).unwrap(), vec![7.5]);
        }
    }
}
