//! The autoscaling policy controller — the piece that closes the
//! elasticity loop.
//!
//! PR 4 built the *mechanism* (`ShardRegistry::grow`/`retire`,
//! `WorkerSet::scale_to`, mid-stream gather discovery) but nothing
//! decided *when* to scale.  [`Autoscaler`] is that decision: a small
//! feedback controller that samples the per-actor telemetry every
//! report interval ([`super::ActorStatsSnapshot`] — learner busy/idle
//! ratio, sampler queue depth — plus the weight caster's shed
//! counters) and emits [`ScaleDirective`]s with **hysteresis**, so the
//! pool converges instead of flapping:
//!
//! * **deadband** — scale up only below `learner_idle_below`
//!   utilization, down only above `learner_busy_above`; the gap between
//!   them is a hold region where no action is taken;
//! * **confirmation streak** — a direction must be observed
//!   `confirm_reports` consecutive reports before it is acted on, so a
//!   one-report blip (or a load oscillating around a threshold) never
//!   moves the pool;
//! * **cooldown** — after an action the controller holds for
//!   `cooldown_reports` reports, giving the grown/shrunk pool time to
//!   show up in the telemetry before the next decision.
//!
//! The controller is deliberately **pure policy**: it owns no actors
//! and performs no scaling itself.  Callers (the metrics-reporting
//! operators in `ops`, which see every report anyway) feed it snapshots
//! via [`Autoscaler::signals`] + [`Autoscaler::decide`] and apply the
//! returned target with `WorkerSet::scale_to` — the same separation
//! MSRL draws between its fragment scheduler and its execution plane.
//! That also makes the hysteresis behavior fully deterministic and
//! unit-testable: feed synthetic signals, observe directives.
//!
//! Control direction, for the standard rollout/learn pipeline (samplers
//! produce, one learner consumes):
//!
//! * learner mostly **idle** → the samplers cannot feed it → grow the
//!   sampler pool;
//! * learner **saturated** → extra samplers are pure overhead (their
//!   batches queue, their weight casts shed) → shrink;
//! * samplers **overloaded** (deep mailboxes, weight casts shedding
//!   beyond `shed_tolerance`) → the pool is over-driven relative to
//!   the consumer → treated as down-pressure regardless of the
//!   learner gauge.

use std::collections::HashMap;

use super::{ActorStatsSnapshot, WeightCastStats};

/// Tuning knobs for one [`Autoscaler`].  Defaults are conservative:
/// symmetric deadband, two-report confirmation, two-report cooldown,
/// one worker per step.  See `docs/actor_runtime.md` ("Autoscaling")
/// for how each knob shapes the response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalerConfig {
    /// Never scale below this many live workers (>= 1 — `scale_to(0)`
    /// would end every stream).
    pub min_workers: usize,
    /// Never scale above this many live workers.
    pub max_workers: usize,
    /// Up-pressure threshold: learner interval utilization below this
    /// means the samplers are starving it.
    pub learner_idle_below: f64,
    /// Down-pressure threshold: learner interval utilization above
    /// this means the samplers are over-driving it.  Must be >
    /// `learner_idle_below`; the gap is the deadband.
    pub learner_busy_above: f64,
    /// A sampler interval queue-depth high-water mark at or above this
    /// counts as overload (down-pressure).
    pub sampler_queue_pressure: usize,
    /// Weight-cast sheds per interval beyond this count as overload
    /// (down-pressure): the pool cannot even absorb its parameter
    /// refreshes.
    pub shed_tolerance: u64,
    /// Reports to hold after an action before the next one.
    pub cooldown_reports: u32,
    /// Consecutive same-direction reports required before acting.
    pub confirm_reports: u32,
    /// Workers added/removed per action.
    pub step: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_workers: 1,
            max_workers: 8,
            learner_idle_below: 0.25,
            learner_busy_above: 0.75,
            sampler_queue_pressure: 16,
            shed_tolerance: 4,
            cooldown_reports: 2,
            confirm_reports: 2,
            step: 1,
        }
    }
}

impl AutoscalerConfig {
    fn validate(&self) {
        assert!(self.min_workers >= 1, "min_workers must be >= 1");
        assert!(self.max_workers >= self.min_workers);
        assert!(
            self.learner_idle_below < self.learner_busy_above,
            "thresholds must leave a deadband \
             (idle_below {} >= busy_above {})",
            self.learner_idle_below,
            self.learner_busy_above
        );
        assert!(self.step >= 1);
        assert!(self.confirm_reports >= 1);
    }
}

/// One report interval's worth of control inputs, already reduced to
/// interval deltas (see [`Autoscaler::signals`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleSignals {
    /// Learner busy fraction over the interval (0 when it did nothing).
    pub learner_utilization: f64,
    /// Aggregate sampler busy fraction over the interval.
    pub sampler_utilization: f64,
    /// Deepest sampler mailbox observed this interval (high-water if it
    /// moved, current depth otherwise).
    pub sampler_queue_hwm: usize,
    /// Weight-cast sheds this interval (0 without a caster).
    pub shed_delta: u64,
    /// Live workers at sampling time — the base the target is computed
    /// from.
    pub live_workers: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

/// An action the caller should apply (`WorkerSet::scale_to(target)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleDirective {
    pub target: usize,
    pub direction: ScaleDirection,
}

/// Lifetime decision counters, attached to `TrainResult::autoscale` and
/// rendered by `pipeline_summary()`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AutoscaleStats {
    /// Reports observed (one `decide` each).
    pub reports: u64,
    pub decisions_up: u64,
    pub decisions_down: u64,
    /// Reports with no directional pressure (inside the deadband, or
    /// already at a pool bound).
    pub held_deadband: u64,
    /// Reports with pressure still inside the confirmation streak.
    pub held_confirm: u64,
    /// Reports with confirmed pressure held by the post-action cooldown.
    pub held_cooldown: u64,
    /// `scale_to` attempts the caller reported as failed
    /// ([`Autoscaler::note_failed`]).
    pub failed: u64,
    /// The most recent directive's target (0 before the first one).
    pub last_target: usize,
}

/// The feedback controller.  One instance per worker pool; not shared
/// across pools (its interval tracking is keyed by actor id).
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    /// Last cumulative (busy_ns, idle_ns) per actor id, for interval
    /// deltas.
    prev_busy_idle: HashMap<u64, (u64, u64)>,
    /// Last queue high-water mark per actor id, for the interval HWM
    /// estimate.
    prev_hwm: HashMap<u64, usize>,
    prev_shed: u64,
    reports_since_action: u32,
    streak_dir: Option<ScaleDirection>,
    streak: u32,
    stats: AutoscaleStats,
}

fn utilization(busy: u64, idle: u64) -> f64 {
    let total = busy + idle;
    if total == 0 {
        0.0
    } else {
        busy as f64 / total as f64
    }
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Self {
        cfg.validate();
        Autoscaler {
            // First confirmed decision is never cooldown-held.
            reports_since_action: cfg.cooldown_reports.saturating_add(1),
            cfg,
            prev_busy_idle: HashMap::new(),
            prev_hwm: HashMap::new(),
            prev_shed: 0,
            streak_dir: None,
            streak: 0,
            stats: AutoscaleStats::default(),
        }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    pub fn stats(&self) -> AutoscaleStats {
        self.stats
    }

    /// Record that the caller's `scale_to` for the last directive
    /// failed (learner dead, registry full) — surfaced in
    /// [`AutoscaleStats::failed`] instead of being silently swallowed.
    pub fn note_failed(&mut self) {
        self.stats.failed += 1;
    }

    /// Reduce a telemetry snapshot to this interval's control signals.
    ///
    /// Counters in [`ActorStatsSnapshot`] are cumulative since spawn;
    /// the controller must react to the *recent* interval, so this
    /// keeps the previous per-actor readings and diffs (a restarted
    /// worker gets a fresh actor id, so its first interval is its
    /// lifetime — correct).  `sampler_ids` selects which actors count
    /// as the scaled pool; everything else in `stats` is ignored.
    pub fn signals(
        &mut self,
        stats: &[ActorStatsSnapshot],
        learner_id: u64,
        sampler_ids: &[u64],
        casts: Option<WeightCastStats>,
        live_workers: usize,
    ) -> AutoscaleSignals {
        let mut learner_utilization = 0.0;
        let mut sampler_busy = 0u64;
        let mut sampler_idle = 0u64;
        let mut sampler_queue_hwm = 0usize;
        for s in stats {
            if s.id != learner_id && !sampler_ids.contains(&s.id) {
                continue;
            }
            let (prev_busy, prev_idle) = self
                .prev_busy_idle
                .insert(s.id, (s.busy_ns, s.idle_ns))
                .unwrap_or((0, 0));
            let busy = s.busy_ns.saturating_sub(prev_busy);
            let idle = s.idle_ns.saturating_sub(prev_idle);
            if s.id == learner_id {
                learner_utilization = utilization(busy, idle);
            } else {
                sampler_busy += busy;
                sampler_idle += idle;
                // Interval HWM estimate: if the lifetime HWM moved,
                // the interval saw that depth; otherwise the current
                // depth bounds it.
                let prev_hwm =
                    self.prev_hwm.insert(s.id, s.queue_hwm).unwrap_or(0);
                let interval_hwm = if s.queue_hwm > prev_hwm {
                    s.queue_hwm
                } else {
                    s.queue_len
                };
                sampler_queue_hwm = sampler_queue_hwm.max(interval_hwm);
            }
        }
        // Drop tracking for actors that disappeared (dead incarnations
        // fall out of the registry snapshot eventually).
        let live_now = |id: &u64| {
            *id == learner_id || sampler_ids.contains(id)
        };
        self.prev_busy_idle.retain(|id, _| live_now(id));
        self.prev_hwm.retain(|id, _| live_now(id));
        let shed_delta = casts
            .map(|c| {
                let total = c.shed;
                let delta = total.saturating_sub(self.prev_shed);
                self.prev_shed = total;
                delta
            })
            .unwrap_or(0);
        AutoscaleSignals {
            learner_utilization,
            sampler_utilization: utilization(sampler_busy, sampler_idle),
            sampler_queue_hwm,
            shed_delta,
            live_workers,
        }
    }

    /// One control step: map this interval's signals to an optional
    /// directive, applying deadband, confirmation streak, and cooldown
    /// (in that order).  Pure and deterministic — the hysteresis tests
    /// drive this directly with synthetic signals.
    pub fn decide(&mut self, s: &AutoscaleSignals) -> Option<ScaleDirective> {
        self.stats.reports += 1;
        self.reports_since_action =
            self.reports_since_action.saturating_add(1);
        let overloaded = s.sampler_queue_hwm
            >= self.cfg.sampler_queue_pressure
            || s.shed_delta > self.cfg.shed_tolerance;
        let direction = if (s.learner_utilization
            > self.cfg.learner_busy_above
            || overloaded)
            && s.live_workers > self.cfg.min_workers
        {
            Some(ScaleDirection::Down)
        } else if s.learner_utilization < self.cfg.learner_idle_below
            && !overloaded
            && s.live_workers < self.cfg.max_workers
        {
            Some(ScaleDirection::Up)
        } else {
            None
        };
        let Some(direction) = direction else {
            self.streak_dir = None;
            self.streak = 0;
            self.stats.held_deadband += 1;
            return None;
        };
        if self.streak_dir == Some(direction) {
            self.streak += 1;
        } else {
            self.streak_dir = Some(direction);
            self.streak = 1;
        }
        if self.streak < self.cfg.confirm_reports {
            self.stats.held_confirm += 1;
            return None;
        }
        if self.reports_since_action <= self.cfg.cooldown_reports {
            self.stats.held_cooldown += 1;
            return None;
        }
        self.reports_since_action = 0;
        self.streak_dir = None;
        self.streak = 0;
        let target = match direction {
            ScaleDirection::Up => {
                self.stats.decisions_up += 1;
                (s.live_workers + self.cfg.step).min(self.cfg.max_workers)
            }
            ScaleDirection::Down => {
                self.stats.decisions_down += 1;
                s.live_workers
                    .saturating_sub(self.cfg.step)
                    .max(self.cfg.min_workers)
            }
        };
        self.stats.last_target = target;
        Some(ScaleDirective { target, direction })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            min_workers: 1,
            max_workers: 4,
            learner_idle_below: 0.3,
            learner_busy_above: 0.7,
            sampler_queue_pressure: 16,
            shed_tolerance: 4,
            cooldown_reports: 0,
            confirm_reports: 1,
            step: 1,
        }
    }

    fn sig(learner_util: f64, live: usize) -> AutoscaleSignals {
        AutoscaleSignals {
            learner_utilization: learner_util,
            sampler_utilization: 0.5,
            sampler_queue_hwm: 0,
            shed_delta: 0,
            live_workers: live,
        }
    }

    #[test]
    fn idle_learner_grows_until_max_then_holds() {
        let mut a = Autoscaler::new(cfg());
        let mut live = 1;
        for _ in 0..8 {
            if let Some(d) = a.decide(&sig(0.05, live)) {
                assert_eq!(d.direction, ScaleDirection::Up);
                assert_eq!(d.target, live + 1);
                live = d.target;
            }
        }
        assert_eq!(live, 4, "pool must converge to max_workers");
        // At the bound: no further directives, counted as held.
        assert!(a.decide(&sig(0.05, live)).is_none());
        let s = a.stats();
        assert_eq!(s.decisions_up, 3);
        assert_eq!(s.decisions_down, 0);
        assert_eq!(s.last_target, 4);
        assert!(s.held_deadband >= 1);
    }

    #[test]
    fn saturated_learner_shrinks_to_min() {
        let mut a = Autoscaler::new(cfg());
        let mut live = 4;
        for _ in 0..8 {
            if let Some(d) = a.decide(&sig(0.95, live)) {
                assert_eq!(d.direction, ScaleDirection::Down);
                live = d.target;
            }
        }
        assert_eq!(live, 1);
        assert!(a.decide(&sig(0.95, live)).is_none(), "min bound holds");
        assert_eq!(a.stats().decisions_down, 3);
    }

    #[test]
    fn deadband_holds_between_thresholds() {
        let mut a = Autoscaler::new(cfg());
        for _ in 0..10 {
            assert_eq!(a.decide(&sig(0.5, 2)), None);
        }
        let s = a.stats();
        assert_eq!(s.held_deadband, 10);
        assert_eq!(s.decisions_up + s.decisions_down, 0);
    }

    #[test]
    fn oscillating_load_does_not_flap() {
        // Alternating up/down pressure every report: with a 2-report
        // confirmation streak the controller must never act — the
        // no-flap guarantee the chaos soak leans on.
        let mut a = Autoscaler::new(AutoscalerConfig {
            confirm_reports: 2,
            ..cfg()
        });
        for k in 0..40 {
            let util = if k % 2 == 0 { 0.05 } else { 0.95 };
            assert_eq!(
                a.decide(&sig(util, 2)),
                None,
                "oscillation produced an action at report {k}"
            );
        }
        let s = a.stats();
        assert_eq!(s.decisions_up + s.decisions_down, 0);
        assert_eq!(s.held_confirm, 40);
    }

    #[test]
    fn cooldown_spaces_consecutive_actions() {
        // Constant up-pressure with a 3-report cooldown: actions land
        // on reports 1, 5, 9 (the first is never cooldown-held).
        let mut a = Autoscaler::new(AutoscalerConfig {
            cooldown_reports: 3,
            max_workers: 8,
            ..cfg()
        });
        let mut acted_at = Vec::new();
        for k in 1..=9 {
            if a.decide(&sig(0.05, 1)).is_some() {
                acted_at.push(k);
            }
        }
        assert_eq!(acted_at, vec![1, 5, 9]);
        assert_eq!(a.stats().held_cooldown, 6);
    }

    #[test]
    fn confirmation_streak_delays_first_action() {
        let mut a = Autoscaler::new(AutoscalerConfig {
            confirm_reports: 3,
            ..cfg()
        });
        assert_eq!(a.decide(&sig(0.05, 1)), None);
        assert_eq!(a.decide(&sig(0.05, 1)), None);
        let d = a.decide(&sig(0.05, 1)).expect("3rd confirmation acts");
        assert_eq!(d.target, 2);
        // A deadband report resets the streak.
        assert_eq!(a.decide(&sig(0.5, 2)), None);
        assert_eq!(a.decide(&sig(0.05, 2)), None, "streak restarted");
    }

    #[test]
    fn overload_forces_down_even_when_learner_is_idle() {
        let mut a = Autoscaler::new(cfg());
        // Deep sampler mailboxes: overload wins over the idle gauge.
        let mut s = sig(0.05, 3);
        s.sampler_queue_hwm = 20;
        let d = a.decide(&s).expect("overload must act");
        assert_eq!(d.direction, ScaleDirection::Down);
        // Shed storms count the same way.
        let mut a = Autoscaler::new(cfg());
        let mut s = sig(0.05, 3);
        s.shed_delta = 9;
        assert_eq!(
            a.decide(&s).unwrap().direction,
            ScaleDirection::Down
        );
    }

    #[test]
    fn step_and_bounds_clamp_targets() {
        let mut a = Autoscaler::new(AutoscalerConfig {
            step: 3,
            max_workers: 4,
            ..cfg()
        });
        assert_eq!(a.decide(&sig(0.05, 3)).unwrap().target, 4, "clamped");
        let mut a = Autoscaler::new(AutoscalerConfig { step: 5, ..cfg() });
        assert_eq!(a.decide(&sig(0.95, 3)).unwrap().target, 1, "floored");
    }

    #[test]
    fn signals_diff_cumulative_counters_per_interval() {
        let mut a = Autoscaler::new(cfg());
        let snap = |id: u64, busy: u64, idle: u64, hwm: usize, len: usize| {
            ActorStatsSnapshot {
                id,
                busy_ns: busy,
                idle_ns: idle,
                queue_hwm: hwm,
                queue_len: len,
                ..Default::default()
            }
        };
        // Interval 1: learner 25% busy lifetime, sampler hwm 5.
        let s1 = a.signals(
            &[snap(0, 25, 75, 0, 0), snap(1, 50, 50, 5, 0)],
            0,
            &[1],
            None,
            1,
        );
        assert!((s1.learner_utilization - 0.25).abs() < 1e-12);
        assert_eq!(s1.sampler_queue_hwm, 5, "first interval = lifetime");
        // Interval 2: learner went 100% busy in the delta (25+75 busy,
        // idle unchanged); sampler hwm unmoved -> current depth (2)
        // bounds the interval.
        let s2 = a.signals(
            &[snap(0, 100, 75, 0, 0), snap(1, 60, 90, 5, 2)],
            0,
            &[1],
            None,
            1,
        );
        assert!((s2.learner_utilization - 1.0).abs() < 1e-12);
        assert_eq!(s2.sampler_queue_hwm, 2);
        assert!((s2.sampler_utilization - 0.2).abs() < 1e-12);
    }

    #[test]
    fn signals_diff_shed_counters() {
        let mut a = Autoscaler::new(cfg());
        let casts = |shed: u64| WeightCastStats { shed, ..Default::default() };
        let s = a.signals(&[], 0, &[], Some(casts(3)), 1);
        assert_eq!(s.shed_delta, 3);
        let s = a.signals(&[], 0, &[], Some(casts(10)), 1);
        assert_eq!(s.shed_delta, 7);
        let s = a.signals(&[], 0, &[], Some(casts(10)), 1);
        assert_eq!(s.shed_delta, 0);
    }

    #[test]
    fn note_failed_surfaces_in_stats() {
        let mut a = Autoscaler::new(cfg());
        a.note_failed();
        a.note_failed();
        assert_eq!(a.stats().failed, 2);
    }

    #[test]
    #[should_panic(expected = "deadband")]
    fn inverted_thresholds_are_rejected() {
        Autoscaler::new(AutoscalerConfig {
            learner_idle_below: 0.8,
            learner_busy_above: 0.2,
            ..AutoscalerConfig::default()
        });
    }
}
