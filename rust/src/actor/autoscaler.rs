//! The autoscaling policy controller — the piece that closes the
//! elasticity loop.
//!
//! PR 4 built the *mechanism* (`ShardRegistry::grow`/`retire`,
//! `WorkerSet::scale_to`, mid-stream gather discovery) but nothing
//! decided *when* to scale.  [`Autoscaler`] is that decision: a small
//! feedback controller that samples the per-actor telemetry every
//! report interval ([`super::ActorStatsSnapshot`] — learner busy/idle
//! ratio, sampler queue depth — plus the weight caster's shed
//! counters) and emits [`ScaleDirective`]s with **hysteresis**, so the
//! pool converges instead of flapping:
//!
//! * **deadband** — scale up only below `learner_idle_below`
//!   utilization, down only above `learner_busy_above`; the gap between
//!   them is a hold region where no action is taken;
//! * **confirmation streak** — a direction must be observed
//!   `confirm_reports` consecutive reports before it is acted on, so a
//!   one-report blip (or a load oscillating around a threshold) never
//!   moves the pool;
//! * **cooldown** — after an action the controller holds for
//!   `cooldown_reports` reports, giving the grown/shrunk pool time to
//!   show up in the telemetry before the next decision.
//!
//! The controller is deliberately **pure policy**: it owns no actors
//! and performs no scaling itself.  Callers (the metrics-reporting
//! operators in `ops`, which see every report anyway) feed it snapshots
//! via [`Autoscaler::signals`] + [`Autoscaler::decide`] and apply the
//! returned target with `WorkerSet::scale_to` — the same separation
//! MSRL draws between its fragment scheduler and its execution plane.
//! That also makes the hysteresis behavior fully deterministic and
//! unit-testable: feed synthetic signals, observe directives.
//!
//! Control direction, for the standard rollout/learn pipeline (samplers
//! produce, one learner consumes):
//!
//! * learner mostly **idle** → the samplers cannot feed it → grow the
//!   sampler pool;
//! * learner **saturated** → extra samplers are pure overhead (their
//!   batches queue, their weight casts shed) → shrink;
//! * samplers **overloaded** (deep mailboxes, weight casts shedding
//!   beyond `shed_tolerance`) → the pool is over-driven relative to
//!   the consumer → treated as down-pressure regardless of the
//!   learner gauge.
//!
//! The same controller core also drives the **replay-shard pool**
//! (sharded prioritized replay behind `ops::ReplayService`), with the
//! control direction flipped — there the scaled pool is the *consumer*
//! of the store stream:
//!
//! * shard mailboxes backing up (`replay_queue_pressure`) or rings
//!   filling (`replay_fill_above`) → the shards cannot absorb the
//!   store/sample traffic → grow, with a step **proportional** to how
//!   far the backlog overshoots the pressure threshold;
//! * sustained not-ready polls with empty mailboxes
//!   (`replay_idle_polls`) → the inflow is spread too thin for shards
//!   to even reach `learning_starts` → shrink.
//!
//! A third loop drives the **gateway-shard pool** (external-episode
//! serving behind `ops::GatewayService`), where the scaled pool is the
//! *server* of client-owned traffic — note the shed polarity flip:
//!
//! * sessions per shard past `gateway_sessions_per_shard`, shard
//!   mailboxes backing up (`gateway_queue_pressure`), or clients being
//!   **shed** beyond `gateway_shed_tolerance` → the tier cannot admit
//!   the offered load → grow (shed traffic is demand the pool turned
//!   away, the opposite of the sampler loop where sheds mean the pool
//!   over-drives its consumer), with a step proportional to the
//!   session overshoot;
//! * a near-empty session table with quiet mailboxes and zero sheds
//!   (`gateway_idle_sessions`) → shrink.
//!
//! All loops share one hysteresis gate (deadband → confirmation
//! streak → cooldown), so the no-flap guarantees proved for the
//! sampler pool hold for the replay and gateway pools too.  Use one
//! [`Autoscaler`] instance per pool: the interval tracking is keyed
//! per pool, not per signal kind.

use std::collections::HashMap;

use super::{ActorStatsSnapshot, WeightCastStats};
use crate::env::GatewayBacklogStats;
use crate::replay::ReplayBacklogStats;

/// Tuning knobs for one [`Autoscaler`].  Defaults are conservative:
/// symmetric deadband, two-report confirmation, two-report cooldown,
/// one worker per step.  See `docs/actor_runtime.md` ("Autoscaling")
/// for how each knob shapes the response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalerConfig {
    /// Never scale below this many live workers (>= 1 — `scale_to(0)`
    /// would end every stream).
    pub min_workers: usize,
    /// Never scale above this many live workers.
    pub max_workers: usize,
    /// Up-pressure threshold: learner interval utilization below this
    /// means the samplers are starving it.
    pub learner_idle_below: f64,
    /// Down-pressure threshold: learner interval utilization above
    /// this means the samplers are over-driving it.  Must be >
    /// `learner_idle_below`; the gap is the deadband.
    pub learner_busy_above: f64,
    /// A sampler interval queue-depth high-water mark at or above this
    /// counts as overload (down-pressure).
    pub sampler_queue_pressure: usize,
    /// Weight-cast sheds per interval beyond this count as overload
    /// (down-pressure): the pool cannot even absorb its parameter
    /// refreshes.
    pub shed_tolerance: u64,
    /// Reports to hold after an action before the next one.
    pub cooldown_reports: u32,
    /// Consecutive same-direction reports required before acting.
    pub confirm_reports: u32,
    /// Workers added/removed per action.  For the replay loop this is
    /// the *base* step; backlog overshoot multiplies it (see
    /// [`Autoscaler::decide_replay`]).
    pub step: usize,
    /// Replay loop: a shard interval mailbox high-water mark at or
    /// above this counts as backlog (up-pressure).
    pub replay_queue_pressure: usize,
    /// Replay loop: a ring fill fraction at or above this counts as
    /// capacity pressure (up-pressure).
    pub replay_fill_above: f64,
    /// Replay loop: this many not-ready polls per interval, with empty
    /// shard mailboxes, counts as idleness (down-pressure).
    pub replay_idle_polls: u64,
    /// Gateway loop: live client sessions per live shard at or above
    /// this counts as load pressure (up-pressure).
    pub gateway_sessions_per_shard: usize,
    /// Gateway loop: a shard interval mailbox high-water mark at or
    /// above this counts as backlog (up-pressure).
    pub gateway_queue_pressure: usize,
    /// Gateway loop: admission/cast sheds per interval beyond this
    /// count as turned-away demand (up-pressure — the polarity flip of
    /// `shed_tolerance`: the gateway *serves* the shed party instead of
    /// driving it).
    pub gateway_shed_tolerance: u64,
    /// Gateway loop: total live sessions at or below this, with quiet
    /// mailboxes, zero sheds, and zero new connects, counts as
    /// idleness (down-pressure).
    pub gateway_idle_sessions: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_workers: 1,
            max_workers: 8,
            learner_idle_below: 0.25,
            learner_busy_above: 0.75,
            sampler_queue_pressure: 16,
            shed_tolerance: 4,
            cooldown_reports: 2,
            confirm_reports: 2,
            step: 1,
            replay_queue_pressure: 8,
            replay_fill_above: 0.85,
            replay_idle_polls: 8,
            gateway_sessions_per_shard: 16,
            gateway_queue_pressure: 8,
            gateway_shed_tolerance: 4,
            gateway_idle_sessions: 2,
        }
    }
}

impl AutoscalerConfig {
    /// Defaults for a **replay-shard pool** controller with the given
    /// bounds (`TrainerConfig::{min,max}_replay_shards`).  Only the
    /// pool bounds differ from [`Default`]; the replay gauges and the
    /// shared hysteresis knobs keep their defaults.
    pub fn replay_defaults(min_shards: usize, max_shards: usize) -> Self {
        let min = min_shards.max(1);
        AutoscalerConfig {
            min_workers: min,
            max_workers: max_shards.max(min),
            ..AutoscalerConfig::default()
        }
    }

    /// Defaults for a **gateway-shard pool** controller with the given
    /// bounds.  As with [`replay_defaults`](Self::replay_defaults),
    /// only the pool bounds differ from [`Default`]; the gateway
    /// gauges and the shared hysteresis knobs keep their defaults.
    pub fn gateway_defaults(min_shards: usize, max_shards: usize) -> Self {
        let min = min_shards.max(1);
        AutoscalerConfig {
            min_workers: min,
            max_workers: max_shards.max(min),
            ..AutoscalerConfig::default()
        }
    }

    fn validate(&self) {
        assert!(self.min_workers >= 1, "min_workers must be >= 1");
        assert!(self.max_workers >= self.min_workers);
        assert!(
            self.learner_idle_below < self.learner_busy_above,
            "thresholds must leave a deadband \
             (idle_below {} >= busy_above {})",
            self.learner_idle_below,
            self.learner_busy_above
        );
        assert!(self.step >= 1);
        assert!(self.confirm_reports >= 1);
        assert!(self.replay_queue_pressure >= 1);
        assert!(
            self.replay_fill_above > 0.0 && self.replay_fill_above <= 1.0,
            "replay_fill_above must be in (0, 1], got {}",
            self.replay_fill_above
        );
        assert!(self.gateway_sessions_per_shard >= 1);
        assert!(self.gateway_queue_pressure >= 1);
    }
}

/// One report interval's worth of control inputs, already reduced to
/// interval deltas (see [`Autoscaler::signals`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleSignals {
    /// Learner busy fraction over the interval (0 when it did nothing).
    pub learner_utilization: f64,
    /// Aggregate sampler busy fraction over the interval.
    pub sampler_utilization: f64,
    /// Deepest sampler mailbox observed this interval (high-water if it
    /// moved, current depth otherwise).
    pub sampler_queue_hwm: usize,
    /// Weight-cast sheds this interval (0 without a caster).
    pub shed_delta: u64,
    /// Live workers at sampling time — the base the target is computed
    /// from.
    pub live_workers: usize,
}

/// One report interval's replay-pool control inputs, reduced from
/// [`ReplayBacklogStats`] by [`Autoscaler::replay_signals`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplaySignals {
    /// Deepest shard mailbox observed this interval (high-water if it
    /// moved, current depth otherwise).
    pub queue_hwm: usize,
    /// Highest ring fill fraction across live shards (point-in-time —
    /// ring contents don't reset between reports).
    pub ring_fill: f64,
    /// Not-ready replay polls this interval.
    pub not_ready_delta: u64,
    /// Samples yielded this interval.
    pub sample_delta: u64,
    /// Live shards at sampling time.
    pub live_shards: usize,
}

/// One report interval's gateway-pool control inputs, reduced from
/// [`GatewayBacklogStats`] by [`Autoscaler::gateway_signals`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GatewaySignals {
    /// Live client sessions across the pool (point-in-time — sessions
    /// persist between reports).
    pub sessions: usize,
    /// Deepest shard mailbox observed this interval (high-water if it
    /// moved, current depth otherwise).
    pub queue_hwm: usize,
    /// Clients shed this interval (admission watermark + cast
    /// backpressure) — turned-away demand, so *up*-pressure here.
    pub shed_delta: u64,
    /// Sessions started this interval — a churn gauge: a near-empty
    /// table that is still admitting clients is not idle.
    pub started_delta: u64,
    /// Live shards at sampling time.
    pub live_shards: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

/// An action the caller should apply (`WorkerSet::scale_to(target)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleDirective {
    pub target: usize,
    pub direction: ScaleDirection,
}

/// Lifetime decision counters, attached to `TrainResult::autoscale` and
/// rendered by `pipeline_summary()`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AutoscaleStats {
    /// Reports observed (one `decide` each).
    pub reports: u64,
    pub decisions_up: u64,
    pub decisions_down: u64,
    /// Reports with no directional pressure (inside the deadband, or
    /// already at a pool bound).
    pub held_deadband: u64,
    /// Reports with pressure still inside the confirmation streak.
    pub held_confirm: u64,
    /// Reports with confirmed pressure held by the post-action cooldown.
    pub held_cooldown: u64,
    /// `scale_to` attempts the caller reported as failed
    /// ([`Autoscaler::note_failed`]).
    pub failed: u64,
    /// The most recent directive's target (0 before the first one).
    pub last_target: usize,
}

/// The feedback controller.  One instance per worker pool; not shared
/// across pools (its interval tracking is keyed by actor id).
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    /// Last cumulative (busy_ns, idle_ns) per actor id, for interval
    /// deltas.
    prev_busy_idle: HashMap<u64, (u64, u64)>,
    /// Last queue high-water mark per actor id, for the interval HWM
    /// estimate.
    prev_hwm: HashMap<u64, usize>,
    prev_shed: u64,
    /// Replay loop interval tracking (pool-aggregate, not per actor:
    /// [`ReplayBacklogStats`] already reduces over the live shards).
    prev_replay_hwm: usize,
    prev_replay_not_ready: u64,
    prev_replay_samples: u64,
    /// Gateway loop interval tracking (pool-aggregate, like replay).
    prev_gateway_hwm: usize,
    prev_gateway_shed: u64,
    prev_gateway_started: u64,
    reports_since_action: u32,
    streak_dir: Option<ScaleDirection>,
    streak: u32,
    stats: AutoscaleStats,
}

fn utilization(busy: u64, idle: u64) -> f64 {
    let total = busy + idle;
    if total == 0 {
        0.0
    } else {
        busy as f64 / total as f64
    }
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Self {
        cfg.validate();
        Autoscaler {
            // First confirmed decision is never cooldown-held.
            reports_since_action: cfg.cooldown_reports.saturating_add(1),
            cfg,
            prev_busy_idle: HashMap::new(),
            prev_hwm: HashMap::new(),
            prev_shed: 0,
            prev_replay_hwm: 0,
            prev_replay_not_ready: 0,
            prev_replay_samples: 0,
            prev_gateway_hwm: 0,
            prev_gateway_shed: 0,
            prev_gateway_started: 0,
            streak_dir: None,
            streak: 0,
            stats: AutoscaleStats::default(),
        }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    pub fn stats(&self) -> AutoscaleStats {
        self.stats
    }

    /// Record that the caller's `scale_to` for the last directive
    /// failed (learner dead, registry full) — surfaced in
    /// [`AutoscaleStats::failed`] instead of being silently swallowed.
    pub fn note_failed(&mut self) {
        self.stats.failed += 1;
    }

    /// Reduce a telemetry snapshot to this interval's control signals.
    ///
    /// Counters in [`ActorStatsSnapshot`] are cumulative since spawn;
    /// the controller must react to the *recent* interval, so this
    /// keeps the previous per-actor readings and diffs (a restarted
    /// worker gets a fresh actor id, so its first interval is its
    /// lifetime — correct).  `sampler_ids` selects which actors count
    /// as the scaled pool; everything else in `stats` is ignored.
    pub fn signals(
        &mut self,
        stats: &[ActorStatsSnapshot],
        learner_id: u64,
        sampler_ids: &[u64],
        casts: Option<WeightCastStats>,
        live_workers: usize,
    ) -> AutoscaleSignals {
        let mut learner_utilization = 0.0;
        let mut sampler_busy = 0u64;
        let mut sampler_idle = 0u64;
        let mut sampler_queue_hwm = 0usize;
        for s in stats {
            if s.id != learner_id && !sampler_ids.contains(&s.id) {
                continue;
            }
            let (prev_busy, prev_idle) = self
                .prev_busy_idle
                .insert(s.id, (s.busy_ns, s.idle_ns))
                .unwrap_or((0, 0));
            let busy = s.busy_ns.saturating_sub(prev_busy);
            let idle = s.idle_ns.saturating_sub(prev_idle);
            if s.id == learner_id {
                learner_utilization = utilization(busy, idle);
            } else {
                sampler_busy += busy;
                sampler_idle += idle;
                // Interval HWM estimate: if the lifetime HWM moved,
                // the interval saw that depth; otherwise the current
                // depth bounds it.
                let prev_hwm =
                    self.prev_hwm.insert(s.id, s.queue_hwm).unwrap_or(0);
                let interval_hwm = if s.queue_hwm > prev_hwm {
                    s.queue_hwm
                } else {
                    s.queue_len
                };
                sampler_queue_hwm = sampler_queue_hwm.max(interval_hwm);
            }
        }
        // Drop tracking for actors that disappeared (dead incarnations
        // fall out of the registry snapshot eventually).
        let live_now = |id: &u64| {
            *id == learner_id || sampler_ids.contains(id)
        };
        self.prev_busy_idle.retain(|id, _| live_now(id));
        self.prev_hwm.retain(|id, _| live_now(id));
        let shed_delta = casts
            .map(|c| {
                let total = c.shed;
                let delta = total.saturating_sub(self.prev_shed);
                self.prev_shed = total;
                delta
            })
            .unwrap_or(0);
        AutoscaleSignals {
            learner_utilization,
            sampler_utilization: utilization(sampler_busy, sampler_idle),
            sampler_queue_hwm,
            shed_delta,
            live_workers,
        }
    }

    /// One control step: map this interval's signals to an optional
    /// directive, applying deadband, confirmation streak, and cooldown
    /// (in that order).  Pure and deterministic — the hysteresis tests
    /// drive this directly with synthetic signals.
    pub fn decide(&mut self, s: &AutoscaleSignals) -> Option<ScaleDirective> {
        let overloaded = s.sampler_queue_hwm
            >= self.cfg.sampler_queue_pressure
            || s.shed_delta > self.cfg.shed_tolerance;
        let direction = if (s.learner_utilization
            > self.cfg.learner_busy_above
            || overloaded)
            && s.live_workers > self.cfg.min_workers
        {
            Some(ScaleDirection::Down)
        } else if s.learner_utilization < self.cfg.learner_idle_below
            && !overloaded
            && s.live_workers < self.cfg.max_workers
        {
            Some(ScaleDirection::Up)
        } else {
            None
        };
        self.gate(direction, s.live_workers, self.cfg.step)
    }

    /// Reduce replay backlog telemetry to this interval's control
    /// signals (the replay-pool analogue of [`Autoscaler::signals`]).
    /// The stats are already pool-aggregate, so the interval diffing is
    /// scalar: the same lifetime-HWM trick as the sampler loop for the
    /// mailbox gauge, `saturating_sub` deltas for the monotone traffic
    /// counters.
    pub fn replay_signals(
        &mut self,
        stats: &ReplayBacklogStats,
    ) -> ReplaySignals {
        let queue_hwm = if stats.max_queue_hwm > self.prev_replay_hwm {
            stats.max_queue_hwm
        } else {
            stats.max_queue_len
        };
        // Straight assignment, not a running max: shard churn can drop
        // the pool-wide lifetime HWM (a high-water shard retires), and
        // tracking the lower value keeps later increases detectable.
        self.prev_replay_hwm = stats.max_queue_hwm;
        let not_ready_delta = stats
            .not_ready
            .saturating_sub(self.prev_replay_not_ready);
        self.prev_replay_not_ready = stats.not_ready;
        let sample_delta =
            stats.samples.saturating_sub(self.prev_replay_samples);
        self.prev_replay_samples = stats.samples;
        ReplaySignals {
            queue_hwm,
            ring_fill: stats.max_ring_fill,
            not_ready_delta,
            sample_delta,
            live_shards: stats.live_shards,
        }
    }

    /// One control step for the replay-shard pool.  Up-pressure is
    /// backlog (shard mailboxes at or past `replay_queue_pressure`) or
    /// capacity pressure (ring fill at or past `replay_fill_above`);
    /// down-pressure is sustained idleness (`replay_idle_polls`
    /// not-ready polls with empty mailboxes and unfilled rings).  The
    /// up step is **proportional** to the backlog overshoot — a
    /// mailbox 3x past the pressure threshold adds `3 * step` shards in
    /// one action instead of crawling there through three cooldown
    /// cycles — and shares [`gate`](Self::decide)'s hysteresis, so
    /// proportional sizing never bypasses confirmation or cooldown.
    pub fn decide_replay(
        &mut self,
        s: &ReplaySignals,
    ) -> Option<ScaleDirective> {
        let backlogged = s.queue_hwm >= self.cfg.replay_queue_pressure;
        let full = s.ring_fill >= self.cfg.replay_fill_above;
        let idle = s.not_ready_delta >= self.cfg.replay_idle_polls
            && s.queue_hwm == 0
            && !full;
        let direction = if (backlogged || full)
            && s.live_shards < self.cfg.max_workers
        {
            Some(ScaleDirection::Up)
        } else if idle && s.live_shards > self.cfg.min_workers {
            Some(ScaleDirection::Down)
        } else {
            None
        };
        let step = if backlogged {
            self.cfg.step
                * (s.queue_hwm / self.cfg.replay_queue_pressure).max(1)
        } else {
            self.cfg.step
        };
        self.gate(direction, s.live_shards, step)
    }

    /// Reduce gateway backlog telemetry to this interval's control
    /// signals (the gateway-pool analogue of
    /// [`Autoscaler::replay_signals`]): the lifetime-HWM trick for the
    /// mailbox gauge, `saturating_sub` deltas for the monotone shed and
    /// started counters, point-in-time session count passed through.
    pub fn gateway_signals(
        &mut self,
        stats: &GatewayBacklogStats,
    ) -> GatewaySignals {
        let queue_hwm = if stats.max_queue_hwm > self.prev_gateway_hwm {
            stats.max_queue_hwm
        } else {
            stats.max_queue_len
        };
        // Straight assignment for the same reason as the replay loop:
        // shard churn can lower the pool-wide lifetime HWM.
        self.prev_gateway_hwm = stats.max_queue_hwm;
        let shed_delta =
            stats.shed.saturating_sub(self.prev_gateway_shed);
        self.prev_gateway_shed = stats.shed;
        let started_delta =
            stats.started.saturating_sub(self.prev_gateway_started);
        self.prev_gateway_started = stats.started;
        GatewaySignals {
            sessions: stats.sessions,
            queue_hwm,
            shed_delta,
            started_delta,
            live_shards: stats.live_shards,
        }
    }

    /// One control step for the gateway-shard pool.  Up-pressure is
    /// session load (`gateway_sessions_per_shard` live sessions per
    /// shard), mailbox backlog (`gateway_queue_pressure`), or clients
    /// being shed past `gateway_shed_tolerance` — shed traffic is
    /// demand the tier turned away, so unlike the sampler loop it
    /// argues for *more* capacity.  Down-pressure is a near-empty
    /// session table (`gateway_idle_sessions`) with quiet mailboxes,
    /// zero sheds, and zero new connects.  The up step is proportional
    /// to the session overshoot and funnels through
    /// [`gate`](Self::decide)'s shared hysteresis.
    pub fn decide_gateway(
        &mut self,
        s: &GatewaySignals,
    ) -> Option<ScaleDirective> {
        let capacity =
            self.cfg.gateway_sessions_per_shard * s.live_shards.max(1);
        let loaded = s.sessions >= capacity;
        let backlogged = s.queue_hwm >= self.cfg.gateway_queue_pressure;
        let shedding = s.shed_delta > self.cfg.gateway_shed_tolerance;
        let idle = s.sessions <= self.cfg.gateway_idle_sessions
            && s.queue_hwm == 0
            && s.shed_delta == 0
            && s.started_delta == 0;
        let direction = if (loaded || backlogged || shedding)
            && s.live_shards < self.cfg.max_workers
        {
            Some(ScaleDirection::Up)
        } else if idle && s.live_shards > self.cfg.min_workers {
            Some(ScaleDirection::Down)
        } else {
            None
        };
        let step = if loaded {
            self.cfg.step * (s.sessions / capacity).max(1)
        } else {
            self.cfg.step
        };
        self.gate(direction, s.live_shards, step)
    }

    /// The shared hysteresis gate: deadband reset, confirmation
    /// streak, post-action cooldown, then bound-clamped target — the
    /// tail every control loop funnels through, so each `decide*`
    /// flavor only differs in how it maps signals to a direction and a
    /// step.
    fn gate(
        &mut self,
        direction: Option<ScaleDirection>,
        live: usize,
        step: usize,
    ) -> Option<ScaleDirective> {
        self.stats.reports += 1;
        self.reports_since_action =
            self.reports_since_action.saturating_add(1);
        let Some(direction) = direction else {
            self.streak_dir = None;
            self.streak = 0;
            self.stats.held_deadband += 1;
            return None;
        };
        if self.streak_dir == Some(direction) {
            self.streak += 1;
        } else {
            self.streak_dir = Some(direction);
            self.streak = 1;
        }
        if self.streak < self.cfg.confirm_reports {
            self.stats.held_confirm += 1;
            return None;
        }
        if self.reports_since_action <= self.cfg.cooldown_reports {
            self.stats.held_cooldown += 1;
            return None;
        }
        self.reports_since_action = 0;
        self.streak_dir = None;
        self.streak = 0;
        let target = match direction {
            ScaleDirection::Up => {
                self.stats.decisions_up += 1;
                (live + step).min(self.cfg.max_workers)
            }
            ScaleDirection::Down => {
                self.stats.decisions_down += 1;
                live.saturating_sub(step).max(self.cfg.min_workers)
            }
        };
        self.stats.last_target = target;
        Some(ScaleDirective { target, direction })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            min_workers: 1,
            max_workers: 4,
            learner_idle_below: 0.3,
            learner_busy_above: 0.7,
            sampler_queue_pressure: 16,
            shed_tolerance: 4,
            cooldown_reports: 0,
            confirm_reports: 1,
            step: 1,
            replay_queue_pressure: 8,
            replay_fill_above: 0.85,
            replay_idle_polls: 8,
            gateway_sessions_per_shard: 16,
            gateway_queue_pressure: 8,
            gateway_shed_tolerance: 4,
            gateway_idle_sessions: 2,
        }
    }

    fn sig(learner_util: f64, live: usize) -> AutoscaleSignals {
        AutoscaleSignals {
            learner_utilization: learner_util,
            sampler_utilization: 0.5,
            sampler_queue_hwm: 0,
            shed_delta: 0,
            live_workers: live,
        }
    }

    #[test]
    fn idle_learner_grows_until_max_then_holds() {
        let mut a = Autoscaler::new(cfg());
        let mut live = 1;
        for _ in 0..8 {
            if let Some(d) = a.decide(&sig(0.05, live)) {
                assert_eq!(d.direction, ScaleDirection::Up);
                assert_eq!(d.target, live + 1);
                live = d.target;
            }
        }
        assert_eq!(live, 4, "pool must converge to max_workers");
        // At the bound: no further directives, counted as held.
        assert!(a.decide(&sig(0.05, live)).is_none());
        let s = a.stats();
        assert_eq!(s.decisions_up, 3);
        assert_eq!(s.decisions_down, 0);
        assert_eq!(s.last_target, 4);
        assert!(s.held_deadband >= 1);
    }

    #[test]
    fn saturated_learner_shrinks_to_min() {
        let mut a = Autoscaler::new(cfg());
        let mut live = 4;
        for _ in 0..8 {
            if let Some(d) = a.decide(&sig(0.95, live)) {
                assert_eq!(d.direction, ScaleDirection::Down);
                live = d.target;
            }
        }
        assert_eq!(live, 1);
        assert!(a.decide(&sig(0.95, live)).is_none(), "min bound holds");
        assert_eq!(a.stats().decisions_down, 3);
    }

    #[test]
    fn deadband_holds_between_thresholds() {
        let mut a = Autoscaler::new(cfg());
        for _ in 0..10 {
            assert_eq!(a.decide(&sig(0.5, 2)), None);
        }
        let s = a.stats();
        assert_eq!(s.held_deadband, 10);
        assert_eq!(s.decisions_up + s.decisions_down, 0);
    }

    #[test]
    fn oscillating_load_does_not_flap() {
        // Alternating up/down pressure every report: with a 2-report
        // confirmation streak the controller must never act — the
        // no-flap guarantee the chaos soak leans on.
        let mut a = Autoscaler::new(AutoscalerConfig {
            confirm_reports: 2,
            ..cfg()
        });
        for k in 0..40 {
            let util = if k % 2 == 0 { 0.05 } else { 0.95 };
            assert_eq!(
                a.decide(&sig(util, 2)),
                None,
                "oscillation produced an action at report {k}"
            );
        }
        let s = a.stats();
        assert_eq!(s.decisions_up + s.decisions_down, 0);
        assert_eq!(s.held_confirm, 40);
    }

    #[test]
    fn cooldown_spaces_consecutive_actions() {
        // Constant up-pressure with a 3-report cooldown: actions land
        // on reports 1, 5, 9 (the first is never cooldown-held).
        let mut a = Autoscaler::new(AutoscalerConfig {
            cooldown_reports: 3,
            max_workers: 8,
            ..cfg()
        });
        let mut acted_at = Vec::new();
        for k in 1..=9 {
            if a.decide(&sig(0.05, 1)).is_some() {
                acted_at.push(k);
            }
        }
        assert_eq!(acted_at, vec![1, 5, 9]);
        assert_eq!(a.stats().held_cooldown, 6);
    }

    #[test]
    fn confirmation_streak_delays_first_action() {
        let mut a = Autoscaler::new(AutoscalerConfig {
            confirm_reports: 3,
            ..cfg()
        });
        assert_eq!(a.decide(&sig(0.05, 1)), None);
        assert_eq!(a.decide(&sig(0.05, 1)), None);
        let d = a.decide(&sig(0.05, 1)).expect("3rd confirmation acts");
        assert_eq!(d.target, 2);
        // A deadband report resets the streak.
        assert_eq!(a.decide(&sig(0.5, 2)), None);
        assert_eq!(a.decide(&sig(0.05, 2)), None, "streak restarted");
    }

    #[test]
    fn overload_forces_down_even_when_learner_is_idle() {
        let mut a = Autoscaler::new(cfg());
        // Deep sampler mailboxes: overload wins over the idle gauge.
        let mut s = sig(0.05, 3);
        s.sampler_queue_hwm = 20;
        let d = a.decide(&s).expect("overload must act");
        assert_eq!(d.direction, ScaleDirection::Down);
        // Shed storms count the same way.
        let mut a = Autoscaler::new(cfg());
        let mut s = sig(0.05, 3);
        s.shed_delta = 9;
        assert_eq!(
            a.decide(&s).unwrap().direction,
            ScaleDirection::Down
        );
    }

    #[test]
    fn step_and_bounds_clamp_targets() {
        let mut a = Autoscaler::new(AutoscalerConfig {
            step: 3,
            max_workers: 4,
            ..cfg()
        });
        assert_eq!(a.decide(&sig(0.05, 3)).unwrap().target, 4, "clamped");
        let mut a = Autoscaler::new(AutoscalerConfig { step: 5, ..cfg() });
        assert_eq!(a.decide(&sig(0.95, 3)).unwrap().target, 1, "floored");
    }

    #[test]
    fn signals_diff_cumulative_counters_per_interval() {
        let mut a = Autoscaler::new(cfg());
        let snap = |id: u64, busy: u64, idle: u64, hwm: usize, len: usize| {
            ActorStatsSnapshot {
                id,
                busy_ns: busy,
                idle_ns: idle,
                queue_hwm: hwm,
                queue_len: len,
                ..Default::default()
            }
        };
        // Interval 1: learner 25% busy lifetime, sampler hwm 5.
        let s1 = a.signals(
            &[snap(0, 25, 75, 0, 0), snap(1, 50, 50, 5, 0)],
            0,
            &[1],
            None,
            1,
        );
        assert!((s1.learner_utilization - 0.25).abs() < 1e-12);
        assert_eq!(s1.sampler_queue_hwm, 5, "first interval = lifetime");
        // Interval 2: learner went 100% busy in the delta (25+75 busy,
        // idle unchanged); sampler hwm unmoved -> current depth (2)
        // bounds the interval.
        let s2 = a.signals(
            &[snap(0, 100, 75, 0, 0), snap(1, 60, 90, 5, 2)],
            0,
            &[1],
            None,
            1,
        );
        assert!((s2.learner_utilization - 1.0).abs() < 1e-12);
        assert_eq!(s2.sampler_queue_hwm, 2);
        assert!((s2.sampler_utilization - 0.2).abs() < 1e-12);
    }

    #[test]
    fn signals_diff_shed_counters() {
        let mut a = Autoscaler::new(cfg());
        let casts = |shed: u64| WeightCastStats { shed, ..Default::default() };
        let s = a.signals(&[], 0, &[], Some(casts(3)), 1);
        assert_eq!(s.shed_delta, 3);
        let s = a.signals(&[], 0, &[], Some(casts(10)), 1);
        assert_eq!(s.shed_delta, 7);
        let s = a.signals(&[], 0, &[], Some(casts(10)), 1);
        assert_eq!(s.shed_delta, 0);
    }

    fn rsig(queue_hwm: usize, live: usize) -> ReplaySignals {
        ReplaySignals {
            queue_hwm,
            ring_fill: 0.0,
            not_ready_delta: 0,
            sample_delta: 16,
            live_shards: live,
        }
    }

    #[test]
    fn replay_backlog_grows_shard_pool() {
        let mut a = Autoscaler::new(cfg());
        let d = a.decide_replay(&rsig(8, 2)).expect("backlog must act");
        assert_eq!(d.direction, ScaleDirection::Up);
        assert_eq!(d.target, 3);
    }

    #[test]
    fn replay_backlog_overshoot_scales_step_proportionally() {
        // Mailbox 3x past the pressure threshold: one action adds 3
        // shards (clamped by max_workers), not 1.
        let mut a = Autoscaler::new(AutoscalerConfig {
            max_workers: 8,
            ..cfg()
        });
        let d = a.decide_replay(&rsig(24, 2)).unwrap();
        assert_eq!(d.target, 5);
        // Clamp still applies.
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide_replay(&rsig(100, 2)).unwrap().target, 4);
    }

    #[test]
    fn replay_ring_fill_grows_even_with_empty_mailboxes() {
        let mut a = Autoscaler::new(cfg());
        let mut s = rsig(0, 2);
        s.ring_fill = 0.9;
        let d = a.decide_replay(&s).expect("capacity pressure must act");
        assert_eq!(d.direction, ScaleDirection::Up);
        assert_eq!(d.target, 3, "fill pressure uses the base step");
    }

    #[test]
    fn replay_idleness_shrinks_and_bounds_hold() {
        let mut a = Autoscaler::new(cfg());
        let mut s = rsig(0, 2);
        s.not_ready_delta = 20;
        s.sample_delta = 0;
        let d = a.decide_replay(&s).expect("idle pool must shrink");
        assert_eq!(d.direction, ScaleDirection::Down);
        assert_eq!(d.target, 1);
        // At min_workers idleness holds instead of acting.
        s.live_shards = 1;
        assert_eq!(a.decide_replay(&s), None);
        // A full ring vetoes the idle signal (warmup of a huge buffer).
        let mut a = Autoscaler::new(cfg());
        s.live_shards = 2;
        s.ring_fill = 0.9;
        assert_eq!(
            a.decide_replay(&s).unwrap().direction,
            ScaleDirection::Up
        );
    }

    #[test]
    fn replay_oscillation_does_not_flap() {
        // Backlog and idleness alternating every report with a
        // 2-report confirmation streak: no action, ever — the same
        // no-flap guarantee as the sampler loop, through the same gate.
        let mut a = Autoscaler::new(AutoscalerConfig {
            confirm_reports: 2,
            ..cfg()
        });
        for k in 0..40 {
            let s = if k % 2 == 0 {
                rsig(20, 2)
            } else {
                let mut s = rsig(0, 2);
                s.not_ready_delta = 20;
                s
            };
            assert_eq!(
                a.decide_replay(&s),
                None,
                "replay oscillation acted at report {k}"
            );
        }
        assert_eq!(a.stats().decisions_up + a.stats().decisions_down, 0);
    }

    #[test]
    fn replay_signals_diff_backlog_stats_per_interval() {
        let mut a = Autoscaler::new(cfg());
        let stats1 = ReplayBacklogStats {
            live_shards: 2,
            max_queue_len: 1,
            max_queue_hwm: 6,
            max_ring_fill: 0.5,
            samples: 10,
            not_ready: 3,
            ..Default::default()
        };
        let s1 = a.replay_signals(&stats1);
        assert_eq!(s1.queue_hwm, 6, "first interval = lifetime HWM");
        assert_eq!(s1.sample_delta, 10);
        assert_eq!(s1.not_ready_delta, 3);
        // HWM unmoved next interval: current depth bounds it; traffic
        // counters reduce to deltas.
        let stats2 = ReplayBacklogStats {
            max_queue_len: 2,
            samples: 25,
            not_ready: 3,
            ..stats1
        };
        let s2 = a.replay_signals(&stats2);
        assert_eq!(s2.queue_hwm, 2);
        assert_eq!(s2.sample_delta, 15);
        assert_eq!(s2.not_ready_delta, 0);
    }

    fn gsig(sessions: usize, live: usize) -> GatewaySignals {
        GatewaySignals {
            sessions,
            queue_hwm: 0,
            shed_delta: 0,
            started_delta: 1,
            live_shards: live,
        }
    }

    #[test]
    fn gateway_session_load_grows_pool() {
        // 16 sessions/shard capacity, 2 shards: 32 live sessions hit
        // the watermark exactly.
        let mut a = Autoscaler::new(cfg());
        let d = a.decide_gateway(&gsig(32, 2)).expect("load must act");
        assert_eq!(d.direction, ScaleDirection::Up);
        assert_eq!(d.target, 3);
    }

    #[test]
    fn gateway_session_overshoot_scales_step_proportionally() {
        // 96 sessions on 2 shards = 3x the 32-session capacity: one
        // action adds 3 shards instead of crawling through cooldowns.
        let mut a = Autoscaler::new(AutoscalerConfig {
            max_workers: 8,
            ..cfg()
        });
        assert_eq!(a.decide_gateway(&gsig(96, 2)).unwrap().target, 5);
        // Clamp still applies.
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide_gateway(&gsig(500, 2)).unwrap().target, 4);
    }

    #[test]
    fn gateway_shed_storm_grows_pool() {
        // Shed polarity flip: turned-away clients grow the gateway
        // tier (the sampler loop shrinks on sheds).
        let mut a = Autoscaler::new(cfg());
        let mut s = gsig(4, 2);
        s.shed_delta = 9;
        let d = a.decide_gateway(&s).expect("shed storm must act");
        assert_eq!(d.direction, ScaleDirection::Up);
        // Mailbox backlog counts the same way.
        let mut a = Autoscaler::new(cfg());
        let mut s = gsig(4, 2);
        s.queue_hwm = 8;
        assert_eq!(
            a.decide_gateway(&s).unwrap().direction,
            ScaleDirection::Up
        );
    }

    #[test]
    fn gateway_idleness_shrinks_and_churn_vetoes() {
        let mut a = Autoscaler::new(cfg());
        let mut s = gsig(1, 3);
        s.started_delta = 0;
        let d = a.decide_gateway(&s).expect("idle pool must shrink");
        assert_eq!(d.direction, ScaleDirection::Down);
        assert_eq!(d.target, 2);
        // At min_workers idleness holds instead of acting.
        s.live_shards = 1;
        assert_eq!(a.decide_gateway(&s), None);
        // A table still admitting clients is not idle, however empty.
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide_gateway(&gsig(1, 3)), None);
        assert_eq!(a.stats().held_deadband, 1);
    }

    #[test]
    fn gateway_oscillation_does_not_flap() {
        // Load and idleness alternating every report with a 2-report
        // confirmation streak: no action, ever — same gate, same
        // no-flap guarantee as the other two loops.
        let mut a = Autoscaler::new(AutoscalerConfig {
            confirm_reports: 2,
            ..cfg()
        });
        for k in 0..40 {
            let s = if k % 2 == 0 {
                gsig(64, 2)
            } else {
                let mut s = gsig(0, 2);
                s.started_delta = 0;
                s
            };
            assert_eq!(
                a.decide_gateway(&s),
                None,
                "gateway oscillation acted at report {k}"
            );
        }
        assert_eq!(a.stats().decisions_up + a.stats().decisions_down, 0);
    }

    #[test]
    fn gateway_signals_diff_backlog_stats_per_interval() {
        let mut a = Autoscaler::new(cfg());
        let stats1 = GatewayBacklogStats {
            live_shards: 2,
            sessions: 5,
            max_queue_len: 1,
            max_queue_hwm: 6,
            started: 10,
            shed: 3,
            ..Default::default()
        };
        let s1 = a.gateway_signals(&stats1);
        assert_eq!(s1.queue_hwm, 6, "first interval = lifetime HWM");
        assert_eq!(s1.sessions, 5);
        assert_eq!(s1.shed_delta, 3);
        assert_eq!(s1.started_delta, 10);
        // HWM unmoved next interval: current depth bounds it; the
        // monotone counters reduce to deltas.
        let stats2 = GatewayBacklogStats {
            max_queue_len: 2,
            started: 14,
            shed: 3,
            ..stats1
        };
        let s2 = a.gateway_signals(&stats2);
        assert_eq!(s2.queue_hwm, 2);
        assert_eq!(s2.started_delta, 4);
        assert_eq!(s2.shed_delta, 0);
    }

    #[test]
    fn note_failed_surfaces_in_stats() {
        let mut a = Autoscaler::new(cfg());
        a.note_failed();
        a.note_failed();
        assert_eq!(a.stats().failed, 2);
    }

    #[test]
    #[should_panic(expected = "deadband")]
    fn inverted_thresholds_are_rejected() {
        Autoscaler::new(AutoscalerConfig {
            learner_idle_below: 0.8,
            learner_busy_above: 0.2,
            ..AutoscalerConfig::default()
        });
    }
}
