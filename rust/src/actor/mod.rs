//! Actor runtime — the substrate the paper gets from Ray.
//!
//! Each actor owns mutable state on a dedicated OS thread; callers send
//! closures ("method calls") through an unbounded mailbox and either
//! block on a typed reply (`call`, Ray's `actor.method.remote()` +
//! `ray.get`), hold a deferred reply handle (`call_deferred`, a Ray
//! object ref — the building block for `ray.wait`-style pipelining), or
//! fire-and-forget (`cast`).  Messages from one sender execute in send
//! order — the ordering guarantee RLlib Flow's barrier semantics build
//! on (paper §4, Creation and Message Passing).
//!
//! Actor state is constructed *inside* the actor thread from a factory
//! closure: PJRT clients (`xla::PjRtClient` wraps an `Rc`) are not
//! `Send`, so each rollout/learner actor creates its own client and
//! compiles its own executables — mirroring the paper's process model,
//! where each Ray actor holds its own TF session.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

static NEXT_ACTOR_ID: AtomicU64 = AtomicU64::new(0);

type Envelope<A> = Box<dyn FnOnce(&mut A) + Send>;

/// A handle to an actor with state type `A`.  Cloneable; the actor
/// thread exits when every handle is dropped and the mailbox drains.
pub struct ActorHandle<A> {
    tx: mpsc::Sender<Envelope<A>>,
    id: u64,
    name: Arc<str>,
}

impl<A> Clone for ActorHandle<A> {
    fn clone(&self) -> Self {
        ActorHandle { tx: self.tx.clone(), id: self.id, name: self.name.clone() }
    }
}

impl<A> std::fmt::Debug for ActorHandle<A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ActorHandle({}#{})", self.name, self.id)
    }
}

/// A pending reply (Ray object ref).  `recv()` blocks until the actor
/// has executed the call.
pub struct Reply<R>(mpsc::Receiver<R>);

impl<R> Reply<R> {
    pub fn recv(self) -> R {
        self.0.recv().expect("actor dropped reply (actor panicked?)")
    }

    pub fn try_recv(&self) -> Option<R> {
        self.0.try_recv().ok()
    }
}

impl<A: 'static> ActorHandle<A> {
    /// Spawn an actor whose state is built by `init` on the actor thread.
    pub fn spawn<F>(name: &str, init: F) -> Self
    where
        F: FnOnce() -> A + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Envelope<A>>();
        let id = NEXT_ACTOR_ID.fetch_add(1, Ordering::Relaxed);
        std::thread::Builder::new()
            .name(format!("{name}#{id}"))
            .spawn(move || {
                let mut state = init();
                while let Ok(msg) = rx.recv() {
                    msg(&mut state);
                }
            })
            .expect("failed to spawn actor thread");
        ActorHandle { tx, id, name: Arc::from(name) }
    }

    /// Call a method and block for its result.
    pub fn call<R, F>(&self, f: F) -> R
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        self.call_deferred(f).recv()
    }

    /// Queue a call, returning a deferred reply handle.  Lets a caller
    /// keep several requests in flight per actor (the paper's
    /// `num_async` pipelining).
    pub fn call_deferred<R, F>(&self, f: F) -> Reply<R>
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        let (otx, orx) = mpsc::sync_channel(1);
        self.tx
            .send(Box::new(move |state| {
                let _ = otx.send(f(state));
            }))
            .unwrap_or_else(|_| panic!("actor {} died", self.name));
        Reply(orx)
    }

    /// Queue a call whose result is delivered into a shared channel,
    /// tagged with this submission's `tag` — the completion-queue
    /// primitive behind `gather_async` (Ray's `ray.wait` analog).
    pub fn call_into<R, F>(&self, tag: usize, out: mpsc::Sender<(usize, R)>, f: F)
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        let _ = self.tx.send(Box::new(move |state| {
            let _ = out.send((tag, f(state)));
        }));
    }

    /// Fire-and-forget message (Ray `x.remote()` without `get`).
    pub fn cast<F>(&self, f: F)
    where
        F: FnOnce(&mut A) + Send + 'static,
    {
        let _ = self.tx.send(Box::new(f));
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Spawn a homogeneous group of actors ("create_rollout_workers").
pub fn spawn_group<A: 'static, F>(
    name: &str,
    count: usize,
    mut make_init: F,
) -> Vec<ActorHandle<A>>
where
    F: FnMut(usize) -> Box<dyn FnOnce() -> A + Send>,
{
    (0..count)
        .map(|i| {
            let init = make_init(i);
            ActorHandle::spawn(&format!("{name}-{i}"), move || init())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        value: i64,
    }

    #[test]
    fn call_returns_result() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        let v = h.call(|c| {
            c.value += 5;
            c.value
        });
        assert_eq!(v, 5);
    }

    #[test]
    fn messages_execute_in_send_order() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        for _ in 0..100 {
            h.cast(|c| c.value += 1);
        }
        h.cast(|c| c.value *= 2);
        assert_eq!(h.call(|c| c.value), 200);
    }

    #[test]
    fn state_initialized_on_actor_thread() {
        let h = ActorHandle::spawn("t", || std::thread::current().id());
        let init_tid = h.call(|tid| *tid);
        let call_tid = h.call(|_| std::thread::current().id());
        assert_eq!(init_tid, call_tid);
        assert_ne!(init_tid, std::thread::current().id());
    }

    #[test]
    fn call_deferred_pipelines() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        let f1 = h.call_deferred(|c| {
            c.value += 1;
            c.value
        });
        let f2 = h.call_deferred(|c| {
            c.value += 1;
            c.value
        });
        assert_eq!(f1.recv(), 1);
        assert_eq!(f2.recv(), 2);
    }

    #[test]
    fn call_into_tags_completions() {
        let h1 = ActorHandle::spawn("a", || Counter { value: 10 });
        let h2 = ActorHandle::spawn("b", || Counter { value: 20 });
        let (tx, rx) = mpsc::channel();
        h1.call_into(0, tx.clone(), |c| c.value);
        h2.call_into(1, tx.clone(), |c| c.value);
        drop(tx);
        let mut got: Vec<(usize, i64)> = rx.iter().collect();
        got.sort();
        assert_eq!(got, vec![(0, 10), (1, 20)]);
    }

    #[test]
    fn group_spawns_distinct_actors() {
        let group =
            spawn_group("w", 4, |i| Box::new(move || Counter { value: i as i64 }));
        let values: Vec<i64> =
            group.iter().map(|h| h.call(|c| c.value)).collect();
        assert_eq!(values, vec![0, 1, 2, 3]);
        let ids: std::collections::HashSet<_> =
            group.iter().map(|h| h.id()).collect();
        assert_eq!(ids.len(), 4);
    }

    #[test]
    fn clones_share_the_actor() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        let h2 = h.clone();
        h.cast(|c| c.value += 1);
        h2.cast(|c| c.value += 1);
        assert_eq!(h.call(|c| c.value), 2);
    }

    #[test]
    fn actors_run_concurrently() {
        // Two actors sleeping in parallel should take ~1x, not 2x.
        let h1 = ActorHandle::spawn("s1", || ());
        let h2 = ActorHandle::spawn("s2", || ());
        let start = std::time::Instant::now();
        let f1 = h1.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(100))
        });
        let f2 = h2.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(100))
        });
        f1.recv();
        f2.recv();
        assert!(start.elapsed() < std::time::Duration::from_millis(180));
    }
}
