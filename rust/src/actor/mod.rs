//! Actor runtime — the substrate the paper gets from Ray.
//!
//! Each actor owns mutable state on a dedicated OS thread; callers send
//! closures ("method calls") through a **bounded ring mailbox** and
//! either block on a typed reply (`call`, Ray's `actor.method.remote()`
//! + `ray.get`), hold a deferred reply handle (`call_deferred`, a Ray
//! object ref), deliver into a shared [`CompletionQueue`] (`call_into`,
//! the batched-`ray.wait` primitive behind `gather_async`), or
//! fire-and-forget (`cast` / `try_cast`).  Messages from one sender
//! execute in send order — the ordering guarantee RLlib Flow's barrier
//! semantics build on (paper §4, Creation and Message Passing).
//!
//! Three properties distinguish this runtime from the seed version:
//!
//! * **Zero-allocation steady state** — a send writes the closure into a
//!   preallocated envelope slot (see [`mailbox`]); no per-message `Box`,
//!   no channel node.  `call` parks on a stack-held reply cell.  The
//!   mailbox is bounded, so a producer that outruns its consumer blocks
//!   (`cast`) or observes `Full` (`try_cast`) instead of growing a heap
//!   queue without limit.
//! * **Supervision** — a panic in an actor's init or in any message
//!   poisons the actor instead of tearing down the driver: queued and
//!   future messages are dropped, every pending reply resolves to
//!   [`ActorDied`], and the handle reports [`ActorHandle::is_poisoned`]
//!   so owners (e.g. `WorkerSet::restart_dead`) can respawn it.
//! * **Telemetry** — every actor exports queue depth (current/high
//!   water), messages processed, and busy/idle time through a global
//!   registry ([`all_actor_stats`]); `StandardMetricsReporting` folds
//!   these into each train result so a starved pipeline stage is
//!   visible, not inferred.
//!
//! Actor state is constructed *inside* the actor thread from a factory
//! closure: PJRT clients (`xla::PjRtClient` wraps an `Rc`) are not
//! `Send`, so each rollout/learner actor creates its own client and
//! compiles its own executables — mirroring the paper's process model,
//! where each Ray actor holds its own TF session.

mod autoscaler;
pub mod faults;
mod mailbox;
mod queue;
mod registry;
pub mod tags;
mod telemetry;

pub use autoscaler::{
    Autoscaler, AutoscalerConfig, AutoscaleSignals, AutoscaleStats,
    GatewaySignals, ReplaySignals, ScaleDirection, ScaleDirective,
};
pub use faults::{FaultAction, FaultCounters, FaultStats};
pub use mailbox::{TryCastError, DEFAULT_MAILBOX_CAPACITY};
pub use queue::{Completion, CompletionQueue};
pub use registry::{
    RegistryFull, ShardRegistry, WeightCastStats, WeightCaster,
    DEFAULT_CAST_WATERMARK, DEFAULT_STALE_VERSIONS,
};
pub use tags::MAX_SHARDS;
pub use telemetry::{all_actor_stats, ActorStatsSnapshot, ActorTelemetry};

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use mailbox::{Envelope, Shared};
use queue::CqGuard;

static NEXT_ACTOR_ID: AtomicU64 = AtomicU64::new(0);

/// The error every blocking interaction with a poisoned actor resolves
/// to: the actor's thread panicked (or its init did) and the message
/// did not run to completion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ActorDied {
    /// `name#id` of the dead actor.
    pub actor: String,
}

impl std::fmt::Display for ActorDied {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "actor {} died (panicked)", self.actor)
    }
}

impl std::error::Error for ActorDied {}

// ---------------------------------------------------------------------
// Reply plumbing
// ---------------------------------------------------------------------

enum ReplyState<R> {
    Waiting,
    Done(R),
    Dropped,
}

/// A one-shot rendezvous cell.  Used on the caller's stack by `call`
/// (zero allocation) and behind an `Arc` by `call_deferred`.
struct ReplyCell<R> {
    state: Mutex<ReplyState<R>>,
    cv: Condvar,
}

impl<R> ReplyCell<R> {
    fn new() -> Self {
        ReplyCell { state: Mutex::new(ReplyState::Waiting), cv: Condvar::new() }
    }

    /// First terminal write wins; wakes all waiters.
    fn fulfill(&self, terminal: ReplyState<R>) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, ReplyState::Waiting) {
            *st = terminal;
            self.cv.notify_all();
        }
    }

    /// Block until terminal; `None` means the message died unexecuted.
    fn wait_take(&self) -> Option<R> {
        let mut st = self.state.lock().unwrap();
        loop {
            match &*st {
                ReplyState::Waiting => st = self.cv.wait(st).unwrap(),
                ReplyState::Dropped => return None,
                ReplyState::Done(_) => {
                    match std::mem::replace(&mut *st, ReplyState::Dropped) {
                        ReplyState::Done(r) => return Some(r),
                        _ => unreachable!(),
                    }
                }
            }
        }
    }

    /// [`Self::wait_take`] with a deadline: `None` while still pending
    /// after `timeout`; a condvar wait, so a fulfillment wakes the
    /// caller immediately instead of at the next poll tick.
    fn wait_take_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Option<Option<R>> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            match &*st {
                ReplyState::Waiting => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    st = self.cv.wait_timeout(st, deadline - now).unwrap().0;
                }
                ReplyState::Dropped => return Some(None),
                ReplyState::Done(_) => {
                    match std::mem::replace(&mut *st, ReplyState::Dropped) {
                        ReplyState::Done(r) => return Some(Some(r)),
                        _ => unreachable!(),
                    }
                }
            }
        }
    }

    fn try_take(&self) -> Option<Option<R>> {
        let mut st = self.state.lock().unwrap();
        match &*st {
            ReplyState::Waiting => None,
            ReplyState::Dropped => Some(None),
            ReplyState::Done(_) => {
                match std::mem::replace(&mut *st, ReplyState::Dropped) {
                    ReplyState::Done(r) => Some(Some(r)),
                    _ => unreachable!(),
                }
            }
        }
    }
}

/// Travels inside a `call` message; points at the caller's stack cell.
///
/// Safety contract: `call` does not return (so the cell stays alive)
/// until the cell reaches a terminal state, and both paths out of this
/// guard (`complete`, `Drop`) write a terminal state exactly once and
/// never touch the cell afterwards.
struct StackReplyGuard<R: Send> {
    cell: *const ReplyCell<R>,
    armed: bool,
}

unsafe impl<R: Send> Send for StackReplyGuard<R> {}

impl<R: Send> StackReplyGuard<R> {
    fn complete(mut self, value: R) {
        self.armed = false;
        unsafe { (*self.cell).fulfill(ReplyState::Done(value)) };
    }
}

impl<R: Send> Drop for StackReplyGuard<R> {
    fn drop(&mut self) {
        if self.armed {
            unsafe { (*self.cell).fulfill(ReplyState::Dropped) };
        }
    }
}

/// Travels inside a `call_deferred` message; owns a share of the cell.
struct ArcReplyGuard<R> {
    cell: Arc<ReplyCell<R>>,
    armed: bool,
}

impl<R> ArcReplyGuard<R> {
    fn complete(mut self, value: R) {
        self.armed = false;
        self.cell.fulfill(ReplyState::Done(value));
    }
}

impl<R> Drop for ArcReplyGuard<R> {
    fn drop(&mut self) {
        if self.armed {
            self.cell.fulfill(ReplyState::Dropped);
        }
    }
}

/// A pending reply (Ray object ref).  `recv()` blocks until the actor
/// has executed the call — or reports [`ActorDied`] if it never will.
pub struct Reply<R> {
    cell: Arc<ReplyCell<R>>,
    actor: Arc<str>,
}

impl<R> Reply<R> {
    pub fn recv(self) -> Result<R, ActorDied> {
        self.cell
            .wait_take()
            .ok_or_else(|| ActorDied { actor: self.actor.to_string() })
    }

    /// `None` while pending; `Some(Err)` once the actor is known dead.
    pub fn try_recv(&self) -> Option<Result<R, ActorDied>> {
        self.cell.try_take().map(|opt| {
            opt.ok_or_else(|| ActorDied { actor: self.actor.to_string() })
        })
    }

    /// Block up to `timeout` for the reply; `None` while still pending.
    /// A fulfillment wakes the waiter immediately (condvar), so a poll
    /// loop built on this (the `WeightCaster` barrier) neither spins
    /// nor adds a full tick of latency to the common prompt-apply case.
    pub fn recv_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Option<Result<R, ActorDied>> {
        self.cell.wait_take_timeout(timeout).map(|opt| {
            opt.ok_or_else(|| ActorDied { actor: self.actor.to_string() })
        })
    }
}

// ---------------------------------------------------------------------
// The handle
// ---------------------------------------------------------------------

/// A handle to an actor with state type `A`.  Cloneable; the actor
/// thread exits when every handle is dropped and the mailbox drains.
pub struct ActorHandle<A> {
    shared: Arc<Shared<A>>,
    id: u64,
    name: Arc<str>,
}

impl<A> Clone for ActorHandle<A> {
    fn clone(&self) -> Self {
        self.shared.add_sender();
        ActorHandle {
            shared: self.shared.clone(),
            id: self.id,
            name: self.name.clone(),
        }
    }
}

impl<A> Drop for ActorHandle<A> {
    fn drop(&mut self) {
        self.shared.remove_sender();
    }
}

impl<A> std::fmt::Debug for ActorHandle<A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ActorHandle({}#{})", self.name, self.id)
    }
}

impl<A: 'static> ActorHandle<A> {
    /// Spawn an actor whose state is built by `init` on the actor
    /// thread, with the default mailbox capacity.
    pub fn spawn<F>(name: &str, init: F) -> Self
    where
        F: FnOnce() -> A + Send + 'static,
    {
        Self::spawn_with_capacity(name, DEFAULT_MAILBOX_CAPACITY, init)
    }

    /// Spawn with an explicit mailbox capacity (the backpressure bound:
    /// senders block once `capacity` messages are queued).
    pub fn spawn_with_capacity<F>(name: &str, capacity: usize, init: F) -> Self
    where
        F: FnOnce() -> A + Send + 'static,
    {
        let id = NEXT_ACTOR_ID.fetch_add(1, Ordering::Relaxed);
        let telemetry = Arc::new(ActorTelemetry::new(name, id));
        telemetry::register(&telemetry);
        let shared = Arc::new(Shared::new(capacity, telemetry));
        shared.add_sender(); // the handle returned below
        let thread_shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("{name}#{id}"))
            .spawn(move || run_actor(thread_shared, init))
            .expect("failed to spawn actor thread");
        ActorHandle { shared, id, name: Arc::from(name) }
    }

    fn died(&self) -> ActorDied {
        ActorDied { actor: format!("{}#{}", self.name, self.id) }
    }

    /// Call a method and block for its result.  The reply cell lives on
    /// this stack frame — no allocation on the steady-state path (the
    /// [`faults::SITE_CALL`] failpoint is one relaxed load when
    /// disarmed).
    // flowlint: hot-path (stack reply cell; pinned by tests/actor_alloc.rs)
    pub fn call<R, F>(&self, f: F) -> Result<R, ActorDied>
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        let fault = faults::send_failpoint(faults::SITE_CALL, &self.name);
        let cell = ReplyCell::new();
        let guard = StackReplyGuard { cell: &cell, armed: true };
        let env = Envelope::new(move |state: &mut A| {
            let guard = guard;
            let r = f(state);
            guard.complete(r);
        });
        if fault.is_some() {
            // Injected Drop/FullMailbox: the envelope never reaches the
            // ring; its guard resolves the cell below, so the caller
            // sees the same ActorDied a real loss produces.
            drop(env);
        } else if let Err(env) = self.shared.send(env) {
            // Dead actor: dropping the envelope fires the guard, which
            // resolves the cell to Dropped below.
            drop(env);
        }
        cell.wait_take().ok_or_else(|| self.died())
    }

    /// Queue a call, returning a deferred reply handle.  Lets a caller
    /// keep several requests in flight per actor (the paper's
    /// `num_async` pipelining).  Allocates the shared reply cell; hot
    /// per-item paths use `call`/`call_into` instead.
    pub fn call_deferred<R, F>(&self, f: F) -> Reply<R>
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        let fault = faults::send_failpoint(faults::SITE_CALL, &self.name);
        let cell = Arc::new(ReplyCell::new());
        let guard = ArcReplyGuard { cell: cell.clone(), armed: true };
        let env = Envelope::new(move |state: &mut A| {
            let guard = guard;
            let r = f(state);
            guard.complete(r);
        });
        if fault.is_some() {
            drop(env); // injected loss: the reply resolves to ActorDied
        } else if let Err(env) = self.shared.send(env) {
            drop(env);
        }
        Reply {
            cell,
            actor: Arc::from(format!("{}#{}", self.name, self.id)),
        }
    }

    /// Non-blocking [`Self::call_deferred`]: queue the call only if the
    /// mailbox has room *right now* — the check and the enqueue are one
    /// atomic ring operation, so the caller can never park in a
    /// blocking send on a full mailbox (the `WeightCaster` barrier
    /// relies on this).  `Err(Full)` means nothing was queued
    /// (backpressure); `Err(Dead)` means the actor is poisoned.
    pub fn try_call_deferred<R, F>(
        &self,
        f: F,
    ) -> Result<Reply<R>, TryCastError>
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        let fault =
            faults::send_failpoint(faults::SITE_TRY_CALL_DEFERRED, &self.name);
        let cell = Arc::new(ReplyCell::new());
        let guard = ArcReplyGuard { cell: cell.clone(), armed: true };
        let env = Envelope::new(move |state: &mut A| {
            let guard = guard;
            let r = f(state);
            guard.complete(r);
        });
        match fault {
            // Injected backpressure: nothing queued, caller sees Full.
            Some(faults::SendFault::Full) => {
                drop(env);
                return Err(TryCastError::Full);
            }
            // Injected loss: the reply resolves to ActorDied.
            Some(faults::SendFault::Drop) => {
                drop(env);
                return Ok(Reply {
                    cell,
                    actor: Arc::from(format!("{}#{}", self.name, self.id)),
                });
            }
            None => {}
        }
        match self.shared.try_send(env) {
            Ok(()) => Ok(Reply {
                cell,
                actor: Arc::from(format!("{}#{}", self.name, self.id)),
            }),
            Err((env, e)) => {
                drop(env);
                Err(e)
            }
        }
    }

    /// Queue a call whose result is delivered into a shared
    /// [`CompletionQueue`], tagged with `tag` — the completion-queue
    /// primitive behind `gather_async` (Ray's `ray.wait` analog).
    ///
    /// Exactly one completion is guaranteed per submission: the value,
    /// or a [`Completion::Dropped`] death notice if the actor dies
    /// before (or while) executing it.  The delivery push respects the
    /// queue's bound, so a slow consumer backpressures the actor.
    // flowlint: hot-path (per-dispatch gather primitive; pinned by tests/actor_alloc.rs)
    pub fn call_into<R, F>(&self, tag: usize, out: &CompletionQueue<R>, f: F)
    where
        R: Send + 'static,
        F: FnOnce(&mut A) -> R + Send + 'static,
    {
        let fault =
            faults::send_failpoint(faults::SITE_CALL_INTO, &self.name);
        // flowlint: allow(hot-path-alloc) -- CompletionQueue clone is an Arc refcount bump
        let guard = CqGuard::new(out.clone(), tag);
        let env = Envelope::new(move |state: &mut A| {
            let guard = guard;
            let r = f(state);
            guard.complete(r);
        });
        if fault.is_some() {
            // Injected loss (either flavor): dropping the envelope
            // fires the guard, so the submission still resolves to its
            // Dropped death notice instead of wedging the gather.
            drop(env);
        } else if let Err(env) = self.shared.send(env) {
            drop(env); // fires the guard -> Dropped notice
        }
    }

    /// Fire-and-forget message (Ray `x.remote()` without `get`).
    /// Blocks while the mailbox is full; silently dropped if the actor
    /// is dead (the [`faults::SITE_CAST`] failpoint is one relaxed load
    /// when disarmed; an injected Drop/FullMailbox loses the message
    /// silently — exactly what a lost cast looks like).
    // flowlint: hot-path (inline envelope write; pinned by tests/actor_alloc.rs)
    pub fn cast<F>(&self, f: F)
    where
        F: FnOnce(&mut A) + Send + 'static,
    {
        if faults::send_failpoint(faults::SITE_CAST, &self.name).is_some() {
            drop(f); // injected loss; destructors (guards) still run
            return;
        }
        if let Err(env) = self.shared.send(Envelope::new(f)) {
            drop(env);
        }
    }

    /// Non-blocking fire-and-forget.  On `Err` the message is dropped:
    /// [`TryCastError::Full`] is the backpressure signal, `Dead` means
    /// the actor is poisoned.
    // flowlint: hot-path (inline envelope write; pinned by tests/actor_alloc.rs)
    pub fn try_cast<F>(&self, f: F) -> Result<(), TryCastError>
    where
        F: FnOnce(&mut A) + Send + 'static,
    {
        match faults::send_failpoint(faults::SITE_TRY_CAST, &self.name) {
            Some(faults::SendFault::Full) => {
                // Injected backpressure: the caller sees the same
                // signal a genuinely full ring would produce.
                drop(f);
                return Err(TryCastError::Full);
            }
            Some(faults::SendFault::Drop) => {
                drop(f); // injected silent loss, like a cast to a dead actor
                return Ok(());
            }
            None => {}
        }
        match self.shared.try_send(Envelope::new(f)) {
            Ok(()) => Ok(()),
            Err((env, e)) => {
                drop(env);
                Err(e)
            }
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// True once the actor's thread has panicked; pending/future
    /// messages resolve to [`ActorDied`].
    ///
    /// Poisoning is published by the actor thread *after* the failing
    /// message unwinds, so a caller that just received [`ActorDied`]
    /// from the panicking call itself may observe `false` for a brief
    /// moment; use [`ActorHandle::await_poisoned`] when acting on a
    /// just-observed death (e.g. before `WorkerSet::restart_dead`).
    pub fn is_poisoned(&self) -> bool {
        self.shared.telemetry.is_poisoned()
    }

    /// Block until the poisoned flag is visible or `timeout` elapses;
    /// returns the final `is_poisoned()` state.  A condvar wait (same
    /// mechanism as [`Reply::recv_timeout`]): the supervised loop's
    /// poison signals it, so the caller wakes immediately instead of on
    /// a 1ms poll tick.
    pub fn await_poisoned(&self, timeout: std::time::Duration) -> bool {
        self.shared.telemetry.await_poisoned(timeout)
    }

    /// Cooperative force-kill — the recovery path deadline supervision
    /// uses on a *suspect* (hung or wedged) shard, where a panic will
    /// never arrive on its own:
    ///
    /// * the mailbox is poisoned immediately: queued envelopes drain
    ///   (their guards deliver death notices), future sends are
    ///   rejected, and an idle actor thread exits;
    /// * the kill flag flips: cooperating long-running sites (the
    ///   `Hang` failpoint, `RolloutWorker::sample`) observe it and
    ///   panic into the normal supervision path, resolving whatever
    ///   message the actor is wedged inside.
    ///
    /// A message that never checks the flag cannot be interrupted (this
    /// is cooperative, not `pthread_cancel`); its completion — if it
    /// ever arrives — is discarded by the gathers' epoch/write-off
    /// accounting.  Idempotent; safe from any thread.
    pub fn kill(&self) {
        self.shared.request_kill();
        self.shared.poison();
    }

    /// Point-in-time telemetry for this actor.
    pub fn stats(&self) -> ActorStatsSnapshot {
        self.shared.telemetry.snapshot()
    }

    /// Current mailbox depth — one relaxed atomic load, cheap enough
    /// for per-broadcast policy decisions (`WeightCaster`'s watermark).
    pub fn queue_len(&self) -> usize {
        self.shared.telemetry.queue_len()
    }

    pub fn mailbox_capacity(&self) -> usize {
        self.shared.capacity()
    }
}

/// The supervised actor loop: build state, execute messages, and on any
/// panic poison the mailbox instead of unwinding into `abort`/driver.
fn run_actor<A, F>(shared: Arc<Shared<A>>, init: F)
where
    F: FnOnce() -> A,
{
    // Install the fault plane's per-thread context before anything can
    // fail: failpoints on this thread match by actor name, and a Hang
    // polls this kill flag.
    faults::set_actor_ctx(faults::ActorCtx {
        name: shared.telemetry.name_arc(),
        killed: shared.kill_flag(),
    });
    let mut state = match catch_unwind(AssertUnwindSafe(init)) {
        Ok(s) => s,
        Err(_) => {
            shared.poison();
            return;
        }
    };
    loop {
        let idle_start = Instant::now();
        let Some(env) = shared.recv() else { break };
        shared
            .telemetry
            .note_idle(idle_start.elapsed().as_nanos() as u64);
        let busy_start = Instant::now();
        // The failpoint runs INSIDE the supervision catch_unwind with
        // the envelope already moved into the closure: a PanicOnce (or
        // a killed Hang) here unwinds, dropping the envelope — its
        // guards deliver death notices — and poisons the actor exactly
        // like a panicking message body.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            faults::failpoint(faults::SITE_ACTOR_LOOP);
            env.invoke(&mut state)
        }));
        if outcome.is_err() {
            // Publish the death before anything else; the panicking
            // message's own reply already resolved during unwind.
            shared.poison();
            return;
        }
        shared
            .telemetry
            .note_busy(busy_start.elapsed().as_nanos() as u64);
    }
}

/// Spawn a homogeneous group of actors ("create_rollout_workers").
pub fn spawn_group<A: 'static, F>(
    name: &str,
    count: usize,
    mut make_init: F,
) -> Vec<ActorHandle<A>>
where
    F: FnMut(usize) -> Box<dyn FnOnce() -> A + Send>,
{
    (0..count)
        .map(|i| {
            let init = make_init(i);
            ActorHandle::spawn(&format!("{name}-{i}"), move || init())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        value: i64,
    }

    #[test]
    fn call_returns_result() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        let v = h
            .call(|c| {
                c.value += 5;
                c.value
            })
            .unwrap();
        assert_eq!(v, 5);
    }

    #[test]
    fn messages_execute_in_send_order() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        for _ in 0..100 {
            h.cast(|c| c.value += 1);
        }
        h.cast(|c| c.value *= 2);
        assert_eq!(h.call(|c| c.value).unwrap(), 200);
    }

    #[test]
    fn state_initialized_on_actor_thread() {
        let h = ActorHandle::spawn("t", || std::thread::current().id());
        let init_tid = h.call(|tid| *tid).unwrap();
        let call_tid = h.call(|_| std::thread::current().id()).unwrap();
        assert_eq!(init_tid, call_tid);
        assert_ne!(init_tid, std::thread::current().id());
    }

    #[test]
    fn call_deferred_pipelines() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        let f1 = h.call_deferred(|c| {
            c.value += 1;
            c.value
        });
        let f2 = h.call_deferred(|c| {
            c.value += 1;
            c.value
        });
        assert_eq!(f1.recv().unwrap(), 1);
        assert_eq!(f2.recv().unwrap(), 2);
    }

    #[test]
    fn call_into_tags_completions() {
        let h1 = ActorHandle::spawn("a", || Counter { value: 10 });
        let h2 = ActorHandle::spawn("b", || Counter { value: 20 });
        let q = CompletionQueue::bounded(4);
        h1.call_into(0, &q, |c| c.value);
        h2.call_into(1, &q, |c| c.value);
        let mut got: Vec<(usize, i64)> = (0..2)
            .map(|_| match q.pop() {
                Completion::Item { tag, value } => (tag, value),
                Completion::Dropped { tag } => panic!("dropped {tag}"),
            })
            .collect();
        got.sort();
        assert_eq!(got, vec![(0, 10), (1, 20)]);
    }

    #[test]
    fn group_spawns_distinct_actors() {
        let group =
            spawn_group("w", 4, |i| Box::new(move || Counter { value: i as i64 }));
        let values: Vec<i64> =
            group.iter().map(|h| h.call(|c| c.value).unwrap()).collect();
        assert_eq!(values, vec![0, 1, 2, 3]);
        let ids: std::collections::HashSet<_> =
            group.iter().map(|h| h.id()).collect();
        assert_eq!(ids.len(), 4);
    }

    #[test]
    fn clones_share_the_actor() {
        let h = ActorHandle::spawn("counter", || Counter { value: 0 });
        let h2 = h.clone();
        h.cast(|c| c.value += 1);
        h2.cast(|c| c.value += 1);
        assert_eq!(h.call(|c| c.value).unwrap(), 2);
    }

    #[test]
    fn actors_run_concurrently() {
        // Two actors sleeping in parallel should take ~1x, not 2x.
        let h1 = ActorHandle::spawn("s1", || ());
        let h2 = ActorHandle::spawn("s2", || ());
        let start = std::time::Instant::now();
        let f1 = h1.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(100))
        });
        let f2 = h2.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(100))
        });
        f1.recv().unwrap();
        f2.recv().unwrap();
        assert!(start.elapsed() < std::time::Duration::from_millis(180));
    }

    // -----------------------------------------------------------------
    // Supervision
    // -----------------------------------------------------------------

    #[test]
    fn panic_poisons_instead_of_crashing() {
        let h = ActorHandle::spawn("doomed", || Counter { value: 0 });
        assert!(!h.is_poisoned());
        let r = h.call(|_c| -> i64 { panic!("worker exploded") });
        assert!(r.is_err());
        // The poisoned flag is published by the actor thread right
        // after the failing reply; wait for it rather than racing it.
        assert!(h.await_poisoned(std::time::Duration::from_secs(2)));
        // Subsequent interactions surface the death, not a panic.
        let err = h.call(|c| c.value).unwrap_err();
        assert!(err.actor.starts_with("doomed#"), "{err}");
        h.cast(|c| c.value += 1); // silently dropped
        assert!(h.call_deferred(|c| c.value).recv().is_err());
        assert_eq!(h.try_cast(|_| {}), Err(TryCastError::Dead));
    }

    #[test]
    fn init_panic_poisons() {
        let h: ActorHandle<Counter> =
            ActorHandle::spawn("stillborn", || panic!("bad init"));
        assert!(h.call(|c| c.value).is_err());
        assert!(h.await_poisoned(std::time::Duration::from_secs(2)));
    }

    #[test]
    fn pending_messages_resolve_on_death() {
        // Queue several deferred calls behind a panicking one: all of
        // them must resolve to Err, none may hang.
        let h = ActorHandle::spawn("chain", || Counter { value: 0 });
        let slow = h.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(20));
        });
        let boom = h.call_deferred(|_| -> i64 { panic!("boom") });
        let after1 = h.call_deferred(|c| c.value);
        let after2 = h.call_deferred(|c| c.value);
        assert!(slow.recv().is_ok());
        assert!(boom.recv().is_err());
        assert!(after1.recv().is_err());
        assert!(after2.recv().is_err());
    }

    #[test]
    fn call_into_delivers_death_notice() {
        let h = ActorHandle::spawn("cq-doomed", || Counter { value: 0 });
        let q: CompletionQueue<i64> = CompletionQueue::bounded(4);
        h.call_into(3, &q, |_| -> i64 { panic!("die mid-call") });
        h.call_into(4, &q, |c| c.value); // behind the panic -> dropped
        let mut tags: Vec<usize> = (0..2)
            .map(|_| match q.pop() {
                Completion::Dropped { tag } => tag,
                Completion::Item { tag, .. } => {
                    panic!("unexpected item from tag {tag}")
                }
            })
            .collect();
        tags.sort();
        assert_eq!(tags, vec![3, 4]);
    }

    // -----------------------------------------------------------------
    // Backpressure
    // -----------------------------------------------------------------

    #[test]
    fn try_cast_reports_full_mailbox() {
        let h = ActorHandle::spawn_with_capacity("tiny", 2, || {
            Counter { value: 0 }
        });
        // Occupy the actor so the mailbox can fill.
        let gate = h.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
        // The actor may or may not have dequeued the gate yet; fill
        // until Full is observed.
        let mut saw_full = false;
        for _ in 0..8 {
            if h.try_cast(|c| c.value += 1) == Err(TryCastError::Full) {
                saw_full = true;
                break;
            }
        }
        assert!(saw_full, "bounded mailbox never reported Full");
        gate.recv().unwrap();
        assert_eq!(h.mailbox_capacity(), 2);
    }

    #[test]
    fn blocking_cast_applies_backpressure_not_loss() {
        let h = ActorHandle::spawn_with_capacity("slowbox", 4, || {
            Counter { value: 0 }
        });
        for _ in 0..64 {
            h.cast(|c| c.value += 1); // blocks rather than drops
        }
        assert_eq!(h.call(|c| c.value).unwrap(), 64);
    }

    // -----------------------------------------------------------------
    // Telemetry
    // -----------------------------------------------------------------

    #[test]
    fn stats_count_messages_and_depth() {
        let h = ActorHandle::spawn("metered", || Counter { value: 0 });
        let gate = h.call_deferred(|_| {
            std::thread::sleep(std::time::Duration::from_millis(30));
        });
        for _ in 0..5 {
            h.cast(|c| c.value += 1);
        }
        gate.recv().unwrap();
        let final_v = h.call(|c| c.value).unwrap();
        assert_eq!(final_v, 5);
        let s = h.stats();
        // gate + 5 casts + 1 call.
        assert_eq!(s.messages_processed, 7);
        assert!(s.queue_hwm >= 1, "casts queued behind the gate");
        assert!(s.busy_ns > 0);
        assert!(!s.poisoned);
        // The global registry sees this actor too.
        assert!(all_actor_stats().iter().any(|a| a.id == h.id()));
    }

    // -----------------------------------------------------------------
    // Fault plane + cooperative kill
    //
    // Fault rules are process-global and unit tests share one binary,
    // so every rule here is scoped to a unique actor name and cleared
    // on the way out.
    // -----------------------------------------------------------------

    #[test]
    fn kill_poisons_an_idle_actor() {
        let h = ActorHandle::spawn("kill-idle", || Counter { value: 0 });
        assert_eq!(h.call(|c| c.value).unwrap(), 0);
        h.kill();
        assert!(h.await_poisoned(std::time::Duration::from_secs(2)));
        assert!(h.call(|c| c.value).is_err());
        assert_eq!(h.try_cast(|_| {}), Err(TryCastError::Dead));
    }

    #[test]
    fn kill_unwedges_a_hung_actor() {
        let h = ActorHandle::spawn("kill-hung-w", || Counter { value: 0 });
        let id = faults::inject(
            faults::SITE_ACTOR_LOOP,
            Some("kill-hung-w"),
            FaultAction::Hang,
        );
        let pending = h.call_deferred(|c| c.value);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(pending.try_recv().is_none(), "hang failpoint did not wedge");
        // The cooperative kill panics the hang into supervision: the
        // wedged message's reply resolves as a death, not a hang.
        h.kill();
        assert!(pending.recv().is_err());
        assert!(h.await_poisoned(std::time::Duration::from_secs(2)));
        faults::clear(id);
    }

    #[test]
    fn injected_loop_panic_poisons_like_a_real_crash() {
        let h = ActorHandle::spawn("po-loop-w", || Counter { value: 0 });
        let id = faults::inject(
            faults::SITE_ACTOR_LOOP,
            Some("po-loop-w"),
            FaultAction::PanicOnce,
        );
        assert!(h.call(|c| c.value).is_err());
        assert!(h.await_poisoned(std::time::Duration::from_secs(2)));
        faults::clear(id);
    }

    #[test]
    fn injected_drop_reply_resolves_call_to_actor_died() {
        let h = ActorHandle::spawn("droprep-w", || Counter { value: 0 });
        let id = faults::inject_with(
            faults::SITE_CALL,
            Some("droprep-w"),
            FaultAction::DropReply,
            1.0,
            None,
            Some(1),
        );
        assert!(h.call(|c| c.value).is_err());
        // The actor itself is healthy — only the message was lost.
        assert!(!h.is_poisoned());
        assert_eq!(h.call(|c| c.value).unwrap(), 0);
        faults::clear(id);
    }

    #[test]
    fn injected_full_mailbox_backpressures_try_call_deferred() {
        let h = ActorHandle::spawn("fullmb-w", || Counter { value: 0 });
        let id = faults::inject_with(
            faults::SITE_TRY_CALL_DEFERRED,
            Some("fullmb-w"),
            FaultAction::FullMailbox,
            1.0,
            None,
            Some(1),
        );
        assert_eq!(
            h.try_call_deferred(|c| c.value).err(),
            Some(TryCastError::Full)
        );
        // Budget spent: the next attempt goes through.
        let r = h.try_call_deferred(|c| c.value).unwrap();
        assert_eq!(r.recv().unwrap(), 0);
        faults::clear(id);
    }

    #[test]
    fn injected_cast_loss_is_silent() {
        let h = ActorHandle::spawn("castloss-w", || Counter { value: 0 });
        let id = faults::inject_with(
            faults::SITE_CAST,
            Some("castloss-w"),
            FaultAction::DropReply,
            1.0,
            None,
            Some(1),
        );
        h.cast(|c| c.value += 10); // lost
        h.cast(|c| c.value += 1); // delivered
        assert_eq!(h.call(|c| c.value).unwrap(), 1);
        faults::clear(id);
    }

    #[test]
    fn injected_try_cast_faults_surface_like_real_ones() {
        let h = ActorHandle::spawn("trycastflt-w", || Counter { value: 0 });
        // FullMailbox -> the caller sees the backpressure signal.
        let id = faults::inject_with(
            faults::SITE_TRY_CAST,
            Some("trycastflt-w"),
            FaultAction::FullMailbox,
            1.0,
            None,
            Some(1),
        );
        assert_eq!(
            h.try_cast(|c| c.value += 10).err(),
            Some(TryCastError::Full)
        );
        faults::clear(id);
        // DropReply -> silent loss, like a cast to a dead actor.
        let id = faults::inject_with(
            faults::SITE_TRY_CAST,
            Some("trycastflt-w"),
            FaultAction::DropReply,
            1.0,
            None,
            Some(1),
        );
        assert!(h.try_cast(|c| c.value += 100).is_ok()); // lost
        assert!(h.try_cast(|c| c.value += 1).is_ok()); // delivered
        assert_eq!(h.call(|c| c.value).unwrap(), 1);
        faults::clear(id);
    }

    #[test]
    fn injected_call_into_loss_yields_a_dropped_notice() {
        let h = ActorHandle::spawn("cqflt-w", || Counter { value: 7 });
        let q: CompletionQueue<i32> = CompletionQueue::bounded(4);
        let id = faults::inject_with(
            faults::SITE_CALL_INTO,
            Some("cqflt-w"),
            FaultAction::DropReply,
            1.0,
            None,
            Some(1),
        );
        // The lost submission must still resolve — as a death notice,
        // never a wedged gather.
        h.call_into(11, &q, |c| c.value);
        assert_eq!(q.pop(), Completion::Dropped { tag: 11 });
        // Budget spent: the next submission completes normally.
        h.call_into(12, &q, |c| c.value);
        assert_eq!(q.pop(), Completion::Item { tag: 12, value: 7 });
        faults::clear(id);
    }
}
