//! The fault-injection plane: process-global, seeded, deterministic
//! failpoints threaded through the control plane's hot sites.
//!
//! Chaos testing this runtime used to mean "kill a thread and hope the
//! schedule cooperates".  A failpoint turns a failure into a *scripted*
//! event: a rule keyed by site name (see the `SITE_*` constants) and an
//! optional actor-name substring, armed with a probability, an
//! `nth`-occurrence trigger, and a fire budget, executing one of five
//! actions:
//!
//! * [`FaultAction::Delay`] — sleep N ms at the site (a slow shard);
//! * [`FaultAction::Hang`] — block at the site until the rule is
//!   [`clear`]ed or the actor is killed ([`ActorHandle::kill`]), in
//!   which case the hang panics and the normal poison/supervision
//!   machinery takes over (a wedged shard, *recoverable* by deadline
//!   supervision);
//! * [`FaultAction::PanicOnce`] — panic at the site (a crash; the rule
//!   disarms after firing so the replacement comes up clean — re-inject
//!   it to script a crash *loop*);
//! * [`FaultAction::DropReply`] — at a send site, silently drop the
//!   envelope: a `call`'s guard resolves to `ActorDied`, a cast
//!   vanishes (a lost message);
//! * [`FaultAction::FullMailbox`] — at a send site, behave as if the
//!   recipient's mailbox were full: `try_*` paths return `Full`,
//!   fire-and-forget paths shed (backpressure without the load).
//!
//! **Cost when disarmed: one relaxed atomic load per site.**  The
//! registry arms a global counter; every site checks it before touching
//! any lock, so the plane is compiled in permanently (no cfg flag — the
//! code you test is the code you ship) without showing up in the
//! mailbox fast path (`tests/actor_alloc.rs` holds with it enabled).
//!
//! Rules come from [`inject`]/[`inject_with`] (tests, tools) or from
//! the environment at first use: `FLOWRL_FAULTS` holds a `;`-separated
//! schedule, e.g.
//!
//! ```text
//! FLOWRL_FAULTS="actor::loop@rollout-2=hang;mailbox::cast=delay:5:p0.1:n3"
//! ```
//!
//! (site `[@actor-substring]` `=` action, with `delay:<ms>`, and
//! optional `p<prob>`, `n<nth>`, `x<max_fires>` suffix tokens), and
//! `FLOWRL_FAULT_SEED` seeds the probability draws so a stochastic
//! schedule replays identically.
//!
//! [`ActorHandle::kill`]: super::ActorHandle::kill

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::Rng;

// ---------------------------------------------------------------------
// Site names
// ---------------------------------------------------------------------

/// `ActorHandle::cast`, evaluated on the sending thread before the
/// envelope reaches the ring.
pub const SITE_CAST: &str = "mailbox::cast";
/// `ActorHandle::try_cast`, sending side (`Drop` loses the message as
/// `Ok`, `FullMailbox` surfaces as `TryCastError::Full`).
pub const SITE_TRY_CAST: &str = "mailbox::try_cast";
/// `ActorHandle::call` / `call_deferred`, sending side.
pub const SITE_CALL: &str = "mailbox::call";
/// `ActorHandle::try_call_deferred`, sending side.
pub const SITE_TRY_CALL_DEFERRED: &str = "mailbox::try_call_deferred";
/// `ActorHandle::call_into`, sending side; an injected fault surfaces
/// as a [`Completion::Dropped`](super::Completion) death notice on the
/// target queue — the loss is visible, never silent.
pub const SITE_CALL_INTO: &str = "mailbox::call_into";
/// The supervised actor loop, on the actor thread, once per message,
/// *inside* the supervision `catch_unwind` (a `PanicOnce` here poisons
/// the actor exactly like a panicking message body).
pub const SITE_ACTOR_LOOP: &str = "actor::loop";
/// `WeightCaster::broadcast`/`broadcast_sync`, once per recipient lane,
/// on the broadcasting thread.
pub const SITE_CASTER_LANE: &str = "caster::lane";
/// `RolloutWorker::sample`, on the worker's actor thread.
pub const SITE_ROLLOUT_SAMPLE: &str = "rollout::sample";

/// Default seed for the registry's probability draws
/// (`FLOWRL_FAULT_SEED` overrides).
pub const DEFAULT_FAULT_SEED: u64 = 0x5EED;

// ---------------------------------------------------------------------
// Actions + rules
// ---------------------------------------------------------------------

/// What a fired failpoint does at its site (see the module docs for
/// per-site semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Sleep this many milliseconds at the site.
    Delay(u64),
    /// Block at the site until the rule is [`clear`]ed (resumes
    /// normally) or the actor is killed (panics into supervision).
    Hang,
    /// Panic at the site; the rule disarms after firing.
    PanicOnce,
    /// Send sites: drop the envelope silently.
    DropReply,
    /// Send sites: behave as if the recipient's mailbox were full.
    FullMailbox,
}

struct Rule {
    id: u64,
    site: String,
    /// Substring match against the actor name; `None` matches any.
    actor: Option<String>,
    action: FaultAction,
    probability: f64,
    /// Fire only on exactly the `nth` matching hit (1-based).
    nth: Option<u64>,
    /// Disarm after this many fires (`PanicOnce` defaults to 1).
    max_fires: Option<u64>,
    hits: u64,
    fired: u64,
    /// Disarmed rules stay resident (a hanging occurrence polls its
    /// rule until [`clear`]) but never fire again.
    disarmed: bool,
}

struct FaultState {
    rules: Vec<Rule>,
    rng: Rng,
    next_id: u64,
}

/// Count of *armed* rules; `u64::MAX` = registry not yet initialized
/// (the sentinel routes the very first check through init, so an
/// env-var schedule arms without any `inject` call while the disarmed
/// steady state stays a single relaxed load).
static ARMED: AtomicU64 = AtomicU64::new(u64::MAX);

static STATE: OnceLock<Mutex<FaultState>> = OnceLock::new();

/// True if any failpoint rule is currently armed.  This is the whole
/// fast path: sites return immediately when it is false.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed) != 0
}

fn state() -> &'static Mutex<FaultState> {
    STATE.get_or_init(|| {
        let seed = std::env::var("FLOWRL_FAULT_SEED")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(DEFAULT_FAULT_SEED);
        let mut st = FaultState {
            rules: Vec::new(),
            rng: Rng::new(seed),
            next_id: 1,
        };
        if let Ok(sched) = std::env::var("FLOWRL_FAULTS") {
            match parse_schedule(&sched) {
                Ok(parsed) => {
                    for p in parsed {
                        push_rule(&mut st, p);
                    }
                }
                Err(e) => {
                    eprintln!("flowrl: ignoring bad FLOWRL_FAULTS: {e}");
                }
            }
        }
        sync_armed(&st);
        Mutex::new(st)
    })
}

fn sync_armed(st: &FaultState) {
    let n = st.rules.iter().filter(|r| !r.disarmed).count() as u64;
    ARMED.store(n, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Schedule parsing (FLOWRL_FAULTS)
// ---------------------------------------------------------------------

struct ParsedRule {
    site: String,
    actor: Option<String>,
    action: FaultAction,
    probability: f64,
    nth: Option<u64>,
    max_fires: Option<u64>,
}

/// Grammar per `;`-separated entry: `site[@actor]=action[:opts...]`.
/// Actions: `delay:<ms>`, `hang`, `panic_once`, `drop_reply`,
/// `full_mailbox`.  Option tokens: `p<float>` (probability),
/// `n<u64>` (nth hit), `x<u64>` (max fires).
fn parse_schedule(s: &str) -> Result<Vec<ParsedRule>, String> {
    let mut out = Vec::new();
    for entry in s.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (lhs, rhs) = entry
            .split_once('=')
            .ok_or_else(|| format!("{entry:?}: missing '='"))?;
        let (site, actor) = match lhs.split_once('@') {
            Some((s, a)) => (s.trim(), Some(a.trim().to_string())),
            None => (lhs.trim(), None),
        };
        if site.is_empty() {
            return Err(format!("{entry:?}: empty site"));
        }
        let mut tokens = rhs.split(':');
        let name = tokens.next().unwrap_or("").trim();
        let action = match name {
            "hang" => FaultAction::Hang,
            "panic_once" => FaultAction::PanicOnce,
            "drop_reply" => FaultAction::DropReply,
            "full_mailbox" => FaultAction::FullMailbox,
            "delay" => {
                let ms = tokens
                    .next()
                    .and_then(|t| t.trim().parse().ok())
                    .ok_or_else(|| {
                        format!("{entry:?}: delay needs delay:<ms>")
                    })?;
                FaultAction::Delay(ms)
            }
            other => return Err(format!("{entry:?}: unknown action {other:?}")),
        };
        let mut probability = 1.0;
        let mut nth = None;
        let mut max_fires = None;
        for tok in tokens {
            let tok = tok.trim();
            if let Some(p) = tok.strip_prefix('p') {
                probability = p
                    .parse()
                    .map_err(|_| format!("{entry:?}: bad probability {tok:?}"))?;
            } else if let Some(n) = tok.strip_prefix('n') {
                nth = Some(n.parse().map_err(|_| {
                    format!("{entry:?}: bad nth {tok:?}")
                })?);
            } else if let Some(x) = tok.strip_prefix('x') {
                max_fires = Some(x.parse().map_err(|_| {
                    format!("{entry:?}: bad max_fires {tok:?}")
                })?);
            } else {
                return Err(format!("{entry:?}: unknown option {tok:?}"));
            }
        }
        if !(0.0..=1.0).contains(&probability) {
            return Err(format!("{entry:?}: probability out of [0,1]"));
        }
        out.push(ParsedRule {
            site: site.to_string(),
            actor,
            action,
            probability,
            nth,
            max_fires,
        });
    }
    Ok(out)
}

fn push_rule(st: &mut FaultState, p: ParsedRule) -> u64 {
    let id = st.next_id;
    st.next_id += 1;
    // PanicOnce disarms after one fire unless the caller widened it.
    let max_fires = match (p.action, p.max_fires) {
        (FaultAction::PanicOnce, None) => Some(1),
        (_, m) => m,
    };
    st.rules.push(Rule {
        id,
        site: p.site,
        actor: p.actor,
        action: p.action,
        probability: p.probability,
        nth: p.nth,
        max_fires,
        hits: 0,
        fired: 0,
        disarmed: false,
    });
    id
}

// ---------------------------------------------------------------------
// Public arming API
// ---------------------------------------------------------------------

/// Arm a rule that always fires at `site` for actors whose name
/// contains `actor` (`None` = any actor).  Returns the rule id for
/// [`clear`].  `PanicOnce` rules disarm themselves after one fire.
pub fn inject(site: &str, actor: Option<&str>, action: FaultAction) -> u64 {
    inject_with(site, actor, action, 1.0, None, None)
}

/// [`inject`] with full arming control: `probability` gates each hit
/// through the registry's seeded RNG, `nth` fires only on exactly the
/// nth matching hit, `max_fires` disarms the rule after that many
/// fires (disarmed rules stay resident until [`clear`]ed, so a hanging
/// occurrence can still be released).
pub fn inject_with(
    site: &str,
    actor: Option<&str>,
    action: FaultAction,
    probability: f64,
    nth: Option<u64>,
    max_fires: Option<u64>,
) -> u64 {
    let mut st = state().lock().unwrap();
    let id = push_rule(
        &mut st,
        ParsedRule {
            site: site.to_string(),
            actor: actor.map(|a| a.to_string()),
            action,
            probability: probability.clamp(0.0, 1.0),
            nth,
            max_fires,
        },
    );
    sync_armed(&st);
    id
}

/// Remove a rule entirely (releases any occurrence currently hanging
/// on it).  Returns false if the id is unknown (already cleared).
///
/// Prefer this over a global wipe: tests in one binary run
/// concurrently, and rules are process-global.
pub fn clear(id: u64) -> bool {
    let mut st = state().lock().unwrap();
    let before = st.rules.len();
    st.rules.retain(|r| r.id != id);
    sync_armed(&st);
    st.rules.len() != before
}

/// Number of resident rules (armed + disarmed-but-unclicked).
pub fn active_rules() -> usize {
    state().lock().unwrap().rules.len()
}

/// Counters a rule has accumulated: `(hits, fired)`.  `None` if the
/// rule was cleared.
pub fn rule_counters(id: u64) -> Option<(u64, u64)> {
    let st = state().lock().unwrap();
    st.rules.iter().find(|r| r.id == id).map(|r| (r.hits, r.fired))
}

fn rule_resident(id: u64) -> bool {
    let st = state().lock().unwrap();
    st.rules.iter().any(|r| r.id == id)
}

// ---------------------------------------------------------------------
// Per-thread actor context (set by the supervised loop)
// ---------------------------------------------------------------------

/// What a failpoint on an actor thread knows about its host: the name
/// rules match against, and the cooperative kill flag a `Hang` polls.
#[derive(Clone)]
pub(crate) struct ActorCtx {
    pub(crate) name: Arc<str>,
    pub(crate) killed: Arc<AtomicBool>,
}

thread_local! {
    static ACTOR_CTX: std::cell::RefCell<Option<ActorCtx>> =
        const { std::cell::RefCell::new(None) };
}

/// Install the actor context on the current thread (the supervised
/// loop calls this once at thread start).
pub(crate) fn set_actor_ctx(ctx: ActorCtx) {
    ACTOR_CTX.with(|c| *c.borrow_mut() = Some(ctx));
}

fn current_ctx() -> Option<ActorCtx> {
    ACTOR_CTX.with(|c| c.borrow().clone())
}

// ---------------------------------------------------------------------
// Evaluation
// ---------------------------------------------------------------------

/// Decide (under the registry lock) whether any rule fires for
/// `site`/`actor`; the action executes *after* the lock is released so
/// a panic can never poison the registry mutex.
fn fire(site: &str, actor: &str) -> Option<(u64, FaultAction)> {
    let mut st = state().lock().unwrap();
    let st = &mut *st;
    for r in st.rules.iter_mut() {
        if r.disarmed || r.site != site {
            continue;
        }
        if let Some(a) = &r.actor {
            if !actor.contains(a.as_str()) {
                continue;
            }
        }
        r.hits += 1;
        if let Some(n) = r.nth {
            if r.hits != n {
                if r.hits > n {
                    // Can never fire again: restore the fast path.
                    r.disarmed = true;
                    sync_armed(st);
                }
                continue;
            }
        }
        if r.probability < 1.0 && !st.rng.chance(r.probability) {
            continue;
        }
        r.fired += 1;
        let done = r.max_fires.is_some_and(|m| r.fired >= m)
            || (r.nth.is_some() && r.max_fires.is_none());
        let out = (r.id, r.action);
        if done {
            r.disarmed = true;
            sync_armed(st);
        }
        return Some(out);
    }
    None
}

/// Block until the rule is cleared or `killed` flips; a kill panics so
/// the hang resolves through the normal supervision path (poison,
/// death notices, restart).
fn hang(id: u64, killed: Option<Arc<AtomicBool>>) {
    loop {
        if !rule_resident(id) {
            return; // released: resume as if the site never fired
        }
        if let Some(k) = &killed {
            // SeqCst to pair with `Shared::request_kill`'s store: a
            // kill must be observed on the next poll, not whenever the
            // cache line happens to migrate.
            if k.load(Ordering::SeqCst) {
                panic!("flowrl fault plane: hung actor killed (rule {id})");
            }
        }
        std::thread::sleep(Duration::from_micros(100));
    }
}

/// Actor-thread failpoint (sites [`SITE_ACTOR_LOOP`],
/// [`SITE_ROLLOUT_SAMPLE`], or any site user code plants on an actor
/// thread).  Executes `Delay`/`Hang`/`PanicOnce` in place; the
/// send-only actions (`DropReply`, `FullMailbox`) are ignored here.
/// One relaxed atomic load when no rule is armed.
#[inline]
pub fn failpoint(site: &str) {
    if !armed() {
        return;
    }
    failpoint_slow(site);
}

#[cold]
fn failpoint_slow(site: &str) {
    let ctx = current_ctx();
    let name = ctx.as_ref().map(|c| c.name.as_ref()).unwrap_or("");
    let Some((id, action)) = fire(site, name) else { return };
    match action {
        FaultAction::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
        }
        FaultAction::Hang => {
            hang(id, ctx.map(|c| c.killed));
        }
        FaultAction::PanicOnce => {
            panic!("flowrl fault plane: panic_once at {site} (rule {id})");
        }
        FaultAction::DropReply | FaultAction::FullMailbox => {}
    }
}

/// What a *send* site does when its failpoint fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SendFault {
    /// Drop the envelope silently (guards resolve as a death would).
    Drop,
    /// Pretend the recipient's mailbox is full.
    Full,
}

/// Send-side failpoint (sites [`SITE_CAST`], [`SITE_CALL`],
/// [`SITE_TRY_CALL_DEFERRED`], [`SITE_CASTER_LANE`]); `actor` is the
/// *recipient's* name.  `Delay`/`Hang`/`PanicOnce` execute on the
/// sending thread right here (a hang at a send site wedges the sender
/// until [`clear`] — there is no kill flag to poll); `DropReply` and
/// `FullMailbox` are returned for the caller to enact on its envelope.
/// One relaxed atomic load when no rule is armed.
#[inline]
pub(crate) fn send_failpoint(site: &str, actor: &str) -> Option<SendFault> {
    if !armed() {
        return None;
    }
    send_failpoint_slow(site, actor)
}

#[cold]
fn send_failpoint_slow(site: &str, actor: &str) -> Option<SendFault> {
    let (id, action) = fire(site, actor)?;
    match action {
        FaultAction::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        FaultAction::Hang => {
            hang(id, None);
            None
        }
        FaultAction::PanicOnce => {
            panic!("flowrl fault plane: panic_once at {site} (rule {id})");
        }
        FaultAction::DropReply => Some(SendFault::Drop),
        FaultAction::FullMailbox => Some(SendFault::Full),
    }
}

// ---------------------------------------------------------------------
// Fault counters (deadline supervision -> TrainResult)
// ---------------------------------------------------------------------

/// Shared counters the deadline-supervision layer increments and the
/// metrics operators snapshot into `TrainResult::faults` — same Arc
/// pattern as `ScaleCounters`.
#[derive(Debug, Default)]
pub struct FaultCounters {
    suspects: AtomicU64,
    forced_restarts: AtomicU64,
    breaker_trips: AtomicU64,
}

impl FaultCounters {
    /// A shard blew its dispatch deadline and was declared suspect.
    pub fn note_suspect(&self) {
        self.suspects.fetch_add(1, Ordering::Relaxed);
    }

    /// A suspect (or crashed) worker was force-restarted under the
    /// `RestartPolicy`.
    pub fn note_forced_restart(&self) {
        self.forced_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// A slot exhausted its restart budget and was breaker-retired.
    pub fn note_breaker_trip(&self) {
        self.breaker_trips.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> FaultStats {
        FaultStats {
            suspects: self.suspects.load(Ordering::Relaxed),
            forced_restarts: self.forced_restarts.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time fault-supervision counters (attached to
/// `TrainResult::faults`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Deadline expiries: a dispatched shard went silent past its
    /// deadline and was written off + force-killed.
    pub suspects: u64,
    /// Restarts performed by the `RestartPolicy` (budgeted, backed
    /// off).
    pub forced_restarts: u64,
    /// Slots permanently retired by the restart circuit breaker.
    pub breaker_trips: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    // Rules are process-global and unit tests share one binary, so
    // every test uses its own site/actor names and clears its rules.

    #[test]
    fn parse_schedule_full_grammar() {
        let rules = parse_schedule(
            "actor::loop@rollout-2=hang; mailbox::cast=delay:5:p0.25:n3 ;\
             rollout::sample=panic_once:x2;;caster::lane@w=full_mailbox",
        )
        .unwrap();
        assert_eq!(rules.len(), 4);
        assert_eq!(rules[0].site, "actor::loop");
        assert_eq!(rules[0].actor.as_deref(), Some("rollout-2"));
        assert_eq!(rules[0].action, FaultAction::Hang);
        assert_eq!(rules[1].action, FaultAction::Delay(5));
        assert_eq!(rules[1].probability, 0.25);
        assert_eq!(rules[1].nth, Some(3));
        assert_eq!(rules[2].action, FaultAction::PanicOnce);
        assert_eq!(rules[2].max_fires, Some(2));
        assert_eq!(rules[3].action, FaultAction::FullMailbox);
    }

    #[test]
    fn parse_schedule_rejects_garbage() {
        assert!(parse_schedule("no_equals_sign").is_err());
        assert!(parse_schedule("site=warp_core_breach").is_err());
        assert!(parse_schedule("site=delay").is_err());
        assert!(parse_schedule("site=hang:p1.5").is_err());
        assert!(parse_schedule("site=hang:q9").is_err());
        assert!(parse_schedule("=hang").is_err());
    }

    #[test]
    fn inject_fire_clear_roundtrip() {
        let site = "test::ifc";
        assert_eq!(fire(site, "anyone"), None);
        let id = inject(site, None, FaultAction::Delay(0));
        assert!(armed());
        assert_eq!(fire(site, "anyone"), Some((id, FaultAction::Delay(0))));
        assert_eq!(rule_counters(id), Some((1, 1)));
        assert!(clear(id));
        assert!(!clear(id));
        assert_eq!(fire(site, "anyone"), None);
    }

    #[test]
    fn actor_substring_gates_matching() {
        let site = "test::sub";
        let id = inject(site, Some("worker-7"), FaultAction::DropReply);
        assert_eq!(fire(site, "rollout-worker-3"), None);
        assert_eq!(
            fire(site, "rollout-worker-7"),
            Some((id, FaultAction::DropReply))
        );
        clear(id);
    }

    #[test]
    fn nth_fires_exactly_once_then_disarms() {
        let site = "test::nth";
        let id = inject_with(
            site,
            None,
            FaultAction::Delay(0),
            1.0,
            Some(3),
            None,
        );
        assert_eq!(fire(site, "a"), None);
        assert_eq!(fire(site, "a"), None);
        assert_eq!(fire(site, "a"), Some((id, FaultAction::Delay(0))));
        // Disarmed after its nth fire, but still resident for clear().
        assert_eq!(fire(site, "a"), None);
        assert_eq!(rule_counters(id), Some((3, 1)));
        clear(id);
    }

    #[test]
    fn panic_once_disarms_after_one_fire() {
        let site = "test::po";
        let id = inject(site, None, FaultAction::PanicOnce);
        assert_eq!(fire(site, "x"), Some((id, FaultAction::PanicOnce)));
        // Second occurrence does not fire (the replacement comes up
        // clean), but the rule is resident until cleared.
        assert_eq!(fire(site, "x"), None);
        assert!(rule_resident(id));
        clear(id);
    }

    #[test]
    fn max_fires_budget_is_respected() {
        let site = "test::mf";
        let id = inject_with(
            site,
            None,
            FaultAction::FullMailbox,
            1.0,
            None,
            Some(2),
        );
        assert!(fire(site, "a").is_some());
        assert!(fire(site, "a").is_some());
        assert_eq!(fire(site, "a"), None);
        assert_eq!(rule_counters(id), Some((2, 2)));
        clear(id);
    }

    #[test]
    fn probability_draws_are_seeded_and_partial() {
        let site = "test::prob";
        let id = inject_with(
            site,
            None,
            FaultAction::Delay(0),
            0.5,
            None,
            None,
        );
        let fires = (0..200).filter(|_| fire(site, "a").is_some()).count();
        // Seeded draw: stable across runs, strictly partial.
        assert!(fires > 50 && fires < 150, "fires={fires}");
        clear(id);
    }

    #[test]
    fn hang_releases_on_clear() {
        let site = "test::hangrel";
        let id = inject(site, None, FaultAction::Hang);
        let t = std::thread::spawn(move || {
            // No actor ctx on this thread: clear() is the only release.
            failpoint(site);
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!t.is_finished(), "hang failpoint did not block");
        clear(id);
        t.join().unwrap();
    }

    #[test]
    fn hang_panics_when_killed() {
        let site = "test::hangkill";
        let killed = Arc::new(AtomicBool::new(false));
        let id = inject(site, Some("hk-actor"), FaultAction::Hang);
        let k = killed.clone();
        let t = std::thread::spawn(move || {
            set_actor_ctx(ActorCtx { name: Arc::from("hk-actor"), killed: k });
            let r = std::panic::catch_unwind(|| failpoint(site));
            assert!(r.is_err(), "kill must panic the hang into supervision");
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!t.is_finished());
        killed.store(true, Ordering::SeqCst);
        t.join().unwrap();
        clear(id);
    }

    #[test]
    fn fault_counters_snapshot() {
        let c = FaultCounters::default();
        c.note_suspect();
        c.note_forced_restart();
        c.note_forced_restart();
        c.note_breaker_trip();
        assert_eq!(
            c.snapshot(),
            FaultStats { suspects: 1, forced_restarts: 2, breaker_trips: 1 }
        );
    }
}
