//! The bounded ring mailbox — the typed message substrate under every
//! actor.
//!
//! A message is an [`Envelope`]: two function pointers plus a 256-byte
//! inline payload the sender's closure is written into directly.  The
//! ring preallocates `capacity` envelope slots at spawn, so a
//! steady-state send is *one slot write* — no per-message `Box`, no
//! allocator traffic (the seed runtime boxed a `dyn FnOnce` per call
//! through an unbounded `mpsc`; see `benches/actor_mailbox.rs` for the
//! before/after).  Closures larger than the inline payload fall back to
//! a boxed thunk whose (16-byte) fat pointer is stored inline — a cold
//! path no hot-loop message in this crate takes.
//!
//! The ring is guarded by one mutex and two condvars (`not_empty`,
//! `not_full`): senders block when the ring is full (the backpressure
//! half of the control plane) and fail fast once the actor is poisoned.
//! All envelope reads/writes happen under the lock; executing a message
//! never does.

use std::mem::{align_of, size_of, ManuallyDrop, MaybeUninit};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::telemetry::ActorTelemetry;

/// Inline payload bytes per envelope.  Large enough for every
/// steady-state message the dataflow layer sends (a plan `Arc`, a queue
/// handle, tags/guards, a recycled `ImpalaBatch` riding to the
/// learner); closures that exceed it are boxed — a cold path (e.g. a
/// whole `SampleBatch` moved by value into a train call, once per
/// train batch, not per item).
pub(crate) const INLINE_PAYLOAD: usize = 256;

/// Default mailbox capacity for [`super::ActorHandle::spawn`].
pub const DEFAULT_MAILBOX_CAPACITY: usize = 256;

#[repr(align(16))]
struct PayloadBuf(MaybeUninit<[u8; INLINE_PAYLOAD]>);

type BoxedMsg<A> = Box<dyn FnOnce(&mut A) + Send>;

/// A type-erased `FnOnce(&mut A)` stored inline (or, oversized, as a
/// boxed thunk whose pointer is stored inline).
pub(crate) struct Envelope<A> {
    call: unsafe fn(*mut u8, &mut A),
    drop: unsafe fn(*mut u8),
    payload: PayloadBuf,
}

unsafe fn call_inline<A, F: FnOnce(&mut A)>(p: *mut u8, state: &mut A) {
    // Moves the closure out of the slot and consumes it.
    (p as *mut F).read()(state)
}

unsafe fn drop_inline<F>(p: *mut u8) {
    drop((p as *mut F).read())
}

unsafe fn call_boxed<A>(p: *mut u8, state: &mut A) {
    ((p as *mut BoxedMsg<A>).read())(state)
}

unsafe fn drop_boxed<A>(p: *mut u8) {
    drop((p as *mut BoxedMsg<A>).read())
}

impl<A> Envelope<A> {
    // flowlint: hot-path (closures <= INLINE_PAYLOAD write straight into the slot)
    pub(crate) fn new<F>(f: F) -> Self
    where
        F: FnOnce(&mut A) + Send + 'static,
    {
        let mut payload = PayloadBuf(MaybeUninit::uninit());
        let base = payload.0.as_mut_ptr() as *mut u8;
        if size_of::<F>() <= INLINE_PAYLOAD
            && align_of::<F>() <= align_of::<PayloadBuf>()
        {
            // Safety: the buffer is large and aligned enough for F, and
            // ownership of `f` transfers into the slot (tracked by the
            // call/drop fn pair).
            unsafe { std::ptr::write(base as *mut F, f) };
            Envelope {
                call: call_inline::<A, F>,
                drop: drop_inline::<F>,
                payload,
            }
        } else {
            // flowlint: allow(hot-path-alloc) -- cold fallback for oversized closures; steady-state messages fit inline
            let boxed: BoxedMsg<A> = Box::new(f);
            unsafe { std::ptr::write(base as *mut BoxedMsg<A>, boxed) };
            Envelope {
                call: call_boxed::<A>,
                drop: drop_boxed::<A>,
                payload,
            }
        }
    }

    /// Execute the message, consuming the payload.
    pub(crate) fn invoke(self, state: &mut A) {
        let mut this = ManuallyDrop::new(self);
        let base = this.payload.0.as_mut_ptr() as *mut u8;
        // Safety: `self` is ManuallyDrop'd, so the payload is consumed
        // exactly once (by the call fn's ptr::read).
        unsafe { (this.call)(base, state) }
    }
}

impl<A> Drop for Envelope<A> {
    fn drop(&mut self) {
        // A dropped-without-invoke envelope (poison drain, dead-actor
        // send) still runs the closure's destructor, which fires any
        // reply/completion guards captured inside it.
        let base = self.payload.0.as_mut_ptr() as *mut u8;
        unsafe { (self.drop)(base) }
    }
}

/// The ring itself; lives inside `Shared::ring` and is only touched
/// under that mutex.
pub(crate) struct Ring<A> {
    slots: Box<[MaybeUninit<Envelope<A>>]>,
    head: usize,
    len: usize,
    /// Set (under the lock) when the actor panicked; no further sends
    /// are accepted.
    pub(crate) poisoned: bool,
    /// Live `ActorHandle` count; the actor thread exits when this hits
    /// zero and the ring drains.
    pub(crate) senders: usize,
}

impl<A> Ring<A> {
    fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "mailbox capacity must be >= 1");
        let slots: Box<[MaybeUninit<Envelope<A>>]> =
            (0..capacity).map(|_| MaybeUninit::uninit()).collect();
        Ring { slots, head: 0, len: 0, poisoned: false, senders: 0 }
    }

    fn is_full(&self) -> bool {
        self.len == self.slots.len()
    }

    fn push(&mut self, env: Envelope<A>) {
        debug_assert!(!self.is_full());
        let idx = (self.head + self.len) % self.slots.len();
        self.slots[idx] = MaybeUninit::new(env);
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Envelope<A>> {
        if self.len == 0 {
            return None;
        }
        // Safety: slots in [head, head+len) are initialized; the slot is
        // logically vacated before the read value escapes.
        let env = unsafe { self.slots[self.head].assume_init_read() };
        self.head = (self.head + 1) % self.slots.len();
        self.len -= 1;
        Some(env)
    }
}

impl<A> Drop for Ring<A> {
    fn drop(&mut self) {
        while let Some(env) = self.pop() {
            drop(env);
        }
    }
}

/// Why a non-blocking send did not enqueue.  The message is dropped in
/// both cases (firing any guards it captured).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TryCastError {
    /// The mailbox is at capacity — backpressure.
    Full,
    /// The actor is poisoned (its thread panicked).
    Dead,
}

/// State shared between every handle and the actor thread.
pub(crate) struct Shared<A> {
    ring: Mutex<Ring<A>>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Slot count, immutable after spawn — readable without the ring
    /// lock (the weight-cast eviction policy compares depth gauges
    /// against it on every broadcast).
    capacity: usize,
    /// The cooperative kill flag (`ActorHandle::kill`): an `Arc` so the
    /// fault plane's per-thread context can hold it independently of
    /// the `Shared` — a `Hang` failpoint polls it and panics into
    /// supervision when it flips.
    killed: Arc<AtomicBool>,
    pub(crate) telemetry: Arc<ActorTelemetry>,
}

impl<A> Shared<A> {
    pub(crate) fn new(capacity: usize, telemetry: Arc<ActorTelemetry>) -> Self {
        Shared {
            ring: Mutex::new(Ring::new(capacity)),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            killed: Arc::new(AtomicBool::new(false)),
            telemetry,
        }
    }

    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    /// A clone of the cooperative kill flag (for the fault plane's
    /// actor-thread context).
    pub(crate) fn kill_flag(&self) -> Arc<AtomicBool> {
        self.killed.clone()
    }

    /// Request a cooperative kill: cooperating long-running sites (the
    /// `Hang` failpoint, `RolloutWorker::sample`'s failpoint) observe
    /// the flag and panic into the normal supervision path.
    pub(crate) fn request_kill(&self) {
        self.killed.store(true, Ordering::SeqCst);
    }

    /// Blocking send: parks while the ring is full.  `Err` returns the
    /// envelope (actor poisoned) so the caller decides how to dispose of
    /// it — dropping it fires its guards.
    // flowlint: hot-path (ring slot write under the mailbox lock)
    pub(crate) fn send(&self, env: Envelope<A>) -> Result<(), Envelope<A>> {
        let mut ring = self.ring.lock().unwrap();
        loop {
            if ring.poisoned {
                drop(ring);
                return Err(env);
            }
            if !ring.is_full() {
                ring.push(env);
                self.telemetry.note_enqueue(ring.len);
                drop(ring);
                self.not_empty.notify_one();
                return Ok(());
            }
            ring = self.not_full.wait(ring).unwrap();
        }
    }

    /// Non-blocking send.
    // flowlint: hot-path (ring slot write under the mailbox lock)
    pub(crate) fn try_send(
        &self,
        env: Envelope<A>,
    ) -> Result<(), (Envelope<A>, TryCastError)> {
        let mut ring = self.ring.lock().unwrap();
        if ring.poisoned {
            drop(ring);
            return Err((env, TryCastError::Dead));
        }
        if ring.is_full() {
            drop(ring);
            return Err((env, TryCastError::Full));
        }
        ring.push(env);
        self.telemetry.note_enqueue(ring.len);
        drop(ring);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Consumer side: next message, or `None` when every handle is gone
    /// and the ring has drained (clean shutdown) — or when the actor
    /// was poisoned *externally* (`ActorHandle::kill` on an idle actor:
    /// the queue is already drained, so the thread exits rather than
    /// parking forever on a mailbox that rejects all sends).
    pub(crate) fn recv(&self) -> Option<Envelope<A>> {
        let mut ring = self.ring.lock().unwrap();
        loop {
            if let Some(env) = ring.pop() {
                self.telemetry.note_dequeue(ring.len);
                drop(ring);
                self.not_full.notify_one();
                return Some(env);
            }
            if ring.senders == 0 || ring.poisoned {
                return None;
            }
            ring = self.not_empty.wait(ring).unwrap();
        }
    }

    /// Mark the actor poisoned, reject all future sends, and drop every
    /// queued envelope (firing their guards, which is how pending
    /// callers learn of the death).  Called by the actor thread after a
    /// message or init panic.
    pub(crate) fn poison(&self) {
        let drained: Vec<Envelope<A>> = {
            let mut ring = self.ring.lock().unwrap();
            ring.poisoned = true;
            let mut v = Vec::with_capacity(ring.len);
            while let Some(env) = ring.pop() {
                v.push(env);
            }
            v
        };
        self.telemetry.note_poisoned();
        self.not_full.notify_all();
        self.not_empty.notify_all();
        // Guards run outside the ring lock: they take reply/queue locks
        // of their own.
        drop(drained);
    }

    pub(crate) fn is_poisoned(&self) -> bool {
        self.ring.lock().unwrap().poisoned
    }

    pub(crate) fn add_sender(&self) {
        self.ring.lock().unwrap().senders += 1;
    }

    pub(crate) fn remove_sender(&self) {
        let mut ring = self.ring.lock().unwrap();
        ring.senders -= 1;
        let last = ring.senders == 0;
        drop(ring);
        if last {
            // Wake the consumer so it can observe shutdown.
            self.not_empty.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn telemetry() -> Arc<ActorTelemetry> {
        Arc::new(ActorTelemetry::new("t", 0))
    }

    #[test]
    fn envelope_roundtrips_inline_closure() {
        let mut x = 10i32;
        let env = Envelope::new(|state: &mut i32| *state += 5);
        env.invoke(&mut x);
        assert_eq!(x, 15);
    }

    #[test]
    fn envelope_boxes_oversized_closures() {
        // Capture > INLINE_PAYLOAD bytes to force the boxed path.
        let big = [7u8; INLINE_PAYLOAD + 64];
        let env = Envelope::new(move |state: &mut u64| {
            *state = big.iter().map(|&b| b as u64).sum();
        });
        let mut x = 0u64;
        env.invoke(&mut x);
        assert_eq!(x, 7 * (INLINE_PAYLOAD as u64 + 64));
    }

    #[test]
    fn dropped_envelope_runs_closure_destructor() {
        struct Bomb(Arc<AtomicUsize>);
        impl Drop for Bomb {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let hits = Arc::new(AtomicUsize::new(0));
        let bomb = Bomb(hits.clone());
        let env = Envelope::new(move |_: &mut i32| {
            let _keep = &bomb;
        });
        drop(env);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn ring_is_fifo_and_bounded() {
        let shared: Shared<Vec<i32>> = Shared::new(4, telemetry());
        for i in 0..4 {
            shared
                .send(Envelope::new(move |v: &mut Vec<i32>| v.push(i)))
                .ok()
                .unwrap();
        }
        // Full now.
        let env = Envelope::new(|v: &mut Vec<i32>| v.push(99));
        assert!(matches!(
            shared.try_send(env),
            Err((_, TryCastError::Full))
        ));
        let mut state = Vec::new();
        {
            let mut ring = shared.ring.lock().unwrap();
            ring.senders = 0;
        }
        while let Some(env) = shared.recv() {
            env.invoke(&mut state);
        }
        assert_eq!(state, vec![0, 1, 2, 3]);
    }

    #[test]
    fn poison_rejects_sends_and_drains() {
        let shared: Shared<i32> = Shared::new(8, telemetry());
        shared.send(Envelope::new(|x: &mut i32| *x += 1)).ok().unwrap();
        shared.poison();
        assert!(shared.is_poisoned());
        assert!(shared.send(Envelope::new(|x: &mut i32| *x += 1)).is_err());
        assert!(matches!(
            shared.try_send(Envelope::new(|x: &mut i32| *x += 1)),
            Err((_, TryCastError::Dead))
        ));
    }
}
