//! Completion-tag encoding — the one place tag arithmetic lives.
//!
//! Gather dispatches identify themselves to the [`CompletionQueue`]
//! with a `usize` tag packing `(epoch << EPOCH_SHIFT) | shard_idx`: a
//! death notice carries only the tag, and the epoch half lets the
//! gather distinguish a completion from the current incarnation of a
//! shard from a stale one raced by a restart.  16 bits of shard index
//! bounds a registry at [`MAX_SHARDS`] shards
//! ([`ShardRegistry::grow`](super::ShardRegistry) enforces it); the
//! remaining bits hold ~2^47 incarnations per shard on 64-bit targets.
//!
//! flowlint's `epoch-tag` rule flags shift-by-16 arithmetic everywhere
//! *except* this file, so every encoder/decoder in the tree routes
//! through [`encode_tag`]/[`decode_tag`] and the layout can never fork.
//!
//! [`CompletionQueue`]: super::CompletionQueue

/// Bit position where the epoch half of a completion tag begins.
pub const EPOCH_SHIFT: u32 = 16;

/// Mask selecting the shard-index half of a completion tag.
pub const SHARD_MASK: usize = (1 << EPOCH_SHIFT) - 1;

/// Hard bound on registry size: shard index `MAX_SHARDS` would alias
/// epoch bits and corrupt completion attribution, so
/// [`ShardRegistry::grow`](super::ShardRegistry) refuses to cross it.
pub const MAX_SHARDS: usize = SHARD_MASK + 1;

/// Pack shard index `idx` and incarnation `epoch` into one tag.
#[inline]
pub fn encode_tag(idx: usize, epoch: u64) -> usize {
    debug_assert!(idx <= SHARD_MASK);
    ((epoch as usize) << EPOCH_SHIFT) | idx
}

/// Split a tag back into `(shard_idx, epoch)`.
#[inline]
pub fn decode_tag(tag: usize) -> (usize, u64) {
    (tag & SHARD_MASK, (tag >> EPOCH_SHIFT) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_across_the_layout() {
        for &(idx, epoch) in &[
            (0usize, 0u64),
            (1, 1),
            (SHARD_MASK, 1),
            (7, u32::MAX as u64),
            (MAX_SHARDS - 1, (1u64 << 40) + 3),
        ] {
            let tag = encode_tag(idx, epoch);
            assert_eq!(decode_tag(tag), (idx, epoch));
        }
    }

    #[test]
    fn shard_half_is_exactly_sixteen_bits() {
        assert_eq!(MAX_SHARDS, 65536);
        assert_eq!(encode_tag(0, 1), MAX_SHARDS);
        // Epoch 0, max shard: the tag stays inside the mask.
        assert_eq!(encode_tag(SHARD_MASK, 0), SHARD_MASK);
    }

    #[test]
    fn epochs_of_the_same_shard_never_collide() {
        let (a, b) = (encode_tag(5, 1), encode_tag(5, 2));
        assert_ne!(a, b);
        assert_eq!(decode_tag(a).0, decode_tag(b).0);
    }
}
