//! The algorithm suite, each expressed as a short dataflow plan over the
//! operators in `crate::ops` — the paper's §5 port of RLlib.
//!
//! Every `*_plan` function returns a lazy `LocalIter<TrainResult>`; one
//! `next()` is one training report.  Compare each plan with its
//! low-level twin in `crate::baseline` — the LoC ratio between the two
//! is Table 2.

pub mod a2c;
pub mod a3c;
pub mod apex;
pub mod dqn;
pub mod gateway;
pub mod impala;
pub mod maml;
pub mod multi_agent;
pub mod offline;
pub mod ppo;

pub use a2c::a2c_plan;
pub use a3c::a3c_plan;
pub use apex::{apex_plan, ApexConfig};
pub use dqn::{dqn_plan, DqnConfig};
pub use gateway::{gateway_dqn_plan, GatewayDqnConfig};
pub use impala::{assemble_time_major, assemble_time_major_into, impala_plan};
pub use maml::{maml_plan, MamlConfig};
pub use multi_agent::{
    ma_sync_protocol, ma_worker_set, multi_agent_plan, multi_agent_plan_on,
    MultiAgentConfig,
};
pub use offline::{offline_dqn_plan, OfflineDqnConfig, OfflineLearner};
pub use ppo::{ppo_plan, ppo_plan_with_epochs};

use std::path::PathBuf;

use crate::env::{CartPole, DummyEnv, Env, MountainCar, TaskCartPole};
use crate::policy::{DqnPolicy, DummyPolicy, PgLossKind, PgPolicy, Policy};
use crate::rollout::{CollectMode, RolloutWorker, WorkerSet};

/// Common trainer configuration (the subset of RLlib's config the
/// ported algorithms use).
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub num_workers: usize,
    pub num_envs_per_worker: usize,
    /// Steps per worker fragment.  Must not exceed the artifact
    /// `fragment` for gradient-on-worker algorithms.
    pub rollout_fragment_length: usize,
    /// ConcatBatches target for the sync algorithms.
    pub train_batch_size: usize,
    pub lr: f32,
    pub artifacts_dir: PathBuf,
    pub seed: u64,
    /// gather_async in-flight requests per worker.
    pub num_async: usize,
    /// Which env the workers run.
    pub env: EnvKind,
    /// Floor for the replay-shard pool when a backlog autoscaler drives
    /// it (Ape-X): the controller never shrinks below this.
    pub min_replay_shards: usize,
    /// Ceiling for the replay-shard pool under backlog autoscaling.
    pub max_replay_shards: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EnvKind {
    CartPole,
    /// Task-distribution CartPole (MAML).
    TaskCartPole,
    /// MountainCar-v0 — sparse-reward control; needs artifacts built
    /// with `--obs-dim 2 --num-actions 3` (see aot.py).
    MountainCar,
    /// Trivial env + dummy policy (sampling microbenchmark, Fig. 13a).
    Dummy,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            num_workers: 2,
            // Matches the artifact inference batch (inf_batch = 8) so
            // no forward-pass padding is wasted (perf O5).
            num_envs_per_worker: 8,
            rollout_fragment_length: 64,
            train_batch_size: 256,
            lr: 5e-3,
            artifacts_dir: crate::runtime::XlaRuntime::default_dir(),
            seed: 0,
            num_async: 2,
            env: EnvKind::CartPole,
            min_replay_shards: 1,
            max_replay_shards: 4,
        }
    }
}

impl TrainerConfig {
    pub fn make_envs(&self, worker_idx: usize) -> Vec<Box<dyn Env>> {
        (0..self.num_envs_per_worker)
            .map(|e| {
                let seed = self
                    .seed
                    // flowlint: allow(epoch-tag) -- rng seed spreading across workers, not a completion tag
                    .wrapping_add((worker_idx as u64) << 16)
                    .wrapping_add(e as u64);
                match self.env {
                    EnvKind::CartPole => {
                        Box::new(CartPole::new(seed)) as Box<dyn Env>
                    }
                    EnvKind::TaskCartPole => Box::new(TaskCartPole::new(seed)),
                    EnvKind::MountainCar => Box::new(MountainCar::new(seed)),
                    EnvKind::Dummy => Box::new(DummyEnv::new(4, 100)),
                }
            })
            .collect()
    }

    /// A worker set whose policies are the policy-gradient family.
    pub fn pg_workers(&self, kind: PgLossKind, mode: CollectMode) -> WorkerSet {
        let cfg = self.clone();
        WorkerSet::new(self.num_workers, move |i| {
            let cfg = cfg.clone();
            Box::new(move || {
                let policy: Box<dyn Policy> = if cfg.env == EnvKind::Dummy {
                    Box::new(DummyPolicy::new(cfg.lr))
                } else {
                    Box::new(PgPolicy::create(
                        &cfg.artifacts_dir,
                        kind,
                        cfg.lr,
                        cfg.seed.wrapping_add(i as u64),
                    ))
                };
                RolloutWorker::new(
                    cfg.make_envs(i),
                    policy,
                    cfg.rollout_fragment_length,
                    mode,
                )
            })
        })
    }

    /// A worker set with DQN policies (Ape-X-style per-worker epsilons).
    pub fn dqn_workers(&self) -> WorkerSet {
        let cfg = self.clone();
        let n = self.num_workers.max(1);
        WorkerSet::new(self.num_workers, move |i| {
            let cfg = cfg.clone();
            // Learner (i=0) acts greedily; workers get the Ape-X
            // epsilon ladder 0.4^(1 + 7*i/(N-1)).
            let epsilon = if i == 0 {
                0.0
            } else {
                0.4f64.powf(1.0 + 7.0 * (i - 1) as f64 / (n.max(2) - 1) as f64)
            };
            Box::new(move || {
                let policy: Box<dyn Policy> = if cfg.env == EnvKind::Dummy {
                    Box::new(DummyPolicy::new(cfg.lr))
                } else {
                    Box::new(DqnPolicy::create(
                        &cfg.artifacts_dir,
                        cfg.lr,
                        epsilon,
                        cfg.seed.wrapping_add(i as u64),
                    ))
                };
                RolloutWorker::new(
                    cfg.make_envs(i),
                    policy,
                    cfg.rollout_fragment_length,
                    CollectMode::Transitions,
                )
            })
        })
    }
}
