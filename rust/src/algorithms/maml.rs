//! MAML — nested-loop meta-learning (paper §A.2.1, Fig. A2).
//!
//! ```text
//! per meta-iteration (gather_sync barrier):
//!   on every worker:  sample_task();
//!                     k x { sample; inner-adapt (SGD) }   # inner loop
//!                     post-adaptation gradient            # meta data
//!   MetaUpdate: average post-adaptation grads, Adam step on the
//!               learner, broadcast          # barrier orders this
//! ```
//! Substitution (DESIGN.md): first-order MAML — the meta-gradient is
//! the post-adaptation gradient (no grad-through-grad), which preserves
//! the *dataflow* the paper's case study is about.

use crate::iter::LocalIter;
use crate::metrics::TrainResult;
use crate::ops::{Reporting, TrainItem};
use crate::iter::ParIter;
use crate::policy::{Gradients, PgLossKind};
use crate::rollout::CollectMode;

use super::{EnvKind, TrainerConfig};

#[derive(Debug, Clone)]
pub struct MamlConfig {
    /// Inner-adaptation gradient steps per task.
    pub inner_steps: usize,
    /// Inner-loop SGD learning rate.
    pub inner_lr: f32,
}

impl Default for MamlConfig {
    fn default() -> Self {
        MamlConfig { inner_steps: 1, inner_lr: 0.05 }
    }
}

pub fn maml_plan(
    config: &TrainerConfig,
    maml: &MamlConfig,
) -> LocalIter<TrainResult> {
    let mut config = config.clone();
    config.env = EnvKind::TaskCartPole;
    // Size fragments to the a3c_grad artifact (see a3c_plan).
    if let Ok(m) =
        crate::runtime::Manifest::load(config.artifacts_dir.join("manifest.json"))
    {
        config.rollout_fragment_length =
            (m.config.fragment / config.num_envs_per_worker).max(1);
    }
    let workers = config.pg_workers(PgLossKind::A3c, CollectMode::OnPolicy);

    let inner_steps = maml.inner_steps;
    let inner_lr = maml.inner_lr;

    // Per-task work, scheduled on each worker: draw a task, adapt the
    // *worker-local* policy copy, return the post-adaptation gradient.
    // Gathering through the registry lets a restarted worker pick up
    // tasks again at the next meta-iteration boundary.
    let meta_grads =
        ParIter::from_registry(workers.registry().clone(), move |w| {
            w.sample_task();
            for _ in 0..inner_steps {
                let batch = w.sample();
                let grads = w.policy.compute_gradients(&batch);
                w.policy.sgd_apply(&grads.flat, inner_lr);
            }
            let post_batch = w.sample();
            Some(w.policy.compute_gradients(&post_batch))
        })
        .gather_sync(); // barrier: all tasks finish before the meta step

    let local = workers.local.clone();
    let caster = workers.caster();
    let meta_update = meta_grads.for_each(move |grads_per_task| {
        let steps: usize = grads_per_task.iter().map(|g| g.count).sum();
        let avg = average_gradients(&grads_per_task);
        let stats = avg.stats.clone();
        let weights: std::sync::Arc<[f32]> = local
            .call(move |w| {
                w.apply_gradients(&avg);
                w.get_weights()
            })
            .expect("MAML meta-learner (local worker) actor died")
            .into();
        // Broadcast the new meta-parameters as a versioned cast; the
        // gather_sync barrier orders the applies before the next
        // meta-iteration's fetches.
        caster.broadcast(weights);
        TrainItem::new(stats, steps)
    });

    Reporting::new(meta_update, &workers, 1).build()
}

/// Average flat gradients across tasks (stats averaged too).
pub fn average_gradients(grads: &[Gradients]) -> Gradients {
    assert!(!grads.is_empty());
    let n = grads.len() as f32;
    let dim = grads[0].flat.len();
    let mut flat = vec![0.0f32; dim];
    for g in grads {
        assert_eq!(g.flat.len(), dim);
        for (acc, v) in flat.iter_mut().zip(&g.flat) {
            *acc += v / n;
        }
    }
    let mut stats = std::collections::BTreeMap::new();
    for g in grads {
        for (k, v) in &g.stats {
            *stats.entry(k.clone()).or_insert(0.0) += v / n as f64;
        }
    }
    Gradients { flat, stats, count: grads.iter().map(|g| g.count).sum() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_gradients_means_components() {
        let g1 = Gradients {
            flat: vec![1.0, 2.0],
            stats: [("loss".to_string(), 1.0)].into(),
            count: 10,
        };
        let g2 = Gradients {
            flat: vec![3.0, 4.0],
            stats: [("loss".to_string(), 3.0)].into(),
            count: 20,
        };
        let avg = average_gradients(&[g1, g2]);
        assert_eq!(avg.flat, vec![2.0, 3.0]);
        assert_eq!(avg.stats["loss"], 2.0);
        assert_eq!(avg.count, 30);
    }

    #[test]
    #[should_panic]
    fn average_gradients_rejects_empty() {
        average_gradients(&[]);
    }
}
