//! A2C — the synchronous advantage actor-critic plan.
//!
//! ```text
//! ParallelRollouts(bulk_sync) -> ConcatBatches(B) -> TrainOneStep
//!   -> StandardMetricsReporting
//! ```

use crate::iter::LocalIter;
use crate::metrics::TrainResult;
use crate::ops::{
    exact_batches, parallel_rollouts_from, Reporting,
    train_one_step,
};
use crate::policy::PgLossKind;
use crate::rollout::CollectMode;
use crate::sample_batch::SampleBatch;

use super::TrainerConfig;

pub fn a2c_plan(config: &TrainerConfig) -> LocalIter<TrainResult> {
    let workers = config.pg_workers(PgLossKind::A2c, CollectMode::OnPolicy);

    // The a2c_grad artifact trains on a fixed batch shape; emit exactly
    // that many rows per train step (remainder carried, nothing lost).
    let grad_batch = crate::runtime::Manifest::load(
        config.artifacts_dir.join("manifest.json"),
    )
    .map(|m| m.config.a2c_train_batch)
    .unwrap_or(config.train_batch_size);

    // Bulk-sync rollouts through the shard registry: one barrier round
    // per item, concatenated, then chunked to the training shape; a
    // restarted worker rejoins at the next round boundary.
    let rollouts = parallel_rollouts_from(&workers)
        .gather_sync()
        .for_each(|round| SampleBatch::concat_all(&round))
        .combine(exact_batches(grad_batch));

    // TrainOneStep publishes a versioned weight cast; the gather_sync
    // barrier guarantees the applies land before the next round's
    // fetches.
    let train_op = rollouts.for_each(train_one_step(&workers));

    Reporting::new(train_op, &workers, 1).build()
}
