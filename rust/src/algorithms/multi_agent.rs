//! Multi-agent PPO + DQN composition (paper §5.3, Fig. 11/12) — the
//! workflow the paper says "end users could not compose before":
//! two *different* training algorithms, with different distributed
//! patterns (on-policy sync vs replay), drive disjoint policies in one
//! environment, composed with `duplicate` + `Union`.
//!
//! ```text
//! rollouts = ParallelRollouts(ma_workers).gather_async()
//! (r1, r2) = rollouts.duplicate()
//! ppo_op = r1.for_each(Select("ppo")).combine(ConcatBatches(B))
//!            .for_each(TrainOneStep(ppo))
//! dqn_op = Union(r2.for_each(Select("dqn")).for_each(StoreToReplay),
//!                Replay(buf).for_each(TrainOneStep(dqn))
//!                           .for_each(UpdateTargetNetwork))
//! return Union(ppo_op, dqn_op)
//! ```
//!
//! The multi-agent workers live on a full [`WorkerSet`] (the
//! `MultiAgentRolloutWorker` instantiation of the generic elastic
//! owner): one shared shard registry, a versioned [`WeightCaster`] per
//! policy registered on the set, and a spawn-and-sync protocol that
//! pushes **every** policy's learner weights into a fresh worker before
//! it is published.  Multi-agent plans therefore share the whole scale
//! machinery — `restart_dead` rejoin, `scale_to` under live traffic,
//! and the autoscaling controller — with the single-agent path.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::actor::{ActorHandle, WeightCaster, DEFAULT_CAST_WATERMARK};
use crate::env::MultiAgentCartPole;
use crate::iter::{concurrently, LocalIter, UnionMode};
use crate::metrics::TrainResult;
use crate::ops::{
    concat_batches, create_replay_shards, parallel_ma_rollouts_from, replay,
    select_policy, store_to_replay_buffer, Reporting, TrainItem,
};
use crate::policy::{DqnPolicy, PgLossKind, PgPolicy, Policy};
use crate::rollout::{MultiAgentRolloutWorker, WorkerSet};

use super::dqn::DqnConfig;
use super::TrainerConfig;

#[derive(Debug, Clone)]
pub struct MultiAgentConfig {
    /// Agents mapped to each policy (paper Fig. 14: 4 per policy).
    pub agents_per_policy: usize,
    pub dqn: DqnConfig,
    pub ppo_epochs: usize,
}

impl Default for MultiAgentConfig {
    fn default() -> Self {
        MultiAgentConfig {
            agents_per_policy: 4,
            dqn: DqnConfig {
                learning_starts: 500,
                ..DqnConfig::default()
            },
            ppo_epochs: 2,
        }
    }
}

/// The per-policy spawn-and-sync protocol every multi-agent
/// [`WorkerSet`] runs on `restart_dead`/`add_worker`: fetch **each**
/// policy's learner weights and cast them into the fresh worker's
/// mailbox before it is published (FIFO per mailbox, so the applies
/// land before any gather dispatch).  Public so tests exercising
/// Dummy-backed multi-agent sets drive the *shipped* protocol instead
/// of a drifting copy.
pub fn ma_sync_protocol() -> impl Fn(
    &ActorHandle<MultiAgentRolloutWorker>,
    &ActorHandle<MultiAgentRolloutWorker>,
) -> crate::util::error::Result<()>
       + Send
       + Sync
       + 'static {
    |local, fresh| {
        // One round-trip for the whole per-policy snapshot (atomic
        // across policies, and the factory lock held by the caller
        // isn't stretched over P learner-mailbox waits).
        let snapshot: Vec<(String, Vec<f32>)> = local
            .call(|w| {
                w.policies
                    .iter()
                    .map(|(pid, p)| (pid.clone(), p.get_weights()))
                    .collect()
            })
            .map_err(|e| {
                crate::util::error::Error::msg(format!(
                    "learner is dead ({e})"
                ))
            })?;
        for (pid, weights) in snapshot {
            let weights: Arc<[f32]> = weights.into();
            fresh.cast(move |w| w.set_weights(&pid, &weights));
        }
        Ok(())
    }
}

/// Build the multi-agent [`WorkerSet`]: 1 local (learner) +
/// `config.num_workers` remote workers, with a sync protocol that
/// fetches and pushes **each** policy's weights (so a worker added by
/// `scale_to`/`restart_dead` starts with every policy's learner state,
/// not just one).
pub fn ma_worker_set(
    config: &TrainerConfig,
    ma: &MultiAgentConfig,
    include_dqn: bool,
    include_ppo: bool,
) -> WorkerSet<MultiAgentRolloutWorker> {
    let make = {
        let config = config.clone();
        let ma = ma.clone();
        move |i: usize| -> Box<dyn FnOnce() -> MultiAgentRolloutWorker + Send> {
            let config = config.clone();
            let ma = ma.clone();
            Box::new(move || {
                let num_agents = ma.agents_per_policy
                    * (include_dqn as usize + include_ppo as usize);
                let env = MultiAgentCartPole::new(
                    num_agents,
                    // flowlint: allow(epoch-tag) -- rng seed spreading across workers, not a completion tag
                    config.seed.wrapping_add((i as u64) << 16),
                    move |agent| {
                        if !include_dqn {
                            "ppo".to_string()
                        } else if !include_ppo {
                            "dqn".to_string()
                        } else if agent % 2 == 0 {
                            "ppo".to_string()
                        } else {
                            "dqn".to_string()
                        }
                    },
                );
                let mut policies: BTreeMap<String, Box<dyn Policy>> =
                    BTreeMap::new();
                if include_ppo {
                    policies.insert(
                        "ppo".into(),
                        Box::new(PgPolicy::create(
                            &config.artifacts_dir,
                            PgLossKind::Ppo { epochs: ma.ppo_epochs },
                            config.lr,
                            config.seed.wrapping_add(i as u64),
                        )),
                    );
                }
                if include_dqn {
                    let epsilon = if i == 0 { 0.0 } else { 0.1 };
                    policies.insert(
                        "dqn".into(),
                        Box::new(DqnPolicy::create(
                            &config.artifacts_dir,
                            config.lr,
                            epsilon,
                            config.seed.wrapping_add(1000 + i as u64),
                        )),
                    );
                }
                MultiAgentRolloutWorker::new(
                    env,
                    policies,
                    config.rollout_fragment_length,
                )
            })
        }
    };
    WorkerSet::with_protocol(
        "ma_local",
        "ma_worker",
        config.num_workers,
        make,
        ma_sync_protocol(),
    )
}

/// The composed two-trainer plan (Fig. 11b) over a fresh worker set.
/// To scale (or autoscale) the set mid-plan, build it with
/// [`ma_worker_set`] and use [`multi_agent_plan_on`] so you keep the
/// set handle.
pub fn multi_agent_plan(
    config: &TrainerConfig,
    ma: &MultiAgentConfig,
) -> LocalIter<TrainResult> {
    let set = ma_worker_set(config, ma, true, true);
    multi_agent_plan_on(&set, config, ma)
}

/// [`multi_agent_plan`] over a caller-owned [`WorkerSet`] (built with
/// [`ma_worker_set`]).  Registers one [`WeightCaster`] per policy on
/// the set (each policy's broadcast coalesces and sheds independently —
/// a worker drowning in DQN syncs still gets the newest PPO parameters
/// in one apply), so workers added by `scale_to` pick up both lanes.
/// Call once per set: each call registers its own casters.
pub fn multi_agent_plan_on(
    set: &WorkerSet<MultiAgentRolloutWorker>,
    config: &TrainerConfig,
    ma: &MultiAgentConfig,
) -> LocalIter<TrainResult> {
    let local = set.local.clone();
    let ppo_caster = Arc::new(WeightCaster::new(
        set.registry().clone(),
        DEFAULT_CAST_WATERMARK,
        |w: &mut MultiAgentRolloutWorker, p: &[f32]| {
            w.set_weights("ppo", p)
        },
    ));
    let dqn_caster = Arc::new(WeightCaster::new(
        set.registry().clone(),
        DEFAULT_CAST_WATERMARK,
        |w: &mut MultiAgentRolloutWorker, p: &[f32]| {
            w.set_weights("dqn", p)
        },
    ));
    set.register_caster(ppo_caster.clone());
    set.register_caster(dqn_caster.clone());

    let rollouts =
        parallel_ma_rollouts_from(set).gather_async(config.num_async);
    let (r_ppo, r_dqn) = rollouts.duplicate();

    // --- PPO subflow (Fig. 12a) ---
    let ppo_local = local.clone();
    let ppo_op = r_ppo
        .filter_map(select_policy("ppo"))
        .combine(concat_batches(config.train_batch_size))
        .for_each(move |batch| {
            let steps = batch.len();
            let (stats, weights) = ppo_local
                .call(move |w| {
                    let stats = w.learn_on_batch("ppo", &batch);
                    (stats, w.get_weights("ppo"))
                })
                .expect("PPO learner (local worker) actor died");
            ppo_caster.broadcast(weights.into());
            TrainItem::new(prefix_stats("ppo", stats), steps)
        });

    // --- DQN subflow (Fig. 12b) ---
    let obs_dim = local.call(|w| w.obs_dim()).expect("local worker died");
    let service = create_replay_shards(
        1,
        obs_dim,
        ma.dqn.buffer_capacity,
        ma.dqn.learning_starts,
        64,
    );
    let mut store = store_to_replay_buffer(&service);
    let store_op = r_dqn
        .filter_map(select_policy("dqn"))
        .for_each(move |b| {
            store(b);
            TrainItem::default()
        });
    let dqn_local = local.clone();
    let target_every = ma.dqn.target_update_every;
    let sync_every = ma.dqn.weight_sync_every;
    let mut since_sync = 0usize;
    let mut since_target = 0usize;
    let replay_op = replay(&service, 1).for_each(move |item| {
        let Some((sample, lease)) = item else {
            return TrainItem::default(); // buffer not ready yet
        };
        let steps = sample.batch.len();
        let indices = sample.indices;
        let batch = sample.batch;
        let (stats, td) = dqn_local
            .call(move |w| {
                let stats = w.learn_on_batch("dqn", &batch);
                let td = w.policies["dqn"].td_abs().unwrap_or_default();
                (stats, td)
            })
            .expect("DQN learner (local worker) actor died");
        lease.update_priorities(indices, td);
        since_sync += 1;
        since_target += steps;
        if since_sync >= sync_every {
            since_sync = 0;
            let weights: Arc<[f32]> = dqn_local
                .call(|w| w.get_weights("dqn"))
                .expect("DQN learner (local worker) actor died")
                .into();
            dqn_caster.broadcast(weights);
        }
        if since_target >= target_every {
            since_target = 0;
            dqn_local.cast(|w| w.update_target("dqn"));
        }
        TrainItem::new(prefix_stats("dqn", stats), steps)
    });
    let dqn_op = concurrently(
        vec![store_op, replay_op],
        UnionMode::RoundRobin { weights: None },
        Some(vec![1]),
    );

    // --- Union of the two trainers (Fig. 11b) ---
    let merged = concurrently(
        vec![ppo_op, dqn_op],
        UnionMode::RoundRobin { weights: None },
        None,
    );

    Reporting::new(merged, set, 1).build()
}

fn prefix_stats(
    prefix: &str,
    stats: BTreeMap<String, f64>,
) -> BTreeMap<String, f64> {
    stats
        .into_iter()
        .map(|(k, v)| (format!("{prefix}/{k}"), v))
        .collect()
}
