//! Ape-X — high-throughput distributed prioritized replay (paper
//! Fig. 10 / Listing A3).
//!
//! ```text
//! rollouts  = ParallelRollouts(workers, mode="async", num_async=2)
//! store_op  = rollouts.for_each(StoreToReplayBuffer(service))
//!                     .zip_with_source_actor()
//!                     .for_each(UpdateWorkerWeights(workers))
//! replay_op = Replay(service, num_async=4)
//!                     .for_each(learner)       # mailbox == Enqueue
//!                     .for_each(UpdateReplayPriorities + TrainOneStep)
//! merged    = Concurrently([store_op, replay_op], mode="async",
//!                          output_indexes=[1])
//! ```
//! The paper's dedicated `LearnerThread` + `Enqueue`/`Dequeue` pair maps
//! onto the local-worker actor: its mailbox *is* the in-queue, and
//! `call` replies are the out-queue.
//!
//! The replay tier is the elastic `ops::ReplayService`: shards live in
//! a registry like rollout workers, the store subflow hash-routes over
//! the live slot set, and a backlog-driven `actor::Autoscaler` (bounds
//! from `TrainerConfig::{min,max}_replay_shards`) grows/retires shards
//! mid-plan from each report's `ReplayBacklogStats`.

use crate::actor::{Autoscaler, AutoscalerConfig};
use crate::iter::{concurrently, LocalIter, UnionMode};
use crate::metrics::TrainResult;
use crate::ops::{
    create_replay_shards, parallel_rollouts_from, replay,
    store_to_replay_buffer, update_target_network, Reporting,
    TrainItem,
};

use super::dqn::{learn_dqn, DqnConfig};
use super::TrainerConfig;

/// Ape-X knobs on top of DQN's.
#[derive(Debug, Clone)]
pub struct ApexConfig {
    pub dqn: DqnConfig,
    /// Replay shards spawned at plan build; the backlog autoscaler then
    /// moves the pool within `TrainerConfig::{min,max}_replay_shards`.
    pub num_replay_actors: usize,
    /// Refresh a worker's weights after it contributed this many steps
    /// (Listing A4's MAX_WEIGHT_SYNC_DELAY).
    pub max_weight_sync_delay: usize,
    /// In-flight replay requests per replay shard.
    pub replay_queue_depth: usize,
    /// Drive the replay-shard pool with a backlog autoscaler (one
    /// replay control step per report).  Off = fixed pool.
    pub autoscale_replay: bool,
}

impl Default for ApexConfig {
    fn default() -> Self {
        ApexConfig {
            dqn: DqnConfig {
                // Ape-X syncs weights through UpdateWorkerWeights in the
                // store subflow, not the learner.
                weight_sync_every: usize::MAX,
                ..DqnConfig::default()
            },
            num_replay_actors: 2,
            max_weight_sync_delay: 400,
            replay_queue_depth: 4,
            autoscale_replay: true,
        }
    }
}

pub fn apex_plan(
    config: &TrainerConfig,
    apex: &ApexConfig,
) -> LocalIter<TrainResult> {
    let workers = config.dqn_workers();
    let obs_dim =
        workers.local.call(|w| w.obs_dim()).expect("local worker died");
    let service = create_replay_shards(
        apex.num_replay_actors,
        obs_dim,
        apex.dqn.buffer_capacity,
        apex.dqn.learning_starts,
        64,
    );

    // (1) Async rollouts -> store -> refresh stale workers' weights.
    // Registry-backed: a restarted worker rejoins this stream live, and
    // the paired source handle is always the current incarnation (a
    // weight push can never target a corpse).
    let local = workers.local.clone();
    let registry = workers.registry().clone();
    let max_delay = apex.max_weight_sync_delay;
    let mut store = store_to_replay_buffer(&service);
    let mut steps_since_update =
        std::collections::HashMap::<u64, usize>::new();
    let store_op = parallel_rollouts_from(&workers)
        .gather_async_with_source(config.num_async)
        .for_each(move |(batch, worker)| {
            let n = store(batch).len();
            // UpdateWorkerWeights: per-worker staleness tracking
            // (Listing A4 lines 96-118 collapse to this closure).
            // Keyed by incarnation id — a replacement starts a fresh
            // countdown (it was just handed the learner's weights by
            // restart_dead).
            let entry = steps_since_update.entry(worker.id()).or_insert(0);
            *entry += n;
            if *entry >= max_delay {
                *entry = 0;
                // Single recipient: move the fetched Vec straight into
                // the cast (an Arc<[f32]> conversion would add a full
                // parameter-vector copy with nothing to amortize it).
                let weights = local
                    .call(|w| w.get_weights())
                    .expect("Ape-X learner (local worker) actor died");
                worker.cast(move |w| w.set_weights(&weights));
            }
            // Under worker churn dead incarnations' counters would pile
            // up; prune to the registry's live set once the map
            // outgrows it.
            if steps_since_update.len() > registry.len() {
                let live: std::collections::HashSet<u64> =
                    registry.handles().iter().map(|h| h.id()).collect();
                steps_since_update.retain(|id, _| live.contains(id));
            }
            TrainItem::default()
        });

    // (2)+(3) Replay -> learner -> priorities, pipelined per shard; the
    // lease inside each item drops TD feedback addressed to a shard
    // incarnation that died or retired while the learner held it.
    let replay_op = replay(&service, apex.replay_queue_depth)
        .for_each(learn_dqn(&workers, usize::MAX))
        .for_each(update_target_network(
            workers.local.clone(),
            apex.dqn.target_update_every,
        ));

    // Execute concurrently as fast as possible; only (2)+(3) surfaces.
    let merged = concurrently(
        vec![store_op, replay_op],
        UnionMode::Async { buffer: 4 },
        Some(vec![1]),
    );

    // Every report carries the replay tier's backlog telemetry; with
    // autoscaling on, a controller bounded by the TrainerConfig shard
    // limits applies one replay control step per report.
    let controller = apex.autoscale_replay.then(|| {
        Autoscaler::new(AutoscalerConfig::replay_defaults(
            config.min_replay_shards,
            config.max_replay_shards,
        ))
    });
    Reporting::new(merged, &workers, 1)
        .replay(&service, controller)
        .build()
}
