//! Train-from-gateway — DQN fed by **client-owned** environments.
//!
//! The inversion of every other plan in this module: instead of the
//! trainer stepping its own envs through `ParallelRollouts`, external
//! clients run their episodes through an elastic
//! [`GatewayService`](crate::ops::GatewayService) and the trainer
//! consumes whatever experience those served episodes leave behind:
//!
//! ```text
//! clients -> GatewayService (batched serving, ε-ladder shards)
//! store_op  = GatewayExperience(gw).for_each(StoreToReplayBuffer)
//! replay_op = Replay(service).for_each(learn + push_weights(gw))
//!                            .for_each(UpdateTargetNetwork)
//! plan      = Union(store_op, replay_op)   # async union
//! ```
//!
//! Differences from [`dqn_plan`](super::dqn_plan) / Ape-X that fall
//! out of the client-owned-env topology:
//!
//! * **The learner is a standalone actor**, not a `WorkerSet` local
//!   slot: there is no rollout pool at all.  Reporting runs over the
//!   *gateway* set instead, so episode metrics are the episodes real
//!   clients completed.
//! * **Weight sync is [`GatewayService::push_weights`]**, not a
//!   `WeightCaster` broadcast: pushes are staleness-keyed (every
//!   [`GatewayDqnConfig::max_weight_staleness`] *trained* steps) and
//!   non-blocking — a busy shard keeps serving on its current weights
//!   and catches the next push.
//! * **Exploration lives at the serving edge**: gateway shards get the
//!   Ape-X epsilon ladder, so the experience mix is exploration-graded
//!   across shards while the learner stays greedy.
//! * Both elastic tiers close their loops through one
//!   [`Reporting`] tail: the replay pool on backlog signals and the
//!   gateway pool on session/queue/shed pressure
//!   (`AutoscalerConfig::gateway_defaults`).

use std::sync::Arc;

use crate::actor::{ActorHandle, Autoscaler, AutoscalerConfig};
use crate::env::GatewayConfig;
use crate::iter::{concurrently, LocalIter, UnionMode};
use crate::metrics::TrainResult;
use crate::ops::{
    create_replay_shards, gateway_experience, replay,
    store_to_replay_buffer, update_target_network, GatewayService,
    Reporting, TrainItem,
};
use crate::policy::{DqnPolicy, DummyPolicy, Policy};
use crate::rollout::{CollectMode, RolloutWorker};

use super::{DqnConfig, EnvKind, TrainerConfig};

/// Knobs for the train-from-gateway plan.
#[derive(Debug, Clone)]
pub struct GatewayDqnConfig {
    pub dqn: DqnConfig,
    /// Gateway shards to start with.
    pub num_gateway_shards: usize,
    /// Autoscaler floor for the gateway pool.
    pub min_gateway_shards: usize,
    /// Autoscaler ceiling for the gateway pool.
    pub max_gateway_shards: usize,
    /// Per-shard session-table knobs.  `obs_dim` is overridden from
    /// the learner's env — clients must submit observations of that
    /// width.
    pub gateway: GatewayConfig,
    /// Push fresh weights to the gateway shards once the learner has
    /// trained this many steps since the last push (the serving-side
    /// staleness bound).
    pub max_weight_staleness: usize,
    /// `Replay` in-flight depth per replay shard.
    pub replay_queue_depth: usize,
    /// Drive the gateway pool with a backlog autoscaler.
    pub autoscale_gateway: bool,
    /// Drive the replay pool with a backlog autoscaler.
    pub autoscale_replay: bool,
}

impl Default for GatewayDqnConfig {
    fn default() -> Self {
        GatewayDqnConfig {
            dqn: DqnConfig::default(),
            num_gateway_shards: 2,
            min_gateway_shards: 1,
            max_gateway_shards: 4,
            gateway: GatewayConfig::default(),
            max_weight_staleness: 400,
            replay_queue_depth: 2,
            autoscale_gateway: true,
            autoscale_replay: false,
        }
    }
}

/// Build the train-from-gateway plan.  Returns the [`GatewayService`]
/// handle — clients `connect()` on it to serve their episodes — plus
/// the report stream; the plan only makes learning progress while
/// clients actually play.
pub fn gateway_dqn_plan(
    config: &TrainerConfig,
    gcfg: &GatewayDqnConfig,
) -> (GatewayService, LocalIter<TrainResult>) {
    // Greedy learner on its own actor (no rollout pool exists here;
    // its envs only define the observation space).
    let learner = {
        let cfg = config.clone();
        ActorHandle::spawn("gateway-learner", move || {
            let policy: Box<dyn Policy> = if cfg.env == EnvKind::Dummy {
                Box::new(DummyPolicy::new(cfg.lr))
            } else {
                Box::new(DqnPolicy::create(
                    &cfg.artifacts_dir,
                    cfg.lr,
                    0.0,
                    cfg.seed,
                ))
            };
            RolloutWorker::new(
                cfg.make_envs(0),
                policy,
                cfg.rollout_fragment_length,
                CollectMode::Transitions,
            )
        })
    };
    let obs_dim =
        learner.call(|w| w.obs_dim()).expect("gateway learner died");

    // Serving tier: epsilon-ladder shards (slot `usize::MAX` is the
    // set's zero-traffic sentinel — greedy, never routed to).
    let n_shards = gcfg.num_gateway_shards.max(1);
    let service = {
        let cfg = config.clone();
        GatewayService::new(
            n_shards,
            GatewayConfig { obs_dim, ..gcfg.gateway.clone() },
            move |slot| -> Box<dyn Policy> {
                if cfg.env == EnvKind::Dummy {
                    return Box::new(DummyPolicy::new(cfg.lr));
                }
                let epsilon = if slot == usize::MAX {
                    0.0
                } else {
                    0.4f64.powf(
                        1.0 + 7.0 * slot as f64
                            / (n_shards.max(2) - 1) as f64,
                    )
                };
                let seed = cfg
                    .seed
                    .wrapping_add((slot as u64).wrapping_add(1_000));
                Box::new(DqnPolicy::create(
                    &cfg.artifacts_dir,
                    cfg.lr,
                    epsilon,
                    seed,
                ))
            },
        )
    };

    let replay_service = create_replay_shards(
        config.min_replay_shards.max(1),
        obs_dim,
        gcfg.dqn.buffer_capacity,
        gcfg.dqn.learning_starts,
        64,
    );

    // (1) Drain served-episode fragments off the gateway shards into
    // the replay tier.  Quiet gateways yield `None` after a backoff,
    // so the union never deadlocks on an idle serving edge.
    let store_op = {
        let mut store = store_to_replay_buffer(&replay_service);
        gateway_experience(&service, config.num_async).for_each(
            move |maybe| {
                if let Some(batch) = maybe {
                    store(batch);
                }
                TrainItem::default()
            },
        )
    };

    // (2) Replay -> learn -> priorities back through the lease ->
    // staleness-keyed weight pushes to the serving edge.
    let replay_op = {
        let local = learner.clone();
        let push_to = service.clone();
        let staleness = gcfg.max_weight_staleness.max(1);
        let mut stale_steps = 0usize;
        replay(&replay_service, gcfg.replay_queue_depth)
            .for_each(move |item| {
                let Some((sample, lease)) = item else {
                    return TrainItem::default();
                };
                let steps = sample.batch.len();
                let indices = sample.indices;
                let batch = sample.batch;
                let (stats, td) = local
                    .call(move |w| w.learn_and_td(&batch))
                    .expect("gateway learner actor died");
                lease.update_priorities(indices, td);
                stale_steps += steps;
                if stale_steps >= staleness {
                    stale_steps = 0;
                    let weights: Arc<[f32]> = local
                        .call(|w| w.get_weights())
                        .expect("gateway learner actor died")
                        .into();
                    push_to.push_weights(weights);
                }
                TrainItem::new(stats, steps)
            })
            .for_each(update_target_network(
                learner.clone(),
                gcfg.dqn.target_update_every,
            ))
    };

    // Async union: storing never waits on learning and vice versa;
    // only the training subflow's items surface.
    let merged = concurrently(
        vec![store_op, replay_op],
        UnionMode::Async { buffer: 4 },
        Some(vec![1]),
    );

    let gateway_ctl = gcfg.autoscale_gateway.then(|| {
        Autoscaler::new(AutoscalerConfig::gateway_defaults(
            gcfg.min_gateway_shards,
            gcfg.max_gateway_shards,
        ))
    });
    let replay_ctl = gcfg.autoscale_replay.then(|| {
        Autoscaler::new(AutoscalerConfig::replay_defaults(
            config.min_replay_shards,
            config.max_replay_shards,
        ))
    });

    // Report over the *gateway* set: episode metrics are the episodes
    // clients completed through the serving edge.
    let reports = Reporting::new(merged, service.set(), 1)
        .replay(&replay_service, replay_ctl)
        .gateway(&service, gateway_ctl)
        .build();
    (service, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_config() -> TrainerConfig {
        TrainerConfig {
            num_workers: 1,
            num_envs_per_worker: 2,
            rollout_fragment_length: 8,
            env: EnvKind::Dummy,
            ..TrainerConfig::default()
        }
    }

    /// Clients play through the gateway; the plan stores their
    /// experience, learns, and reports gateway telemetry.
    #[test]
    fn trains_from_client_episodes() {
        let cfg = dummy_config();
        let gcfg = GatewayDqnConfig {
            dqn: DqnConfig {
                buffer_capacity: 4096,
                learning_starts: 32,
                ..DqnConfig::default()
            },
            num_gateway_shards: 2,
            gateway: GatewayConfig {
                fragment: 16,
                ..GatewayConfig::default()
            },
            max_weight_staleness: 64,
            autoscale_gateway: false,
            ..GatewayDqnConfig::default()
        };
        let (service, mut plan) = gateway_dqn_plan(&cfg, &gcfg);

        // A background client swarm: 4 threads, episodes of 20 steps.
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let svc = service.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let obs = vec![0.25f32 * t as f32; 4];
                    while !stop.load(std::sync::atomic::Ordering::Relaxed)
                    {
                        let Ok(session) = svc.connect() else {
                            std::thread::sleep(
                                std::time::Duration::from_millis(1),
                            );
                            continue;
                        };
                        for _ in 0..20 {
                            if session.request_action(&obs).is_err() {
                                break;
                            }
                            let _ = session.log_reward(1.0);
                        }
                        let _ = session.end(Some(&obs));
                    }
                })
            })
            .collect();

        let mut saw_gateway = false;
        let mut steps_trained = 0u64;
        for _ in 0..40 {
            let r = plan.next().expect("plan ended");
            if let Some(gw) = &r.gateway {
                saw_gateway = true;
                assert!(gw.live_shards >= 1);
            }
            steps_trained = r.num_env_steps_trained;
            if steps_trained > 0 {
                break;
            }
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        assert!(saw_gateway, "reports never carried gateway telemetry");
        assert!(
            steps_trained > 0,
            "learner never trained on client experience"
        );
        let stats = service.backlog_stats();
        assert!(stats.completed > 0, "no client episode completed");
        assert!(stats.transitions > 0, "no transitions drained");
    }
}
