//! DQN — replay-buffer training with interleaved store/replay subflows
//! (paper Fig. 12b):
//!
//! ```text
//! store_op  = rollouts.for_each(StoreToReplayBuffer(service))
//! replay_op = Replay(service).for_each(TrainOneStep)
//!                            .for_each(UpdateTargetNetwork)
//! dqn_op    = Union(store_op, replay_op)    # round-robin 1:1
//! ```
//!
//! The replay tier is the elastic [`crate::ops::ReplayService`] even in this
//! single-shard configuration — same registry machinery as Ape-X, just
//! with one shard and no autoscaler.

use crate::iter::{concurrently, LocalIter, UnionMode};
use crate::metrics::TrainResult;
use crate::ops::{
    create_replay_shards, parallel_rollouts_from, replay,
    store_to_replay_buffer, update_target_network, Reporting,
    ReplayLease, TrainItem,
};
use crate::rollout::WorkerSet;

use super::TrainerConfig;

/// DQN-specific knobs.
#[derive(Debug, Clone)]
pub struct DqnConfig {
    pub buffer_capacity: usize,
    pub learning_starts: usize,
    pub target_update_every: usize,
    /// Broadcast learner weights to workers every N train steps.
    pub weight_sync_every: usize,
}

impl Default for DqnConfig {
    fn default() -> Self {
        DqnConfig {
            buffer_capacity: 50_000,
            learning_starts: 1_000,
            target_update_every: 500,
            weight_sync_every: 5,
        }
    }
}

pub fn dqn_plan(
    config: &TrainerConfig,
    dqn: &DqnConfig,
) -> LocalIter<TrainResult> {
    let workers = config.dqn_workers();
    let obs_dim =
        workers.local.call(|w| w.obs_dim()).expect("local worker died");
    let service = create_replay_shards(
        1,
        obs_dim,
        dqn.buffer_capacity,
        dqn.learning_starts,
        64,
    );

    // (1) Collect and store transitions (registry-backed: restarted
    // workers rejoin the running stream).
    let store_op = parallel_rollouts_from(&workers)
        .gather_async(config.num_async)
        .for_each(store_to_replay_buffer(&service))
        .for_each(|_| TrainItem::default());

    // (2) Replay, learn on the local worker, feed TD errors back as
    // priorities through the lease, periodically sync target net +
    // worker weights.
    let replay_op = replay(&service, 1)
        .for_each(learn_dqn(&workers, dqn.weight_sync_every))
        .for_each(update_target_network(
            workers.local.clone(),
            dqn.target_update_every,
        ));

    // Round-robin 1:1 keeps the classic DQN step ratio; only the
    // training subflow's items surface.
    let dqn_op = concurrently(
        vec![store_op, replay_op],
        UnionMode::RoundRobin { weights: None },
        Some(vec![1]),
    );

    Reporting::new(dqn_op, &workers, 1).build()
}

/// The learner closure shared by DQN and Ape-X: learn on the local
/// worker, push priorities back through the sample's [`ReplayLease`]
/// (updates addressed to a restarted or retired shard incarnation are
/// discarded by the lease, not misapplied), occasionally broadcast
/// weights (as a versioned cast through the set's `WeightCaster` —
/// superseded versions coalesce, overloaded workers shed instead of
/// stalling the learner).  Not-ready replay items (buffer below
/// learning-starts) pass through as empty `TrainItem`s so concurrent
/// subflows keep making progress.
pub(crate) fn learn_dqn(
    workers: &WorkerSet,
    weight_sync_every: usize,
) -> impl FnMut(
    Option<(crate::replay::ReplaySample, ReplayLease)>,
) -> TrainItem
       + Send
       + 'static {
    let local = workers.local.clone();
    let caster = workers.caster();
    let mut since_sync = 0usize;
    move |item| {
        let Some((sample, lease)) = item else {
            return TrainItem::default();
        };
        let steps = sample.batch.len();
        let indices = sample.indices;
        let batch = sample.batch;
        let (stats, td) = local
            .call(move |w| w.learn_and_td(&batch))
            .expect("DQN learner (local worker) actor died");
        lease.update_priorities(indices, td);
        since_sync += 1;
        if since_sync >= weight_sync_every {
            since_sync = 0;
            let weights: std::sync::Arc<[f32]> = local
                .call(|w| w.get_weights())
                .expect("DQN learner (local worker) actor died")
                .into();
            caster.broadcast(weights);
        }
        TrainItem::new(stats, steps)
    }
}
