//! A3C — the paper's running example (Fig. 4 / Fig. 9a / Listing A1).
//!
//! ```text
//! workers = create_rollout_workers()
//! grads = ParallelRollouts(workers)
//!     .par_for_each(ComputeGradients())   # on the source actors
//!     .gather_async()                     # pink arrow
//! apply_op = grads.for_each(ApplyGradients(workers))
//! return ReportMetrics(apply_op, workers)
//! ```

use crate::iter::LocalIter;
use crate::metrics::TrainResult;
use crate::ops::{
    apply_gradients, compute_gradients, parallel_rollouts_from,
    Reporting,
};
use crate::policy::PgLossKind;
use crate::rollout::CollectMode;

use super::TrainerConfig;

pub fn a3c_plan(config: &TrainerConfig) -> LocalIter<TrainResult> {
    // Workers compute gradients on their own fragments; size the
    // fragment so fragment x envs == the a3c_grad artifact batch
    // (otherwise rows beyond the artifact shape would be dropped).
    let mut config = config.clone();
    if let Ok(m) =
        crate::runtime::Manifest::load(config.artifacts_dir.join("manifest.json"))
    {
        config.rollout_fragment_length =
            (m.config.fragment / config.num_envs_per_worker).max(1);
    }
    let workers = config.pg_workers(PgLossKind::A3c, CollectMode::OnPolicy);

    // Registry-backed async gathers: a restarted worker's gradients
    // flow into the running stream on its next dispatch.
    let grads = parallel_rollouts_from(&workers)
        .for_each(|w, batch| compute_gradients()(w, batch))
        .gather_async_with_source(config.num_async);

    let apply_op = grads.for_each(apply_gradients(workers.local.clone()));

    Reporting::new(apply_op, &workers, 1).build()
}
