//! Offline DQN — replay-buffer training whose experience source is a
//! recorded episode log instead of live envs:
//!
//! ```text
//! read_op   = ReadFromLogs(readers, service)      # tail-follow .flog segments
//! replay_op = Replay(service).for_each(TrainOneStep)
//!                            .for_each(UpdateTargetNetwork)
//! offline_op = Union(read_op, replay_op)          # async, training surfaced
//! ```
//!
//! The replay → learn half is structurally identical to [`super::dqn`];
//! the *only* difference is which source op feeds the buffer — the
//! paper's compositionality claim applied to offline RL.  The plan
//! constructs **zero** environment instances (checkable via
//! [`crate::env::constructed_count`]; `tests/offline.rs` asserts it),
//! and the learner lives in a one-actor [`WorkerSet`] so the shared
//! [`crate::ops::Reporting`] tail drives reports exactly as online
//! plans do.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::actor::ActorHandle;
use crate::iter::{concurrently, LocalIter, UnionMode};
use crate::metrics::{EpisodeRecord, TrainResult};
use crate::offline::{discover_streams, LogStreamReader, OfflineCounters};
use crate::ops::{
    create_replay_shards, read_from_logs, replay, Reporting, ReplayLease,
    TrainItem,
};
use crate::policy::{DqnPolicy, DummyPolicy, Policy};
use crate::rollout::{WorkerMetrics, WorkerSet};
use crate::SampleBatch;

use super::dqn::DqnConfig;
use super::{EnvKind, TrainerConfig};

/// Offline-specific knobs (everything env-shaped that a live
/// [`TrainerConfig`] would have derived from its workers).
#[derive(Debug, Clone)]
pub struct OfflineDqnConfig {
    /// Directory holding the `.flog` segments to train from.
    pub log_dir: PathBuf,
    /// Streams to follow; empty ⇒ follow every stream discovered in
    /// `log_dir` at plan-construction time.
    pub streams: Vec<String>,
    /// Observation dimensionality of the logged transitions (with no
    /// env to ask, the replay shards need it up front).
    pub obs_dim: usize,
    /// In-flight async depth of the replay source.
    pub replay_queue_depth: usize,
}

impl Default for OfflineDqnConfig {
    fn default() -> Self {
        OfflineDqnConfig {
            log_dir: PathBuf::from("episode-logs"),
            streams: Vec::new(),
            obs_dim: 4,
            replay_queue_depth: 1,
        }
    }
}

/// The offline learner actor: a bare policy plus a trained-step counter.
/// No envs, no builders, no episode state — it exists so the replay →
/// learn stage and the [`Reporting`] tail have the same actor shape as
/// a rollout worker without dragging the sampling machinery along.
pub struct OfflineLearner {
    policy: Box<dyn Policy>,
    steps_trained: usize,
}

impl OfflineLearner {
    pub fn new(policy: Box<dyn Policy>) -> Self {
        OfflineLearner { policy, steps_trained: 0 }
    }

    /// One SGD step plus the per-row |TD| vector for priority feedback
    /// (mirrors `RolloutWorker::learn_and_td`).
    pub fn learn_and_td(
        &mut self,
        batch: &SampleBatch,
    ) -> (BTreeMap<String, f64>, Vec<f32>) {
        self.steps_trained += batch.len();
        let stats = self.policy.learn_on_batch(batch);
        let td = self.policy.td_abs().unwrap_or_default();
        (stats, td)
    }

    pub fn update_target(&mut self) {
        self.policy.update_target();
    }

    pub fn get_weights(&self) -> Vec<f32> {
        self.policy.get_weights()
    }

    pub fn set_weights(&mut self, weights: &[f32]) {
        self.policy.set_weights(weights);
    }

    pub fn steps_trained(&self) -> usize {
        self.steps_trained
    }
}

impl WorkerMetrics for OfflineLearner {
    /// No sampler exists in an offline plan, so the learner reports its
    /// replayed-and-trained steps through the set's step counter (the
    /// log-ingestion side is reported separately via
    /// [`TrainResult::offline`]).
    fn drain_metrics(&mut self) -> (Vec<EpisodeRecord>, usize) {
        (Vec::new(), std::mem::take(&mut self.steps_trained))
    }
}

/// Train DQN purely from recorded logs.  `config` supplies the policy
/// knobs (lr, artifacts, seed, `EnvKind::Dummy` selects the dummy
/// policy for tests); no env is ever constructed.
pub fn offline_dqn_plan(
    config: &TrainerConfig,
    dqn: &DqnConfig,
    offline: &OfflineDqnConfig,
) -> LocalIter<TrainResult> {
    let counters = OfflineCounters::new();
    let streams = if offline.streams.is_empty() {
        discover_streams(&offline.log_dir)
    } else {
        offline.streams.clone()
    };
    let readers: Vec<LogStreamReader> = streams
        .into_iter()
        .map(|s| LogStreamReader::follow(&offline.log_dir, s, counters.clone()))
        .collect();

    // One local learner, zero remotes.  The sync protocol still pushes
    // learner weights should the set ever be scaled up.
    let cfg = config.clone();
    let learners: WorkerSet<OfflineLearner> = WorkerSet::with_protocol(
        "offline-learner",
        "offline-learner-r",
        0,
        move |_| {
            let cfg = cfg.clone();
            Box::new(move || {
                let policy: Box<dyn Policy> = if cfg.env == EnvKind::Dummy {
                    Box::new(DummyPolicy::new(cfg.lr))
                } else {
                    Box::new(DqnPolicy::create(
                        &cfg.artifacts_dir,
                        cfg.lr,
                        0.0,
                        cfg.seed,
                    ))
                };
                OfflineLearner::new(policy)
            })
        },
        |learner: &ActorHandle<OfflineLearner>,
         fresh: &ActorHandle<OfflineLearner>| {
            let weights = learner.call(|l| l.get_weights()).map_err(|e| {
                crate::util::error::Error::msg(format!(
                    "offline learner is dead ({e})"
                ))
            })?;
            fresh.cast(move |l| l.set_weights(&weights));
            Ok(())
        },
    );

    let service = create_replay_shards(
        config.min_replay_shards.max(1),
        offline.obs_dim,
        dqn.buffer_capacity,
        dqn.learning_starts,
        64,
    );

    // (1) Tail the logs into the replay tier (the offline twin of
    // rollouts → StoreToReplayBuffer).
    let read_op = read_from_logs(readers, &service)
        .for_each(|_| TrainItem::default());

    // (2) Replay → learn → target sync, exactly as in the online plan.
    let local = learners.local.clone();
    let replay_op = replay(&service, offline.replay_queue_depth.max(1))
        .for_each(learn_offline(local.clone()))
        .for_each(sync_target(local, dqn.target_update_every));

    // Async union: the reader side must keep tailing while the learner
    // blocks on a not-yet-warm buffer; only training items surface.
    let offline_op = concurrently(
        vec![read_op, replay_op],
        UnionMode::Async { buffer: 4 },
        Some(vec![1]),
    );

    Reporting::new(offline_op, &learners, 1)
        .replay(&service, None)
        .offline(counters)
        .build()
}

/// The offline learner closure — the shape of `dqn::learn_dqn` minus
/// the weight broadcast (there are no samplers to sync).
fn learn_offline(
    local: ActorHandle<OfflineLearner>,
) -> impl FnMut(Option<(crate::replay::ReplaySample, ReplayLease)>) -> TrainItem
       + Send
       + 'static {
    move |item| {
        let Some((sample, lease)) = item else {
            return TrainItem::default();
        };
        let steps = sample.batch.len();
        let indices = sample.indices;
        let batch = sample.batch;
        let (stats, td) = local
            .call(move |l| l.learn_and_td(&batch))
            .expect("offline learner actor died");
        lease.update_priorities(indices, td);
        TrainItem::new(stats, steps)
    }
}

/// `UpdateTargetNetwork` for the offline learner actor (the shared
/// `ops::update_target_network` is `RolloutWorker`-typed).
fn sync_target(
    local: ActorHandle<OfflineLearner>,
    every: usize,
) -> impl FnMut(TrainItem) -> TrainItem + Send + 'static {
    let mut since_update = 0usize;
    move |item| {
        since_update += item.steps_trained;
        if since_update >= every {
            since_update = 0;
            local.cast(|l| l.update_target());
        }
        item
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::{EpisodeLogWriter, WriterConfig};
    use crate::sample_batch::SampleBatchBuilder;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("flowrl_offdqn_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn synthetic_batch(obs_dim: usize, n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(obs_dim);
        let obs = vec![0.25; obs_dim];
        for i in 0..n {
            b.add_transition_with_logp(
                &obs,
                (i % 2) as i32,
                1.0,
                &obs,
                i % 10 == 9,
                -0.69,
            );
        }
        b.build()
    }

    #[test]
    fn trains_from_synthetic_logs_with_dummy_policy() {
        let dir = tmp_dir("plan");
        let mut w = EpisodeLogWriter::create(
            &dir,
            "synthetic",
            WriterConfig::default(),
        )
        .unwrap();
        for _ in 0..8 {
            w.append(&synthetic_batch(4, 32)).unwrap();
        }

        let config = TrainerConfig {
            env: EnvKind::Dummy,
            min_replay_shards: 1,
            ..TrainerConfig::default()
        };
        let dqn = DqnConfig {
            buffer_capacity: 1024,
            learning_starts: 64,
            target_update_every: 128,
            weight_sync_every: 5,
        };
        let offline = OfflineDqnConfig {
            log_dir: dir.clone(),
            obs_dim: 4,
            ..OfflineDqnConfig::default()
        };

        let mut plan = offline_dqn_plan(&config, &dqn, &offline);
        let mut trained = 0usize;
        let mut saw_offline_stats = false;
        for _ in 0..200 {
            let report = plan.next().expect("plan is infinite");
            trained += report.num_env_steps_trained as usize;
            if let Some(stats) = report.offline {
                saw_offline_stats = true;
                assert_eq!(stats.corrupt_frames, 0);
                assert_eq!(stats.streams, 1);
            }
            if trained > 0 && saw_offline_stats {
                break;
            }
        }
        assert!(trained > 0, "no training progress from logs");
        assert!(saw_offline_stats, "TrainResult::offline never populated");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn learner_drains_trained_steps_as_metrics() {
        let mut l = OfflineLearner::new(Box::new(DummyPolicy::new(0.1)));
        let batch = synthetic_batch(4, 16);
        let (_stats, _td) = l.learn_and_td(&batch);
        assert_eq!(l.steps_trained(), 16);
        let (eps, steps) = l.drain_metrics();
        assert!(eps.is_empty());
        assert_eq!(steps, 16);
        assert_eq!(l.steps_trained(), 0);
    }
}
