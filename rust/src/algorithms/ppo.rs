//! PPO — bulk-sync collection, SGD epochs in the learner.
//!
//! ```text
//! ParallelRollouts(bulk_sync) -> ConcatBatches(B)
//!   -> TrainOneStep (SGD epochs over shuffled minibatches)
//!   -> StandardMetricsReporting
//! ```
//! The SGD-epoch loop lives in `PgPolicy::learn_on_batch` (the paper
//! keeps it inside `TrainOneStep`'s `sgd_minibatch` config likewise).

use crate::iter::LocalIter;
use crate::metrics::TrainResult;
use crate::ops::{
    concat_batches, parallel_rollouts_from, Reporting,
    train_one_step,
};
use crate::policy::PgLossKind;
use crate::rollout::CollectMode;
use crate::sample_batch::SampleBatch;

use super::TrainerConfig;

pub fn ppo_plan(config: &TrainerConfig) -> LocalIter<TrainResult> {
    ppo_plan_with_epochs(config, 4)
}

pub fn ppo_plan_with_epochs(
    config: &TrainerConfig,
    epochs: usize,
) -> LocalIter<TrainResult> {
    let workers =
        config.pg_workers(PgLossKind::Ppo { epochs }, CollectMode::OnPolicy);

    // Registry-backed bulk-sync rollouts: restarted workers rejoin at
    // the next round boundary.
    let rollouts = parallel_rollouts_from(&workers)
        .gather_sync()
        .for_each(|round| SampleBatch::concat_all(&round))
        .combine(concat_batches(config.train_batch_size));

    let train_op = rollouts.for_each(train_one_step(&workers));

    Reporting::new(train_op, &workers, 1).build()
}
