//! IMPALA — async rollouts feeding a V-trace learner.
//!
//! ```text
//! ParallelRollouts(async, num_async) -> assemble [T, B] time-major
//!   -> learner (impala_grad: V-trace Pallas kernel)
//!   -> broadcast weights -> StandardMetricsReporting
//! ```
//! Workers run `impala_b` env lanes for `impala_t` steps, so one worker
//! fragment is exactly one learner batch; behaviour log-probs ride in
//! the batch for the importance correction.

use crate::iter::LocalIter;
use crate::metrics::TrainResult;
use crate::ops::{
    parallel_rollouts_from, Reporting, TrainItem,
};
use crate::policy::{ImpalaBatch, PgLossKind};
use crate::rollout::CollectMode;
use crate::sample_batch::SampleBatch;

use super::TrainerConfig;

/// Convert an env-major worker fragment (lane-contiguous segments of
/// length `t_len`) into the time-major [T, B] layout `impala_grad`
/// expects, writing into `out`'s recycled storage (no allocation once
/// `out` has reached steady-state capacity).  The fragment must be
/// exactly `t_len * b_lanes` rows with next_obs present.
pub fn assemble_time_major_into(
    batch: &SampleBatch,
    t_len: usize,
    b_lanes: usize,
    out: &mut ImpalaBatch,
) {
    assert_eq!(batch.len(), t_len * b_lanes, "fragment shape mismatch");
    assert!(!batch.next_obs.is_empty(), "IMPALA needs next_obs");
    let rows = t_len * b_lanes;
    out.t_len = t_len;
    out.b_lanes = b_lanes;
    out.obs.clear();
    out.obs.reserve(rows * batch.obs_dim);
    out.actions.clear();
    out.actions.reserve(rows);
    out.behaviour_logp.clear();
    out.behaviour_logp.reserve(rows);
    out.rewards.clear();
    out.rewards.reserve(rows);
    out.dones.clear();
    out.dones.reserve(rows);
    out.bootstrap_obs.clear();
    out.bootstrap_obs.reserve(b_lanes * batch.obs_dim);
    out.mask.clear();
    out.mask.resize(rows, 1.0);
    for t in 0..t_len {
        for lane in 0..b_lanes {
            let row = lane * t_len + t; // env-major -> time-major
            out.obs.extend_from_slice(batch.obs_row(row));
            out.actions.push(batch.actions[row]);
            out.behaviour_logp.push(batch.action_logp[row]);
            out.rewards.push(batch.rewards[row]);
            out.dones.push(batch.dones[row]);
        }
    }
    for lane in 0..b_lanes {
        let last = lane * t_len + (t_len - 1);
        out.bootstrap_obs.extend_from_slice(batch.next_obs_row(last));
    }
}

/// [`assemble_time_major_into`] into a fresh batch (tests/benches and
/// one-shot callers).
pub fn assemble_time_major(
    batch: &SampleBatch,
    t_len: usize,
    b_lanes: usize,
) -> ImpalaBatch {
    let mut out = ImpalaBatch::default();
    assemble_time_major_into(batch, t_len, b_lanes, &mut out);
    out
}

pub fn impala_plan(config: &TrainerConfig) -> LocalIter<TrainResult> {
    // Force the worker geometry the impala_grad artifact expects.
    let mut config = config.clone();
    let (t_len, b_lanes) = {
        // Read the manifest once on the driver for shapes only.
        let m = crate::runtime::Manifest::load(
            config.artifacts_dir.join("manifest.json"),
        )
        .expect("manifest for impala geometry");
        (m.config.impala_t, m.config.impala_b)
    };
    config.rollout_fragment_length = t_len;
    config.num_envs_per_worker = b_lanes;

    let workers = config
        .pg_workers(PgLossKind::Impala, CollectMode::OnPolicyWithNextObs);

    let local = workers.local.clone();
    // The time-major learner batch's storage is recycled: it rides to
    // the learner actor inside the call and comes back with the reply,
    // so steady state reassembles with zero allocation.  Rollouts are
    // registry-backed (restarted workers rejoin live), and the paired
    // source handle is always the current incarnation.
    let mut scratch = ImpalaBatch::default();
    let train_op = parallel_rollouts_from(&workers)
        .gather_async_with_source(config.num_async)
        .for_each(move |(batch, source)| {
            let steps = batch.len();
            let mut tb = std::mem::take(&mut scratch);
            assemble_time_major_into(&batch, t_len, b_lanes, &mut tb);
            let (stats, weights, tb_back) = local
                .call(move |w| {
                    let stats = w.policy.learn_impala(&tb);
                    (stats, w.get_weights(), tb)
                })
                .expect("IMPALA learner (local worker) actor died");
            scratch = tb_back;
            // Per-source weight refresh (fine-grained, like A3C) plus
            // the learner keeps remotes loosely in sync.
            source.cast(move |w| w.set_weights(&weights));
            TrainItem::new(stats, steps)
        });

    Reporting::new(train_op, &workers, 1).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    #[test]
    fn assemble_transposes_env_major_to_time_major() {
        // 2 lanes x 3 steps; obs value encodes (lane, t) as lane*10+t.
        let mut b = SampleBatchBuilder::new(1);
        for lane in 0..2 {
            for t in 0..3 {
                b.add_step_with_next(
                    &[(lane * 10 + t) as f32],
                    t as i32,
                    t as f32,
                    &[(lane * 10 + t + 1) as f32],
                    false,
                    -0.5 * lane as f32,
                    0.0,
                );
            }
        }
        let tb = assemble_time_major(&b.build(), 3, 2);
        // Time-major: row index = t * B + lane.
        assert_eq!(tb.obs, vec![0.0, 10.0, 1.0, 11.0, 2.0, 12.0]);
        assert_eq!(tb.actions, vec![0, 0, 1, 1, 2, 2]);
        assert_eq!(tb.behaviour_logp[1], -0.5);
        // Bootstrap = next_obs of each lane's last row.
        assert_eq!(tb.bootstrap_obs, vec![3.0, 13.0]);
        assert_eq!(tb.mask, vec![1.0; 6]);
    }

    #[test]
    fn assemble_into_recycles_storage() {
        let mk = |base: f32| {
            let mut b = SampleBatchBuilder::new(1);
            for lane in 0..2 {
                for t in 0..3 {
                    let v = base + (lane * 10 + t) as f32;
                    b.add_step_with_next(
                        &[v],
                        t as i32,
                        v,
                        &[v + 1.0],
                        false,
                        0.0,
                        0.0,
                    );
                }
            }
            b.build()
        };
        let mut scratch = ImpalaBatch::default();
        assemble_time_major_into(&mk(0.0), 3, 2, &mut scratch);
        let ptr = scratch.obs.as_ptr();
        let cap = scratch.obs.capacity();
        assemble_time_major_into(&mk(100.0), 3, 2, &mut scratch);
        // Same shape -> same storage, fresh contents.
        assert_eq!(scratch.obs.as_ptr(), ptr, "obs storage reallocated");
        assert_eq!(scratch.obs.capacity(), cap);
        assert_eq!(scratch.obs, assemble_time_major(&mk(100.0), 3, 2).obs);
        assert_eq!(scratch.mask, vec![1.0; 6]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn assemble_rejects_bad_shape() {
        let mut b = SampleBatchBuilder::new(1);
        b.add_step_with_next(&[0.0], 0, 0.0, &[1.0], false, 0.0, 0.0);
        assemble_time_major(&b.build(), 3, 2);
    }
}
