//! `Concurrently` / `Union` — composing concurrently executing dataflow
//! fragments (paper §4 Concurrency, Figure 8; used by Ape-X and the
//! multi-agent PPO+DQN composition).
//!
//! Union tags are plain child indices (no epoch encoding): children are
//! driver-side iterators, not actor incarnations, so there is nothing
//! to replace live.  Elasticity composes through the *children*: a
//! fragment built over a `ShardRegistry` keeps streaming (and adopts
//! replacement workers) inside a running union — see the
//! `async_union_child_adopts_replacement_worker` test.

use crate::actor::{Completion, CompletionQueue};

use super::LocalIter;

#[derive(Debug, Clone)]
pub enum UnionMode {
    /// Pull children in a fixed rotation on the driver.  `weights[i]`
    /// pulls are taken from child i per cycle — the rate-limiting knob
    /// (Acme-style fixed-ratio progress, paper §2.2/§4).  `None` = 1
    /// pull each.  Deterministic and fully lazy.
    RoundRobin { weights: Option<Vec<usize>> },
    /// Drive every child from its own driver thread, yielding items as
    /// they become ready (maximum pipeline overlap — Ape-X's
    /// mode="async").  Each child's completions flow through one shared
    /// [`CompletionQueue`] with a **per-child credit of `buffer`**: a
    /// child may run at most `buffer` items ahead of consumption (plus
    /// the one item in its hands), then parks — real backpressure, per
    /// fragment, not a shared-channel approximation.
    Async { buffer: usize },
}

/// Compose concurrent sub-flows into one iterator.
///
/// `output_indexes`: if set, items from children not listed are still
/// *driven* (their side effects happen) but dropped from the output —
/// e.g. Ape-X emits only sub-flow (3)'s items (`output_indexes=[2]`).
pub fn concurrently<T: Send + 'static>(
    children: Vec<LocalIter<T>>,
    mode: UnionMode,
    output_indexes: Option<Vec<usize>>,
) -> LocalIter<T> {
    let emit = move |idx: usize| {
        output_indexes.as_ref().map_or(true, |s| s.contains(&idx))
    };
    match mode {
        UnionMode::RoundRobin { weights } => {
            let weights = match weights {
                Some(w) => {
                    assert_eq!(w.len(), children.len(), "weights length");
                    assert!(w.iter().all(|&x| x >= 1), "weights must be >= 1");
                    w
                }
                None => vec![1; children.len()],
            };
            round_robin(children, weights, emit)
        }
        UnionMode::Async { buffer } => async_union(children, buffer, emit),
    }
}

fn round_robin<T: Send + 'static>(
    children: Vec<LocalIter<T>>,
    weights: Vec<usize>,
    emit: impl Fn(usize) -> bool + Send + 'static,
) -> LocalIter<T> {
    let mut children: Vec<Option<LocalIter<T>>> =
        children.into_iter().map(Some).collect();
    let mut cursor = 0usize;
    let mut left_in_cycle = weights[0];
    LocalIter::from_fn(move || loop {
        if children.iter().all(|c| c.is_none()) {
            return None;
        }
        if children[cursor].is_none() || left_in_cycle == 0 {
            cursor = (cursor + 1) % children.len();
            left_in_cycle = weights[cursor];
            continue;
        }
        match children[cursor].as_mut().unwrap().next() {
            Some(t) => {
                left_in_cycle -= 1;
                let idx = cursor;
                if left_in_cycle == 0 {
                    cursor = (cursor + 1) % children.len();
                    left_in_cycle = weights[cursor];
                }
                if emit(idx) {
                    return Some(t);
                }
                // Driven but dropped: keep pulling.
            }
            None => {
                children[cursor] = None;
                cursor = (cursor + 1) % children.len();
                left_in_cycle = weights[cursor];
            }
        }
    })
}

/// Driver-side state; closing the queue on drop releases child threads
/// parked in `push` when the consumer abandons the stream mid-way.
struct AsyncUnionState<T: Send + 'static> {
    queue: CompletionQueue<Option<T>>,
    live: usize,
}

impl<T: Send + 'static> Drop for AsyncUnionState<T> {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Rides each child driver thread: if the child unwinds (a panic inside
/// the fragment, e.g. a dead-learner `expect`), a death notice still
/// reaches the consumer so the union terminates instead of parking
/// forever — the disconnect semantics the old per-child mpsc sender
/// gave for free.
struct UnionChildGuard<T: Send + 'static> {
    queue: CompletionQueue<Option<T>>,
    tag: usize,
    done: bool,
}

impl<T: Send + 'static> Drop for UnionChildGuard<T> {
    fn drop(&mut self) {
        if !self.done {
            self.queue.push_dropped(self.tag);
        }
    }
}

fn async_union<T: Send + 'static>(
    children: Vec<LocalIter<T>>,
    buffer: usize,
    emit: impl Fn(usize) -> bool + Send + 'static,
) -> LocalIter<T> {
    assert!(buffer >= 1);
    let mut lazy: Option<AsyncUnionState<T>> = None;
    let mut children = Some(children);
    LocalIter::from_fn(move || {
        let st = lazy.get_or_insert_with(|| {
            // First pull: spawn one driver thread per child.  The
            // per-child credit means each child runs at most `buffer`
            // items ahead of the consumer.
            let children = children.take().unwrap();
            let queue: CompletionQueue<Option<T>> =
                CompletionQueue::per_tag(children.len().max(1), buffer);
            let live = children.len();
            for (i, mut child) in children.into_iter().enumerate() {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("union-{i}"))
                    .spawn(move || {
                        let mut guard = UnionChildGuard {
                            queue: q.clone(),
                            tag: i,
                            done: false,
                        };
                        loop {
                            // child.next() may panic (a subflow's
                            // dead-learner expect): the guard then
                            // delivers the death notice.
                            let item = child.next();
                            let end = item.is_none();
                            // push returns false once the consumer
                            // closed the queue — stop driving.
                            if !q.push(i, item) || end {
                                guard.done = true;
                                return;
                            }
                        }
                    })
                    .expect("spawn union driver");
            }
            AsyncUnionState { queue, live }
        });
        loop {
            if st.live == 0 {
                return None;
            }
            match st.queue.pop() {
                Completion::Item { tag, value: Some(t) } => {
                    if emit(tag) {
                        return Some(t);
                    }
                }
                // None = clean child end; Dropped = the child driver
                // panicked (its guard delivered the notice).
                Completion::Item { value: None, .. }
                | Completion::Dropped { .. } => st.live -= 1,
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn round_robin_alternates() {
        let a = LocalIter::from_items(vec![1, 3, 5]);
        let b = LocalIter::from_items(vec![2, 4, 6]);
        let got = concurrently(
            vec![a, b],
            UnionMode::RoundRobin { weights: None },
            None,
        )
        .collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn round_robin_weights_rate_limit() {
        // 2 pulls from a per 1 from b — fixed 2:1 progress ratio.
        let a = LocalIter::from_items(vec![10, 11, 12, 13]);
        let b = LocalIter::from_items(vec![20, 21]);
        let got = concurrently(
            vec![a, b],
            UnionMode::RoundRobin { weights: Some(vec![2, 1]) },
            None,
        )
        .collect();
        assert_eq!(got, vec![10, 11, 20, 12, 13, 21]);
    }

    #[test]
    fn round_robin_continues_after_exhaustion() {
        let a = LocalIter::from_items(vec![1]);
        let b = LocalIter::from_items(vec![2, 3, 4]);
        let got = concurrently(
            vec![a, b],
            UnionMode::RoundRobin { weights: None },
            None,
        )
        .collect();
        assert_eq!(got, vec![1, 2, 3, 4]);
    }

    #[test]
    fn output_indexes_drive_but_drop() {
        let driven = Arc::new(AtomicUsize::new(0));
        let d = driven.clone();
        let mut n = 0;
        let store_op = LocalIter::from_fn(move || {
            n += 1;
            if n > 3 {
                return None;
            }
            d.fetch_add(1, Ordering::SeqCst);
            Some(0) // side-effecting subflow, output dropped
        });
        let update_op = LocalIter::from_items(vec![100, 200, 300]);
        let got = concurrently(
            vec![store_op, update_op],
            UnionMode::RoundRobin { weights: None },
            Some(vec![1]),
        )
        .collect();
        assert_eq!(got, vec![100, 200, 300]);
        assert_eq!(driven.load(Ordering::SeqCst), 3); // side effects ran
    }

    #[test]
    fn async_mode_yields_everything() {
        let a = LocalIter::from_items(vec![1, 2]);
        let b = LocalIter::from_items(vec![3]);
        let mut got =
            concurrently(vec![a, b], UnionMode::Async { buffer: 4 }, None)
                .collect();
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn async_mode_with_output_indexes() {
        let a = LocalIter::from_items(vec![1, 2, 3]);
        let b = LocalIter::from_items(vec![10, 20]);
        let got = concurrently(
            vec![a, b],
            UnionMode::Async { buffer: 2 },
            Some(vec![0]),
        )
        .collect();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn async_mode_overlaps_slow_children() {
        // One slow and one fast child: total wall-clock must be far
        // below the serial sum (true concurrency).
        let slow = LocalIter::from_items(vec![1, 2, 3, 4]).for_each(|x| {
            std::thread::sleep(std::time::Duration::from_millis(25));
            x
        });
        let fast = LocalIter::from_items(vec![10, 20, 30, 40]).for_each(|x| {
            std::thread::sleep(std::time::Duration::from_millis(25));
            x
        });
        let start = std::time::Instant::now();
        let got = concurrently(
            vec![slow, fast],
            UnionMode::Async { buffer: 2 },
            None,
        )
        .collect();
        assert_eq!(got.len(), 8);
        let elapsed = start.elapsed();
        assert!(
            elapsed < std::time::Duration::from_millis(190),
            "children did not overlap: {elapsed:?}"
        );
    }

    #[test]
    fn empty_children_end_immediately() {
        let a = LocalIter::from_items(Vec::<i32>::new());
        let got = concurrently(
            vec![a],
            UnionMode::RoundRobin { weights: None },
            None,
        )
        .collect();
        assert!(got.is_empty());
    }

    // -----------------------------------------------------------------
    // Weighted fairness: the RoundRobin ratio is exact at every cycle
    // boundary, not just in aggregate.
    // -----------------------------------------------------------------

    #[test]
    fn round_robin_weighted_fairness_ratios() {
        // Infinite children tagged by id, weights 3:1:2.
        let mk = |id: usize| {
            let mut n = 0usize;
            LocalIter::from_fn(move || {
                n += 1;
                Some((id, n))
            })
        };
        let weights = vec![3usize, 1, 2];
        let cycle: usize = weights.iter().sum();
        let it = concurrently(
            vec![mk(0), mk(1), mk(2)],
            UnionMode::RoundRobin { weights: Some(weights.clone()) },
            None,
        );
        let mut it = it;
        let cycles = 50;
        let mut got = Vec::new();
        for _ in 0..cycles * cycle {
            got.push(it.next().unwrap());
        }
        // At every cycle boundary each child has been driven exactly
        // weight[i] * cycles_so_far times: a fixed progress ratio, the
        // Acme-style rate limiting the paper's Union(weights) models.
        for k in 1..=cycles {
            let prefix = &got[..k * cycle];
            for (id, &w) in weights.iter().enumerate() {
                let pulls = prefix.iter().filter(|(c, _)| *c == id).count();
                assert_eq!(
                    pulls,
                    w * k,
                    "child {id} off-ratio after {k} cycles"
                );
            }
        }
        // Per-child items arrive in order.
        for id in 0..3 {
            let seq: Vec<usize> =
                got.iter().filter(|(c, _)| *c == id).map(|(_, n)| *n).collect();
            assert_eq!(seq, (1..=seq.len()).collect::<Vec<_>>());
        }
    }

    // -----------------------------------------------------------------
    // Async-mode backpressure: a child is driven at most `buffer` items
    // ahead of consumption (plus the single item in its hands).
    // -----------------------------------------------------------------

    #[test]
    fn async_mode_buffer_bounds_runahead() {
        let buffer = 3usize;
        let driven = Arc::new(AtomicUsize::new(0));
        let d = driven.clone();
        let child = LocalIter::from_fn(move || {
            Some(d.fetch_add(1, Ordering::SeqCst) + 1) // items 1, 2, 3, ...
        });
        let mut it = concurrently(
            vec![child],
            UnionMode::Async { buffer },
            None,
        );
        let mut consumed = 0usize;
        for _ in 0..5 {
            // Let the child run as far ahead as the credit allows.
            assert!(it.next().is_some());
            consumed += 1;
            std::thread::sleep(std::time::Duration::from_millis(20));
            let produced = driven.load(Ordering::SeqCst);
            assert!(
                produced <= consumed + buffer + 1,
                "child ran {produced} ahead of {consumed} (buffer {buffer})"
            );
        }
        // And it does pipeline: with consumption stalled the child still
        // fills its whole credit.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let produced = driven.load(Ordering::SeqCst);
        assert!(
            produced >= consumed + buffer,
            "child failed to prefetch: {produced} vs {consumed}+{buffer}"
        );
        drop(it); // close() releases the parked child thread
    }

    #[test]
    fn async_mode_terminates_when_a_child_panics() {
        // A child fragment that panics (e.g. a dead-learner expect in a
        // subflow closure) must end the union, not hang the driver.
        let mut n = 0;
        let doomed = LocalIter::from_fn(move || {
            n += 1;
            if n >= 2 {
                panic!("child fragment exploded");
            }
            Some(100)
        });
        let healthy = LocalIter::from_items(vec![1, 2, 3]);
        let got = concurrently(
            vec![doomed, healthy],
            UnionMode::Async { buffer: 2 },
            None,
        )
        .collect();
        // Everything the healthy child produced arrives, plus at most
        // the doomed child's first item; then the stream ENDS.
        let healthy_items: Vec<i32> =
            got.iter().copied().filter(|&x| x < 100).collect();
        let mut sorted = healthy_items.clone();
        sorted.sort();
        assert_eq!(sorted, vec![1, 2, 3]);
        assert!(got.iter().filter(|&&x| x == 100).count() <= 1);
    }

    #[test]
    fn async_union_child_adopts_replacement_worker() {
        // The Ape-X topology: a registry-backed gather fragment runs as
        // one child of an async union.  Kill its worker mid-stream,
        // publish a replacement into the registry, and the *running*
        // union must start emitting the replacement's items — the child
        // fragment never ends, no plan rebuild.
        use crate::actor::{ActorHandle, ShardRegistry};
        use crate::iter::ParIter;

        struct W {
            base: i32,
            n: i32,
        }
        // Shard 0 streams forever (keeps the fragment alive across the
        // fault); shard 1 dies after two items.
        let healthy = ActorHandle::spawn("union-healthy", || W {
            base: 0,
            n: 0,
        });
        let doomed = ActorHandle::spawn("union-doomed", || W {
            base: 1000,
            n: 0,
        });
        let registry =
            ShardRegistry::new(vec![healthy.clone(), doomed.clone()]);
        let gather_child = ParIter::from_registry(registry.clone(), |w| {
            w.n += 1;
            if w.base == 1000 && w.n >= 3 {
                panic!("worker dies after two items");
            }
            Some(w.base + w.n)
        })
        .gather_async(1);
        let steady = LocalIter::from_items(vec![-1; 50]);
        let mut it = concurrently(
            vec![gather_child, steady],
            UnionMode::Async { buffer: 2 },
            None,
        );
        for _ in 0..20 {
            let x = it.next().expect("fragment must keep streaming");
            assert!(x < 2000, "nothing above the doomed incarnation yet");
        }
        assert!(doomed.await_poisoned(std::time::Duration::from_secs(2)));
        registry.publish(
            1,
            ActorHandle::spawn("union-fresh", || W { base: 2000, n: 0 }),
        );
        let mut replacement_items = 0;
        for _ in 0..300 {
            let x = it.next().expect("fragment must keep streaming");
            if x > 2000 {
                replacement_items += 1;
            }
        }
        assert!(
            replacement_items > 0,
            "replacement items never surfaced through the running union"
        );
        drop(it);
    }

    #[test]
    fn async_mode_dropping_stream_releases_children() {
        // A consumer that abandons the stream must not leave child
        // threads parked forever: close() fails their next push.
        let alive = Arc::new(AtomicUsize::new(0));
        let a = alive.clone();
        let child = LocalIter::from_fn(move || {
            a.fetch_add(1, Ordering::SeqCst);
            Some(1)
        });
        let mut it =
            concurrently(vec![child], UnionMode::Async { buffer: 1 }, None);
        assert_eq!(it.next(), Some(1));
        drop(it);
        std::thread::sleep(std::time::Duration::from_millis(30));
        let settled = alive.load(Ordering::SeqCst);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(
            alive.load(Ordering::SeqCst),
            settled,
            "child kept being driven after the stream was dropped"
        );
    }
}
